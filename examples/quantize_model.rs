//! **The end-to-end driver** (EXPERIMENTS.md §E2E): loads the three
//! build-time-trained transformers, quantizes each with the paper's method
//! grid, and evaluates perplexity (3 held-out streams) and QA (7 probe
//! suites) through the AOT-compiled PJRT executables — the full Table-1
//! analog, proving L3 (solvers + coordinator) × L2 (HLO model) × runtime
//! compose.
//!
//! Usage:
//!   cargo run --release --example quantize_model            # full grid
//!   cargo run --release --example quantize_model -- --model small
//!   cargo run --release --example quantize_model -- --setting per-tensor
//!   cargo run --release --example quantize_model -- --fast  # wgm+fp only

use anyhow::Result;
use msb_quant::cli::Args;
use msb_quant::harness::{eval_quantized, Artifacts};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::runtime::ModelRunner;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let arts = Artifacts::load()?;
    let model_filter = args.get("model").map(String::from);
    let setting = args.str_or("setting", "block").to_string();
    let fast = args.has("fast");
    let threads = args.usize_or("threads", 1)?;

    let (cfg, per_tensor, label) = match setting.as_str() {
        "block" => (QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap(), false, "4-bit block-wise"),
        "per-tensor" => (QuantConfig::per_tensor(6).unwrap().with_window(64).unwrap(), true, "6-bit per-tensor"),
        s => anyhow::bail!("--setting {s}? use block|per-tensor"),
    };

    let mut grid = vec![Method::Fp];
    if fast {
        grid.push(Method::Wgm);
    } else {
        grid.extend(Method::table1_grid(per_tensor));
    }

    println!("=== Table 1 analog: {label} ===");
    println!(
        "(models are the build-time-trained stand-ins; see DESIGN.md Substitutions)\n"
    );

    let mut rows = Vec::new();
    for spec in arts.manifest.models.clone() {
        if let Some(f) = &model_filter {
            if &spec.name != f {
                continue;
            }
        }
        println!("-- model {} ({} params) --", spec.name, spec.total_params());
        let weights = arts.weights(&spec)?;
        let mut runner = ModelRunner::new(&arts.manifest, &spec, &weights)?;
        for &method in &grid {
            let report =
                eval_quantized(&arts, &spec, &mut runner, &weights, method, &cfg, threads)?;
            println!("  {}", report.row());
            rows.push(report);
        }
        println!();
    }

    // paper-shaped summary: does WGM beat GPTQ/RTN and track FP?
    println!("=== summary ===");
    for chunk in rows.chunks_exact(grid.len()) {
        let fp = &chunk[0];
        let best_q = chunk[1..]
            .iter()
            .min_by(|a, b| a.avg_ppl().total_cmp(&b.avg_ppl()))
            .unwrap();
        let wgm = chunk.iter().find(|r| r.method == "wgm");
        println!(
            "{:<6}: FP ppl {:.2}; best quantized = {} ({:.2}){}",
            fp.model,
            fp.avg_ppl(),
            best_q.method,
            best_q.avg_ppl(),
            wgm.map(|w| format!(
                "; wgm {:.2} ({:+.1}% vs FP)",
                w.avg_ppl(),
                (w.avg_ppl() / fp.avg_ppl() - 1.0) * 100.0
            ))
            .unwrap_or_default()
        );
    }
    Ok(())
}
