//! Solver playground: the four algorithms head-to-head on synthetic
//! instances — oracle gap, speed-quality tradeoff, window sensitivity.
//! No artifacts needed.
//!
//!   cargo run --release --example solver_playground [-- --n 262144]

use msb_quant::cli::Args;
use msb_quant::msb::{Algo, Solver};
use msb_quant::stats::Rng;

fn run(algo: Algo, vals: &[f32], groups: usize) -> (f64, f64) {
    let solver = Solver::new(algo).with_lambda(0.75);
    let t0 = std::time::Instant::now();
    let code = solver.quantize(vals, groups);
    (code.sse(vals), t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.usize_or("n", 1 << 16)?;
    let groups = args.usize_or("groups", 8)?;
    let mut rng = Rng::new(args.usize_or("seed", 3)? as u64);
    let mut vals = vec![0.0f32; n];
    rng.fill_normal(&mut vals, 1.0);

    println!("instance: N(0,1), n = {n}, target groups = {groups}\n");
    println!("{:<22} {:>14} {:>10} {:>12}", "solver", "SSE", "time (s)", "Melem/s");

    // DG oracle only on a subsample (O(n²) — same infeasibility the paper
    // reports in Table 4)
    let dg_n = n.min(2048);
    let (dg_sse, dg_t) = run(Algo::Dg, &vals[..dg_n], groups);
    println!(
        "{:<22} {:>14.4} {:>10.3} {:>12.2}   (on first {} elems only)",
        "dg (oracle)", dg_sse, dg_t, dg_n as f64 / dg_t / 1e6, dg_n
    );
    // heuristics on the same subsample for a direct gap readout
    for (name, algo) in [
        ("gg @dg-subsample", Algo::Gg),
        ("wgm w=16 @subsample", Algo::Wgm { window: 16 }),
    ] {
        let (sse, t) = run(algo, &vals[..dg_n], groups);
        println!(
            "{:<22} {:>14.4} {:>10.3} {:>12.2}   (gap {:+.2}%)",
            name,
            sse,
            t,
            dg_n as f64 / t / 1e6,
            (sse / dg_sse - 1.0) * 100.0
        );
    }
    println!();

    // full instance: the production solvers
    for (name, algo) in [
        ("gg", Algo::Gg),
        ("wgm w=16", Algo::Wgm { window: 16 }),
        ("wgm w=64", Algo::Wgm { window: 64 }),
        ("wgm w=256", Algo::Wgm { window: 256 }),
        (
            "wgm-lo (256 bins)",
            Algo::WgmLo { bins: 256, range: 32, max_iters: 12, patience: 3 },
        ),
    ] {
        let (sse, t) = run(algo, &vals, groups);
        println!("{:<22} {:>14.4} {:>10.3} {:>12.2}", name, sse, t, n as f64 / t / 1e6);
    }

    println!(
        "\nexpected shape (paper §3.3): SSE dg ≤ gg ≤ wgm(w↑), time gg ≫ wgm ≫ wgm-lo"
    );
    Ok(())
}
