//! Quickstart: quantize a synthetic weight matrix with every
//! calibration-free method and compare reconstruction error — no artifacts
//! required. Run with `cargo run --release --example quickstart`.

use msb_quant::msb::{lambda, Algo, Solver, SortedMags};
use msb_quant::quant::{
    hqq::HqqQuantizer, msb::MsbQuantizer, nf4::Nf4Quantizer, rtn::RtnQuantizer,
    xnor::XnorQuantizer, QuantConfig, Quantizer,
};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

fn main() {
    // A heavy-tailed "LLM-like" weight matrix: Gaussian bulk + outliers.
    let mut rng = Rng::new(42);
    let w = Matrix::weightlike(512, 512, &mut rng);
    println!("matrix 512x512, ||W||_F = {:.3}\n", w.fro_norm());

    // --- 4-bit block-wise (the paper's primary setting) ------------------
    let cfg = QuantConfig::block_wise(4, 64).unwrap();
    println!("4-bit block-wise (t=64):        SSE        bits/weight");
    let methods: Vec<Box<dyn Quantizer>> = vec![
        Box::new(RtnQuantizer::symmetric()),
        Box::new(Nf4Quantizer::nf4()),
        Box::new(HqqQuantizer::default()),
        Box::new(XnorQuantizer::blocked()),
        Box::new(MsbQuantizer::wgm()),
    ];
    for m in &methods {
        let t0 = std::time::Instant::now();
        let q = m.quantize(&w, &cfg);
        println!(
            "  {:<14} {:>12.4}   {:>6.2}   ({:.2}s)",
            m.name(),
            q.mse(&w),
            q.effective_bits,
            t0.elapsed().as_secs_f64()
        );
    }

    // --- 6-bit per-tensor --------------------------------------------------
    let cfg6 = QuantConfig::per_tensor(6).unwrap();
    println!("\n6-bit per-tensor (w=64):");
    for m in [MsbQuantizer::wgm(), MsbQuantizer::wgm_lo()] {
        let t0 = std::time::Instant::now();
        let q = m.quantize(&w, &cfg6);
        println!(
            "  {:<14} {:>12.4}   {:>6.2}   ({:.2}s)",
            m.name(),
            q.mse(&w),
            q.effective_bits,
            t0.elapsed().as_secs_f64()
        );
    }

    // --- the objective itself -----------------------------------------------
    let sm = SortedMags::from_values(&w.data);
    println!(
        "\nλ boundary theory (Appendix C): λ_min ≈ {:.3e}, λ_max ≈ {:.3e}, Λ(0.75) = {:.3e}",
        lambda::lambda_min(&sm.mags),
        lambda::lambda_max(&sm.mags),
        lambda::lambda_of(0.75, &sm.mags),
    );

    // one-group MSB == XNOR, the conceptual anchor (§2.2)
    let xnor_like = Solver::new(Algo::Gg).quantize(&w.data, 1);
    println!(
        "MSB with g=1 degenerates to XNOR: single scale α = {:.5} (mean |w| = {:.5})",
        xnor_like.levels[0],
        w.data.iter().map(|v| v.abs() as f64).sum::<f64>() / w.len() as f64
    );
}
