//! Serving demo: a long-lived eval server owns the PJRT-compiled model,
//! dynamic-batches concurrent scoring requests, and reports latency /
//! throughput / batch-fill telemetry — the request path with Python
//! nowhere in sight.
//!
//!   cargo run --release --example serve_eval -- [--model small]
//!       [--requests 64] [--clients 8] [--method wgm]
//!       [--packed payload.msbt] [--decode-threads N]
//!       [--fused payload.msbt] [--threads N] [--batch B]
//!
//! With `--packed`, the server boots straight from a packed `.msbt`
//! payload (`msb pack`): codes + scale tables are decoded on the pool
//! (`--decode-threads`, default = available cores) and no offline PTQ
//! runs — the deployable-artifact serving path.
//!
//! With `--fused`, the server never decodes at all: it holds one
//! `kernels::PackedLinear` per layer (codes + scale tables, 4–6x smaller
//! than f32) behind a dynamic-batching `GemvServer`, and every request is
//! answered by the fused GEMV/GEMM kernels straight off the codes. This
//! path needs no `artifacts/` directory — the payload is the model.

use std::time::{Duration, Instant};

use anyhow::Result;
use msb_quant::cli::Args;
use msb_quant::harness::Artifacts;
use msb_quant::io::msbt;
use msb_quant::pipeline::{decode_packed_model, quantize_model};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::runtime::{FusedModel, ModelRunner};
use msb_quant::server::{EvalServer, GemvServer};
use msb_quant::stats::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    if let Some(payload) = args.get("fused") {
        let payload = payload.to_string();
        return serve_fused(&args, &payload);
    }
    let arts = Artifacts::load()?;
    let spec = arts.manifest.model(args.str_or("model", "small"))?.clone();
    let n_requests = args.usize_or("requests", 64)?;
    let n_clients = args.usize_or("clients", 8)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;

    let weights = arts.weights(&spec)?;
    let qweights = if let Some(payload) = args.get("packed") {
        // boot from a deployable packed artifact: decode codes + scales
        // back to f32 on the pool, no PTQ step on the serving host;
        // default to one decode worker per available core
        let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = args.usize_or("decode-threads", default_threads)?;
        let t0 = Instant::now();
        let map = msbt::read_file(payload)?;
        let decoded = decode_packed_model(&map, threads)?;
        println!(
            "serving {} from packed artifact {payload} (decoded {} tensors in {:.2}s)",
            spec.name,
            decoded.len(),
            t0.elapsed().as_secs_f64()
        );
        decoded
    } else {
        // offline PTQ step (L3 coordinator), then serve the quantized model
        let cfg = QuantConfig::block_wise(4, 64);
        let calib;
        let calib_ref = if method.needs_calibration() {
            calib = arts.calib(&spec)?;
            Some(&calib)
        } else {
            None
        };
        let qm = quantize_model(&spec, weights.clone(), calib_ref, method, &cfg, 1)?;
        println!(
            "serving {} quantized with {} ({:.2} bits/weight, PTQ took {:.2}s)",
            spec.name,
            method.name(),
            if qm.layers.is_empty() { 16.0 } else { qm.mean_effective_bits() },
            qm.wall_seconds
        );
        qm.weights
    };

    // PJRT handles are not Send: the server thread builds the runner itself
    let manifest = arts.manifest.clone();
    let spec_for_server = spec.clone();
    let base_weights = weights; // moved: the base set is only needed once
    let (server, client) = EvalServer::spawn_with(
        move || {
            let mut runner = ModelRunner::new(&manifest, &spec_for_server, &base_weights)
                .expect("compile model in server thread");
            runner.update_weights(&qweights).expect("swap quantized weights");
            runner
        },
        Duration::from_millis(5),
    );

    // fire concurrent clients scoring held-out windows
    let stream = arts.eval_stream("eval_wk")?.to_vec();
    let seq = spec.seq;
    anyhow::ensure!(
        stream.len() > seq,
        "eval_wk stream ({} tokens) must be longer than seq ({seq})",
        stream.len()
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = client.clone();
        let stream = stream.clone();
        let per_client = n_requests / n_clients;
        handles.push(std::thread::spawn(move || -> (f64, Vec<f64>) {
            let mut nll = 0.0;
            let mut lat = Vec::new();
            let mut count = 0usize;
            for r in 0..per_client {
                let start = (c * 7919 + r * 104729) % (stream.len() - seq);
                let toks = stream[start..start + seq].to_vec();
                let t = Instant::now();
                let resp = client.score(toks).expect("score");
                lat.push(t.elapsed().as_secs_f64() * 1e3);
                nll -= resp.logprobs.iter().sum::<f64>() / resp.logprobs.len() as f64;
                count += 1;
            }
            (nll / count as f64, lat)
        }));
    }
    let mut all_lat = Vec::new();
    let mut mean_nll = 0.0;
    for h in handles {
        let (nll, lat) = h.join().expect("client thread");
        mean_nll += nll / n_clients as f64;
        all_lat.extend(lat);
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    let stats = server.shutdown();

    all_lat.sort_by(f64::total_cmp);
    let p = |q: f64| all_lat[((all_lat.len() - 1) as f64 * q) as usize];
    println!("\n{} requests over {} clients in {:.2}s", stats.requests, n_clients, wall);
    println!(
        "throughput {:.1} req/s | latency p50 {:.1}ms p90 {:.1}ms p99 {:.1}ms",
        stats.requests as f64 / wall,
        p(0.5),
        p(0.9),
        p(0.99)
    );
    println!(
        "batches {} (mean fill {:.2}, max {}) | stream ppl≈{:.2}",
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        stats.max_batch_fill,
        mean_nll.exp()
    );
    Ok(())
}

/// Fused serving: hold the model as `PackedLinear` handles (never decoded
/// f32), dynamic-batch concurrent matvec requests through `GemvServer`,
/// and self-check one served response per layer against the serial fused
/// gemv (bit-identical by the kernels' determinism contract).
fn serve_fused(args: &Args, payload: &str) -> Result<()> {
    let n_requests = args.usize_or("requests", 64)?;
    let n_clients = args.usize_or("clients", 8)?.max(1);
    anyhow::ensure!(n_requests >= n_clients, "--requests must be >= --clients");
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = args.usize_or("threads", default_threads)?;
    let batch_cap = args.usize_or("batch", 8)?;

    let t0 = Instant::now();
    let map = msbt::read_file(payload)?;
    let model = FusedModel::from_packed_map(&map)?;
    let (pb, fb) = (model.payload_bytes(), model.f32_bytes());
    println!(
        "serving {} fused {} layers from {payload} in {:.2}s \
         ({pb} payload bytes = {:.3}x of the {fb}-byte f32 set; no decode)",
        model.method(),
        model.linears().len(),
        t0.elapsed().as_secs_f64(),
        pb as f64 / fb as f64,
    );

    // reference answers computed serially BEFORE the model moves into the
    // server thread; the served responses must be bit-identical
    let probe = |cols: usize, seed: u64| {
        let mut x = vec![0.0f32; cols];
        Rng::new(seed).fill_normal(&mut x, 1.0);
        x
    };
    let layers: Vec<(String, usize)> =
        model.linears().iter().map(|(n, l)| (n.clone(), l.cols())).collect();
    let references: Vec<(String, Vec<f32>, Vec<f32>)> = layers
        .iter()
        .enumerate()
        .map(|(i, (name, cols))| {
            let x = probe(*cols, 0x5EED + i as u64);
            let y = model.linear(name).expect("layer").gemv(&x);
            (name.clone(), x, y)
        })
        .collect();

    let (server, client) = GemvServer::spawn(model, threads, batch_cap, Duration::from_millis(5));
    for (name, x, want) in &references {
        let got = client.infer(name, x.clone())?;
        anyhow::ensure!(&got == want, "{name}: served response != serial fused gemv");
    }
    println!("self-check OK: served responses bit-identical to serial fused gemv");
    // the self-check requests above ride the same server; subtract them
    // from the reported load numbers so throughput/fill reflect the run
    let warmup = references.len() as u64;

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = client.clone();
        let layers = layers.clone();
        let per_client = n_requests / n_clients;
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut lat = Vec::new();
            for r in 0..per_client {
                let (name, cols) = &layers[(c * 7919 + r) % layers.len()];
                let x = {
                    let mut v = vec![0.0f32; *cols];
                    Rng::new((c * 104729 + r) as u64).fill_normal(&mut v, 1.0);
                    v
                };
                let t = Instant::now();
                let y = client.infer(name, x).expect("fused infer");
                lat.push(t.elapsed().as_secs_f64() * 1e3);
                assert!(y.iter().all(|v| v.is_finite()), "{name}: non-finite output");
            }
            lat
        }));
    }
    let mut all_lat = Vec::new();
    for h in handles {
        all_lat.extend(h.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    let stats = server.shutdown();

    all_lat.sort_by(f64::total_cmp);
    let p = |q: f64| all_lat[((all_lat.len() - 1) as f64 * q) as usize];
    let (reqs, batches) = (
        stats.requests.saturating_sub(warmup),
        stats.batches.saturating_sub(warmup),
    );
    println!("\n{reqs} fused requests over {n_clients} clients in {wall:.2}s");
    println!(
        "throughput {:.1} req/s | latency p50 {:.2}ms p90 {:.2}ms p99 {:.2}ms",
        reqs as f64 / wall,
        p(0.5),
        p(0.9),
        p(0.99)
    );
    println!(
        "gemm batches {batches} (mean fill {:.2}, max {}) — each batch decodes every tile once",
        reqs as f64 / batches.max(1) as f64,
        stats.max_batch_fill
    );
    Ok(())
}
