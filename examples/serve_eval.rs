//! Serving demo: a long-lived eval server owns the PJRT-compiled model,
//! dynamic-batches concurrent scoring requests, and reports latency /
//! throughput / batch-fill telemetry — the request path with Python
//! nowhere in sight.
//!
//!   cargo run --release --example serve_eval -- [--model small]
//!       [--requests 64] [--clients 8] [--method wgm]
//!       [--packed payload.msbt] [--decode-threads N]
//!
//! With `--packed`, the server boots straight from a packed `.msbt`
//! payload (`msb pack`): codes + scale tables are decoded on the pool
//! (`--decode-threads`, default = available cores) and no offline PTQ
//! runs — the deployable-artifact serving path.

use std::time::{Duration, Instant};

use anyhow::Result;
use msb_quant::cli::Args;
use msb_quant::harness::Artifacts;
use msb_quant::io::msbt;
use msb_quant::pipeline::{decode_packed_model, quantize_model};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::runtime::ModelRunner;
use msb_quant::server::EvalServer;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let arts = Artifacts::load()?;
    let spec = arts.manifest.model(args.str_or("model", "small"))?.clone();
    let n_requests = args.usize_or("requests", 64)?;
    let n_clients = args.usize_or("clients", 8)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;

    let weights = arts.weights(&spec)?;
    let qweights = if let Some(payload) = args.get("packed") {
        // boot from a deployable packed artifact: decode codes + scales
        // back to f32 on the pool, no PTQ step on the serving host;
        // default to one decode worker per available core
        let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = args.usize_or("decode-threads", default_threads)?;
        let t0 = Instant::now();
        let map = msbt::read_file(payload)?;
        let decoded = decode_packed_model(&map, threads)?;
        println!(
            "serving {} from packed artifact {payload} (decoded {} tensors in {:.2}s)",
            spec.name,
            decoded.len(),
            t0.elapsed().as_secs_f64()
        );
        decoded
    } else {
        // offline PTQ step (L3 coordinator), then serve the quantized model
        let cfg = QuantConfig::block_wise(4, 64);
        let calib;
        let calib_ref = if method.needs_calibration() {
            calib = arts.calib(&spec)?;
            Some(&calib)
        } else {
            None
        };
        let qm = quantize_model(&spec, weights.clone(), calib_ref, method, &cfg, 1)?;
        println!(
            "serving {} quantized with {} ({:.2} bits/weight, PTQ took {:.2}s)",
            spec.name,
            method.name(),
            if qm.layers.is_empty() { 16.0 } else { qm.mean_effective_bits() },
            qm.wall_seconds
        );
        qm.weights
    };

    // PJRT handles are not Send: the server thread builds the runner itself
    let manifest = arts.manifest.clone();
    let spec_for_server = spec.clone();
    let base_weights = weights; // moved: the base set is only needed once
    let (server, client) = EvalServer::spawn_with(
        move || {
            let mut runner = ModelRunner::new(&manifest, &spec_for_server, &base_weights)
                .expect("compile model in server thread");
            runner.update_weights(&qweights).expect("swap quantized weights");
            runner
        },
        Duration::from_millis(5),
    );

    // fire concurrent clients scoring held-out windows
    let stream = arts.eval_stream("eval_wk")?.to_vec();
    let seq = spec.seq;
    anyhow::ensure!(
        stream.len() > seq,
        "eval_wk stream ({} tokens) must be longer than seq ({seq})",
        stream.len()
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = client.clone();
        let stream = stream.clone();
        let per_client = n_requests / n_clients;
        handles.push(std::thread::spawn(move || -> (f64, Vec<f64>) {
            let mut nll = 0.0;
            let mut lat = Vec::new();
            let mut count = 0usize;
            for r in 0..per_client {
                let start = (c * 7919 + r * 104729) % (stream.len() - seq);
                let toks = stream[start..start + seq].to_vec();
                let t = Instant::now();
                let resp = client.score(toks).expect("score");
                lat.push(t.elapsed().as_secs_f64() * 1e3);
                nll -= resp.logprobs.iter().sum::<f64>() / resp.logprobs.len() as f64;
                count += 1;
            }
            (nll / count as f64, lat)
        }));
    }
    let mut all_lat = Vec::new();
    let mut mean_nll = 0.0;
    for h in handles {
        let (nll, lat) = h.join().expect("client thread");
        mean_nll += nll / n_clients as f64;
        all_lat.extend(lat);
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    let stats = server.shutdown();

    all_lat.sort_by(f64::total_cmp);
    let p = |q: f64| all_lat[((all_lat.len() - 1) as f64 * q) as usize];
    println!("\n{} requests over {} clients in {:.2}s", stats.requests, n_clients, wall);
    println!(
        "throughput {:.1} req/s | latency p50 {:.1}ms p90 {:.1}ms p99 {:.1}ms",
        stats.requests as f64 / wall,
        p(0.5),
        p(0.9),
        p(0.99)
    );
    println!(
        "batches {} (mean fill {:.2}, max {}) | stream ppl≈{:.2}",
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        stats.max_batch_fill,
        mean_nll.exp()
    );
    Ok(())
}
