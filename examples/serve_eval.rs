//! Serving demo: a long-lived server owns the model, dynamic-batches
//! concurrent scoring requests, and reports latency / throughput /
//! batch-fill telemetry — the request path with Python nowhere in sight.
//!
//!   cargo run --release --example serve_eval -- [--backend runner|fused|forward]
//!       [--payload payload.msbt] [--requests 64] [--clients 8]
//!       [--threads N] [--model small] [--method wgm] [--batch B]
//!       [--mac f32|int8|auto] [--streams N] [--page-tokens P] [--chunk C]
//!       [--spec] [--draft-len K] [--max-new N]
//!       [--max-waiting N] [--inject panic@S:N,nan@S:N,draft-panic@S:N,delay@MS]
//!       [--vocab V --d D --layers L --heads H --ff F --seq S --rows R]
//!
//! One `--backend` flag selects the serving construction; every backend
//! is built through `runtime::BackendBuilder`, which carries the shared
//! knobs (`--threads`, 0 = one per core; `--mac` picks the fused MAC
//! path for the `fused`/`forward` backends — `int8` runs the integer
//! multiply-accumulate on affine-decode methods, `auto` falls back to
//! f32 per layer where no affine decode exists):
//!
//! * `runner` — the PJRT-compiled XLA forward (needs `artifacts/`).
//!   With `--payload`, boots straight from a packed `.msbt` artifact
//!   (codes + scales decode on the builder's pool at swap-in); without
//!   it, runs offline PTQ with `--method` first.
//! * `fused` — holds one `kernels::PackedLinear` per layer (4–6x smaller
//!   than f32) behind a dynamic-batching `GemvServer`; every request is
//!   answered straight off the codes, nothing is ever decoded.
//! * `forward` — the fused CPU transformer forward (`forward::ForwardModel`):
//!   full token scoring straight off the codes behind the same
//!   `EvalServer` the runner uses — no `artifacts/`, no XLA. The
//!   architecture flags must match the payload (shapes are validated
//!   at load; `msb score` emits compatible payloads). With `--streams N`
//!   the forward backend switches to the continuous-batching scheduler
//!   (`EvalServer::spawn_batched`): every active stream rides one fused
//!   `step_batch` per decode step over the paged KV arena, and every
//!   served response is checked bit-identical to solo scoring. Adding
//!   `--spec` tacks on a greedy-generation arm that decodes the same
//!   prompt mix plain and self-speculatively (`--draft-len` caps the
//!   drafter), asserts the outputs bit-identical, and reports the step
//!   savings and draft accept rate.
//!
//! Robustness knobs (forward backend with `--streams`): `--max-waiting`
//! bounds the admission queue (excess requests are load-shed with
//! `Overloaded`), and `--inject` scripts deterministic faults —
//! `panic@STEP:STREAM` (panic inside the fused step), `nan@STEP:STREAM`
//! (NaN logits for one stream), `draft-panic@STEP:STREAM` (drafter
//! panic, demotes the stream to plain decode), `delay@MILLIS` (per-step
//! stall). Faulted streams are quarantined and counted; the survivors
//! stay gated bit-identical to solo scoring, and the run reports the
//! faulted/shed/deadline-missed/degraded counters.

use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use msb_quant::cli::Args;
use msb_quant::forward::{synth, ForwardSpec};
use msb_quant::harness::Artifacts;
use msb_quant::io::msbt;
use msb_quant::pipeline::{quantize, QuantizeOptions};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::runtime::BackendBuilder;
use msb_quant::server::{EvalServer, GemvServer};
use msb_quant::stats::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let backend = args.str_or("backend", "runner").to_string();
    let payload = args.get("payload").map(String::from);
    let threads = args.usize_or("threads", args.usize_or("decode-threads", 0)?)?;
    let mac = msb_quant::kernels::MacMode::parse(args.str_or("mac", "f32"))?;
    let faults = match args.get("inject") {
        Some(spec) => msb_quant::server::faults::FaultPlan::parse(spec).context("--inject")?,
        None => msb_quant::server::faults::FaultPlan::new(),
    };
    let builder = BackendBuilder::new()
        .threads(threads)
        .mac(mac)
        .max_streams(args.usize_or("streams", 0)?.max(1))
        .kv_page_tokens(args.usize_or("page-tokens", 16)?)
        .speculative(args.has("spec"))
        .draft_len(args.usize_or("draft-len", 4)?)
        .max_waiting(args.usize_or("max-waiting", 256)?)
        .faults(faults);
    match backend.as_str() {
        "runner" => serve_runner(&args, &builder, payload),
        "fused" => {
            serve_fused(&args, &builder, &payload.context("--backend fused needs --payload")?)
        }
        "forward" => {
            serve_forward(&args, &builder, &payload.context("--backend forward needs --payload")?)
        }
        other => anyhow::bail!("unknown backend '{other}' (expected runner|fused|forward)"),
    }
}

/// How many workers "0 = auto" resolves to for paths that need a count
/// up front (the fused server's kernel pool).
fn auto_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

fn serve_runner(args: &Args, builder: &BackendBuilder, payload: Option<String>) -> Result<()> {
    let arts = Artifacts::load()?;
    let spec = arts.manifest.model(args.str_or("model", "small"))?.clone();
    let n_requests = args.usize_or("requests", 64)?;
    let n_clients = args.usize_or("clients", 8)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;

    let weights = arts.weights(&spec)?;
    let qweights = if let Some(payload) = &payload {
        // boot from a deployable packed artifact: the payload map goes to
        // update_weights as-is and decodes on the builder's pool at
        // swap-in — no PTQ step on the serving host
        let map = msbt::read_file(payload)?;
        println!("serving {} from packed artifact {payload} (decode on swap-in)", spec.name);
        map
    } else {
        // offline PTQ step (L3 coordinator), then serve the quantized model
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let calib;
        let calib_ref = if method.needs_calibration() {
            calib = arts.calib(&spec)?;
            Some(&calib)
        } else {
            None
        };
        let opts = QuantizeOptions::new().with_threads(1);
        let qm = quantize(&spec, weights.clone(), calib_ref, method, &cfg, &opts)?;
        println!(
            "serving {} quantized with {} ({:.2} bits/weight, PTQ took {:.2}s)",
            spec.name,
            method.name(),
            if qm.layers.is_empty() { 16.0 } else { qm.mean_effective_bits() },
            qm.wall_seconds
        );
        qm.weights
    };

    // PJRT handles are not Send: the server thread builds the runner itself
    let manifest = arts.manifest.clone();
    let spec_for_server = spec.clone();
    let base_weights = weights; // moved: the base set is only needed once
    let builder = builder.clone();
    let (server, client) = EvalServer::spawn_with(
        move || {
            let mut runner = builder
                .runner(&manifest, &spec_for_server, &base_weights)
                .and_then(|b| b.into_runner())
                .expect("compile model in server thread");
            runner.update_weights(&qweights).expect("swap quantized weights");
            runner
        },
        Duration::from_millis(5),
    );

    // fire concurrent clients scoring held-out windows
    let stream = arts.eval_stream("eval_wk")?.to_vec();
    let seq = spec.seq;
    anyhow::ensure!(
        stream.len() > seq,
        "eval_wk stream ({} tokens) must be longer than seq ({seq})",
        stream.len()
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = client.clone();
        let stream = stream.clone();
        let per_client = n_requests / n_clients;
        handles.push(std::thread::spawn(move || -> (f64, Vec<f64>) {
            let mut nll = 0.0;
            let mut lat = Vec::new();
            let mut count = 0usize;
            for r in 0..per_client {
                let start = (c * 7919 + r * 104729) % (stream.len() - seq);
                let toks = stream[start..start + seq].to_vec();
                let t = Instant::now();
                let resp = client.score(toks).expect("score");
                lat.push(t.elapsed().as_secs_f64() * 1e3);
                nll -= resp.logprobs.iter().sum::<f64>() / resp.logprobs.len() as f64;
                count += 1;
            }
            (nll / count as f64, lat)
        }));
    }
    let mut all_lat = Vec::new();
    let mut mean_nll = 0.0;
    for h in handles {
        let (nll, lat) = h.join().expect("client thread");
        mean_nll += nll / n_clients as f64;
        all_lat.extend(lat);
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    let stats = server.shutdown()?;
    report(&mut all_lat, stats.requests, stats.batches, stats.max_batch_fill, n_clients, wall);
    println!("stream ppl≈{:.2}", mean_nll.exp());
    Ok(())
}

/// Fused serving: hold the model as `PackedLinear` handles (never decoded
/// f32), dynamic-batch concurrent matvec requests through `GemvServer`,
/// and self-check one served response per layer against the serial fused
/// gemv (bit-identical by the kernels' determinism contract).
fn serve_fused(args: &Args, builder: &BackendBuilder, payload: &str) -> Result<()> {
    let n_requests = args.usize_or("requests", 64)?;
    let n_clients = args.usize_or("clients", 8)?.max(1);
    anyhow::ensure!(n_requests >= n_clients, "--requests must be >= --clients");
    let threads = auto_threads(args.usize_or("threads", 0)?);
    let batch_cap = args.usize_or("batch", 8)?;

    let t0 = Instant::now();
    let map = msbt::read_file(payload)?;
    let model = builder.fused(&map)?.into_fused()?;
    let (pb, fb) = (model.payload_bytes(), model.f32_bytes());
    println!(
        "serving {} fused {} layers from {payload} in {:.2}s \
         ({pb} payload bytes = {:.3}x of the {fb}-byte f32 set; no decode; mac={})",
        model.method(),
        model.linears().len(),
        t0.elapsed().as_secs_f64(),
        pb as f64 / fb as f64,
        model.mac().name(),
    );

    // reference answers computed serially BEFORE the model moves into the
    // server thread; the served responses must be bit-identical
    let probe = |cols: usize, seed: u64| {
        let mut x = vec![0.0f32; cols];
        Rng::new(seed).fill_normal(&mut x, 1.0);
        x
    };
    let layers: Vec<(String, usize)> =
        model.linears().iter().map(|(n, l)| (n.clone(), l.cols())).collect();
    let references: Vec<(String, Vec<f32>, Vec<f32>)> = layers
        .iter()
        .enumerate()
        .map(|(i, (name, cols))| {
            let x = probe(*cols, 0x5EED + i as u64);
            let y = model.linear(name).expect("layer").gemv(&x);
            (name.clone(), x, y)
        })
        .collect();

    let fallbacks = model.mac_fallbacks();
    let (server, client) = GemvServer::spawn(model, threads, batch_cap, Duration::from_millis(5));
    for (name, x, want) in &references {
        let got = client.infer(name, x.clone())?;
        anyhow::ensure!(&got == want, "{name}: served response != serial fused gemv");
    }
    println!("self-check OK: served responses bit-identical to serial fused gemv");
    // the self-check requests above ride the same server; subtract them
    // from the reported load numbers so throughput/fill reflect the run
    let warmup = references.len() as u64;

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = client.clone();
        let layers = layers.clone();
        let per_client = n_requests / n_clients;
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut lat = Vec::new();
            for r in 0..per_client {
                let (name, cols) = &layers[(c * 7919 + r) % layers.len()];
                let x = {
                    let mut v = vec![0.0f32; *cols];
                    Rng::new((c * 104729 + r) as u64).fill_normal(&mut v, 1.0);
                    v
                };
                let t = Instant::now();
                let y = client.infer(name, x).expect("fused infer");
                lat.push(t.elapsed().as_secs_f64() * 1e3);
                assert!(y.iter().all(|v| v.is_finite()), "{name}: non-finite output");
            }
            lat
        }));
    }
    let mut all_lat = Vec::new();
    for h in handles {
        all_lat.extend(h.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    let stats = server.shutdown()?;
    let (reqs, batches) =
        (stats.requests.saturating_sub(warmup), stats.batches.saturating_sub(warmup));
    report(&mut all_lat, reqs, batches, stats.max_batch_fill, n_clients, wall);
    if fallbacks > 0 {
        println!("mac fallbacks: {fallbacks} layer(s) fell back to the f32 MAC");
    }
    Ok(())
}

/// CPU-forward serving: full token scoring straight off the packed codes
/// behind the same `EvalServer` the PJRT runner uses. Before serving, the
/// KV-cached incremental decode is checked bit-identical against the
/// full-sequence recompute (the forward pass determinism contract).
fn serve_forward(args: &Args, builder: &BackendBuilder, payload: &str) -> Result<()> {
    let streams = args.usize_or("streams", 0)?;
    if streams > 0 {
        return serve_forward_batched(args, builder, payload);
    }
    let n_requests = args.usize_or("requests", 64)?;
    let n_clients = args.usize_or("clients", 8)?.max(1);
    let fs = ForwardSpec::new(
        args.usize_or("vocab", 256)?,
        args.usize_or("d", 64)?,
        args.usize_or("layers", 2)?,
        args.usize_or("heads", 4)?,
        args.usize_or("ff", 128)?,
        args.usize_or("seq", 32)?,
        args.usize_or("rows", 4)?,
    )?;
    let t0 = Instant::now();
    let map = msbt::read_file(payload)?;
    let model = builder.forward(fs.clone(), &map)?.into_forward()?;
    let (pb, fb) = (model.payload_bytes(), model.f32_bytes());
    println!(
        "serving fused CPU forward ({} layers, d={}, vocab={}) from {payload} in {:.2}s \
         ({pb} payload bytes = {:.3}x of the {fb}-byte f32 projections)",
        fs.layers,
        fs.d,
        fs.vocab,
        t0.elapsed().as_secs_f64(),
        pb as f64 / fb as f64,
    );

    // self-check: incremental decode reproduces the full recompute exactly
    let toks = synth::synth_tokens(&fs, fs.seq, 0x5EED);
    let full = model.logits(&toks)?;
    let mut kv = model.kv_state();
    for i in 0..fs.seq {
        let col: Vec<i32> = (0..fs.batch).map(|bi| toks[bi * fs.seq + i]).collect();
        let step = model.step(&mut kv, &col)?;
        for bi in 0..fs.batch {
            let want = &full[(bi * fs.seq + i) * fs.vocab..(bi * fs.seq + i + 1) * fs.vocab];
            anyhow::ensure!(
                step[bi * fs.vocab..(bi + 1) * fs.vocab] == *want,
                "incremental decode diverged at position {i}"
            );
        }
    }
    println!("self-check OK: KV-cached decode bit-identical to full recompute");

    let (vocab, seq) = (fs.vocab, fs.seq);
    let fallbacks = model.mac_fallbacks();
    let (server, client) = EvalServer::spawn(model, Duration::from_millis(5));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = client.clone();
        let per_client = n_requests / n_clients;
        handles.push(std::thread::spawn(move || -> (f64, Vec<f64>) {
            let mut nll = 0.0;
            let mut lat = Vec::new();
            let mut count = 0usize;
            for r in 0..per_client {
                let mut rng = Rng::new((c * 104729 + r) as u64);
                let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
                let t = Instant::now();
                let resp = client.score(toks).expect("score");
                lat.push(t.elapsed().as_secs_f64() * 1e3);
                nll -= resp.logprobs.iter().sum::<f64>() / resp.logprobs.len() as f64;
                count += 1;
            }
            (nll / count.max(1) as f64, lat)
        }));
    }
    let mut all_lat = Vec::new();
    let mut mean_nll = 0.0;
    for h in handles {
        let (nll, lat) = h.join().expect("client thread");
        mean_nll += nll / n_clients as f64;
        all_lat.extend(lat);
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    let stats = server.shutdown()?;
    report(&mut all_lat, stats.requests, stats.batches, stats.max_batch_fill, n_clients, wall);
    println!("random-stream ppl≈{:.2} (uniform tokens ⇒ ≈vocab {})", mean_nll.exp(), vocab);
    if fallbacks > 0 {
        println!("mac fallbacks: {fallbacks} projection(s) fell back to the f32 MAC");
    }
    Ok(())
}

/// Continuous-batching forward serving (`--streams N`): requests are
/// admitted into stream slots between decode steps, every active stream
/// rides one fused `step_batch` over the paged KV arena, and pages are
/// recycled the moment a stream retires. Every served response is
/// checked bit-identical to solo scoring before the run reports.
fn serve_forward_batched(args: &Args, builder: &BackendBuilder, payload: &str) -> Result<()> {
    use msb_quant::eval::LogProbs;
    use msb_quant::server::BatchConfig;

    let n_requests = args.usize_or("requests", 64)?.max(1);
    let n_clients = args.usize_or("clients", 8)?.max(1);
    let fs = ForwardSpec::new(
        args.usize_or("vocab", 256)?,
        args.usize_or("d", 64)?,
        args.usize_or("layers", 2)?,
        args.usize_or("heads", 4)?,
        args.usize_or("ff", 128)?,
        args.usize_or("seq", 32)?,
        1, // streams are the batch here; the arena holds one slot each
    )?;
    let t0 = Instant::now();
    let map = msbt::read_file(payload)?;
    let model = builder.forward(fs.clone(), &map)?.into_forward()?;
    let fallbacks = model.mac_fallbacks();
    let (pb, fb) = (model.payload_bytes(), model.f32_bytes());
    println!(
        "serving continuous-batched CPU forward ({} layers, d={}, vocab={}) from {payload} \
         in {:.2}s ({pb} payload bytes = {:.3}x of the {fb}-byte f32 projections; \
         {} stream slots, {}-token pages)",
        fs.layers,
        fs.d,
        fs.vocab,
        t0.elapsed().as_secs_f64(),
        builder.get_max_streams(),
        builder.get_kv_page_tokens(),
    );

    // prompt mix sweeps half to full context so prefill chunking and
    // retirement interleave; solo references are the bit-identity ground
    // truth, computed before the model moves into the server thread
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| {
            let len = (fs.seq / 2 + (i * 3) % (fs.seq / 2 + 1)).max(1).min(fs.seq);
            synth::synth_tokens(&fs, len, 0xA11CE ^ i as u64)
        })
        .collect();
    let reference: Vec<Vec<f64>> = prompts
        .iter()
        .map(|t| -> Result<Vec<f64>> {
            let mut kv = model.kv_state();
            let out = model.step(&mut kv, t)?;
            let lp = LogProbs::new(&out, fs.vocab);
            Ok((1..t.len()).map(|p| lp.logp(p - 1, t[p] as usize)).collect())
        })
        .collect::<Result<_>>()?;

    let bc = BatchConfig {
        prefill_chunk: args.usize_or("chunk", 8)?.max(1),
        ..builder.batch_config()
    };
    let inject = !builder.get_faults().is_empty();
    if inject {
        println!("fault injection: {}", builder.get_faults().describe());
    }
    let (server, client) = EvalServer::spawn_batched(model, bc)?;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = client.clone();
        let prompts = prompts.clone();
        let reference = reference.clone();
        // with an injection plan, quarantined/shed requests reply typed
        // errors — count them instead of failing the run
        handles.push(std::thread::spawn(move || -> Result<(Vec<f64>, usize)> {
            let mut lat = Vec::new();
            let mut faulted = 0usize;
            let mut i = c;
            while i < prompts.len() {
                let t = Instant::now();
                match client.score(prompts[i].clone()) {
                    Ok(resp) => {
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                        anyhow::ensure!(
                            resp.logprobs == reference[i],
                            "request {i}: batched logprobs diverged from solo scoring"
                        );
                    }
                    Err(_) if inject => faulted += 1,
                    Err(e) => anyhow::bail!("request {i}: {e:#}"),
                }
                i += n_clients;
            }
            Ok((lat, faulted))
        }));
    }
    let mut all_lat = Vec::new();
    let mut faulted_requests = 0usize;
    for h in handles {
        let (lat, faulted) = h.join().expect("client thread")?;
        all_lat.extend(lat);
        faulted_requests += faulted;
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    let stats = server.shutdown()?;
    if faulted_requests == 0 {
        println!(
            "self-check OK: all {n_requests} batched responses bit-identical to solo scoring"
        );
    } else {
        println!(
            "self-check OK: {} of {n_requests} batched responses bit-identical to solo \
             scoring ({faulted_requests} quarantined by injection)",
            n_requests - faulted_requests
        );
    }
    report(&mut all_lat, stats.requests, stats.batches, stats.max_batch_fill, n_clients, wall);
    println!(
        "scheduler: {} admitted, {} retired, max queue wait {} steps",
        stats.admitted, stats.retired, stats.max_wait_steps
    );
    println!(
        "robustness: {} faulted, {} shed, {} deadline-missed, {} degraded, {} rejected",
        stats.faulted, stats.shed, stats.deadline_missed, stats.degraded, stats.rejected
    );
    let hist: Vec<String> = stats
        .step_width_hist
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > 0)
        .map(|(w, &n)| format!("{}x{n}", w + 1))
        .collect();
    println!("step width histogram (width x steps): {}", hist.join(" "));
    println!(
        "kv arena: peak {} of {} pages ({} bytes at peak)",
        stats.peak_pages, stats.total_pages, stats.peak_page_bytes
    );
    if fallbacks > 0 {
        println!("mac fallbacks: {fallbacks} projection(s) fell back to the f32 MAC");
    }

    if builder.get_speculative() {
        serve_forward_generate(args, builder, payload, &fs, &prompts)?;
    }
    Ok(())
}

/// `--spec` generation arm: greedy-decode the same prompt mix twice —
/// plain chunked decode, then self-speculative draft-verify — assert the
/// outputs bit-identical, and report the step savings and accept rate.
fn serve_forward_generate(
    args: &Args,
    builder: &BackendBuilder,
    payload: &str,
    fs: &ForwardSpec,
    prompts: &[Vec<i32>],
) -> Result<()> {
    use msb_quant::server::{BatchConfig, ServerStats};

    let draft_len = args.usize_or("draft-len", 4)?.max(1);
    let max_new = args.usize_or("max-new", (fs.seq / 2).max(1))?.max(1);
    // leave generation headroom inside the context window
    let keep = (fs.seq / 2).max(1);
    let gen_prompts: Vec<Vec<i32>> =
        prompts.iter().map(|p| p[..p.len().min(keep)].to_vec()).collect();

    let inject = !builder.get_faults().is_empty();
    // per-generation outcome: served tokens, or the typed error a
    // quarantined/faulted stream replied with
    type GenOutcomes = Vec<Result<Vec<i32>>>;
    let run = |speculative: bool| -> Result<(GenOutcomes, ServerStats, f64)> {
        let map = msbt::read_file(payload)?;
        let model = builder.forward(fs.clone(), &map)?.into_forward()?;
        let bc = BatchConfig {
            prefill_chunk: args.usize_or("chunk", 8)?.max(1),
            ..builder.clone().speculative(speculative).batch_config()
        };
        let (server, client) = EvalServer::spawn_batched(model, bc)?;
        let t = Instant::now();
        let handles: Vec<_> = gen_prompts
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, p)| {
                let client = client.clone();
                std::thread::spawn(move || (i, client.generate(p, max_new)))
            })
            .collect();
        let mut outs: Vec<Option<Result<Vec<i32>>>> =
            (0..gen_prompts.len()).map(|_| None).collect();
        for h in handles {
            let (i, resp) = h.join().expect("generate client thread");
            outs[i] = Some(resp.map(|g| g.tokens));
        }
        let dt = t.elapsed().as_secs_f64();
        drop(client);
        let stats = server.shutdown()?;
        let outs = outs.into_iter().map(|o| o.expect("all slots filled above")).collect();
        Ok((outs, stats, dt))
    };
    let (plain, pstats, t_plain) = run(false)?;
    let (spec, sstats, t_spec) = run(true)?;
    // injected faults land at different rounds under the two schedules,
    // so gate only generations that survived both runs
    let mut new_tokens = 0usize;
    let mut gen_faulted = 0usize;
    for (i, (p, s)) in plain.iter().zip(&spec).enumerate() {
        match (p, s) {
            (Ok(p), Ok(s)) => {
                anyhow::ensure!(
                    s == p,
                    "generation {i}: speculative decode diverged from plain greedy"
                );
                new_tokens += p.len();
            }
            _ if inject => gen_faulted += 1,
            (Err(e), _) | (_, Err(e)) => anyhow::bail!("generation {i} failed: {e:#}"),
        }
    }
    let quarantined = if gen_faulted > 0 {
        format!(" ({gen_faulted} quarantined by injection)")
    } else {
        String::new()
    };
    println!(
        "spec decode: bit-identity spec == plain on {} generation(s){quarantined}, \
         {new_tokens} new tokens",
        plain.len() - gen_faulted
    );
    println!(
        "  plain {t_plain:.3}s ({:.0} tok/s, {} steps) | spec {t_spec:.3}s ({:.0} tok/s, \
         {} steps) | {:.2}x",
        new_tokens as f64 / t_plain,
        pstats.batches,
        new_tokens as f64 / t_spec,
        sstats.batches,
        t_plain / t_spec
    );
    match sstats.accept_rate() {
        Some(r) => println!(
            "  drafter: {} drafted, {} accepted ({:.0}% accept rate, draft cap {draft_len})",
            sstats.drafted,
            sstats.accepted,
            100.0 * r
        ),
        None => println!("  drafter: never proposed (no recurring suffixes in this workload)"),
    }
    Ok(())
}

/// Shared telemetry footer: request totals, latency percentiles, fill.
fn report(
    all_lat: &mut [f64],
    requests: u64,
    batches: u64,
    max_fill: usize,
    n_clients: usize,
    wall: f64,
) {
    all_lat.sort_by(f64::total_cmp);
    let p = |q: f64| {
        if all_lat.is_empty() { 0.0 } else { all_lat[((all_lat.len() - 1) as f64 * q) as usize] }
    };
    println!("\n{requests} requests over {n_clients} clients in {wall:.2}s");
    println!(
        "throughput {:.1} req/s | latency p50 {:.2}ms p90 {:.2}ms p99 {:.2}ms",
        requests as f64 / wall,
        p(0.5),
        p(0.9),
        p(0.99)
    );
    println!(
        "batches {batches} (mean fill {:.2}, max {max_fill})",
        requests as f64 / batches.max(1) as f64
    );
}
