//! Integration tests across modules. Tests that need `artifacts/` skip
//! gracefully when it hasn't been built (CI without `make artifacts`).

use msb_quant::harness::Artifacts;
use msb_quant::io::msbt;
use msb_quant::msb::{Algo, Solver};
use msb_quant::pipeline::{quantize, Method, QuantizeOptions};
use msb_quant::quant::{msb::MsbQuantizer, QuantConfig, Quantizer};
use msb_quant::runtime::{LogitsFn, ModelRunner};
use msb_quant::stats::Rng;
use msb_quant::tensor::Matrix;

fn artifacts() -> Option<Artifacts> {
    if !msb_quant::artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Artifacts::load().expect("artifacts load"))
}

// ---------------------------------------------------------------------------
// solver ↔ quantizer ↔ packing consistency (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn solver_codebook_kernel_layout_roundtrip() {
    // The rust (codes, scales) layout must decode identically through the
    // same math the Pallas kernel implements (gather + sign).
    let mut rng = Rng::new(5);
    let w = Matrix::randn(16, 128, &mut rng);
    let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
    let q = MsbQuantizer::wgm().quantize(&w, &cfg);
    let p = q.msb.as_ref().unwrap();
    let codes = p.codes.as_ref().unwrap();
    // kernel-style decode: w[i] = sign(c) * scales[blk(i)*L + |c|-1]
    for (i, &c) in codes.iter().enumerate() {
        let expect = if c == 0 {
            0.0
        } else {
            let blk = i / p.block;
            let mag = p.scales[blk * p.levels + (c.unsigned_abs() as usize - 1)];
            if c < 0 {
                -mag
            } else {
                mag
            }
        };
        let got = q.dequant.data[i];
        assert!(
            (got - expect).abs() <= expect.abs() * 0.01 + 1e-6,
            "elem {i}: kernel decode {expect} vs dequant {got}"
        );
    }
}

#[test]
fn all_methods_produce_finite_bounded_output() {
    let mut rng = Rng::new(6);
    let w = Matrix::weightlike(32, 256, &mut rng);
    let cfg = QuantConfig::block_wise(4, 64).unwrap();
    for method in [
        Method::Rtn,
        Method::Bnb,
        Method::Hqq,
        Method::Wgm,
        Method::Gg,
        Method::Xnor,
        Method::BlockedXnor,
    ] {
        // drive through the pipeline layer with a synthetic 1-layer spec
        use msb_quant::io::manifest::{ModelSpec, ParamSpec};
        use msb_quant::io::msbt::{Tensor, TensorMap};
        let spec = ModelSpec {
            name: "x".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![ParamSpec { name: "w".into(), shape: vec![32, 256], quant: true }],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        };
        let mut weights = TensorMap::new();
        weights.insert("w".into(), Tensor::f32(vec![32, 256], w.data.clone()));
        let qm = quantize(&spec, weights, None, method, &cfg,
            &QuantizeOptions::new().with_threads(2))
        .unwrap();
        let out = qm.weights.get("w").unwrap().as_f32().unwrap();
        assert!(out.iter().all(|v| v.is_finite()), "{method:?}");
        let absmax_in = w.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let absmax_out = out.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(absmax_out <= absmax_in * 2.0, "{method:?} blew up magnitudes");
    }
}

/// Acceptance anchor for the packed pipeline: MSB 4-bit block-wise
/// (t=64) → `export_packed` → `.msbt` v2 file → `decode_packed_model`
/// reproduces the simulated-dequant weights bit-identically, the packed
/// file is ≤ 0.25× the f32 `.msbt`, and the measured payload accounting
/// is within 2% of the paper's 6.00 bits/weight.
#[test]
fn packed_msbt_v2_roundtrip_size_and_bits() {
    use msb_quant::io::manifest::{ModelSpec, ParamSpec};
    use msb_quant::io::msbt::{Tensor, TensorMap};
    use msb_quant::pipeline::decode_packed_model;

    let spec = ModelSpec {
        name: "p".into(),
        d: 32,
        layers: 1,
        heads: 2,
        ff: 64,
        seq: 16,
        params: vec![
            ParamSpec { name: "tok_emb".into(), shape: vec![10, 32], quant: false },
            ParamSpec { name: "layer0.w1".into(), shape: vec![32, 512], quant: true },
            ParamSpec { name: "layer0.w2".into(), shape: vec![64, 256], quant: true },
        ],
        weights_file: String::new(),
        calib_file: String::new(),
        fwd_hlo: String::new(),
    };
    let mut rng = Rng::new(31);
    let mut weights = TensorMap::new();
    for (name, r, c) in [("tok_emb", 10, 32), ("layer0.w1", 32, 512), ("layer0.w2", 64, 256)] {
        let m = Matrix::randn(r, c, &mut rng);
        weights.insert(name.into(), Tensor::f32(vec![r, c], m.data));
    }

    let cfg = QuantConfig::block_wise(4, 64).unwrap();
    let opts = QuantizeOptions::new().with_threads(2).with_packed();
    let qm = quantize(&spec, weights, None, Method::Wgm, &cfg, &opts).unwrap();

    let dir = std::env::temp_dir().join(format!("msbt_pack_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let f32_path = dir.join("f32.msbt");
    let packed_path = dir.join("packed.msbt");
    msbt::write_file(&f32_path, &qm.weights).unwrap();
    msbt::write_file(&packed_path, &qm.export_packed().unwrap()).unwrap();

    // ≤ 0.25x of the f32 artifact (6/32 = 0.1875x + record headers)
    let f32_size = std::fs::metadata(&f32_path).unwrap().len();
    let packed_size = std::fs::metadata(&packed_path).unwrap().len();
    assert!(
        (packed_size as f64) <= 0.25 * f32_size as f64,
        "packed {packed_size} bytes vs f32 {f32_size} bytes"
    );

    // measured payload accounting within 2% of the paper's 6.00 bits/wt
    let bits = qm.packed_effective_bits();
    assert!((bits - 6.0).abs() <= 0.12, "measured {bits} bits/weight");

    // file → decode reproduces the simulated dequant bit-identically
    let back = msbt::read_file(&packed_path).unwrap();
    for threads in [1usize, 4] {
        let decoded = decode_packed_model(&back, threads).unwrap();
        assert_eq!(decoded, qm.weights, "threads {threads}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance anchor for the fused kernels: packed `.msbt` file →
/// `FusedModel` (PackedLinear handles, no f32 decode) → served through
/// `GemvServer` — responses bit-identical to the serial fused gemv, the
/// fused gemv within 1e-5 of the decode-then-matvec reference, and the
/// handles holding ≤ 0.25× the f32 bytes.
#[test]
fn fused_gemv_serves_packed_file_end_to_end() {
    use msb_quant::io::manifest::{ModelSpec, ParamSpec};
    use msb_quant::io::msbt::{Tensor, TensorMap};
    use msb_quant::pipeline::decode_packed_model;
    use msb_quant::runtime::FusedModel;
    use msb_quant::server::GemvServer;

    let spec = ModelSpec {
        name: "fz".into(),
        d: 32,
        layers: 1,
        heads: 2,
        ff: 64,
        seq: 16,
        params: vec![
            ParamSpec { name: "layer0.w1".into(), shape: vec![32, 512], quant: true },
            ParamSpec { name: "layer0.w2".into(), shape: vec![64, 256], quant: true },
        ],
        weights_file: String::new(),
        calib_file: String::new(),
        fwd_hlo: String::new(),
    };
    let mut rng = Rng::new(32);
    let mut weights = TensorMap::new();
    for (name, r, c) in [("layer0.w1", 32usize, 512usize), ("layer0.w2", 64, 256)] {
        let mut m = Matrix::randn(r, c, &mut rng);
        m.data[11] = 0.0; // exception-list coverage through the file format
        weights.insert(name.into(), Tensor::f32(vec![r, c], m.data));
    }
    let cfg = QuantConfig::block_wise(4, 64).unwrap();
    let opts = QuantizeOptions::new().with_threads(2).with_packed();
    let qm = quantize(&spec, weights, None, Method::Wgm, &cfg, &opts).unwrap();

    let dir = std::env::temp_dir().join(format!("msbt_fused_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("packed.msbt");
    msbt::write_file(&path, &qm.export_packed().unwrap()).unwrap();
    let back = msbt::read_file(&path).unwrap();

    let fm = FusedModel::from_packed_map(&back).unwrap();
    assert!(4 * fm.payload_bytes() <= fm.f32_bytes(), "handles must stay packed");
    let decoded = decode_packed_model(&back, 1).unwrap();
    let mut probes: Vec<(String, Vec<f32>, Vec<f32>)> = Vec::new();
    for (name, l) in fm.linears() {
        let w = decoded.get(name).unwrap().to_matrix().unwrap();
        let mut x = vec![0.0f32; l.cols()];
        Rng::new(33).fill_normal(&mut x, 1.0);
        let y = l.gemv(&x);
        msb_quant::kernels::assert_matvec_close(&w, &x, &y, 1e-5);
        probes.push((name.clone(), x, y));
    }

    let (server, client) = GemvServer::spawn(fm, 2, 4, std::time::Duration::from_millis(1));
    for (name, x, want) in &probes {
        let got = client.infer(name, x.clone()).unwrap();
        assert_eq!(&got, want, "{name}: served != serial fused gemv");
    }
    drop(client);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, probes.len() as u64);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn solver_hierarchy_on_shared_instance() {
    // The paper's expectation is DG ≤ GG ≤ WGM "typically, with small
    // absolute differences" (Appendix D.2). Only DG-optimality is a hard
    // guarantee; greedy variants may swap places on individual instances,
    // so we assert the oracle bound plus a tight gap for every heuristic.
    let mut rng = Rng::new(7);
    let mut vals = vec![0.0f32; 1024];
    rng.fill_normal(&mut vals, 1.0);
    let sse = |algo: Algo| Solver::new(algo).quantize(&vals, 8).sse(&vals);
    let dg = sse(Algo::Dg);
    for (name, algo, max_gap) in [
        ("gg", Algo::Gg, 1.5),
        ("wgm16", Algo::Wgm { window: 16 }, 1.5),
        // window 128 on n=1024 leaves just 8 windows => the initialization
        // *is* the answer; the paper's Fig 9 shows exactly this degradation
        ("wgm128", Algo::Wgm { window: 128 }, 4.0),
    ] {
        let h = sse(algo);
        assert!(dg <= h + 1e-9, "oracle beaten by {name}: dg {dg} vs {h}");
        assert!(h <= dg * max_gap + 1e-9, "{name} gap too large: {h} vs oracle {dg}");
    }
}

// ---------------------------------------------------------------------------
// artifact-backed runtime tests
// ---------------------------------------------------------------------------

#[test]
fn runtime_fp_forward_matches_expected_shapes() {
    let Some(arts) = artifacts() else { return };
    let spec = arts.manifest.model("tiny").unwrap();
    let weights = arts.weights(spec).unwrap();
    let runner = ModelRunner::new(&arts.manifest, spec, &weights).unwrap();
    let (b, t, v) = (runner.batch(), runner.seq(), runner.vocab());
    let tokens: Vec<i32> = (0..b * t).map(|i| (i % 90) as i32 + 1).collect();
    let logits = runner.logits(&tokens).unwrap();
    assert_eq!(logits.len(), b * t * v);
    assert!(logits.iter().all(|v| v.is_finite()));
    // determinism
    let logits2 = runner.logits(&tokens).unwrap();
    assert_eq!(logits, logits2);
}

#[test]
fn runtime_weight_swap_changes_logits() {
    let Some(arts) = artifacts() else { return };
    let spec = arts.manifest.model("tiny").unwrap();
    let weights = arts.weights(spec).unwrap();
    let mut runner = ModelRunner::new(&arts.manifest, spec, &weights).unwrap();
    let tokens: Vec<i32> =
        (0..runner.batch() * runner.seq()).map(|i| (i % 90) as i32 + 1).collect();
    let before = runner.logits(&tokens).unwrap();
    let qm = quantize(
        spec,
        weights.clone(),
        None,
        Method::Wgm,
        &QuantConfig::block_wise(2, 64).unwrap(), // 2-bit: large, visible distortion
        &QuantizeOptions::new(),
    )
    .unwrap();
    // QuantizedModel.weights carries the full parameter set (pass-through
    // included), so every ABI slot gets refreshed
    let n = runner.update_weights(&qm.weights).unwrap();
    assert_eq!(n, spec.params.len());
    let after = runner.logits(&tokens).unwrap();
    assert_ne!(before, after);
    // and swapping the originals back restores the FP logits
    runner.update_weights(&weights).unwrap();
    let restored = runner.logits(&tokens).unwrap();
    assert_eq!(before, restored);
}

#[test]
fn quantized_ppl_ordering_fp_best() {
    let Some(arts) = artifacts() else { return };
    let spec = arts.manifest.model("tiny").unwrap();
    let weights = arts.weights(spec).unwrap();
    let mut runner = ModelRunner::new(&arts.manifest, spec, &weights).unwrap();
    let stream = arts.eval_stream("eval_wk").unwrap();
    let short = &stream[..(96 * 16).min(stream.len())];

    let fp = msb_quant::eval::perplexity(&runner, short).unwrap();
    let qm2 = quantize(spec, weights.clone(), None, Method::Wgm,
        &QuantConfig::block_wise(2, 64).unwrap(), &QuantizeOptions::new()).unwrap();
    runner.update_weights(&qm2.weights).unwrap();
    let q2 = msb_quant::eval::perplexity(&runner, short).unwrap();
    let qm4 = quantize(spec, weights.clone(), None, Method::Wgm,
        &QuantConfig::block_wise(4, 64).unwrap(), &QuantizeOptions::new()).unwrap();
    runner.update_weights(&qm4.weights).unwrap();
    let q4 = msb_quant::eval::perplexity(&runner, short).unwrap();

    assert!(fp < q4, "fp {fp} < wgm4 {q4}");
    assert!(q4 < q2, "wgm4 {q4} < wgm2 {q2} (more bits must help)");
}

#[test]
fn native_msb_kernel_executable_runs_and_tracks_simulated_path() {
    let Some(arts) = artifacts() else { return };
    let Some(k) = arts.manifest.msb_kernel_model.clone() else { return };
    let spec = arts.manifest.model(&k.name).unwrap();
    let weights = arts.weights(spec).unwrap();
    let rt = msb_quant::runtime::Runtime::cpu().unwrap();
    let exe = rt.load_hlo(arts.manifest.path(&k.hlo)).unwrap();

    let block = arts.manifest.msb_block;
    let cfg = QuantConfig::block_wise(4, block).unwrap().no_bf16();
    let q = MsbQuantizer::wgm();
    let toks: Vec<i32> = (0..k.batch * spec.seq).map(|i| (i % 90) as i32 + 1).collect();
    let mut bufs = vec![rt.upload_i32(&toks, &[k.batch, spec.seq]).unwrap()];
    for p in &spec.params {
        if !p.quant {
            bufs.push(
                rt.upload_f32(weights.get(&p.name).unwrap().as_f32().unwrap(), &p.shape)
                    .unwrap(),
            );
        }
    }
    let mut qweights = weights.clone();
    for p in spec.params.iter().filter(|p| p.quant) {
        let w = weights.get(&p.name).unwrap().to_matrix().unwrap();
        let qt = q.quantize(&w, &cfg);
        let payload = qt.msb.as_ref().unwrap();
        bufs.push(rt.upload_i8(payload.codes.as_ref().unwrap(), &p.shape).unwrap());
        bufs.push(
            rt.upload_f32(&payload.scales, &[p.shape[0], p.shape[1] / block, k.levels])
                .unwrap(),
        );
        qweights.insert(
            p.name.clone(),
            msbt::Tensor::f32(p.shape.clone(), qt.dequant.data),
        );
    }
    let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let native = exe.run_buffers(&args).unwrap();
    assert!(native.iter().all(|v| v.is_finite()));

    // compare against the simulated path (dequantized weights through the
    // dense executable) on the same tokens: identical math => tight match
    let mut runner = ModelRunner::new(&arts.manifest, spec, &weights).unwrap();
    runner.update_weights(&qweights).unwrap();
    // runner batch is manifest.eval_batch (8) but kernel exe uses k.batch (4):
    // replicate tokens to fill
    let (b, t, v) = (runner.batch(), runner.seq(), runner.vocab());
    let mut full = vec![0i32; b * t];
    for r in 0..b {
        let src = r % k.batch;
        full[r * t..(r + 1) * t].copy_from_slice(&toks[src * t..(src + 1) * t]);
    }
    let simulated = runner.logits(&full).unwrap();
    let mut max_err = 0.0f32;
    for r in 0..k.batch {
        for i in 0..t * v {
            let a = native[r * t * v + i];
            let bsim = simulated[r * t * v + i];
            max_err = max_err.max((a - bsim).abs());
        }
    }
    assert!(max_err < 0.15, "native vs simulated logit gap {max_err}");
}

#[test]
fn harness_report_row_formats() {
    let Some(arts) = artifacts() else { return };
    let spec = arts.manifest.model("tiny").unwrap();
    let weights = arts.weights(spec).unwrap();
    let mut runner = ModelRunner::new(&arts.manifest, spec, &weights).unwrap();
    let report = msb_quant::harness::eval_quantized(
        &arts,
        spec,
        &mut runner,
        &weights,
        Method::Rtn,
        &QuantConfig::block_wise(4, 64).unwrap(),
        1,
    )
    .unwrap();
    assert_eq!(report.ppl.len(), 3);
    assert_eq!(report.qa.len(), 7);
    assert!(report.avg_ppl() > 1.0);
    assert!(report.row().contains("rtn"));
}
