//! `msb` — the L3 coordinator CLI.
//!
//! ```text
//! msb info                              artifact + model summary
//! msb solve   --algo wgm --n 65536 --groups 32 --window 64
//! msb quantize --model base --method wgm --bits 4 --granularity block
//! msb eval    --model base --method wgm --bits 4 --granularity block
//! msb pack    --model base --method wgm  write a packed .msbt v2 payload
//! msb decode  --in base_wgm_packed.msbt  reconstruct f32 weights
//! msb score   --method wgm --bits 4      fused CPU forward token scoring
//! msb serve-bench --streams 4            continuous-batching decode bench
//! msb serve-bench --spec --draft-len 4   + self-speculative decode arm
//! msb kernel  run the Pallas-MSB native executable (small model)
//! ```

use std::time::Instant;

use anyhow::{Context, Result};
use msb_quant::cli::Args;
use msb_quant::harness::{eval_quantized, Artifacts};
use msb_quant::io::msbt;
use msb_quant::msb::{Algo, Solver};
use msb_quant::pipeline::{decode_packed_model, quantize, QuantizeOptions};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::runtime::ModelRunner;
use msb_quant::stats::Rng;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let r = match args.command.as_str() {
        "info" => cmd_info(),
        "solve" => cmd_solve(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "pack" => cmd_pack(&args),
        "decode" => cmd_decode(&args),
        "gemv-bench" => cmd_gemv_bench(&args),
        "score" => cmd_score(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "kernel" => cmd_kernel(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{HELP}")),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
msb — MSB dynamic-grouping PTQ (paper reproduction)

commands:
  info       artifact and model summary
  solve      run a solver on a synthetic N(0,1) instance
             --algo dg|gg|wgm|wgm-lo --n <elems> --groups <g> --window <w>
  quantize   quantize a trained model, write <model>_<method>.msbt
             --model tiny|small|base --method rtn|bnb|hqq|gptq|wgm|wgm-lo|...
             --bits B --granularity block|tensor --block T --window W
  eval       quantize + PPL/QA evaluation through the PJRT runtime
             (same flags as quantize; --method fp for the baseline row)
  pack       quantize + write the deployable packed payload (.msbt v2:
             u4/i8 codes + bf16 scale tables); same flags as quantize,
             default --out <model>_<method>_packed.msbt
  decode     reconstruct f32 weights from a packed payload
             --in <packed.msbt> [--out decoded.msbt] [--threads N]
             [--verify <f32.msbt>]  (bit-exact check against a reference,
             per tensor, reusing the decoded map; skips the output write
             unless --out is given)
  gemv-bench fused packed-weight GEMV vs decode-then-matmul ablation
             --in <packed.msbt> [--layer L] | --rows R --cols C
             [--method wgm --bits 4 --block 64 --granularity block]
             [--threads N] [--batch B] [--reps K]
             [--mac f32|int8|auto]  (int8: integer MAC arm for
             affine-decode methods — rtn, rtn-asym, hqq, xnor)
  score      fused CPU transformer forward token scoring on a synthetic
             model (no artifacts/, no XLA): quantize to a packed payload,
             run every projection straight off the codes, gate against
             the f32 twin at 1e-4 relative (int8 MAC: 1e-2 L2-relative),
             report ppl + logprobs
             [--method wgm --bits 4 --block 64] [--vocab V --d D
             --layers L --heads H --ff F --seq S --rows R]
             [--threads N] [--seed K] [--mac f32|int8|auto]
             [--out payload.msbt]
  serve-bench continuous-batching decode over the paged KV arena on a
             synthetic model: concurrent client streams drive the
             EvalServer scheduler (chunked prefill, page recycling),
             self-checked bit-identical to solo scoring before any
             number prints; reports solo vs batched tokens/sec, step
             width histogram, and page occupancy
             [--streams N] [--requests R] [--page-tokens P] [--chunk C]
             [--method rtn --bits 4 --block 64] [--vocab V --d D
             --layers L --heads H --ff F --seq S]
             [--threads N] [--seed K] [--mac f32|int8|auto]
             [--spec] [--draft-len K] [--max-new N]  (generation arm:
             plain vs self-speculative greedy decode — prompt-lookup
             drafts verified in the same fused step, bit-identical
             output, fewer steps; reports accept rate)
             [--inject panic@S:N,nan@S:N,draft-panic@S:N,delay@MS]
             (deterministic fault injection: scripted step panics / NaN
             logits / drafter panics at round S against stream ordinal
             N, per-step stalls; faulted streams are quarantined, the
             survivors stay gated bit-identical, robustness counters
             print: faulted/shed/deadline-missed/degraded)
  kernel     execute the native Pallas-MSB HLO for the small model
";

fn parse_cfg(args: &Args) -> Result<QuantConfig> {
    let bits = args.u32_or("bits", 4)?;
    let block = args.usize_or("block", 64)?;
    let gran = args.str_or("granularity", "block");
    let mut cfg = match gran {
        "block" | "blockwise" => QuantConfig::block_wise(bits, block)?,
        "tensor" | "per-tensor" => QuantConfig::per_tensor(bits)?,
        g => anyhow::bail!("bad --granularity '{g}'"),
    };
    if let Some(w) = args.get("window") {
        cfg = cfg.with_window(w.parse().context("--window")?)?;
    }
    if let Some(l) = args.get("lambda") {
        cfg = cfg.with_lambda(l.parse().context("--lambda")?);
    }
    Ok(cfg)
}

fn cmd_info() -> Result<()> {
    let arts = Artifacts::load()?;
    let m = &arts.manifest;
    println!("artifacts: {}", m.dir.display());
    println!("vocab {} | msb block {} | eval batch {}", m.vocab, m.msb_block, m.eval_batch);
    println!("eval streams: {:?}", m.eval_streams);
    println!(
        "probe suites: {:?}",
        m.probe_suites.iter().map(|s| format!("{}({})", s.name, s.n)).collect::<Vec<_>>()
    );
    for spec in &m.models {
        println!(
            "model {:<6} d={} L={} heads={} ff={} seq={}  params={}  quantizable={}",
            spec.name,
            spec.d,
            spec.layers,
            spec.heads,
            spec.ff,
            spec.seq,
            spec.total_params(),
            spec.quantizable().count()
        );
    }
    if let Some(k) = &m.msb_kernel_model {
        println!("native MSB-kernel executable: {} ({} levels)", k.hlo, k.levels);
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 65_536)?;
    let groups = args.usize_or("groups", 32)?;
    let window = args.usize_or("window", 64)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let algo = match args.str_or("algo", "wgm") {
        "dg" => Algo::Dg,
        "gg" => Algo::Gg,
        "wgm" => Algo::Wgm { window },
        "wgm-lo" => Algo::WgmLo { bins: 256, range: 32, max_iters: 12, patience: 3 },
        a => anyhow::bail!("bad --algo '{a}'"),
    };
    let mut rng = Rng::new(seed);
    let mut vals = vec![0.0f32; n];
    rng.fill_normal(&mut vals, 1.0);
    let solver = Solver::new(algo.clone()).with_lambda(args.f64_or("lambda", 0.75)?);
    let t0 = Instant::now();
    let code = solver.quantize(&vals, groups);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} n={} groups={} -> levels={} sse={:.4} bits/code={} time={:.3}s ({:.1}M elem/s)",
        algo.name(),
        n,
        groups,
        code.num_levels(),
        code.sse(&vals),
        code.code_bits(),
        dt,
        n as f64 / dt / 1e6
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let arts = Artifacts::load()?;
    let model = args.str_or("model", "small");
    let spec = arts.manifest.model(model)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;
    let cfg = parse_cfg(args)?;
    let weights = arts.weights(spec)?;
    let calib;
    let calib_ref = if method.needs_calibration() {
        calib = arts.calib(spec)?;
        Some(&calib)
    } else {
        None
    };
    let threads = args.usize_or("threads", 1)?;
    let opts = QuantizeOptions::new().with_threads(threads);
    let qm = quantize(spec, weights, calib_ref, method, &cfg, &opts)?;
    println!(
        "{} {} quantized in {:.2}s: total SSE {:.4}, {:.2} bits/weight",
        model,
        method.name(),
        qm.wall_seconds,
        qm.total_sse(),
        qm.mean_effective_bits()
    );
    for l in &qm.layers {
        println!("  {:<16} {}x{}  sse {:.5}  {:.3}s", l.name, l.rows, l.cols, l.sse, l.seconds);
    }
    let out = args
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("{model}_{}.msbt", method.name()));
    msbt::write_file(&out, &qm.weights)?;
    println!("wrote {out}");
    Ok(())
}

/// Quantize and write the deployable packed payload (.msbt v2).
fn cmd_pack(args: &Args) -> Result<()> {
    let arts = Artifacts::load()?;
    let model = args.str_or("model", "small");
    let spec = arts.manifest.model(model)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;
    let cfg = parse_cfg(args)?.with_packed();
    let weights = arts.weights(spec)?;
    let f32_elems: usize = weights.values().map(|t| t.data.len()).sum();
    let calib;
    let calib_ref = if method.needs_calibration() {
        calib = arts.calib(spec)?;
        Some(&calib)
    } else {
        None
    };
    let threads = args.usize_or("threads", 1)?;
    let opts = QuantizeOptions::new().with_threads(threads);
    let qm = quantize(spec, weights, calib_ref, method, &cfg, &opts)?;
    let payload = qm.export_packed()?;
    let out = args
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("{model}_{}_packed.msbt", method.name()));
    msbt::write_file(&out, &payload)?;
    let size = std::fs::metadata(&out)?.len();
    println!(
        "{} {} packed in {:.2}s: {} layers, {:.3} bits/weight (measured), \
         {} bytes on disk ({:.3}x of f32)",
        model,
        method.name(),
        qm.wall_seconds,
        qm.packed.len(),
        qm.packed_effective_bits(),
        size,
        size as f64 / (f32_elems * 4) as f64,
    );
    for (name, pt) in &qm.packed {
        println!(
            "  {:<16} {}x{}  {} code bits  {:.3} bits/weight  {} zero exceptions",
            name,
            pt.rows,
            pt.cols,
            pt.code_bits,
            pt.effective_bits(),
            pt.zeros.len()
        );
    }
    println!("wrote {out}");
    Ok(())
}

/// Reconstruct f32 weights from a packed payload; artifacts not required.
/// `--verify` checks the *in-memory* decoded map against the reference —
/// one decode serves both the output and the verification (no second
/// decode, and verify-only runs skip the O(model) file write entirely
/// unless `--out` is given explicitly).
fn cmd_decode(args: &Args) -> Result<()> {
    let input = args.get("in").context("--in <packed.msbt> required")?;
    let threads = args.usize_or("threads", 1)?;
    let map = msbt::read_file(input)?;
    let t0 = Instant::now();
    let decoded = decode_packed_model(&map, threads)?;
    println!(
        "decoded {} tensors from {input} in {:.2}s ({threads} thread(s))",
        decoded.len(),
        t0.elapsed().as_secs_f64()
    );
    let verifying = args.get("verify").is_some();
    if let Some(reference) = args.get("verify") {
        let expect = msbt::read_file(reference)?;
        for (name, want) in &expect {
            match decoded.get(name) {
                Some(got) if got == want => {}
                Some(_) => anyhow::bail!(
                    "decode mismatch: tensor '{name}' of {input} differs from {reference}"
                ),
                None => anyhow::bail!("decode mismatch: {reference} has '{name}', decode lacks it"),
            }
        }
        for name in decoded.keys() {
            anyhow::ensure!(
                expect.contains_key(name),
                "decode mismatch: decode has '{name}', {reference} lacks it"
            );
        }
        println!("verify OK: bit-identical to {reference} ({} tensors)", expect.len());
    }
    if let Some(out) = args.get("out") {
        msbt::write_file(out, &decoded)?;
        println!("wrote {out}");
    } else if !verifying {
        let out = "decoded.msbt";
        msbt::write_file(out, &decoded)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Fused packed-weight GEMV ablation: compute `y = W·x` directly on the
/// codes ([`msb_quant::kernels::PackedLinear`]) vs the old
/// decode-to-f32-then-matmul path, on a real packed artifact (`--in`) or
/// a synthetic proxy layer. Self-checking: the fused result must match
/// the f64 reference to 1e-5 relative before any number is printed, and
/// the `--mac int8` arm must match it to 2.5e-2 (activation rounding)
/// with pooled bit-identical to serial.
fn cmd_gemv_bench(args: &Args) -> Result<()> {
    use msb_quant::benchlib;
    use msb_quant::kernels::{dense_gemv, PackedLinear};
    use msb_quant::quant::engine::{decode_packed, quantize_serial};
    use msb_quant::quant::registry;

    let reps = args.usize_or("reps", 5)?.max(1);
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = args.usize_or("threads", default_threads)?.max(1);
    let batch = args.usize_or("batch", 8)?.max(1);
    let mac = msb_quant::kernels::MacMode::parse(args.str_or("mac", "f32"))?;

    let (label, pt) = if let Some(path) = args.get("in") {
        let map = msbt::read_file(path)?;
        let (method, mut packed, _) = msb_quant::pipeline::packed_tensors(&map)?;
        let name = match args.get("layer") {
            Some(l) => l.to_string(),
            None => packed
                .iter()
                .max_by_key(|(_, p)| p.n_elems())
                .map(|(n, _)| n.clone())
                .context("empty packed artifact")?,
        };
        let pt = packed.remove(&name).with_context(|| format!("no packed layer '{name}'"))?;
        (format!("{method} {name} ({}x{})", pt.rows, pt.cols), pt)
    } else {
        let rows = args.usize_or("rows", 1024)?;
        let cols = args.usize_or("cols", 1024)?;
        let method = Method::parse(args.str_or("method", "wgm"))?;
        let cfg = parse_cfg(args)?.with_packed();
        let q = registry::block_quantizer(method)
            .with_context(|| format!("{} has no block-partitioned path", method.name()))?;
        let w = benchlib::proxy_matrix(rows, cols);
        let qt = quantize_serial(&*q, &w, &cfg);
        let pt = qt.packed.with_context(|| format!("{} emits no packed payload", method.name()))?;
        (format!("{} {rows}x{cols}", method.name()), pt)
    };

    let n_blocks = pt.n_blocks() as f64;
    let n = pt.n_elems() as f64;
    let decoder = registry::block_decoder(&pt.method)?;
    let pl = PackedLinear::new(pt)?;
    // errors up front for `--mac int8` on methods without an affine decode
    let pl8 = pl.clone().with_mac(mac)?;
    let mut x = vec![0.0f32; pl.cols()];
    Rng::new(0xF00D).fill_normal(&mut x, 1.0);

    // correctness gate: fused vs f64 reference on the decoded matrix
    let decoded = decode_packed(decoder.clone(), pl.packed(), None);
    let y = pl.gemv(&x);
    msb_quant::kernels::assert_matvec_close(&decoded, &x, &y, 1e-5);

    let t_fused = benchlib::time_median(reps, || pl.gemv(&x));
    let t_base = benchlib::time_median(reps, || {
        let m = decode_packed(decoder.clone(), pl.packed(), None);
        dense_gemv(&m, &x, pl.kernel())
    });
    let mut pool = msb_quant::pool::ThreadPool::new(threads, threads * 4);
    let y_pooled = pl.gemv_pooled(&x, &pool);
    anyhow::ensure!(y == y_pooled, "pooled gemv diverged from serial");
    let t_pooled = benchlib::time_median(reps, || pl.gemv_pooled(&x, &pool));
    let mut xs = vec![0.0f32; batch * pl.cols()];
    Rng::new(0xF00E).fill_normal(&mut xs, 1.0);
    let t_gemm = benchlib::time_median(reps, || pl.gemm_pooled(&xs, batch, &pool));
    // integer MAC arm: activations quantized to i8 per 64-block, i32
    // accumulation, one f32 epilogue per block pair (2.5e-2 budget)
    let int8 = if pl8.int8_active() {
        let y8 = pl8.gemv(&x);
        msb_quant::kernels::assert_matvec_close(&decoded, &x, &y8, 2.5e-2);
        let y8_pooled = pl8.gemv_pooled(&x, &pool);
        anyhow::ensure!(y8 == y8_pooled, "pooled int8 gemv diverged from serial");
        let t8 = benchlib::time_median(reps, || pl8.gemv(&x));
        let t8_pooled = benchlib::time_median(reps, || pl8.gemv_pooled(&x, &pool));
        Some((t8, t8_pooled))
    } else {
        None
    };
    pool.shutdown();

    println!(
        "fused GEMV ablation: {label} ({} kernel, {threads} threads, mac={})",
        pl.kernel().name(),
        mac.name()
    );
    println!(
        "  payload {} bytes ({:.3}x of f32); {} zero exceptions",
        pl.payload_bytes(),
        pl.payload_bytes() as f64 / (n * 4.0),
        pl.packed().zeros.len()
    );
    let gflops = |t: f64, mults: f64| 2.0 * mults / t / 1e9;
    println!(
        "  decode+matmul  {:>9.4}s  {:>10.0} blk/s  {:>6.2} GFLOP/s",
        t_base,
        n_blocks / t_base,
        gflops(t_base, n)
    );
    println!(
        "  fused serial   {:>9.4}s  {:>10.0} blk/s  {:>6.2} GFLOP/s  ({:.2}x)",
        t_fused,
        n_blocks / t_fused,
        gflops(t_fused, n),
        t_base / t_fused
    );
    println!(
        "  fused pooled   {:>9.4}s  {:>10.0} blk/s  {:>6.2} GFLOP/s",
        t_pooled,
        n_blocks / t_pooled,
        gflops(t_pooled, n)
    );
    println!(
        "  fused gemm x{batch} {:>8.4}s  {:>10.0} blk/s  {:>6.2} GFLOP/s (amortized decode)",
        t_gemm,
        n_blocks * batch as f64 / t_gemm,
        gflops(t_gemm, n * batch as f64)
    );
    if let Some((t8, t8_pooled)) = int8 {
        println!(
            "  int8 serial    {:>9.4}s  {:>10.0} blk/s  {:>6.2} GFLOP/s  ({:.2}x vs fused f32)",
            t8,
            n_blocks / t8,
            gflops(t8, n),
            t_fused / t8
        );
        println!(
            "  int8 pooled    {:>9.4}s  {:>10.0} blk/s  {:>6.2} GFLOP/s  ({:.2}x vs fused f32)",
            t8_pooled,
            n_blocks / t8_pooled,
            gflops(t8_pooled, n),
            t_pooled / t8_pooled
        );
    } else if mac != msb_quant::kernels::MacMode::F32 {
        println!("  int8 MAC       (no affine decode for this method; f32 fallback)");
    }
    Ok(())
}

/// Fused CPU forward token scoring on a synthetic transformer — the
/// XLA-free end of the pipeline. Quantizes seeded weights to a packed
/// payload, runs the full forward with every projection computed
/// straight off the codes, and refuses to print numbers unless the
/// logits match the f32 twin (same layer graph over the decoded
/// weights) within 1e-4 relative — or, when `--mac` engages the integer
/// MAC, within 1e-2 L2-relative (the activation-rounding budget).
fn cmd_score(args: &Args) -> Result<()> {
    use msb_quant::eval::{perplexity, LogProbs};
    use msb_quant::forward::{synth, ForwardSpec};
    use msb_quant::runtime::BackendBuilder;

    let fs = ForwardSpec::new(
        args.usize_or("vocab", 256)?,
        args.usize_or("d", 64)?,
        args.usize_or("layers", 2)?,
        args.usize_or("heads", 4)?,
        args.usize_or("ff", 128)?,
        args.usize_or("seq", 32)?,
        args.usize_or("rows", 4)?,
    )?;
    let method = Method::parse(args.str_or("method", "wgm"))?;
    anyhow::ensure!(
        !method.needs_calibration(),
        "msb score is calibration-free; {} needs calibration activations",
        method.name()
    );
    let cfg = parse_cfg(args)?.with_packed();
    let threads = args.usize_or("threads", 1)?.max(1);
    let seed = args.usize_or("seed", 7)? as u64;
    let mac = msb_quant::kernels::MacMode::parse(args.str_or("mac", "f32"))?;

    let spec = synth::model_spec(&fs, "score");
    let weights = synth::synth_weights(&fs, seed);
    let t0 = Instant::now();
    let opts = QuantizeOptions::new().with_threads(threads);
    let qm = quantize(&spec, weights, None, method, &cfg, &opts)?;
    let payload = qm.export_packed()?;
    let t_quant = t0.elapsed().as_secs_f64();

    let builder = BackendBuilder::new().threads(threads).mac(mac);
    let model = builder.forward(fs.clone(), &payload)?.into_forward()?;
    // every projection shares one method, so int8 engages all-or-none:
    // any counted fallback means the method lacks an affine decode
    let int8_engaged = mac != msb_quant::kernels::MacMode::F32 && model.mac_fallbacks() == 0;
    let twin = builder
        .forward_dense(fs.clone(), &decode_packed_model(&payload, threads)?)?
        .into_forward()?;

    let toks = synth::synth_tokens(&fs, fs.seq, seed ^ 0x5EED);
    let t1 = Instant::now();
    let fused = model.logits(&toks)?;
    let t_fwd = t1.elapsed().as_secs_f64();
    let t2 = Instant::now();
    let dense = twin.logits(&toks)?;
    let t_twin = t2.elapsed().as_secs_f64();

    // acceptance gate: codes-path logits vs the f32 twin on the decoded
    // map. The f32 MAC is near-exact (1e-4 max-rel); the int8 MAC trades
    // a bounded activation-rounding error for speed (1e-2 L2-relative).
    let mut max_rel = 0.0f64;
    let (mut d2, mut b2) = (0.0f64, 0.0f64);
    for (&a, &b) in fused.iter().zip(&dense) {
        let scale = (a.abs().max(b.abs()) as f64).max(1e-3);
        max_rel = max_rel.max(((a - b).abs() as f64) / scale);
        d2 += ((a - b) as f64).powi(2);
        b2 += (b as f64).powi(2);
    }
    let l2_rel = (d2 / b2.max(1e-30)).sqrt();
    if int8_engaged {
        anyhow::ensure!(
            l2_rel <= 1e-2,
            "int8-MAC logits diverged from the f32 twin: L2 rel {l2_rel:.3e} > 1e-2"
        );
    } else {
        anyhow::ensure!(
            max_rel <= 1e-4,
            "fused logits diverged from the f32 twin: max rel {max_rel:.3e} > 1e-4"
        );
    }

    let ppl_q = perplexity(&model, &toks)?;
    let ppl_f = perplexity(&twin, &toks)?;
    let lp = LogProbs::new(&fused[..fs.seq * fs.vocab], fs.vocab);
    let scored = fs.seq.saturating_sub(1).max(1);
    let mean_lp: f64 = (0..fs.seq - 1)
        .map(|p| lp.logp(p, toks[p + 1] as usize))
        .sum::<f64>()
        / scored as f64;

    println!(
        "score: {} L={} d={} heads={} ff={} seq={} rows={} \
         ({} kernel, {threads} thread(s), mac={}{})",
        method.name(),
        fs.layers,
        fs.d,
        fs.heads,
        fs.ff,
        fs.seq,
        fs.batch,
        msb_quant::kernels::Kernel::detect().name(),
        mac.name(),
        if int8_engaged { " [int8 active]" } else { "" }
    );
    println!(
        "  payload {} bytes ({:.3}x of the f32 projections), quantized in {:.2}s",
        model.payload_bytes(),
        model.payload_bytes() as f64 / model.f32_bytes() as f64,
        t_quant
    );
    println!(
        "  fused forward {} logits in {:.3}s | f32 twin {:.3}s | \
         max rel {:.2e} | L2 rel {:.2e} ({})",
        fused.len(),
        t_fwd,
        t_twin,
        max_rel,
        l2_rel,
        if int8_engaged { "gate 1e-2 L2, int8 MAC" } else { "gate 1e-4 max-rel" }
    );
    println!("  stream ppl: fused {ppl_q:.4} vs twin {ppl_f:.4}");
    println!("  row 0 mean next-token logprob {mean_lp:.4}");
    if model.mac_fallbacks() > 0 {
        println!(
            "  mac fallbacks: {} projection(s) fell back to the f32 MAC (no affine decode)",
            model.mac_fallbacks()
        );
    }

    if let Some(out) = args.get("out") {
        msbt::write_file(out, &payload)?;
        println!("wrote {out} (serve it: serve_eval --backend forward --payload {out})");
    }
    Ok(())
}

/// Continuous-batching decode benchmark on a synthetic model: concurrent
/// client streams score through the [`msb_quant::server::EvalServer`]
/// scheduler over the paged KV arena. Self-checking: every batched
/// result must be bit-identical to solo scoring (one stream at a time
/// through `ForwardModel::step`) before any number is printed.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    use msb_quant::eval::LogProbs;
    use msb_quant::forward::{synth, ForwardSpec};
    use msb_quant::runtime::BackendBuilder;
    use msb_quant::server::faults::FaultPlan;
    use msb_quant::server::{BatchConfig, EvalServer, Response, ServerStats};

    let fs = ForwardSpec::new(
        args.usize_or("vocab", 256)?,
        args.usize_or("d", 64)?,
        args.usize_or("layers", 2)?,
        args.usize_or("heads", 4)?,
        args.usize_or("ff", 128)?,
        args.usize_or("seq", 32)?,
        1,
    )?;
    let method = Method::parse(args.str_or("method", "rtn"))?;
    anyhow::ensure!(
        !method.needs_calibration(),
        "msb serve-bench is calibration-free; {} needs calibration activations",
        method.name()
    );
    let cfg = parse_cfg(args)?.with_packed();
    let threads = args.usize_or("threads", 1)?.max(1);
    let seed = args.usize_or("seed", 7)? as u64;
    let mac = msb_quant::kernels::MacMode::parse(args.str_or("mac", "f32"))?;
    let streams = args.usize_or("streams", 4)?.max(1);
    let requests = args.usize_or("requests", streams * 2)?.max(1);
    let page_tokens = args.usize_or("page-tokens", 16)?.max(1);
    let chunk = args.usize_or("chunk", 8)?.max(1);
    let faults = match args.get("inject") {
        Some(spec) => FaultPlan::parse(spec).context("--inject")?,
        None => FaultPlan::new(),
    };

    let spec = synth::model_spec(&fs, "serve-bench");
    let weights = synth::synth_weights(&fs, seed);
    let opts = QuantizeOptions::new().with_threads(threads);
    let qm = quantize(&spec, weights, None, method, &cfg, &opts)?;
    let payload = qm.export_packed()?;

    let builder = BackendBuilder::new()
        .threads(threads)
        .mac(mac)
        .max_streams(streams)
        .kv_page_tokens(page_tokens)
        .faults(faults.clone());
    let model = builder.forward(fs.clone(), &payload)?.into_forward()?;
    let fallbacks = model.mac_fallbacks();

    // request mix: prompt lengths sweep from half context to (almost)
    // full context so prefill chunking and retirement actually interleave
    let prompts: Vec<Vec<i32>> = (0..requests)
        .map(|i| {
            let len = (fs.seq / 2 + (i * 3) % (fs.seq / 2 + 1)).max(1).min(fs.seq);
            synth::synth_tokens(&fs, len, seed ^ (0x51ED + i as u64))
        })
        .collect();
    let total_tokens: usize = prompts.iter().map(|t| t.len()).sum();

    // solo reference + sequential baseline: same model, one stream at a
    // time. `step` over the full prompt is the batched path's ground
    // truth — step_batch is bit-identical per stream by construction.
    let t0 = Instant::now();
    let mut reference = Vec::with_capacity(requests);
    for t in &prompts {
        let mut kv = model.kv_state();
        let out = model.step(&mut kv, t)?;
        let lp = LogProbs::new(&out, fs.vocab);
        let lps: Vec<f64> = (1..t.len()).map(|p| lp.logp(p - 1, t[p] as usize)).collect();
        reference.push(lps);
    }
    let t_solo = t0.elapsed().as_secs_f64();

    let bc = BatchConfig {
        prefill_chunk: chunk,
        max_waiting_steps: 32,
        linger: std::time::Duration::from_millis(5),
        ..builder.batch_config()
    };
    let (server, client) = EvalServer::spawn_batched(model, bc)?;
    let t1 = Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let c = client.clone();
            let t = t.clone();
            std::thread::spawn(move || (i, c.score(t)))
        })
        .collect();
    let mut results: Vec<Option<Result<Response>>> = (0..requests).map(|_| None).collect();
    for h in handles {
        let (i, r) = h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))?;
        results[i] = Some(r);
    }
    let t_batched = t1.elapsed().as_secs_f64();
    drop(client);
    let stats = server.shutdown()?;

    // acceptance gate: batched logprobs bit-identical to solo, per stream.
    // Streams quarantined by an injected fault are counted, not gated —
    // any error without an injection plan is still fatal.
    let mut faulted_streams = 0usize;
    for (i, r) in results.iter().enumerate() {
        match r.as_ref().expect("all slots filled above") {
            Ok(r) => anyhow::ensure!(
                r.logprobs == reference[i],
                "stream {i}: batched logprobs diverged from solo scoring"
            ),
            Err(_) if !faults.is_empty() => faulted_streams += 1,
            Err(e) => anyhow::bail!("stream {i} failed: {e:#}"),
        }
    }

    println!(
        "serve-bench: {} L={} d={} heads={} ff={} seq={} | {} streams, {} requests, \
         {} tokens ({} kernel, {threads} thread(s), mac={})",
        method.name(),
        fs.layers,
        fs.d,
        fs.heads,
        fs.ff,
        fs.seq,
        streams,
        requests,
        total_tokens,
        msb_quant::kernels::Kernel::detect().name(),
        mac.name()
    );
    if !faults.is_empty() {
        println!("  fault injection: {}", faults.describe());
    }
    if faulted_streams == 0 {
        println!("  bit-identity: batched == solo on all {requests} request(s)");
    } else {
        println!(
            "  bit-identity: batched == solo on {} of {requests} request(s) \
             ({faulted_streams} quarantined by injection)",
            requests - faulted_streams
        );
    }
    println!(
        "  solo sequential {:.3}s ({:.0} tok/s) | batched {:.3}s ({:.0} tok/s) | {:.2}x",
        t_solo,
        total_tokens as f64 / t_solo,
        t_batched,
        total_tokens as f64 / t_batched,
        t_solo / t_batched
    );
    println!(
        "  scheduler: {} admitted, {} retired, {} coalesced steps, max fill {}, \
         max queue wait {} steps",
        stats.admitted, stats.retired, stats.batches, stats.max_batch_fill, stats.max_wait_steps
    );
    let hist: Vec<String> = stats
        .step_width_hist
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > 0)
        .map(|(w, &n)| format!("{}x{n}", w + 1))
        .collect();
    println!("  step width histogram (width x steps): {}", hist.join(" "));
    println!(
        "  kv arena: peak {} of {} pages ({} bytes at peak, {}-token pages)",
        stats.peak_pages, stats.total_pages, stats.peak_page_bytes, page_tokens
    );
    println!(
        "  robustness: {} faulted, {} shed, {} deadline-missed, {} degraded, \
         {} rejected",
        stats.faulted, stats.shed, stats.deadline_missed, stats.degraded, stats.rejected
    );
    if fallbacks > 0 {
        println!(
            "  mac fallbacks: {fallbacks} projection(s) fell back to the f32 MAC \
             (no affine decode)"
        );
    }

    if args.has("spec") {
        // generation arm: plain vs self-speculative greedy decode over the
        // same prompt set, bit-identity asserted before any number prints
        let draft_len = args.usize_or("draft-len", 4)?.max(1);
        let max_new = args.usize_or("max-new", (fs.seq / 2).max(1))?.max(1);
        let gen_prompts: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| {
                let keep = p.len().min((fs.seq / 2).max(1));
                p[..keep].to_vec()
            })
            .collect();
        // per-generation outcome: served tokens, or the typed error a
        // quarantined/faulted stream replied with
        type GenOutcomes = Vec<Result<Vec<i32>>>;
        let run = |speculative: bool| -> Result<(GenOutcomes, ServerStats, f64)> {
            let model = builder.forward(fs.clone(), &payload)?.into_forward()?;
            let bc = BatchConfig {
                prefill_chunk: chunk,
                max_waiting_steps: 32,
                linger: std::time::Duration::from_millis(5),
                ..builder.clone().speculative(speculative).draft_len(draft_len).batch_config()
            };
            let (server, client) = EvalServer::spawn_batched(model, bc)?;
            let t = Instant::now();
            let handles: Vec<_> = gen_prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let c = client.clone();
                    let p = p.clone();
                    std::thread::spawn(move || (i, c.generate(p, max_new)))
                })
                .collect();
            let mut outs: Vec<Option<Result<Vec<i32>>>> =
                (0..gen_prompts.len()).map(|_| None).collect();
            for h in handles {
                let (i, r) =
                    h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))?;
                outs[i] = Some(r.map(|g| g.tokens));
            }
            let dt = t.elapsed().as_secs_f64();
            drop(client);
            let stats = server.shutdown()?;
            let outs = outs.into_iter().map(|o| o.expect("all slots filled above")).collect();
            Ok((outs, stats, dt))
        };
        let (plain, pstats, t_plain) = run(false)?;
        let (spec, sstats, t_spec) = run(true)?;
        // injected faults land at different rounds under the two
        // schedules, so gate only generations that survived both runs
        let mut new_tokens = 0usize;
        let mut gen_faulted = 0usize;
        for (i, (p, s)) in plain.iter().zip(&spec).enumerate() {
            match (p, s) {
                (Ok(p), Ok(s)) => {
                    anyhow::ensure!(
                        s == p,
                        "generation {i}: speculative decode diverged from plain greedy"
                    );
                    new_tokens += p.len();
                }
                _ if !faults.is_empty() => gen_faulted += 1,
                (Err(e), _) | (_, Err(e)) => anyhow::bail!("generation {i} failed: {e:#}"),
            }
        }
        let quarantined = if gen_faulted > 0 {
            format!(" ({gen_faulted} quarantined by injection)")
        } else {
            String::new()
        };
        println!(
            "  spec decode: bit-identity spec == plain on {} generation(s){quarantined}, \
             {new_tokens} new tokens",
            plain.len() - gen_faulted
        );
        println!(
            "    plain {:.3}s ({:.0} tok/s, {} steps) | spec {:.3}s ({:.0} tok/s, \
             {} steps) | {:.2}x",
            t_plain,
            new_tokens as f64 / t_plain,
            pstats.batches,
            t_spec,
            new_tokens as f64 / t_spec,
            sstats.batches,
            t_plain / t_spec
        );
        match sstats.accept_rate() {
            Some(r) => println!(
                "    drafter: {} drafted, {} accepted ({:.0}% accept rate, \
                 draft cap {draft_len})",
                sstats.drafted,
                sstats.accepted,
                100.0 * r
            ),
            None => println!(
                "    drafter: never proposed (no recurring suffixes in this workload)"
            ),
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let arts = Artifacts::load()?;
    let model = args.str_or("model", "small");
    let spec = arts.manifest.model(model)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;
    let cfg = parse_cfg(args)?;
    let weights = arts.weights(spec)?;
    let mut runner = ModelRunner::new(&arts.manifest, spec, &weights)?;
    let report = eval_quantized(
        &arts,
        spec,
        &mut runner,
        &weights,
        method,
        &cfg,
        args.usize_or("threads", 1)?,
    )?;
    println!("{}", report.row());
    for (name, v) in &report.ppl {
        println!("  ppl {name}: {v:.3}");
    }
    for (name, v) in &report.qa {
        println!("  qa  {name}: {v:.3}");
    }
    Ok(())
}

fn cmd_kernel() -> Result<()> {
    use msb_quant::quant::{msb::MsbQuantizer, Quantizer};
    let arts = Artifacts::load()?;
    let k = arts
        .manifest
        .msb_kernel_model
        .as_ref()
        .context("no msb_kernel_model in manifest (re-run make artifacts)")?;
    let spec = arts.manifest.model(&k.name)?;
    let weights = arts.weights(spec)?;
    let rt = msb_quant::runtime::Runtime::cpu()?;
    println!("compiling {} (Pallas interpret-mode HLO)...", k.hlo);
    let exe = rt.load_hlo(arts.manifest.path(&k.hlo))?;

    // ABI: tokens, non-quant params (spec order), then (codes, scales) pairs
    let block = arts.manifest.msb_block;
    let cfg = QuantConfig::block_wise(4, block).unwrap().no_bf16();
    let q = MsbQuantizer::wgm();
    let mut bufs = Vec::new();
    let toks: Vec<i32> = (0..k.batch * spec.seq).map(|i| (i % 90) as i32 + 1).collect();
    bufs.push(rt.upload_i32(&toks, &[k.batch, spec.seq])?);
    for p in &spec.params {
        if !p.quant {
            bufs.push(rt.upload_f32(weights.get(&p.name).unwrap().as_f32()?, &p.shape)?);
        }
    }
    let t0 = Instant::now();
    for p in spec.params.iter().filter(|p| p.quant) {
        let w = weights.get(&p.name).unwrap().to_matrix()?;
        let qt = q.quantize(&w, &cfg);
        let payload = qt.msb.as_ref().unwrap();
        let codes = payload.codes.as_ref().context("codes overflow i8")?;
        bufs.push(rt.upload_i8(codes, &p.shape)?);
        bufs.push(rt.upload_f32(
            &payload.scales,
            &[p.shape[0], p.shape[1] / block, k.levels],
        )?);
    }
    println!("quantized + uploaded in {:.2}s; executing...", t0.elapsed().as_secs_f64());
    let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let t1 = Instant::now();
    let logits = exe.run_buffers(&args)?;
    println!(
        "native MSB forward OK: {} logits in {:.2}s (batch {} x seq {} x vocab {})",
        logits.len(),
        t1.elapsed().as_secs_f64(),
        k.batch,
        spec.seq,
        arts.manifest.vocab
    );
    anyhow::ensure!(logits.iter().all(|v| v.is_finite()), "non-finite logits");
    Ok(())
}
