//! `msb` — the L3 coordinator CLI.
//!
//! ```text
//! msb info                              artifact + model summary
//! msb solve   --algo wgm --n 65536 --groups 32 --window 64
//! msb quantize --model base --method wgm --bits 4 --granularity block
//! msb eval    --model base --method wgm --bits 4 --granularity block
//! msb pack    --model base --method wgm  write a packed .msbt v2 payload
//! msb decode  --in base_wgm_packed.msbt  reconstruct f32 weights
//! msb kernel  run the Pallas-MSB native executable (small model)
//! ```

use std::time::Instant;

use anyhow::{Context, Result};
use msb_quant::cli::Args;
use msb_quant::harness::{eval_quantized, Artifacts};
use msb_quant::io::msbt;
use msb_quant::msb::{Algo, Solver};
use msb_quant::pipeline::{decode_packed_model, quantize_model};
use msb_quant::quant::registry::Method;
use msb_quant::quant::QuantConfig;
use msb_quant::runtime::ModelRunner;
use msb_quant::stats::Rng;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let r = match args.command.as_str() {
        "info" => cmd_info(),
        "solve" => cmd_solve(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "pack" => cmd_pack(&args),
        "decode" => cmd_decode(&args),
        "kernel" => cmd_kernel(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{HELP}")),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
msb — MSB dynamic-grouping PTQ (paper reproduction)

commands:
  info       artifact and model summary
  solve      run a solver on a synthetic N(0,1) instance
             --algo dg|gg|wgm|wgm-lo --n <elems> --groups <g> --window <w>
  quantize   quantize a trained model, write <model>_<method>.msbt
             --model tiny|small|base --method rtn|bnb|hqq|gptq|wgm|wgm-lo|...
             --bits B --granularity block|tensor --block T --window W
  eval       quantize + PPL/QA evaluation through the PJRT runtime
             (same flags as quantize; --method fp for the baseline row)
  pack       quantize + write the deployable packed payload (.msbt v2:
             u4/i8 codes + bf16 scale tables); same flags as quantize,
             default --out <model>_<method>_packed.msbt
  decode     reconstruct f32 weights from a packed payload
             --in <packed.msbt> [--out decoded.msbt] [--threads N]
             [--verify <f32.msbt>]  (bit-exact check against a reference)
  kernel     execute the native Pallas-MSB HLO for the small model
";

fn parse_cfg(args: &Args) -> Result<QuantConfig> {
    let bits = args.u32_or("bits", 4)?;
    let block = args.usize_or("block", 64)?;
    let gran = args.str_or("granularity", "block");
    let mut cfg = match gran {
        "block" | "blockwise" => QuantConfig::block_wise(bits, block),
        "tensor" | "per-tensor" => QuantConfig::per_tensor(bits),
        g => anyhow::bail!("bad --granularity '{g}'"),
    };
    if let Some(w) = args.get("window") {
        cfg = cfg.with_window(w.parse().context("--window")?);
    }
    if let Some(l) = args.get("lambda") {
        cfg = cfg.with_lambda(l.parse().context("--lambda")?);
    }
    Ok(cfg)
}

fn cmd_info() -> Result<()> {
    let arts = Artifacts::load()?;
    let m = &arts.manifest;
    println!("artifacts: {}", m.dir.display());
    println!("vocab {} | msb block {} | eval batch {}", m.vocab, m.msb_block, m.eval_batch);
    println!("eval streams: {:?}", m.eval_streams);
    println!(
        "probe suites: {:?}",
        m.probe_suites.iter().map(|s| format!("{}({})", s.name, s.n)).collect::<Vec<_>>()
    );
    for spec in &m.models {
        println!(
            "model {:<6} d={} L={} heads={} ff={} seq={}  params={}  quantizable={}",
            spec.name,
            spec.d,
            spec.layers,
            spec.heads,
            spec.ff,
            spec.seq,
            spec.total_params(),
            spec.quantizable().count()
        );
    }
    if let Some(k) = &m.msb_kernel_model {
        println!("native MSB-kernel executable: {} ({} levels)", k.hlo, k.levels);
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 65_536)?;
    let groups = args.usize_or("groups", 32)?;
    let window = args.usize_or("window", 64)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let algo = match args.str_or("algo", "wgm") {
        "dg" => Algo::Dg,
        "gg" => Algo::Gg,
        "wgm" => Algo::Wgm { window },
        "wgm-lo" => Algo::WgmLo { bins: 256, range: 32, max_iters: 12, patience: 3 },
        a => anyhow::bail!("bad --algo '{a}'"),
    };
    let mut rng = Rng::new(seed);
    let mut vals = vec![0.0f32; n];
    rng.fill_normal(&mut vals, 1.0);
    let solver = Solver::new(algo.clone()).with_lambda(args.f64_or("lambda", 0.75)?);
    let t0 = Instant::now();
    let code = solver.quantize(&vals, groups);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} n={} groups={} -> levels={} sse={:.4} bits/code={} time={:.3}s ({:.1}M elem/s)",
        algo.name(),
        n,
        groups,
        code.num_levels(),
        code.sse(&vals),
        code.code_bits(),
        dt,
        n as f64 / dt / 1e6
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let arts = Artifacts::load()?;
    let model = args.str_or("model", "small");
    let spec = arts.manifest.model(model)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;
    let cfg = parse_cfg(args)?;
    let weights = arts.weights(spec)?;
    let calib;
    let calib_ref = if method.needs_calibration() {
        calib = arts.calib(spec)?;
        Some(&calib)
    } else {
        None
    };
    let threads = args.usize_or("threads", 1)?;
    let qm = quantize_model(spec, weights, calib_ref, method, &cfg, threads)?;
    println!(
        "{} {} quantized in {:.2}s: total SSE {:.4}, {:.2} bits/weight",
        model,
        method.name(),
        qm.wall_seconds,
        qm.total_sse(),
        qm.mean_effective_bits()
    );
    for l in &qm.layers {
        println!("  {:<16} {}x{}  sse {:.5}  {:.3}s", l.name, l.rows, l.cols, l.sse, l.seconds);
    }
    let out = args
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("{model}_{}.msbt", method.name()));
    msbt::write_file(&out, &qm.weights)?;
    println!("wrote {out}");
    Ok(())
}

/// Quantize and write the deployable packed payload (.msbt v2).
fn cmd_pack(args: &Args) -> Result<()> {
    let arts = Artifacts::load()?;
    let model = args.str_or("model", "small");
    let spec = arts.manifest.model(model)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;
    let cfg = parse_cfg(args)?.with_packed();
    let weights = arts.weights(spec)?;
    let f32_elems: usize = weights.values().map(|t| t.data.len()).sum();
    let calib;
    let calib_ref = if method.needs_calibration() {
        calib = arts.calib(spec)?;
        Some(&calib)
    } else {
        None
    };
    let threads = args.usize_or("threads", 1)?;
    let qm = quantize_model(spec, weights, calib_ref, method, &cfg, threads)?;
    let payload = qm.export_packed()?;
    let out = args
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("{model}_{}_packed.msbt", method.name()));
    msbt::write_file(&out, &payload)?;
    let size = std::fs::metadata(&out)?.len();
    println!(
        "{} {} packed in {:.2}s: {} layers, {:.3} bits/weight (measured), \
         {} bytes on disk ({:.3}x of f32)",
        model,
        method.name(),
        qm.wall_seconds,
        qm.packed.len(),
        qm.packed_effective_bits(),
        size,
        size as f64 / (f32_elems * 4) as f64,
    );
    for (name, pt) in &qm.packed {
        println!(
            "  {:<16} {}x{}  {} code bits  {:.3} bits/weight  {} zero exceptions",
            name,
            pt.rows,
            pt.cols,
            pt.code_bits,
            pt.effective_bits(),
            pt.zeros.len()
        );
    }
    println!("wrote {out}");
    Ok(())
}

/// Reconstruct f32 weights from a packed payload; artifacts not required.
fn cmd_decode(args: &Args) -> Result<()> {
    let input = args.get("in").context("--in <packed.msbt> required")?;
    let threads = args.usize_or("threads", 1)?;
    let map = msbt::read_file(input)?;
    let t0 = Instant::now();
    let decoded = decode_packed_model(&map, threads)?;
    println!(
        "decoded {} tensors from {input} in {:.2}s ({threads} thread(s))",
        decoded.len(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(reference) = args.get("verify") {
        let expect = msbt::read_file(reference)?;
        anyhow::ensure!(
            decoded == expect,
            "decode mismatch: {input} does not reproduce {reference}"
        );
        println!("verify OK: bit-identical to {reference}");
    }
    let out = args.str_or("out", "decoded.msbt");
    msbt::write_file(out, &decoded)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let arts = Artifacts::load()?;
    let model = args.str_or("model", "small");
    let spec = arts.manifest.model(model)?;
    let method = Method::parse(args.str_or("method", "wgm"))?;
    let cfg = parse_cfg(args)?;
    let weights = arts.weights(spec)?;
    let mut runner = ModelRunner::new(&arts.manifest, spec, &weights)?;
    let report = eval_quantized(
        &arts,
        spec,
        &mut runner,
        &weights,
        method,
        &cfg,
        args.usize_or("threads", 1)?,
    )?;
    println!("{}", report.row());
    for (name, v) in &report.ppl {
        println!("  ppl {name}: {v:.3}");
    }
    for (name, v) in &report.qa {
        println!("  qa  {name}: {v:.3}");
    }
    Ok(())
}

fn cmd_kernel() -> Result<()> {
    use msb_quant::quant::{msb::MsbQuantizer, Quantizer};
    let arts = Artifacts::load()?;
    let k = arts
        .manifest
        .msb_kernel_model
        .as_ref()
        .context("no msb_kernel_model in manifest (re-run make artifacts)")?;
    let spec = arts.manifest.model(&k.name)?;
    let weights = arts.weights(spec)?;
    let rt = msb_quant::runtime::Runtime::cpu()?;
    println!("compiling {} (Pallas interpret-mode HLO)...", k.hlo);
    let exe = rt.load_hlo(arts.manifest.path(&k.hlo))?;

    // ABI: tokens, non-quant params (spec order), then (codes, scales) pairs
    let block = arts.manifest.msb_block;
    let cfg = QuantConfig::block_wise(4, block).no_bf16();
    let q = MsbQuantizer::wgm();
    let mut bufs = Vec::new();
    let toks: Vec<i32> = (0..k.batch * spec.seq).map(|i| (i % 90) as i32 + 1).collect();
    bufs.push(rt.upload_i32(&toks, &[k.batch, spec.seq])?);
    for p in &spec.params {
        if !p.quant {
            bufs.push(rt.upload_f32(weights.get(&p.name).unwrap().as_f32()?, &p.shape)?);
        }
    }
    let t0 = Instant::now();
    for p in spec.params.iter().filter(|p| p.quant) {
        let w = weights.get(&p.name).unwrap().to_matrix()?;
        let qt = q.quantize(&w, &cfg);
        let payload = qt.msb.as_ref().unwrap();
        let codes = payload.codes.as_ref().context("codes overflow i8")?;
        bufs.push(rt.upload_i8(codes, &p.shape)?);
        bufs.push(rt.upload_f32(
            &payload.scales,
            &[p.shape[0], p.shape[1] / block, k.levels],
        )?);
    }
    println!("quantized + uploaded in {:.2}s; executing...", t0.elapsed().as_secs_f64());
    let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let t1 = Instant::now();
    let logits = exe.run_buffers(&args)?;
    println!(
        "native MSB forward OK: {} logits in {:.2}s (batch {} x seq {} x vocab {})",
        logits.len(),
        t1.elapsed().as_secs_f64(),
        k.batch,
        spec.seq,
        arts.manifest.vocab
    );
    anyhow::ensure!(logits.iter().all(|v| v.is_finite()), "non-finite logits");
    Ok(())
}
