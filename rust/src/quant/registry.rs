//! Method registry: the single dispatch point from a method identifier to
//! a constructed [`Quantizer`]. Previously this logic lived twice — in
//! `pipeline::Method::build_quantizer` and in `quant::calibration_free_zoo`
//! — and every caller (pipeline, CLI, benches, examples) picked one at
//! random. Now the pipeline, `main.rs`, the bench binaries and the examples
//! all consume this table.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::engine::BlockQuantizer;
use super::{
    gptq::GptqQuantizer, hqq::HqqQuantizer, msb::MsbQuantizer, nf4::Nf4Quantizer,
    rtn::RtnQuantizer, xnor::XnorQuantizer, Quantizer,
};

/// Every method that can appear in a Table-1-style grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full precision (identity) — the FP rows.
    Fp,
    Rtn,
    /// BnB-style NF4 (4-bit block-wise only).
    Bnb,
    Hqq,
    /// Calibration-based; consumes the build-time Gram matrices.
    Gptq,
    /// MSB / Algorithm 3 (the paper's production solver).
    Wgm,
    /// MSB / Algorithm 4 (per-tensor refinement).
    WgmLo,
    /// MSB / Algorithm 2.
    Gg,
    /// MSB / WGM + double quantization of scales (Appendix G).
    WgmDq,
    Xnor,
    BlockedXnor,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp => "fp",
            Method::Rtn => "rtn",
            Method::Bnb => "bnb",
            Method::Hqq => "hqq",
            Method::Gptq => "gptq",
            Method::Wgm => "wgm",
            Method::WgmLo => "wgm-lo",
            Method::Gg => "gg",
            Method::WgmDq => "wgm-dq",
            Method::Xnor => "xnor",
            Method::BlockedXnor => "blocked-xnor",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "fp" => Method::Fp,
            "rtn" => Method::Rtn,
            "bnb" | "nf4" => Method::Bnb,
            "hqq" => Method::Hqq,
            "gptq" => Method::Gptq,
            "wgm" | "msb" => Method::Wgm,
            "wgm-lo" | "wgmlo" => Method::WgmLo,
            "gg" => Method::Gg,
            "wgm-dq" => Method::WgmDq,
            "xnor" => Method::Xnor,
            "blocked-xnor" => Method::BlockedXnor,
            other => anyhow::bail!("unknown method '{other}'"),
        })
    }

    /// The paper's Table 1 grid for a granularity. "/" cells (BnB and GPTQ
    /// per-tensor, WGM-LO block-wise) are omitted exactly as in the paper.
    pub fn table1_grid(per_tensor: bool) -> Vec<Method> {
        if per_tensor {
            vec![Method::Rtn, Method::Hqq, Method::Wgm, Method::WgmLo]
        } else {
            vec![Method::Gptq, Method::Rtn, Method::Bnb, Method::Hqq, Method::Wgm]
        }
    }

    pub fn needs_calibration(&self) -> bool {
        matches!(self, Method::Gptq)
    }
}

/// Build the quantizer for `method`. `gptq` requires the layer Hessian as
/// `(row-major in_dim × in_dim data, in_dim)`; every other method ignores
/// it. `fp` is the identity and has no quantizer.
pub fn build_quantizer(
    method: Method,
    hessian: Option<(&[f32], usize)>,
) -> Result<Box<dyn Quantizer>> {
    Ok(match method {
        Method::Fp => anyhow::bail!("fp is the identity; nothing to build"),
        Method::Rtn => Box::new(RtnQuantizer::symmetric()),
        Method::Bnb => Box::new(Nf4Quantizer::nf4()),
        Method::Hqq => Box::new(HqqQuantizer::default()),
        Method::Gptq => {
            let (h, in_dim) = hessian.context("gptq requires a calibration Hessian")?;
            Box::new(GptqQuantizer::new().with_hessian(h, in_dim))
        }
        Method::Wgm | Method::WgmDq => Box::new(MsbQuantizer::wgm()),
        Method::WgmLo => Box::new(MsbQuantizer::wgm_lo()),
        Method::Gg => Box::new(MsbQuantizer::gg()),
        Method::Xnor => Box::new(XnorQuantizer::whole()),
        Method::BlockedXnor => Box::new(XnorQuantizer::blocked()),
    })
}

/// The engine view of `method` for tile-level scheduling: the
/// [`BlockQuantizer`] whose `quantize_tile` the model-global scheduler
/// (`pipeline`) fans out as `(layer, tile)` jobs. `None` for methods that
/// are not block-partitionable (GPTQ's column-sequential error
/// propagation) or have no quantizer at all (FP). Must stay consistent
/// with [`build_quantizer`]: the returned instance is the same type the
/// boxed `Quantizer` wires to the engine drivers, so tiled scheduling is
/// bit-identical to `quantize_with_pool`.
pub fn block_quantizer(method: Method) -> Option<Arc<dyn BlockQuantizer>> {
    Some(match method {
        Method::Fp | Method::Gptq => return None,
        Method::Rtn => Arc::new(RtnQuantizer::symmetric()),
        Method::Bnb => Arc::new(Nf4Quantizer::nf4()),
        Method::Hqq => Arc::new(HqqQuantizer::default()),
        Method::Wgm | Method::WgmDq => Arc::new(MsbQuantizer::wgm()),
        Method::WgmLo => Arc::new(MsbQuantizer::wgm_lo()),
        Method::Gg => Arc::new(MsbQuantizer::gg()),
        Method::Xnor => Arc::new(XnorQuantizer::whole()),
        Method::BlockedXnor => Arc::new(XnorQuantizer::blocked()),
    })
}

/// Resolve a packed payload's `method` string (a `BlockQuantizer::name()`)
/// to the quantizer whose `decode_block` reconstructs it. Every MSB solver
/// shares one decode (sign · scale gather), so any `msb-*` name maps to
/// the WGM instance.
pub fn block_decoder(method: &str) -> Result<Arc<dyn BlockQuantizer>> {
    Ok(match method {
        "rtn" => Arc::new(RtnQuantizer::symmetric()),
        "rtn-asym" => Arc::new(RtnQuantizer::asymmetric()),
        "bnb-nf4" => Arc::new(Nf4Quantizer::nf4()),
        "bnb-fp4" => Arc::new(Nf4Quantizer::fp4()),
        "hqq" => Arc::new(HqqQuantizer::default()),
        "xnor" => Arc::new(XnorQuantizer::whole()),
        "blocked-xnor" => Arc::new(XnorQuantizer::blocked()),
        m if m.starts_with("msb-") => Arc::new(MsbQuantizer::wgm()),
        other => anyhow::bail!("no packed decoder for method '{other}'"),
    })
}

/// The calibration-free method zoo (GPTQ is constructed separately with its
/// Hessian). Order matches the paper's tables.
pub fn calibration_free_zoo() -> Vec<Box<dyn Quantizer>> {
    [Method::Rtn, Method::Bnb, Method::Hqq, Method::Wgm]
        .into_iter()
        .map(|m| build_quantizer(m, None).expect("calibration-free build"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_paper_methods() {
        let names: Vec<_> = calibration_free_zoo().iter().map(|q| q.name()).collect();
        assert_eq!(names, vec!["rtn", "bnb-nf4", "hqq", "msb-wgm"]);
    }

    #[test]
    fn build_dispatches_every_method() {
        let h = vec![1.0f32, 0.0, 0.0, 1.0];
        for (m, want) in [
            (Method::Rtn, "rtn"),
            (Method::Bnb, "bnb-nf4"),
            (Method::Hqq, "hqq"),
            (Method::Wgm, "msb-wgm"),
            (Method::WgmDq, "msb-wgm"),
            (Method::WgmLo, "msb-wgm-lo"),
            (Method::Gg, "msb-gg"),
            (Method::Xnor, "xnor"),
            (Method::BlockedXnor, "blocked-xnor"),
        ] {
            assert_eq!(build_quantizer(m, Some((&h, 2))).unwrap().name(), want);
        }
    }

    #[test]
    fn gptq_requires_hessian_fp_unbuildable() {
        assert!(build_quantizer(Method::Gptq, None).is_err());
        assert!(build_quantizer(Method::Fp, None).is_err());
        let h = vec![1.0f32; 4];
        assert_eq!(build_quantizer(Method::Gptq, Some((&h, 2))).unwrap().name(), "gptq");
    }

    #[test]
    fn block_decoder_resolves_packable_methods() {
        for name in
            ["rtn", "rtn-asym", "bnb-nf4", "bnb-fp4", "hqq", "xnor", "blocked-xnor", "msb-wgm"]
        {
            let d = block_decoder(name).unwrap();
            if name.starts_with("msb-") {
                assert!(d.name().starts_with("msb-"));
            } else {
                assert_eq!(d.name(), name);
            }
        }
        assert!(block_decoder("gptq").is_err());
        assert!(block_decoder("zero").is_err());
    }

    /// The scheduler relies on `block_quantizer` agreeing with
    /// `build_quantizer` method-for-method — a mismatch would silently
    /// change results between the tiled and whole-layer paths.
    #[test]
    fn block_quantizer_consistent_with_build() {
        for m in [
            Method::Rtn,
            Method::Bnb,
            Method::Hqq,
            Method::Wgm,
            Method::WgmDq,
            Method::WgmLo,
            Method::Gg,
            Method::Xnor,
            Method::BlockedXnor,
        ] {
            let bq = block_quantizer(m).unwrap_or_else(|| panic!("{m:?} must tile"));
            let boxed = build_quantizer(m, None).unwrap();
            assert_eq!(bq.name(), boxed.name(), "{m:?}");
        }
        assert!(block_quantizer(Method::Fp).is_none());
        assert!(block_quantizer(Method::Gptq).is_none());
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::Fp,
            Method::Rtn,
            Method::Bnb,
            Method::Hqq,
            Method::Gptq,
            Method::Wgm,
            Method::WgmLo,
            Method::Gg,
            Method::WgmDq,
            Method::Xnor,
            Method::BlockedXnor,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }
}
