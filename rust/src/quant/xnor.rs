//! XNOR-Net binarization (Rastegari et al. 2016) and its blocked variant —
//! the 1-bit ancestors MSB generalizes, plus the all-zero dummy baseline
//! from the Fig 2/3 ablations.
//!
//! Closed form (eq. 1): B* = sign(W), α* = ‖W‖₁/|W|.

use super::engine::{impl_quantizer_via_engine, BlockMeta, BlockPlan, BlockQuantizer};
use super::packing::{CodeScheme, PackSpec};
use super::{Granularity, QuantConfig};

#[derive(Clone, Debug)]
pub struct XnorQuantizer {
    /// Per-block α instead of a single whole-tensor α (BLOCKED-XNOR).
    pub blocked: bool,
}

impl XnorQuantizer {
    pub fn whole() -> Self {
        XnorQuantizer { blocked: false }
    }

    pub fn blocked() -> Self {
        XnorQuantizer { blocked: true }
    }

    /// Binarize one block; returns `(α, sign codes)` with codes collected
    /// only when `emit`.
    fn binarize(block: &[f32], out: &mut [f32], emit: bool) -> (f32, Vec<i8>) {
        let n = block.len() as f64;
        let alpha = (block.iter().map(|&v| v.abs() as f64).sum::<f64>() / n) as f32;
        let mut codes = Vec::with_capacity(if emit { block.len() } else { 0 });
        for (o, &v) in out.iter_mut().zip(block) {
            *o = if v == 0.0 {
                0.0 // zero-loss special group, consistent with MSB
            } else {
                alpha * v.signum()
            };
            if emit {
                let c = if v == 0.0 { 0i8 } else { v.signum() as i8 };
                codes.push(c);
            }
        }
        (alpha, codes)
    }
}

impl BlockQuantizer for XnorQuantizer {
    fn name(&self) -> &'static str {
        if self.blocked {
            "blocked-xnor"
        } else {
            "xnor"
        }
    }

    /// Whole-tensor XNOR is one instance regardless of granularity; the
    /// blocked variant follows the config (per-tensor degrades to one
    /// α per row) with legacy flat chunking, so the Fig 2–5 sweeps can run
    /// matrices smaller than the block size.
    fn plan(&self, rows: usize, cols: usize, cfg: &QuantConfig) -> BlockPlan {
        if self.blocked {
            match cfg.granularity {
                Granularity::BlockWise { t } => BlockPlan::flat(rows, cols, t),
                Granularity::PerTensor => BlockPlan::flat(rows, cols, cols),
            }
        } else {
            BlockPlan::per_tensor(rows, cols)
        }
    }

    fn quantize_block(&self, data: &[f32], out: &mut [f32], cfg: &QuantConfig) -> BlockMeta {
        let emit = cfg.emit_packed;
        let (alpha, codes) = Self::binarize(data, out, emit);
        let mut meta = BlockMeta::default();
        if emit {
            meta.scales.push(alpha);
            meta.codes = Some(codes);
        }
        meta
    }

    /// Sign bit + one bf16 α per block.
    fn effective_bits(&self, _cfg: &QuantConfig, plan: &BlockPlan) -> f64 {
        1.0 + 16.0 / plan.block as f64
    }

    /// One sign bit per element (±α); exact zeros ride the exception
    /// list. Stored at nibble granularity on disk.
    fn pack_spec(&self, _cfg: &QuantConfig) -> Option<PackSpec> {
        Some(PackSpec {
            code_bits: 1,
            scheme: CodeScheme::SignLevel,
            scales_per_block: 1,
            f32_scales: false,
        })
    }

    fn decode_block(&self, codes: &[i8], scales: &[f32], out: &mut [f32]) {
        let alpha = scales[0];
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = if c == 0 {
                0.0
            } else if c < 0 {
                -alpha
            } else {
                alpha
            };
        }
    }
}

impl_quantizer_via_engine!(XnorQuantizer);

/// All-zero "quantizer" — the dummy floor in Fig 2/3.
#[derive(Clone, Debug)]
pub struct ZeroQuantizer;

impl BlockQuantizer for ZeroQuantizer {
    fn name(&self) -> &'static str {
        "zero"
    }

    fn quantize_block(&self, _data: &[f32], out: &mut [f32], _cfg: &QuantConfig) -> BlockMeta {
        out.fill(0.0);
        BlockMeta::default()
    }

    fn effective_bits(&self, _cfg: &QuantConfig, _plan: &BlockPlan) -> f64 {
        0.0
    }
}

impl_quantizer_via_engine!(ZeroQuantizer);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msb::{Algo, Solver};
    use crate::quant::Quantizer;
    use crate::stats::Rng;
    use crate::tensor::Matrix;

    #[test]
    fn closed_form_alpha() {
        let w = Matrix::from_vec(1, 4, vec![1.0, -2.0, 3.0, -4.0]);
        let q = XnorQuantizer::whole().quantize(&w, &QuantConfig::per_tensor(1).unwrap().no_bf16());
        assert_eq!(q.dequant.data, vec![2.5, -2.5, 2.5, -2.5]);
    }

    #[test]
    fn xnor_error_equals_identity() {
        // ‖A − αB‖² = ‖A‖² − ‖A‖₁²/|A| (paper §3.2) — for zero-free input
        let mut rng = Rng::new(1);
        let mut w = Matrix::randn(8, 32, &mut rng);
        for v in &mut w.data {
            if *v == 0.0 {
                *v = 0.1;
            }
        }
        let q = XnorQuantizer::whole().quantize(&w, &QuantConfig::per_tensor(1).unwrap().no_bf16());
        let n = w.len() as f64;
        let l1: f64 = w.data.iter().map(|&v| v.abs() as f64).sum();
        let l2: f64 = w.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
        crate::testing::assert_close(q.mse(&w), l2 - l1 * l1 / n, 1e-6, 1e-9);
    }

    #[test]
    fn blocked_no_worse_than_whole() {
        let mut rng = Rng::new(2);
        let mut w = Matrix::randn(8, 256, &mut rng);
        for (i, v) in w.data.iter_mut().enumerate() {
            *v *= 1.0 + (i / 256) as f32;
        }
        let cfg = QuantConfig::block_wise(1, 64).unwrap().no_bf16();
        let whole = XnorQuantizer::whole().quantize(&w, &cfg);
        let blocked = XnorQuantizer::blocked().quantize(&w, &cfg);
        assert!(blocked.mse(&w) <= whole.mse(&w));
    }

    #[test]
    fn msb_single_group_equals_xnor() {
        // MSB with one group degenerates to XNOR — the conceptual link the
        // paper builds on
        let mut rng = Rng::new(3);
        let w = Matrix::randn(4, 32, &mut rng);
        let xnor = XnorQuantizer::whole().quantize(&w, &QuantConfig::per_tensor(1).unwrap().no_bf16());
        let code = Solver::new(Algo::Gg).quantize(&w.data, 1);
        let msb = code.dequantize();
        for (a, b) in xnor.dequant.data.iter().zip(&msb) {
            crate::testing::assert_close(*a as f64, *b as f64, 1e-5, 1e-7);
        }
    }

    #[test]
    fn zero_dummy_is_worst() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(8, 64, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let zero = ZeroQuantizer.quantize(&w, &cfg);
        let xnor = XnorQuantizer::whole().quantize(&w, &cfg);
        assert!(zero.mse(&w) > xnor.mse(&w));
        crate::testing::assert_close(zero.mse(&w), w.fro_norm().powi(2), 1e-9, 1e-9);
    }
}
