//! The paper's quantizer: MSB via dynamic grouping, wired to the [`crate::msb`]
//! solvers for both granularities.
//!
//! * per-tensor (6-bit): one solve over all non-zero magnitudes,
//!   2^{b-1} groups, default window 64;
//! * block-wise (4-bit): an independent solve per `t`-element row block
//!   (default t=64, window 1), 8 scales per block.
//!
//! Storage accounting (paper §4.1): codes are `b` bits, scales bf16 →
//! block-wise effective bits = b + L·16/t (6.00 bits/weight at b=4, L=8,
//! t=64); per-tensor metadata is negligible.

use crate::msb::{Algo, MsbCode, Solver};

use super::engine::{impl_quantizer_via_engine, BlockMeta, BlockPlan, BlockQuantizer, TileMeta};
use super::packing::{CodeScheme, PackSpec};
use super::{Granularity, QuantConfig};

/// Which solver backs the quantizer (WGM window comes from the config).
#[derive(Clone, Debug, PartialEq)]
pub enum MsbAlgo {
    Dg,
    Gg,
    Wgm,
    WgmLo { bins: usize, range: usize, max_iters: usize, patience: usize },
}

#[derive(Clone, Debug)]
pub struct MsbQuantizer {
    pub algo: MsbAlgo,
    /// §3.4 group-mass normalization of the variance term.
    pub normalized: bool,
}

impl MsbQuantizer {
    /// Algorithm 3 — the paper's production solver.
    pub fn wgm() -> Self {
        MsbQuantizer { algo: MsbAlgo::Wgm, normalized: false }
    }

    /// Algorithm 2.
    pub fn gg() -> Self {
        MsbQuantizer { algo: MsbAlgo::Gg, normalized: false }
    }

    /// Algorithm 1 (oracle; small instances only).
    pub fn dg() -> Self {
        MsbQuantizer { algo: MsbAlgo::Dg, normalized: false }
    }

    /// Algorithm 4 with the paper's defaults (T=12, k=256 bins).
    pub fn wgm_lo() -> Self {
        MsbQuantizer {
            algo: MsbAlgo::WgmLo { bins: 256, range: 32, max_iters: 12, patience: 3 },
            normalized: false,
        }
    }

    fn solver(&self, cfg: &QuantConfig) -> Solver {
        let algo = match &self.algo {
            MsbAlgo::Dg => Algo::Dg,
            MsbAlgo::Gg => Algo::Gg,
            MsbAlgo::Wgm => Algo::Wgm { window: cfg.window.max(1) },
            MsbAlgo::WgmLo { bins, range, max_iters, patience } => Algo::WgmLo {
                bins: *bins,
                range: *range,
                max_iters: *max_iters,
                patience: *patience,
            },
        };
        // cfg.lambda is λ̃ — the per-instance Λ map happens at solve time
        let mut s = Solver::new(algo);
        if self.normalized {
            s = s.normalized();
        }
        s
    }

    /// Quantize a single flat block, returning its code (handles all-zero
    /// blocks by emitting a zero codebook). `tilde` is mapped through the
    /// Appendix-C Λ for this instance's magnitude range.
    fn block_code(&self, solver: &Solver, data: &[f32], levels: usize, tilde: f64) -> MsbCode {
        let sm = crate::msb::SortedMags::from_values(data);
        if sm.is_empty() {
            return MsbCode { n: data.len(), levels: vec![0.0], codes: vec![0; data.len()] };
        }
        let lam = crate::msb::lambda::lambda_of(tilde, &sm.mags);
        let grouping = solver.clone().with_lambda(lam).solve_sorted(&sm, levels);
        MsbCode::build(data, &sm, &grouping)
    }

    /// The production WGM/GG block window, when the allocation-free tile
    /// path applies; DG / WGM-LO go through the generic solver.
    fn fast_window(&self, cfg: &QuantConfig) -> Option<usize> {
        match &self.algo {
            MsbAlgo::Wgm => Some(cfg.window.max(1)),
            MsbAlgo::Gg => Some(1),
            _ => None,
        }
    }

    /// Allocation-free block-wise WGM path (§Perf): reuses the sort,
    /// prefix-sum and merge workspaces across every block of the tile and
    /// writes scales/codes/dequant directly into the output buffers. The
    /// merge itself dispatches to the flat scan kernel for block-sized
    /// instances (`msb::gg::SCAN_KERNEL_MAX`) — bit-identical to the heap,
    /// ablated in `benches/perf_hotpath.rs`. Semantically identical to the
    /// generic path (asserted by tests).
    fn quantize_tile_fast(
        &self,
        data: &[f32],
        t: usize,
        window: usize,
        levels: usize,
        out: &mut [f32],
        meta: &mut TileMeta,
    ) {
        use crate::msb::gg::{greedy_merge_ws, MergeWorkspace};
        use crate::msb::objective::{CostParams, Prefix, SortedMags};

        let mut sm = SortedMags::default();
        let mut prefix = Prefix::default();
        let mut ws = MergeWorkspace::default();
        let mut bounds: Vec<usize> = Vec::new();
        let win = window.max(1);
        let scales = &mut meta.scales;
        let codes = meta.codes.as_mut().expect("fast tile path requires i8 codes");

        for (bi, blk) in data.chunks_exact(t).enumerate() {
            let base = bi * t;
            sm.rebuild(blk);
            let n = sm.len();
            if n == 0 {
                out[base..base + t].fill(0.0);
                scales.resize(scales.len() + levels, 0.0);
                codes.resize(codes.len() + t, 0);
                continue;
            }
            prefix.rebuild(&sm.mags);
            // Appendix C: λ is inapplicable to fixed-group-count greedy
            // solvers — merge on pure variance (mirrors Solver::solve_with_prefix)
            let params = CostParams { lambda: 0.0, normalized: self.normalized, total: n };
            // window-k initial partition, streamed without allocation
            let n_init = n.div_ceil(win);
            greedy_merge_ws(
                &mut ws,
                &prefix,
                (0..n_init).map(|i| (i * win, ((i + 1) * win).min(n))),
                levels,
                &params,
                &mut bounds,
            );
            let g = bounds.len();
            debug_assert!(g <= levels && g <= 127);

            // per-group scales (ascending by construction), padded to L
            let scale_base = scales.len();
            let mut s = 0usize;
            for &e in &bounds {
                scales.push(prefix.mean(s, e) as f32);
                s = e;
            }
            let last = scales[scale_base + g - 1];
            scales.resize(scale_base + levels, last);

            // codes + dequant straight from the grouping
            let code_base = codes.len();
            codes.resize(code_base + t, 0);
            out[base..base + t].fill(0.0);
            let mut s = 0usize;
            for (k, &e) in bounds.iter().enumerate() {
                let mag = scales[scale_base + k];
                for pos in s..e {
                    let orig = sm.order[pos] as usize;
                    let neg = blk[orig] < 0.0;
                    codes[code_base + orig] = if neg { -(k as i8 + 1) } else { k as i8 + 1 };
                    out[base + orig] = if neg { -mag } else { mag };
                }
                s = e;
            }
        }
    }
}

impl BlockQuantizer for MsbQuantizer {
    fn name(&self) -> &'static str {
        match self.algo {
            MsbAlgo::Dg => "msb-dg",
            MsbAlgo::Gg => "msb-gg",
            MsbAlgo::Wgm => "msb-wgm",
            MsbAlgo::WgmLo { .. } => "msb-wgm-lo",
        }
    }

    /// Generic single-block path (per-tensor instances, DG / WGM-LO blocks,
    /// and >i8 level counts).
    fn quantize_block(&self, data: &[f32], out: &mut [f32], cfg: &QuantConfig) -> BlockMeta {
        let solver = self.solver(cfg);
        let levels = cfg.levels();
        let code = self.block_code(&solver, data, levels, cfg.lambda);
        code.dequantize_into(out);
        BlockMeta { scales: code.levels_padded(levels), codes: code.codes_i8() }
    }

    /// Block-wise WGM/GG tiles take the allocation-free workspace path;
    /// everything else falls back to the per-block generic solver.
    fn quantize_tile(
        &self,
        data: &[f32],
        block: usize,
        out: &mut [f32],
        cfg: &QuantConfig,
    ) -> TileMeta {
        let levels = cfg.levels();
        let blockwise = matches!(cfg.granularity, Granularity::BlockWise { .. });
        let mut meta = TileMeta::new();
        if let Some(win) = self.fast_window(cfg) {
            if blockwise && levels <= 127 {
                meta.scales.reserve(data.len() / block * levels);
                if let Some(codes) = meta.codes.as_mut() {
                    codes.reserve(data.len());
                }
                self.quantize_tile_fast(data, block, win, levels, out, &mut meta);
                return meta;
            }
        }
        for (blk, o) in data.chunks(block).zip(out.chunks_mut(block)) {
            meta.push(self.quantize_block(blk, o, cfg));
        }
        meta
    }

    /// Paper §4.1: b-bit codes + L bf16 scales per block (block-wise), or
    /// one L-entry table amortized over the tensor (per-tensor).
    fn effective_bits(&self, cfg: &QuantConfig, plan: &BlockPlan) -> f64 {
        super::packing::msb_effective_bits(
            cfg.bits,
            cfg.levels(),
            plan.payload_block(),
            plan.rows * plan.cols,
            plan.per_tensor,
        )
    }

    fn emits_msb_payload(&self) -> bool {
        true
    }

    /// Sign bit + ⌈log₂ L⌉ level bits (b bits total at L = 2^{b-1});
    /// exact zeros ride the exception list. Level counts beyond i8 (large
    /// per-tensor settings) have no exportable codes.
    fn pack_spec(&self, cfg: &QuantConfig) -> Option<PackSpec> {
        let levels = cfg.levels();
        if levels > 127 {
            return None;
        }
        let level_bits = levels.next_power_of_two().trailing_zeros();
        Some(PackSpec {
            code_bits: 1 + level_bits,
            scheme: CodeScheme::SignLevel,
            scales_per_block: levels,
            f32_scales: false,
        })
    }

    /// `ŵ = sign(c) · α_{|c|-1}` — the kernel decode, same math as
    /// [`crate::msb::MsbCode::dequantize_into`].
    fn decode_block(&self, codes: &[i8], scales: &[f32], out: &mut [f32]) {
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = if c == 0 {
                0.0
            } else {
                let mag = scales[(c.unsigned_abs() as usize) - 1];
                if c < 0 {
                    -mag
                } else {
                    mag
                }
            };
        }
    }
}

impl_quantizer_via_engine!(MsbQuantizer);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Quantizer;
    use crate::stats::Rng;
    use crate::tensor::Matrix;

    fn weight(rows: usize, cols: usize, seed: u64) -> Matrix {
        Matrix::randn(rows, cols, &mut Rng::new(seed))
    }

    #[test]
    fn block_wise_shapes() {
        let w = weight(8, 128, 1);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let q = MsbQuantizer::wgm().quantize(&w, &cfg);
        assert_eq!(q.dequant.rows, 8);
        let p = q.msb.unwrap();
        assert_eq!(p.levels, 8);
        assert_eq!(p.scales.len(), (8 * 128 / 64) * 8);
        assert_eq!(p.codes.unwrap().len(), 8 * 128);
    }

    #[test]
    fn per_tensor_uses_single_instance() {
        let w = weight(16, 64, 2);
        let cfg = QuantConfig::per_tensor(6).unwrap().no_bf16();
        let q = MsbQuantizer::wgm().quantize(&w, &cfg);
        let p = q.msb.unwrap();
        assert_eq!(p.scales.len(), 32);
        assert_eq!(p.block, 64); // per-tensor payload stripe = cols
    }

    #[test]
    fn more_bits_less_error() {
        let w = weight(16, 256, 3);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 6] {
            let cfg = QuantConfig::block_wise(bits, 64).unwrap().no_bf16();
            let q = MsbQuantizer::wgm().quantize(&w, &cfg);
            let e = q.mse(&w);
            assert!(e < last, "bits {bits}: {e} !< {last}");
            last = e;
        }
    }

    #[test]
    fn wgm_beats_coarse_window_blockwise() {
        let w = weight(32, 512, 4);
        let fine = MsbQuantizer::wgm()
            .quantize(&w, &QuantConfig::block_wise(4, 64).unwrap().with_window(1).unwrap().no_bf16());
        let coarse = MsbQuantizer::wgm()
            .quantize(&w, &QuantConfig::block_wise(4, 64).unwrap().with_window(32).unwrap().no_bf16());
        assert!(fine.mse(&w) <= coarse.mse(&w) + 1e-9);
    }

    #[test]
    fn effective_bits_paper_values() {
        let w = weight(8, 128, 5);
        // 4-bit block-wise t=64: 4 + 8*16/64 = 6.00 bits/weight (paper §4.1)
        let q = MsbQuantizer::wgm().quantize(&w, &QuantConfig::block_wise(4, 64).unwrap());
        crate::testing::assert_close(q.effective_bits, 6.0, 1e-12, 0.0);
        // per-tensor metadata negligible
        let q6 = MsbQuantizer::wgm().quantize(&w, &QuantConfig::per_tensor(6).unwrap());
        assert!(q6.effective_bits < 6.6);
    }

    #[test]
    fn zeros_stay_zero() {
        let mut w = weight(4, 64, 6);
        w.data[5] = 0.0;
        w.data[100] = 0.0;
        let q = MsbQuantizer::wgm().quantize(&w, &QuantConfig::block_wise(4, 64).unwrap());
        assert_eq!(q.dequant.data[5], 0.0);
        assert_eq!(q.dequant.data[100], 0.0);
    }

    #[test]
    fn all_zero_matrix_ok() {
        let w = Matrix::zeros(4, 64);
        let q = MsbQuantizer::wgm().quantize(&w, &QuantConfig::block_wise(4, 64).unwrap());
        assert_eq!(q.mse(&w), 0.0);
    }

    #[test]
    fn solvers_agree_on_structure() {
        let w = weight(4, 64, 7);
        let cfg = QuantConfig::block_wise(3, 64).unwrap().no_bf16();
        for q in [MsbQuantizer::gg(), MsbQuantizer::wgm(), MsbQuantizer::wgm_lo()] {
            let out = q.quantize(&w, &cfg);
            // signs must always be preserved
            for (a, b) in w.data.iter().zip(&out.dequant.data) {
                if *a != 0.0 && *b != 0.0 {
                    assert_eq!(a.signum(), b.signum());
                }
            }
        }
    }

    #[test]
    fn fast_tile_path_matches_generic() {
        // §Perf fast path must be semantically identical to the generic
        // per-block solver for every window / bits combination
        let w = weight(16, 256, 99);
        for (bits, win) in [(4u32, 1usize), (4, 8), (3, 2), (2, 1)] {
            let cfg = QuantConfig::block_wise(bits, 64).unwrap().with_window(win).unwrap().no_bf16();
            let q = MsbQuantizer::wgm();
            let fast = q.quantize(&w, &cfg); // engine serial → fast tile
            // generic path: replicate per block via the single-block API
            let mut dequant = Matrix::zeros(w.rows, w.cols);
            let mut scales = Vec::new();
            let mut codes = Vec::new();
            for (bi, blk) in w.row_blocks(64).enumerate() {
                let out = &mut dequant.data[bi * 64..(bi + 1) * 64];
                let meta = q.quantize_block(blk, out, &cfg);
                scales.extend(meta.scales);
                codes.extend(meta.codes.unwrap());
            }
            assert_eq!(fast.dequant.data, dequant.data, "bits {bits} win {win}");
            let p = fast.msb.unwrap();
            assert_eq!(p.scales, scales);
            assert_eq!(p.codes.unwrap(), codes);
        }
    }

    #[test]
    fn fast_block_path_zero_blocks() {
        let mut w = Matrix::zeros(2, 128);
        w.data[70] = 1.5; // second block of row 0 has one value
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let q = MsbQuantizer::wgm().quantize(&w, &cfg);
        assert_eq!(q.mse(&w), 0.0); // exact: single value gets its own scale
        let p = q.msb.unwrap();
        assert_eq!(&p.scales[..8], &[0.0; 8]); // all-zero block
        assert_eq!(p.codes.as_ref().unwrap()[70], 1);
    }

    #[test]
    fn dg_oracle_beats_wgm_blockwise() {
        let w = weight(2, 128, 8);
        let cfg = QuantConfig::block_wise(3, 64).unwrap().no_bf16().with_lambda(0.0);
        let dg = MsbQuantizer::dg().quantize(&w, &cfg);
        let wgm = MsbQuantizer::wgm().quantize(
            &w,
            &QuantConfig::block_wise(3, 64).unwrap().with_window(8).unwrap().no_bf16().with_lambda(0.0),
        );
        assert!(dg.mse(&w) <= wgm.mse(&w) + 1e-9);
    }
}
