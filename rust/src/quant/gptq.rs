//! GPTQ (Frantar et al. 2022) — the paper's calibration-*based* baseline:
//! column-by-column quantization with second-order error compensation.
//!
//! Consumes a layer Hessian H = XᵀX accumulated from calibration
//! activations (built at artifact time by python/compile/aot.py, shipped in
//! `{model}_calib.msbt`). Algorithm (standard GPTQ):
//!
//! 1. damp: H += ε·mean(diag H)·I
//! 2. U = chol(H⁻¹) upper-triangular (here: Lᵀ of the lower Cholesky)
//! 3. for each column j: quantize w_j on the running grid, propagate
//!    err = (w_j − q_j)/U_jj into columns j+1.. via U_{j,j+1..}
//!
//! Grid: symmetric absmax per (row, group of `t` columns), refreshed at
//! group boundaries from the *updated* weights — matching GPTQ's
//! group_size behaviour.

use crate::la::SquareMat;
use crate::tensor::Matrix;

use super::{finish_dequant, Granularity, QuantConfig, QuantizedTensor, Quantizer};

#[derive(Clone, Debug)]
pub struct GptqQuantizer {
    /// Hessian damping fraction (GPTQ default 0.01).
    pub percdamp: f64,
    hessian: Option<SquareMat>,
}

impl GptqQuantizer {
    pub fn new() -> Self {
        GptqQuantizer { percdamp: 0.01, hessian: None }
    }

    /// Attach the calibration Hessian (in-dim × in-dim, f32 row-major).
    pub fn with_hessian(mut self, h_data: &[f32], in_dim: usize) -> Self {
        assert_eq!(h_data.len(), in_dim * in_dim);
        self.hessian = Some(SquareMat::from_vec(
            in_dim,
            h_data.iter().map(|&x| x as f64).collect(),
        ));
        self
    }

    /// Identity-Hessian fallback (degenerates to RTN with compensation off).
    fn hessian_or_identity(&self, n: usize) -> SquareMat {
        match &self.hessian {
            Some(h) => {
                assert_eq!(h.n, n, "Hessian dim {} != in-dim {n}", h.n);
                h.clone()
            }
            None => SquareMat::identity(n),
        }
    }
}

impl Default for GptqQuantizer {
    fn default() -> Self {
        Self::new()
    }
}

/// Symmetric grid snap.
#[inline]
fn snap(v: f32, scale: f32, qmax: f32) -> f32 {
    if scale == 0.0 {
        return 0.0;
    }
    (v / scale).round().clamp(-qmax, qmax) * scale
}

impl Quantizer for GptqQuantizer {
    fn name(&self) -> &'static str {
        "gptq"
    }

    fn needs_calibration(&self) -> bool {
        true
    }

    fn quantize(&self, w: &Matrix, cfg: &QuantConfig) -> QuantizedTensor {
        let (rows, cols) = (w.rows, w.cols);
        let group = match cfg.granularity {
            Granularity::PerTensor => cols,
            Granularity::BlockWise { t } => t.min(cols),
        };
        assert!(cols % group == 0);
        let qmax = ((1i64 << (cfg.bits - 1)) - 1) as f32;

        // damped Hessian → inverse → upper Cholesky of the inverse
        let mut h = self.hessian_or_identity(cols);
        // dead columns (zero diag) must not stall the grid
        for j in 0..cols {
            if h.at(j, j) == 0.0 {
                h.set(j, j, 1.0);
            }
        }
        h.add_diag(self.percdamp * h.mean_diag() + 1e-8);
        let hinv = h.inverse_pd().expect("damped Hessian must be PD");
        let l = hinv.cholesky().expect("H^-1 PD");
        // U = Lᵀ: U[j][k] for k >= j is l.at(k, j)

        let mut work = w.data.clone(); // running (compensated) weights
        let mut dequant = vec![0.0f32; rows * cols];
        let mut scales = vec![0.0f32; rows]; // per-row scale of current group

        for j in 0..cols {
            if j % group == 0 {
                // refresh per-row absmax scales from the *updated* weights
                for (r, s) in scales.iter_mut().enumerate() {
                    let seg = &work[r * cols + j..r * cols + (j + group).min(cols)];
                    let absmax = seg.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    *s = absmax / qmax;
                }
            }
            let ujj = l.at(j, j);
            for r in 0..rows {
                let wj = work[r * cols + j];
                let q = snap(wj, scales[r], qmax);
                dequant[r * cols + j] = q;
                let err = (wj - q) as f64 / ujj;
                // propagate into remaining columns
                let row = &mut work[r * cols..(r + 1) * cols];
                for k in (j + 1)..cols {
                    row[k] -= (err * l.at(k, j)) as f32;
                }
            }
        }

        QuantizedTensor {
            method: self.name().to_string(),
            rows,
            cols,
            dequant: finish_dequant(Matrix::from_vec(rows, cols, dequant), cfg),
            effective_bits: super::packing::uniform_effective_bits(cfg.bits, group, false),
            msb: None,
            // column-sequential error propagation has no block-local codes
            packed: None,
        }
    }
}

/// Layer-output proxy loss: tr((W−Q) H (W−Q)ᵀ) — what GPTQ actually
/// minimizes; used by tests and the e2e comparison.
pub fn hessian_loss(w: &Matrix, q: &Matrix, h: &SquareMat) -> f64 {
    assert_eq!(w.cols, h.n);
    let mut total = 0.0f64;
    let n = w.cols;
    let mut diff = vec![0.0f64; n];
    for r in 0..w.rows {
        for c in 0..n {
            diff[c] = (w.at(r, c) - q.at(r, c)) as f64;
        }
        // dᵀ H d
        for i in 0..n {
            let di = diff[i];
            if di == 0.0 {
                continue;
            }
            let row = &h.a[i * n..(i + 1) * n];
            let mut acc = 0.0;
            for (dj, hij) in diff.iter().zip(row) {
                acc += dj * hij;
            }
            total += di * acc;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::RtnQuantizer;
    use crate::stats::Rng;

    /// Random Gram matrix H = XᵀX from synthetic "activations".
    fn gram(in_dim: usize, samples: usize, seed: u64) -> SquareMat {
        let mut rng = Rng::new(seed);
        let x: Vec<f64> = (0..samples * in_dim).map(|_| rng.normal()).collect();
        let mut h = SquareMat::zeros(in_dim);
        for s in 0..samples {
            let row = &x[s * in_dim..(s + 1) * in_dim];
            for i in 0..in_dim {
                for j in 0..in_dim {
                    h.a[i * in_dim + j] += row[i] * row[j];
                }
            }
        }
        h
    }

    #[test]
    fn beats_rtn_on_hessian_loss() {
        // the whole point of GPTQ: lower tr(ΔH Δᵀ) than naive rounding
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 64, &mut rng);
        let h = gram(64, 256, 2);
        let hf: Vec<f32> = h.a.iter().map(|&x| x as f32).collect();
        let cfg = QuantConfig::block_wise(3, 64).unwrap().no_bf16();
        let gptq = GptqQuantizer::new().with_hessian(&hf, 64).quantize(&w, &cfg);
        let rtn = RtnQuantizer::symmetric().quantize(&w, &cfg);
        let lg = hessian_loss(&w, &gptq.dequant, &h);
        let lr = hessian_loss(&w, &rtn.dequant, &h);
        assert!(lg < lr, "gptq {lg} !< rtn {lr}");
    }

    #[test]
    fn identity_hessian_close_to_rtn() {
        // with H = I there is nothing to compensate into: first column of
        // each group equals RTN exactly; overall error stays comparable
        let mut rng = Rng::new(3);
        let w = Matrix::randn(8, 64, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let gptq = GptqQuantizer::new().quantize(&w, &cfg);
        let rtn = RtnQuantizer::symmetric().quantize(&w, &cfg);
        assert!(gptq.mse(&w) <= rtn.mse(&w) * 1.5);
    }

    #[test]
    fn group_refresh_happens() {
        // per-group scales: a matrix whose second block is 10x larger must
        // not smear the first block's grid
        let mut rng = Rng::new(4);
        let mut w = Matrix::randn(4, 128, &mut rng);
        for v in &mut w.data[64 * 4 - 256..] {
            *v *= 10.0;
        }
        let err_on = |q: &QuantizedTensor| -> f64 {
            w.data[..64]
                .iter()
                .zip(&q.dequant.data[..64])
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum()
        };
        // blockwise group refresh isolates the first block's grid from the
        // inflated second block; per-tensor grouping smears it
        let bw = GptqQuantizer::new().quantize(&w, &QuantConfig::block_wise(4, 64).unwrap().no_bf16());
        let pt = GptqQuantizer::new().quantize(&w, &QuantConfig::per_tensor(4).unwrap().no_bf16());
        assert!(err_on(&bw) < err_on(&pt), "{} !< {}", err_on(&bw), err_on(&pt));
    }

    #[test]
    fn zero_diag_hessian_handled() {
        let mut h = gram(32, 64, 5);
        for j in 0..32 {
            h.a[5 * 32 + j] = 0.0;
            h.a[j * 32 + 5] = 0.0;
        }
        let hf: Vec<f32> = h.a.iter().map(|&x| x as f32).collect();
        let mut rng = Rng::new(6);
        let w = Matrix::randn(4, 32, &mut rng);
        let q = GptqQuantizer::new()
            .with_hessian(&hf, 32)
            .quantize(&w, &QuantConfig::block_wise(4, 32).unwrap().no_bf16());
        assert!(q.dequant.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn needs_calibration_flag() {
        assert!(GptqQuantizer::new().needs_calibration());
        assert!(!RtnQuantizer::symmetric().needs_calibration());
    }
}
