//! Quantizer framework + the paper's full comparison zoo.
//!
//! Everything implements [`Quantizer`]: MSB (the paper's method, all four
//! solvers), RTN, BnB-style NF4/FP4, HQQ, GPTQ (calibrated), XNOR /
//! BLOCKED-XNOR, and the all-zero dummy from Fig 2/3. Output is a
//! [`QuantizedTensor`]: the *simulated-dequantized* weights (decoded
//! through bf16, paper §4.1) plus storage accounting and, for MSB, the
//! (codes, scales) pairs the L1 Pallas kernel consumes.
//!
//! The calibration-free methods are expressed per block against
//! [`engine::BlockQuantizer`]; the [`engine`] owns slicing, intra-layer
//! parallelism and reassembly, and [`registry`] owns method dispatch.

pub mod dq;
pub mod engine;
pub mod gptq;
pub mod hqq;
pub mod mixed;
pub mod msb;
pub mod nf4;
pub mod packing;
pub mod registry;
pub mod rtn;
pub mod transform;
pub mod xnor;

pub use registry::calibration_free_zoo;

use anyhow::{ensure, Result};

use crate::pool::ThreadPool;
use crate::tensor::Matrix;

/// Quantization granularity (paper §4: per-tensor vs 64-element row blocks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerTensor,
    /// `t` consecutive elements per row form an independent instance.
    BlockWise { t: usize },
}

#[derive(Clone, Debug)]
pub struct QuantConfig {
    /// Target bit-width b; MSB uses 2^{b-1} positive scales (+ sign bit).
    pub bits: u32,
    pub granularity: Granularity,
    /// Solver window size (WGM); paper defaults: 64 per-tensor, 1 block-wise.
    pub window: usize,
    /// λ̃ ∈ [0, 1]: the interpretable reparameterization of the λ
    /// regularizer (Appendix C). Each solve maps it through
    /// Λ(λ̃) = λ_min + λ̃(λ_max − λ_min) *for its own instance* — passing a
    /// raw λ here would dwarf the within-block variances of real weight
    /// scales and corrupt the merge order. Paper default: 0.75 (inert for
    /// externally-fixed group counts, Table 5).
    pub lambda: f64,
    /// Round decoded values through bf16 (paper's storage protocol).
    pub bf16: bool,
    /// Emit the deployable packed payload (codes + scale tables,
    /// [`packing::PackedTensor`]) alongside the simulated dequant. Off by
    /// default: emission costs one code byte per element on the quantize
    /// path. Never changes the dequant output.
    pub emit_packed: bool,
}

impl QuantConfig {
    /// Deployable bit-widths. Research sweeps beyond this (the g=256/512
    /// oracle settings of Tables 5/7) construct the struct literally.
    fn check_bits(bits: u32) -> Result<()> {
        ensure!((1..=8).contains(&bits), "bit-width {bits} outside deployable range 1..=8");
        Ok(())
    }

    pub fn per_tensor(bits: u32) -> Result<Self> {
        Self::check_bits(bits)?;
        Ok(QuantConfig {
            bits,
            granularity: Granularity::PerTensor,
            window: 64,
            lambda: 0.75,
            bf16: true,
            emit_packed: false,
        })
    }

    pub fn block_wise(bits: u32, t: usize) -> Result<Self> {
        Self::check_bits(bits)?;
        ensure!(t > 0, "block size t must be positive");
        Ok(QuantConfig {
            bits,
            granularity: Granularity::BlockWise { t },
            window: 1,
            lambda: 0.75,
            bf16: true,
            emit_packed: false,
        })
    }

    /// Request packed-payload emission (see [`QuantConfig::emit_packed`]).
    pub fn with_packed(mut self) -> Self {
        self.emit_packed = true;
        self
    }

    pub fn with_window(mut self, w: usize) -> Result<Self> {
        ensure!(w > 0, "solver window must be positive");
        self.window = w;
        Ok(self)
    }

    pub fn with_lambda(mut self, l: f64) -> Self {
        self.lambda = l;
        self
    }

    pub fn no_bf16(mut self) -> Self {
        self.bf16 = false;
        self
    }

    /// Number of positive scales: 2^{b-1} (the sign bit is the other half
    /// of the budget).
    pub fn levels(&self) -> usize {
        1usize << (self.bits.saturating_sub(1))
    }

    /// Solver/scale block size in elements for a `rows x cols` matrix:
    /// block-wise = `t` consecutive elements within a row; per-tensor = the
    /// whole matrix shares one instance (a single scale set). The full
    /// layout (instance count, MSB scale-table stripe) lives in
    /// [`engine::BlockPlan`].
    pub fn block_elems(&self, rows: usize, cols: usize) -> usize {
        match self.granularity {
            Granularity::PerTensor => rows * cols,
            Granularity::BlockWise { t } => t,
        }
    }
}

/// MSB (codes, scales) in the L1 kernel's layout, attached when the method
/// supports native execution.
#[derive(Clone, Debug, PartialEq)]
pub struct MsbPayload {
    /// int8 sign·(level+1) codes, row-major [rows, cols]. None when the
    /// level count exceeds i8 (large per-tensor settings).
    pub codes: Option<Vec<i8>>,
    /// f32 scales [rows * cols/block, levels] flattened.
    pub scales: Vec<f32>,
    pub levels: usize,
    pub block: usize,
}

#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub method: String,
    pub rows: usize,
    pub cols: usize,
    /// Simulated-dequantized weights (already bf16-rounded if configured).
    pub dequant: Matrix,
    /// Effective storage cost in bits/weight including scale metadata.
    pub effective_bits: f64,
    /// Kernel payload (MSB only).
    pub msb: Option<MsbPayload>,
    /// Deployable packed payload (codes + scale tables), present when the
    /// config requested emission ([`QuantConfig::emit_packed`]) and the
    /// method supports packing.
    pub packed: Option<packing::PackedTensor>,
}

impl QuantizedTensor {
    /// Total squared reconstruction error — the "MSE" the paper reports in
    /// Tables 2/4/6 (Frobenius², not element-mean).
    pub fn mse(&self, original: &Matrix) -> f64 {
        self.dequant.sse(original)
    }

    /// Element-mean squared error.
    pub fn mean_se(&self, original: &Matrix) -> f64 {
        self.dequant.sse(original) / original.len() as f64
    }
}

/// A weight-only PTQ method.
pub trait Quantizer: Send + Sync {
    fn name(&self) -> &'static str;

    fn quantize(&self, w: &Matrix, cfg: &QuantConfig) -> QuantizedTensor;

    /// Block-parallel quantization: engine-backed methods fan their block
    /// instances out over `pool` (bit-identical to [`Quantizer::quantize`]);
    /// whole-matrix methods (GPTQ) fall back to the serial path.
    fn quantize_with_pool(
        &self,
        w: &Matrix,
        cfg: &QuantConfig,
        pool: &ThreadPool,
    ) -> QuantizedTensor {
        let _ = pool;
        self.quantize(w, cfg)
    }

    /// Whether the method needs calibration data (GPTQ). Calibrated methods
    /// get their Hessian through [`gptq::GptqQuantizer::with_hessian`].
    fn needs_calibration(&self) -> bool {
        false
    }
}

/// Apply the configured bf16 decode round-trip.
pub(crate) fn finish_dequant(mut m: Matrix, cfg: &QuantConfig) -> Matrix {
    if cfg.bf16 {
        for v in &mut m.data {
            *v = crate::tensor::bf16::round(*v);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_levels() {
        assert_eq!(QuantConfig::block_wise(4, 64).unwrap().levels(), 8);
        assert_eq!(QuantConfig::per_tensor(6).unwrap().levels(), 32);
        assert_eq!(QuantConfig::per_tensor(1).unwrap().levels(), 1);
    }

    #[test]
    fn block_elems() {
        assert_eq!(QuantConfig::per_tensor(4).unwrap().block_elems(4, 512), 2048);
        assert_eq!(QuantConfig::block_wise(4, 64).unwrap().block_elems(4, 512), 64);
    }

    #[test]
    fn constructors_reject_degenerate_settings() {
        assert!(QuantConfig::per_tensor(0).is_err());
        assert!(QuantConfig::per_tensor(9).is_err());
        assert!(QuantConfig::block_wise(0, 64).is_err());
        assert!(QuantConfig::block_wise(9, 64).is_err());
        assert!(QuantConfig::block_wise(4, 0).is_err());
        assert!(QuantConfig::block_wise(4, 64).unwrap().with_window(0).is_err());
        // The happy path still composes.
        let cfg = QuantConfig::per_tensor(6).unwrap().with_window(16).unwrap();
        assert_eq!((cfg.bits, cfg.window), (6, 16));
    }
}
