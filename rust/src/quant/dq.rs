//! Double quantization (Appendix G): recursively quantize the *scale*
//! metadata with the same WGM algorithm — blocks of 2048 scales at 6 bits —
//! trading a small accuracy loss for 6.00 → 4.78 bits/weight.

use crate::msb::{Algo, Solver};
use crate::tensor::Matrix;

use super::{MsbPayload, QuantConfig, QuantizedTensor};

#[derive(Clone, Copy, Debug)]
pub struct DqConfig {
    /// Bits for the scale codes (paper: 6).
    pub scale_bits: u32,
    /// Scales per double-quantization block (paper: 2048).
    pub scale_block: usize,
}

impl Default for DqConfig {
    fn default() -> Self {
        DqConfig { scale_bits: 6, scale_block: 2048 }
    }
}

/// Apply double quantization to an MSB-quantized tensor: quantize its scale
/// table with WGM, rebuild the dequantized weights from the coarsened
/// scales, and update the storage accounting.
pub fn double_quantize(
    qt: &QuantizedTensor,
    original_cfg: &QuantConfig,
    dq: &DqConfig,
) -> QuantizedTensor {
    let payload = qt
        .msb
        .as_ref()
        .expect("double quantization applies to MSB-quantized tensors");
    let codes = payload
        .codes
        .as_ref()
        .expect("double quantization needs i8 codes (≤127 levels)");

    // 1. quantize the scale vector in scale_block chunks with WGM (w=1);
    //    cfg.lambda is λ̃ — map through Λ per chunk
    let scale_levels = 1usize << (dq.scale_bits - 1);
    let mut q_scales = vec![0.0f32; payload.scales.len()];
    for (ci, chunk) in payload.scales.chunks(dq.scale_block).enumerate() {
        let sm = crate::msb::SortedMags::from_values(chunk);
        let lam = crate::msb::lambda::lambda_of(original_cfg.lambda, &sm.mags);
        let solver = Solver::new(Algo::Wgm { window: 1 }).with_lambda(lam);
        let code = solver.quantize(chunk, scale_levels);
        let deq = code.dequantize();
        let base = ci * dq.scale_block;
        // scales are positive; decode through bf16 like any stored value
        for (i, v) in deq.iter().enumerate() {
            q_scales[base + i] = crate::tensor::bf16::round(*v);
        }
    }

    // 2. rebuild dequantized weights from codes + coarsened scales
    let (rows, cols) = (qt.rows, qt.cols);
    let block = payload.block;
    let levels = payload.levels;
    let mut dequant = Matrix::zeros(rows, cols);
    for (i, &c) in codes.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let blk = i / block;
        let lvl = (c.unsigned_abs() as usize) - 1;
        let mag = q_scales[blk * levels + lvl];
        dequant.data[i] = if c < 0 { -mag } else { mag };
    }
    if original_cfg.bf16 {
        for v in &mut dequant.data {
            *v = crate::tensor::bf16::round(*v);
        }
    }

    QuantizedTensor {
        method: format!("{}-dq", qt.method),
        rows,
        cols,
        dequant,
        effective_bits: super::packing::msb_dq_effective_bits(
            original_cfg.bits,
            levels,
            block,
            dq.scale_bits,
            scale_levels,
            dq.scale_block,
        ),
        msb: Some(MsbPayload {
            codes: Some(codes.clone()),
            scales: q_scales,
            levels,
            block,
        }),
        // the recursively-quantized scale table needs its own container
        // format (scale codes + meta-scales); not modeled as a payload yet
        packed: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::msb::MsbQuantizer;
    use crate::quant::Quantizer;
    use crate::stats::Rng;

    fn setup() -> (Matrix, QuantizedTensor, QuantConfig) {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(32, 256, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let q = MsbQuantizer::wgm().quantize(&w, &cfg);
        (w, q, cfg)
    }

    #[test]
    fn dq_degrades_slightly() {
        let (w, q, cfg) = setup();
        let dq = double_quantize(&q, &cfg, &DqConfig::default());
        let (e0, e1) = (q.mse(&w), dq.mse(&w));
        assert!(e1 >= e0 * 0.999, "dq can't beat single quantization");
        assert!(e1 <= e0 * 2.0, "dq degradation should be mild: {e0} -> {e1}");
    }

    #[test]
    fn dq_reduces_effective_bits() {
        let (_, q, cfg) = setup();
        let dq = double_quantize(&q, &cfg, &DqConfig::default());
        crate::testing::assert_close(q.effective_bits, 6.0, 1e-12, 0.0);
        crate::testing::assert_close(dq.effective_bits, 4.78125, 1e-12, 0.0);
        assert_eq!(dq.method, "msb-wgm-dq");
    }

    #[test]
    fn dq_preserves_codes_and_signs() {
        let (w, q, cfg) = setup();
        let dq = double_quantize(&q, &cfg, &DqConfig::default());
        assert_eq!(q.msb.as_ref().unwrap().codes, dq.msb.as_ref().unwrap().codes);
        for (a, b) in w.data.iter().zip(&dq.dequant.data) {
            if *a != 0.0 && *b != 0.0 {
                assert_eq!(a.signum(), b.signum());
            }
        }
    }

    #[test]
    fn small_scale_block_checks_chunking() {
        let (_, q, cfg) = setup();
        let dq = double_quantize(&q, &cfg, &DqConfig { scale_bits: 6, scale_block: 16 });
        assert_eq!(dq.msb.unwrap().scales.len(), q.msb.unwrap().scales.len());
    }
}
