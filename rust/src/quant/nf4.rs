//! BnB-style 4-bit codebook quantization: NF4 (normal-float, the QLoRA
//! codebook) and FP4 (e2m1), absmax-normalized per block of 64 — the
//! "BnB" baseline of Table 1. Pure-Rust reimplementation of the numerics;
//! the CUDA kernels are irrelevant to the simulated-dequant protocol.

use super::engine::{impl_quantizer_via_engine, BlockMeta, BlockPlan, BlockQuantizer};
use super::packing::{CodeScheme, PackSpec};
use super::QuantConfig;

/// The 16 NF4 levels (bitsandbytes / QLoRA, Dettmers et al. 2023):
/// quantiles of N(0,1) normalized to [-1, 1].
pub const NF4_LEVELS: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// FP4 (e2m1) value set, normalized to absmax 1.
pub const FP4_LEVELS: [f32; 16] = [
    -1.0, -0.6666667, -0.5, -0.33333334, -0.25, -0.16666667, -0.083333336, -0.0,
    0.0, 0.083333336, 0.16666667, 0.25, 0.33333334, 0.5, 0.6666667, 1.0,
];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codebook {
    Nf4,
    Fp4,
}

#[derive(Clone, Debug)]
pub struct Nf4Quantizer {
    pub codebook: Codebook,
}

impl Nf4Quantizer {
    pub fn nf4() -> Self {
        Nf4Quantizer { codebook: Codebook::Nf4 }
    }

    pub fn fp4() -> Self {
        Nf4Quantizer { codebook: Codebook::Fp4 }
    }

    fn levels(&self) -> &'static [f32; 16] {
        match self.codebook {
            Codebook::Nf4 => &NF4_LEVELS,
            Codebook::Fp4 => &FP4_LEVELS,
        }
    }
}

/// Nearest codebook index (linear scan over 16 — branch-predictable and
/// faster than binary search at this size).
#[inline]
fn nearest_idx(levels: &[f32; 16], x: f32) -> usize {
    let mut best = 0usize;
    let mut bd = (x - levels[0]).abs();
    for (i, &l) in levels.iter().enumerate().skip(1) {
        let d = (x - l).abs();
        if d < bd {
            bd = d;
            best = i;
        }
    }
    best
}

impl BlockQuantizer for Nf4Quantizer {
    fn name(&self) -> &'static str {
        match self.codebook {
            Codebook::Nf4 => "bnb-nf4",
            Codebook::Fp4 => "bnb-fp4",
        }
    }

    fn quantize_block(&self, data: &[f32], out: &mut [f32], cfg: &QuantConfig) -> BlockMeta {
        assert_eq!(cfg.bits, 4, "{} is a fixed 4-bit codebook", BlockQuantizer::name(self));
        let emit = cfg.emit_packed;
        let mut meta = BlockMeta::default();
        let levels = self.levels();
        let absmax = data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if absmax == 0.0 {
            out.fill(0.0);
            if emit {
                meta.scales.push(0.0);
                meta.codes = Some(vec![0i8; data.len()]);
            }
            return meta;
        }
        let mut codes = Vec::with_capacity(if emit { data.len() } else { 0 });
        for (o, &v) in out.iter_mut().zip(data) {
            let idx = nearest_idx(levels, v / absmax);
            *o = levels[idx] * absmax;
            if emit {
                codes.push(idx as i8);
            }
        }
        if emit {
            meta.scales.push(absmax);
            meta.codes = Some(codes);
        }
        meta
    }

    /// 4-bit codes + one f32 absmax per block (bnb keeps absmax in fp32
    /// unless double-quantized).
    fn effective_bits(&self, _cfg: &QuantConfig, plan: &BlockPlan) -> f64 {
        super::packing::nf4_effective_bits(plan.block)
    }

    /// 4-bit codebook indices + the fp32 absmax (the BnB layout).
    fn pack_spec(&self, _cfg: &QuantConfig) -> Option<PackSpec> {
        Some(PackSpec {
            code_bits: 4,
            scheme: CodeScheme::Unsigned,
            scales_per_block: 1,
            f32_scales: true,
        })
    }

    fn decode_block(&self, codes: &[i8], scales: &[f32], out: &mut [f32]) {
        let absmax = scales[0];
        if absmax == 0.0 {
            out.fill(0.0);
            return;
        }
        let levels = self.levels();
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = levels[(c as usize) & 15] * absmax;
        }
    }
}

impl_quantizer_via_engine!(Nf4Quantizer);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::RtnQuantizer;
    use crate::quant::Quantizer;
    use crate::stats::Rng;
    use crate::tensor::Matrix;

    #[test]
    fn codebooks_sorted_and_symmetric_ends() {
        for levels in [&NF4_LEVELS, &FP4_LEVELS] {
            assert!(levels.windows(2).all(|w| w[0] <= w[1]));
            assert_eq!(levels[0], -1.0);
            assert_eq!(levels[15], 1.0);
        }
        assert!(NF4_LEVELS.contains(&0.0));
    }

    #[test]
    fn absmax_element_survives() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(4, 64, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let q = Nf4Quantizer::nf4().quantize(&w, &cfg);
        for (blk, dq) in w.row_blocks(64).zip(q.dequant.row_blocks(64)) {
            let (mi, _) = blk
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().total_cmp(&b.1.abs()))
                .unwrap();
            assert!((dq[mi] - blk[mi]).abs() < 1e-6, "absmax maps to ±1");
        }
    }

    #[test]
    fn nf4_beats_rtn_on_gaussian() {
        // the entire point of NF4: better grid for normal data
        let mut rng = Rng::new(2);
        let w = Matrix::randn(32, 256, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let nf4 = Nf4Quantizer::nf4().quantize(&w, &cfg);
        let rtn = RtnQuantizer::symmetric().quantize(&w, &cfg);
        assert!(nf4.mse(&w) < rtn.mse(&w));
    }

    #[test]
    fn fp4_differs_from_nf4() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(8, 64, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let a = Nf4Quantizer::nf4().quantize(&w, &cfg);
        let b = Nf4Quantizer::fp4().quantize(&w, &cfg);
        assert_ne!(a.dequant.data, b.dequant.data);
    }

    #[test]
    #[should_panic(expected = "fixed 4-bit")]
    fn rejects_other_bit_widths() {
        let w = Matrix::zeros(2, 64);
        Nf4Quantizer::nf4().quantize(&w, &QuantConfig::block_wise(3, 64).unwrap());
    }

    #[test]
    fn effective_bits() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(2, 64, &mut rng);
        let q = Nf4Quantizer::nf4().quantize(&w, &QuantConfig::block_wise(4, 64).unwrap());
        crate::testing::assert_close(q.effective_bits, 4.5, 1e-12, 0.0);
    }
}
