//! RTN (round-to-nearest) — the paper's simple uniform baseline: absmax
//! scaling per tensor/block, optional asymmetric zero-point variant.
//! Expressed per block against the [`engine`](super::engine); slicing,
//! threading and bf16 finishing live there.

use super::engine::{impl_quantizer_via_engine, BlockMeta, BlockPlan, BlockQuantizer};
use super::QuantConfig;

#[derive(Clone, Debug)]
pub struct RtnQuantizer {
    pub asymmetric: bool,
}

impl RtnQuantizer {
    /// Symmetric absmax grid (the paper's RTN has "no zero point shift").
    pub fn symmetric() -> Self {
        RtnQuantizer { asymmetric: false }
    }

    /// Affine min/max grid with zero point.
    pub fn asymmetric() -> Self {
        RtnQuantizer { asymmetric: true }
    }

    fn quantize_block_sym(block: &[f32], out: &mut [f32], bits: u32) {
        let qmax = ((1i64 << (bits - 1)) - 1) as f32; // e.g. 7 at 4-bit
        let absmax = block.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if absmax == 0.0 {
            out.fill(0.0);
            return;
        }
        let scale = absmax / qmax;
        for (o, &v) in out.iter_mut().zip(block) {
            let q = (v / scale).round().clamp(-qmax, qmax);
            *o = q * scale;
        }
    }

    fn quantize_block_asym(block: &[f32], out: &mut [f32], bits: u32) {
        let qmax = ((1i64 << bits) - 1) as f32; // e.g. 15 at 4-bit
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in block {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi <= lo {
            out.fill(lo);
            return;
        }
        let scale = (hi - lo) / qmax;
        for (o, &v) in out.iter_mut().zip(block) {
            let q = ((v - lo) / scale).round().clamp(0.0, qmax);
            *o = q * scale + lo;
        }
    }
}

impl BlockQuantizer for RtnQuantizer {
    fn name(&self) -> &'static str {
        if self.asymmetric {
            "rtn-asym"
        } else {
            "rtn"
        }
    }

    fn quantize_block(&self, data: &[f32], out: &mut [f32], cfg: &QuantConfig) -> BlockMeta {
        if self.asymmetric {
            Self::quantize_block_asym(data, out, cfg.bits);
        } else {
            Self::quantize_block_sym(data, out, cfg.bits);
        }
        BlockMeta::default()
    }

    /// b-bit codes + one bf16 scale (+ one bf16 zero point) per block.
    fn effective_bits(&self, cfg: &QuantConfig, plan: &BlockPlan) -> f64 {
        super::packing::uniform_effective_bits(cfg.bits, plan.block, self.asymmetric)
    }
}

impl_quantizer_via_engine!(RtnQuantizer);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Quantizer;
    use crate::stats::Rng;
    use crate::tensor::Matrix;

    #[test]
    fn exact_on_grid_points() {
        // values already on the symmetric 3-bit grid survive exactly
        let w = Matrix::from_vec(1, 4, vec![-3.0, -1.0, 0.0, 3.0]);
        let cfg = QuantConfig::per_tensor(3).no_bf16();
        let q = RtnQuantizer::symmetric().quantize(&w, &cfg);
        assert_eq!(q.dequant.data, vec![-3.0, -1.0, 0.0, 3.0]);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 64, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).no_bf16();
        let q = RtnQuantizer::symmetric().quantize(&w, &cfg);
        for (blk, dq) in w.row_blocks(64).zip(q.dequant.row_blocks(64)) {
            let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let step = absmax / 7.0;
            for (a, b) in blk.iter().zip(dq) {
                assert!((a - b).abs() <= step / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 256, &mut rng);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let q = RtnQuantizer::symmetric()
                .quantize(&w, &QuantConfig::block_wise(bits, 64).no_bf16());
            let e = q.mse(&w);
            assert!(e < last);
            last = e;
        }
    }

    #[test]
    fn blockwise_beats_per_tensor() {
        // a matrix with per-block scale variation
        let mut rng = Rng::new(3);
        let mut w = Matrix::randn(4, 256, &mut rng);
        for (i, v) in w.data.iter_mut().enumerate() {
            *v *= 1.0 + (i / 64) as f32; // growing magnitude per block
        }
        let pt = RtnQuantizer::symmetric().quantize(&w, &QuantConfig::per_tensor(4).no_bf16());
        let bw = RtnQuantizer::symmetric()
            .quantize(&w, &QuantConfig::block_wise(4, 64).no_bf16());
        assert!(bw.mse(&w) < pt.mse(&w));
    }

    #[test]
    fn asymmetric_handles_shifted_data() {
        let mut rng = Rng::new(4);
        let mut w = Matrix::randn(4, 64, &mut rng);
        for v in &mut w.data {
            *v += 10.0; // all-positive shifted distribution
        }
        let cfg = QuantConfig::block_wise(4, 64).no_bf16();
        let sym = RtnQuantizer::symmetric().quantize(&w, &cfg);
        let asym = RtnQuantizer::asymmetric().quantize(&w, &cfg);
        assert!(asym.mse(&w) < sym.mse(&w));
    }

    #[test]
    fn zero_block() {
        let w = Matrix::zeros(2, 64);
        let q = RtnQuantizer::symmetric().quantize(&w, &QuantConfig::block_wise(4, 64));
        assert_eq!(q.mse(&w), 0.0);
    }

    #[test]
    fn constant_block_asym_exact() {
        let w = Matrix::from_vec(1, 64, vec![2.5; 64]);
        let q = RtnQuantizer::asymmetric().quantize(&w, &QuantConfig::block_wise(4, 64).no_bf16());
        assert_eq!(q.mse(&w), 0.0);
    }
}
