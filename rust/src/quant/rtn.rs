//! RTN (round-to-nearest) — the paper's simple uniform baseline: absmax
//! scaling per tensor/block, optional asymmetric zero-point variant.

use crate::tensor::Matrix;

use super::{finish_dequant, QuantConfig, QuantizedTensor, Quantizer};

#[derive(Clone, Debug)]
pub struct RtnQuantizer {
    pub asymmetric: bool,
}

impl RtnQuantizer {
    /// Symmetric absmax grid (the paper's RTN has "no zero point shift").
    pub fn symmetric() -> Self {
        RtnQuantizer { asymmetric: false }
    }

    /// Affine min/max grid with zero point.
    pub fn asymmetric() -> Self {
        RtnQuantizer { asymmetric: true }
    }

    fn quantize_block_sym(block: &[f32], out: &mut [f32], bits: u32) {
        let qmax = ((1i64 << (bits - 1)) - 1) as f32; // e.g. 7 at 4-bit
        let absmax = block.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if absmax == 0.0 {
            out.fill(0.0);
            return;
        }
        let scale = absmax / qmax;
        for (o, &v) in out.iter_mut().zip(block) {
            let q = (v / scale).round().clamp(-qmax, qmax);
            *o = q * scale;
        }
    }

    fn quantize_block_asym(block: &[f32], out: &mut [f32], bits: u32) {
        let qmax = ((1i64 << bits) - 1) as f32; // e.g. 15 at 4-bit
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in block {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi <= lo {
            out.fill(lo);
            return;
        }
        let scale = (hi - lo) / qmax;
        for (o, &v) in out.iter_mut().zip(block) {
            let q = ((v - lo) / scale).round().clamp(0.0, qmax);
            *o = q * scale + lo;
        }
    }
}

impl Quantizer for RtnQuantizer {
    fn name(&self) -> &'static str {
        if self.asymmetric {
            "rtn-asym"
        } else {
            "rtn"
        }
    }

    fn quantize(&self, w: &Matrix, cfg: &QuantConfig) -> QuantizedTensor {
        let block = cfg.block_elems(w.rows, w.cols);
        assert!(block == w.len() || w.cols % block == 0, "block {block} !| cols {}", w.cols);
        let mut dequant = Matrix::zeros(w.rows, w.cols);
        for (bi, blk) in w.data.chunks(block).enumerate() {
            let out = &mut dequant.data[bi * block..bi * block + blk.len()];
            if self.asymmetric {
                Self::quantize_block_asym(blk, out, cfg.bits);
            } else {
                Self::quantize_block_sym(blk, out, cfg.bits);
            }
        }
        QuantizedTensor {
            method: self.name().to_string(),
            rows: w.rows,
            cols: w.cols,
            dequant: finish_dequant(dequant, cfg),
            effective_bits: super::packing::uniform_effective_bits(
                cfg.bits, block, self.asymmetric,
            ),
            msb: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    #[test]
    fn exact_on_grid_points() {
        // values already on the symmetric 3-bit grid survive exactly
        let w = Matrix::from_vec(1, 4, vec![-3.0, -1.0, 0.0, 3.0]);
        let cfg = QuantConfig::per_tensor(3).no_bf16();
        let q = RtnQuantizer::symmetric().quantize(&w, &cfg);
        assert_eq!(q.dequant.data, vec![-3.0, -1.0, 0.0, 3.0]);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 64, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).no_bf16();
        let q = RtnQuantizer::symmetric().quantize(&w, &cfg);
        for (blk, dq) in w.row_blocks(64).zip(q.dequant.row_blocks(64)) {
            let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let step = absmax / 7.0;
            for (a, b) in blk.iter().zip(dq) {
                assert!((a - b).abs() <= step / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 256, &mut rng);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let q = RtnQuantizer::symmetric()
                .quantize(&w, &QuantConfig::block_wise(bits, 64).no_bf16());
            let e = q.mse(&w);
            assert!(e < last);
            last = e;
        }
    }

    #[test]
    fn blockwise_beats_per_tensor() {
        // a matrix with per-block scale variation
        let mut rng = Rng::new(3);
        let mut w = Matrix::randn(4, 256, &mut rng);
        for (i, v) in w.data.iter_mut().enumerate() {
            *v *= 1.0 + (i / 64) as f32; // growing magnitude per block
        }
        let pt = RtnQuantizer::symmetric().quantize(&w, &QuantConfig::per_tensor(4).no_bf16());
        let bw = RtnQuantizer::symmetric()
            .quantize(&w, &QuantConfig::block_wise(4, 64).no_bf16());
        assert!(bw.mse(&w) < pt.mse(&w));
    }

    #[test]
    fn asymmetric_handles_shifted_data() {
        let mut rng = Rng::new(4);
        let mut w = Matrix::randn(4, 64, &mut rng);
        for v in &mut w.data {
            *v += 10.0; // all-positive shifted distribution
        }
        let cfg = QuantConfig::block_wise(4, 64).no_bf16();
        let sym = RtnQuantizer::symmetric().quantize(&w, &cfg);
        let asym = RtnQuantizer::asymmetric().quantize(&w, &cfg);
        assert!(asym.mse(&w) < sym.mse(&w));
    }

    #[test]
    fn zero_block() {
        let w = Matrix::zeros(2, 64);
        let q = RtnQuantizer::symmetric().quantize(&w, &QuantConfig::block_wise(4, 64));
        assert_eq!(q.mse(&w), 0.0);
    }

    #[test]
    fn constant_block_asym_exact() {
        let w = Matrix::from_vec(1, 64, vec![2.5; 64]);
        let q = RtnQuantizer::asymmetric().quantize(&w, &QuantConfig::block_wise(4, 64).no_bf16());
        assert_eq!(q.mse(&w), 0.0);
    }
}
