//! RTN (round-to-nearest) — the paper's simple uniform baseline: absmax
//! scaling per tensor/block, optional asymmetric zero-point variant.
//! Expressed per block against the [`engine`](super::engine); slicing,
//! threading and bf16 finishing live there.
//!
//! Storage-true metadata: under the bf16 protocol the scale (and zero
//! point) are rounded through bf16 *before* reconstruction — the grid a
//! deployed decoder would actually build from the stored scale table — so
//! the packed decode path reproduces the simulated dequant bit-for-bit.

use crate::tensor::bf16;

use super::engine::{impl_quantizer_via_engine, BlockMeta, BlockPlan, BlockQuantizer};
use super::packing::{CodeScheme, PackSpec};
use super::QuantConfig;

#[derive(Clone, Debug)]
pub struct RtnQuantizer {
    pub asymmetric: bool,
}

impl RtnQuantizer {
    /// Symmetric absmax grid (the paper's RTN has "no zero point shift").
    pub fn symmetric() -> Self {
        RtnQuantizer { asymmetric: false }
    }

    /// Affine min/max grid with zero point.
    pub fn asymmetric() -> Self {
        RtnQuantizer { asymmetric: true }
    }

    /// Symmetric path; returns `(scale, codes)` with codes collected only
    /// when `emit` (packed-payload emission).
    fn quantize_block_sym(
        block: &[f32],
        out: &mut [f32],
        bits: u32,
        store_bf16: bool,
        emit: bool,
    ) -> (f32, Vec<i8>) {
        let qmax = ((1i64 << (bits - 1)) - 1) as f32; // e.g. 7 at 4-bit
        let absmax = block.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mut scale = absmax / qmax;
        if store_bf16 {
            scale = bf16::round(scale); // the stored grid, not an ideal one
        }
        if absmax == 0.0 || scale == 0.0 {
            // all-zero block, or a subnormal scale that underflows bf16
            out.fill(0.0);
            return (0.0, vec![0i8; if emit { block.len() } else { 0 }]);
        }
        let mut codes = Vec::with_capacity(if emit { block.len() } else { 0 });
        for (o, &v) in out.iter_mut().zip(block) {
            let q = (v / scale).round().clamp(-qmax, qmax);
            *o = q * scale;
            if emit {
                codes.push(q as i8);
            }
        }
        (scale, codes)
    }

    /// Asymmetric path; returns `(scale, zero_point, codes)`.
    fn quantize_block_asym(
        block: &[f32],
        out: &mut [f32],
        bits: u32,
        store_bf16: bool,
        emit: bool,
    ) -> (f32, f32, Vec<i8>) {
        let qmax = ((1i64 << bits) - 1) as f32; // e.g. 15 at 4-bit
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in block {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let zp = if store_bf16 { bf16::round(lo) } else { lo };
        let mut scale = (hi - lo) / qmax;
        if store_bf16 {
            scale = bf16::round(scale);
        }
        if hi <= lo || scale == 0.0 {
            // constant block (or degenerate range): q = 0, value = zp
            out.fill(zp);
            return (0.0, zp, vec![0i8; if emit { block.len() } else { 0 }]);
        }
        let mut codes = Vec::with_capacity(if emit { block.len() } else { 0 });
        for (o, &v) in out.iter_mut().zip(block) {
            let q = ((v - zp) / scale).round().clamp(0.0, qmax);
            *o = q * scale + zp;
            if emit {
                codes.push(q as i8);
            }
        }
        (scale, zp, codes)
    }
}

impl BlockQuantizer for RtnQuantizer {
    fn name(&self) -> &'static str {
        if self.asymmetric {
            "rtn-asym"
        } else {
            "rtn"
        }
    }

    fn quantize_block(&self, data: &[f32], out: &mut [f32], cfg: &QuantConfig) -> BlockMeta {
        let emit = cfg.emit_packed && self.pack_spec(cfg).is_some();
        let mut meta = BlockMeta::default();
        if self.asymmetric {
            let (s, z, codes) = Self::quantize_block_asym(data, out, cfg.bits, cfg.bf16, emit);
            if emit {
                meta.scales.extend([s, z]);
                meta.codes = Some(codes);
            }
        } else {
            let (s, codes) = Self::quantize_block_sym(data, out, cfg.bits, cfg.bf16, emit);
            if emit {
                meta.scales.push(s);
                meta.codes = Some(codes);
            }
        }
        meta
    }

    /// b-bit codes + one bf16 scale (+ one bf16 zero point) per block.
    fn effective_bits(&self, cfg: &QuantConfig, plan: &BlockPlan) -> f64 {
        super::packing::uniform_effective_bits(cfg.bits, plan.block, self.asymmetric)
    }

    /// Symmetric: sign-magnitude codes in b bits; asymmetric: unsigned
    /// grid indices (codes must fit i8, so asym caps at 7 bits).
    fn pack_spec(&self, cfg: &QuantConfig) -> Option<PackSpec> {
        if self.asymmetric {
            if cfg.bits >= 8 {
                return None;
            }
            Some(PackSpec {
                code_bits: cfg.bits,
                scheme: CodeScheme::Unsigned,
                scales_per_block: 2,
                f32_scales: false,
            })
        } else {
            if cfg.bits > 8 {
                return None;
            }
            Some(PackSpec {
                code_bits: cfg.bits,
                scheme: CodeScheme::SignMagnitude,
                scales_per_block: 1,
                f32_scales: false,
            })
        }
    }

    fn decode_block(&self, codes: &[i8], scales: &[f32], out: &mut [f32]) {
        if self.asymmetric {
            let (s, z) = (scales[0], scales[1]);
            if s == 0.0 {
                out.fill(z);
                return;
            }
            for (o, &c) in out.iter_mut().zip(codes) {
                *o = c as f32 * s + z;
            }
        } else {
            let s = scales[0];
            if s == 0.0 {
                out.fill(0.0);
                return;
            }
            for (o, &c) in out.iter_mut().zip(codes) {
                *o = c as f32 * s;
            }
        }
    }
}

impl_quantizer_via_engine!(RtnQuantizer);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Quantizer;
    use crate::stats::Rng;
    use crate::tensor::Matrix;

    #[test]
    fn exact_on_grid_points() {
        // values already on the symmetric 3-bit grid survive exactly
        let w = Matrix::from_vec(1, 4, vec![-3.0, -1.0, 0.0, 3.0]);
        let cfg = QuantConfig::per_tensor(3).unwrap().no_bf16();
        let q = RtnQuantizer::symmetric().quantize(&w, &cfg);
        assert_eq!(q.dequant.data, vec![-3.0, -1.0, 0.0, 3.0]);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 64, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let q = RtnQuantizer::symmetric().quantize(&w, &cfg);
        for (blk, dq) in w.row_blocks(64).zip(q.dequant.row_blocks(64)) {
            let absmax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let step = absmax / 7.0;
            for (a, b) in blk.iter().zip(dq) {
                assert!((a - b).abs() <= step / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 256, &mut rng);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let q = RtnQuantizer::symmetric()
                .quantize(&w, &QuantConfig::block_wise(bits, 64).unwrap().no_bf16());
            let e = q.mse(&w);
            assert!(e < last);
            last = e;
        }
    }

    #[test]
    fn blockwise_beats_per_tensor() {
        // a matrix with per-block scale variation
        let mut rng = Rng::new(3);
        let mut w = Matrix::randn(4, 256, &mut rng);
        for (i, v) in w.data.iter_mut().enumerate() {
            *v *= 1.0 + (i / 64) as f32; // growing magnitude per block
        }
        let pt = RtnQuantizer::symmetric().quantize(&w, &QuantConfig::per_tensor(4).unwrap().no_bf16());
        let bw = RtnQuantizer::symmetric()
            .quantize(&w, &QuantConfig::block_wise(4, 64).unwrap().no_bf16());
        assert!(bw.mse(&w) < pt.mse(&w));
    }

    #[test]
    fn asymmetric_handles_shifted_data() {
        let mut rng = Rng::new(4);
        let mut w = Matrix::randn(4, 64, &mut rng);
        for v in &mut w.data {
            *v += 10.0; // all-positive shifted distribution
        }
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let sym = RtnQuantizer::symmetric().quantize(&w, &cfg);
        let asym = RtnQuantizer::asymmetric().quantize(&w, &cfg);
        assert!(asym.mse(&w) < sym.mse(&w));
    }

    #[test]
    fn zero_block() {
        let w = Matrix::zeros(2, 64);
        let q = RtnQuantizer::symmetric().quantize(&w, &QuantConfig::block_wise(4, 64).unwrap());
        assert_eq!(q.mse(&w), 0.0);
    }

    #[test]
    fn constant_block_asym_exact() {
        let w = Matrix::from_vec(1, 64, vec![2.5; 64]);
        let q = RtnQuantizer::asymmetric().quantize(&w, &QuantConfig::block_wise(4, 64).unwrap().no_bf16());
        assert_eq!(q.mse(&w), 0.0);
    }
}
