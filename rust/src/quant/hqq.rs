//! HQQ — Half-Quadratic Quantization (Badri & Shaji 2023): calibration-free
//! weight-only quantization that optimizes the zero-point of an affine grid
//! under a robust ℓ_p norm via half-quadratic splitting.
//!
//! Model: W ≈ s·(Q − z), Q ∈ [0, 2^b−1]. Alternating updates:
//!   E   ← shrink_lp(W − s·(Q − z))          (proximal / soft-threshold)
//!   z   ← mean(Q − (W − E)/s)               (closed form)
//!   Q   ← clamp(round(W/s + z))
//! with β annealed by κ each step. Mirrors the official solver's structure,
//! executed on CPU.

use crate::tensor::bf16;

use super::engine::{impl_quantizer_via_engine, BlockMeta, BlockPlan, BlockQuantizer};
use super::packing::{CodeScheme, PackSpec};
use super::QuantConfig;

#[derive(Clone, Debug)]
pub struct HqqQuantizer {
    pub p: f64,
    pub beta: f64,
    pub kappa: f64,
    pub iters: usize,
}

impl Default for HqqQuantizer {
    fn default() -> Self {
        // the official defaults: lp=0.7, beta=1e1, kappa=1.01, iters=20
        HqqQuantizer { p: 0.7, beta: 10.0, kappa: 1.01, iters: 20 }
    }
}

/// Generalized soft-threshold for the ℓ_p proximal operator (p < 1):
/// shrink(x) = sign(x)·max(0, |x| − β^{p−2}·|x|^{p−1}) (HQQ appendix form).
#[inline]
fn shrink_lp(x: f32, beta: f64, p: f64) -> f32 {
    let ax = x.abs() as f64;
    if ax < 1e-12 {
        return 0.0;
    }
    let shrunk = (ax - ax.powf(p - 1.0) * beta.powf(p - 2.0)).max(0.0);
    (x.signum() as f64 * shrunk) as f32
}

impl HqqQuantizer {
    /// One half-quadratic solve over a single block. Reconstruction uses
    /// the storage-rounded `(s, z)` when `store_bf16` (the metadata a
    /// deployed decoder reads back); returns `(s, z, codes)` with codes
    /// collected only when `emit`.
    fn solve_block(
        &self,
        w: &[f32],
        out: &mut [f32],
        bits: u32,
        store_bf16: bool,
        emit: bool,
    ) -> (f32, f32, Vec<i8>) {
        let round_meta = |x: f32| if store_bf16 { bf16::round(x) } else { x };
        let qmax = ((1i64 << bits) - 1) as f32;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in w {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi <= lo {
            // constant block: exact representation as s·(1 − 0)
            let s = round_meta(lo);
            out.fill(s);
            return (s, 0.0, vec![1i8; if emit { w.len() } else { 0 }]);
        }
        let s = (hi - lo) / qmax;
        let mut z = -lo / s;
        let mut beta = self.beta;
        let mut q: Vec<f32> = w.iter().map(|&v| (v / s + z).round().clamp(0.0, qmax)).collect();
        for _ in 0..self.iters {
            // E ← shrink(W − s(Q − z))
            // z ← mean(Q − (W − E)/s)
            let mut zsum = 0.0f64;
            for (&wi, &qi) in w.iter().zip(&q) {
                let e = shrink_lp(wi - s * (qi - z), beta, self.p);
                zsum += (qi - (wi - e) / s) as f64;
            }
            z = (zsum / w.len() as f64) as f32;
            for (qi, &wi) in q.iter_mut().zip(w) {
                *qi = (wi / s + z).round().clamp(0.0, qmax);
            }
            beta *= self.kappa;
        }
        let (sr, zr) = (round_meta(s), round_meta(z));
        let mut codes = Vec::with_capacity(if emit { w.len() } else { 0 });
        for (o, &qi) in out.iter_mut().zip(&q) {
            *o = sr * (qi - zr);
            if emit {
                codes.push(qi as i8);
            }
        }
        (sr, zr, codes)
    }
}

impl BlockQuantizer for HqqQuantizer {
    fn name(&self) -> &'static str {
        "hqq"
    }

    fn quantize_block(&self, data: &[f32], out: &mut [f32], cfg: &QuantConfig) -> BlockMeta {
        let emit = cfg.emit_packed && self.pack_spec(cfg).is_some();
        let (s, z, codes) = self.solve_block(data, out, cfg.bits, cfg.bf16, emit);
        let mut meta = BlockMeta::default();
        if emit {
            meta.scales.extend([s, z]);
            meta.codes = Some(codes);
        }
        meta
    }

    /// Affine grid: scale + zero-point per block (bf16 each).
    fn effective_bits(&self, cfg: &QuantConfig, plan: &BlockPlan) -> f64 {
        super::packing::uniform_effective_bits(cfg.bits, plan.block, true)
    }

    /// Unsigned grid indices + (scale, zero-point); the `0..2^b-1` codes
    /// must fit i8, so packing caps at 7 bits.
    fn pack_spec(&self, cfg: &QuantConfig) -> Option<PackSpec> {
        if cfg.bits >= 8 {
            return None;
        }
        Some(PackSpec {
            code_bits: cfg.bits,
            scheme: CodeScheme::Unsigned,
            scales_per_block: 2,
            f32_scales: false,
        })
    }

    fn decode_block(&self, codes: &[i8], scales: &[f32], out: &mut [f32]) {
        let (s, z) = (scales[0], scales[1]);
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = s * (c as f32 - z);
        }
    }
}

impl_quantizer_via_engine!(HqqQuantizer);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::RtnQuantizer;
    use crate::quant::Quantizer;
    use crate::stats::Rng;
    use crate::tensor::Matrix;

    #[test]
    fn improves_over_plain_asym_rtn_on_outliers() {
        // HQQ's robust objective should cope better with heavy tails
        let mut rng = Rng::new(1);
        let mut w = Matrix::zeros(16, 256);
        rng.fill_weightlike(&mut w.data, 0.05, 0.01);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let hqq = HqqQuantizer::default().quantize(&w, &cfg);
        let rtn = RtnQuantizer::asymmetric().quantize(&w, &cfg);
        // robust lp fitting should not be (much) worse; typically better
        assert!(hqq.mse(&w) <= rtn.mse(&w) * 1.05, "{} vs {}", hqq.mse(&w), rtn.mse(&w));
    }

    #[test]
    fn shrink_lp_properties() {
        // odd, contractive, zero fixed point
        assert_eq!(shrink_lp(0.0, 10.0, 0.7), 0.0);
        for x in [0.1f32, 1.0, 5.0, -3.0] {
            let s = shrink_lp(x, 10.0, 0.7);
            assert!(s.abs() <= x.abs());
            assert_eq!(shrink_lp(-x, 10.0, 0.7), -s);
        }
    }

    #[test]
    fn constant_block_exact() {
        let w = Matrix::from_vec(1, 64, vec![3.25; 64]);
        let q = HqqQuantizer::default().quantize(&w, &QuantConfig::block_wise(4, 64).unwrap().no_bf16());
        assert!(q.mse(&w) < 1e-9);
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 256, &mut rng);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 6] {
            let q = HqqQuantizer::default()
                .quantize(&w, &QuantConfig::block_wise(bits, 64).unwrap().no_bf16());
            let e = q.mse(&w);
            assert!(e < last, "bits {bits}");
            last = e;
        }
    }

    #[test]
    fn deterministic() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(4, 128, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let a = HqqQuantizer::default().quantize(&w, &cfg);
        let b = HqqQuantizer::default().quantize(&w, &cfg);
        assert_eq!(a.dequant.data, b.dequant.data);
    }
}
