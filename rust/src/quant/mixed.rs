//! Sensitivity-driven mixed-precision MSB — the BiLLM-inspired extension
//! the paper's §2.2 motivates: "under tight precision budgets, performance
//! depends ... on how representational capacity is allocated across groups
//! of heterogeneous sensitivity".
//!
//! Blocks are ranked by a sensitivity score (activation-weighted energy if
//! a Gram diagonal is available, else plain magnitude-variance); the top
//! `hot_frac` get one extra bit and an equal mass of the least sensitive
//! blocks gives one up, keeping the average bit budget at the base width.

use crate::pool::ThreadPool;
use crate::tensor::Matrix;

use super::engine::{pool_ordered_map, tile_size};
use super::msb::MsbQuantizer;
use super::{finish_dequant, Granularity, QuantConfig, QuantizedTensor, Quantizer};

#[derive(Clone, Debug)]
pub struct MixedMsbQuantizer {
    pub hot_frac: f64,
    /// Optional diag(H) (len = cols) for activation-aware sensitivity.
    pub diag_h: Option<Vec<f32>>,
}

/// Quantize one run of consecutive `t`-element blocks at their assigned
/// widths, returning the dequantized values and per-block effective bits.
/// Free function so pool jobs can own everything they capture.
fn solve_run(
    inner: &MsbQuantizer,
    data: &[f32],
    bits: &[u32],
    t: usize,
    window: usize,
    lambda: f64,
) -> (Vec<f32>, Vec<f64>) {
    let mut out = Vec::with_capacity(data.len());
    let mut effs = Vec::with_capacity(bits.len());
    for (i, &b) in bits.iter().enumerate() {
        // Built literally: hot blocks run at base+1 bits, which may step
        // outside the deployable 1..=8 range the validated constructors
        // enforce (e.g. an 8-bit base promotes to 9).
        let bcfg = QuantConfig {
            bits: b,
            granularity: Granularity::BlockWise { t },
            window,
            lambda,
            bf16: false,
            emit_packed: false,
        };
        let bm = Matrix::from_vec(1, t, data[i * t..(i + 1) * t].to_vec());
        let q = inner.quantize(&bm, &bcfg);
        out.extend(q.dequant.data);
        effs.push(q.effective_bits);
    }
    (out, effs)
}

impl MixedMsbQuantizer {
    pub fn new(hot_frac: f64) -> Self {
        MixedMsbQuantizer { hot_frac: hot_frac.clamp(0.0, 0.5), diag_h: None }
    }

    pub fn with_diag_h(mut self, diag_h: Vec<f32>) -> Self {
        self.diag_h = Some(diag_h);
        self
    }

    /// Sensitivity of one block: Σ w² (· diag_h if available).
    fn sensitivity(&self, blk: &[f32], col0: usize, cols: usize) -> f64 {
        match &self.diag_h {
            Some(d) => blk
                .iter()
                .enumerate()
                .map(|(i, &v)| (v as f64) * (v as f64) * d[(col0 + i) % cols] as f64)
                .sum(),
            None => blk.iter().map(|&v| (v as f64) * (v as f64)).sum(),
        }
    }

    /// Rank blocks by sensitivity and assign a bit-width per block,
    /// balancing the total storage budget around the base width.
    fn assign_bits(&self, w: &Matrix, cfg: &QuantConfig, t: usize) -> Vec<u32> {
        let n_blocks = w.len() / t;
        let n_hot = ((n_blocks as f64) * self.hot_frac) as usize;

        let mut order: Vec<usize> = (0..n_blocks).collect();
        let scores: Vec<f64> = w
            .row_blocks(t)
            .enumerate()
            .map(|(bi, blk)| self.sensitivity(blk, (bi * t) % w.cols, w.cols))
            .collect();
        order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
        // balance the *total* storage budget: promoting a block costs
        // 1 + L·16/t extra bits/weight (codes + doubled scale table) while
        // demoting refunds 1 + (L/2)·16/t — demote proportionally more.
        let l = cfg.levels() as f64;
        let cost_up = 1.0 + l * 16.0 / t as f64;
        let cost_down = 1.0 + (l / 2.0) * 16.0 / t as f64;
        let n_cold = (((n_hot as f64) * cost_up / cost_down).round() as usize)
            .min(n_blocks.saturating_sub(n_hot));
        let mut bits_of = vec![cfg.bits; n_blocks];
        for &bi in order.iter().take(n_hot) {
            bits_of[bi] = cfg.bits + 1;
        }
        for &bi in order.iter().rev().take(n_cold) {
            bits_of[bi] = cfg.bits.saturating_sub(1).max(1);
        }
        bits_of
    }

    /// Quantize every block at its assigned width, optionally fanning the
    /// per-block solves out over `pool` (input-ordered, bit-identical to
    /// the serial loop).
    fn run(&self, w: &Matrix, cfg: &QuantConfig, pool: Option<&ThreadPool>) -> QuantizedTensor {
        let t = match cfg.granularity {
            Granularity::BlockWise { t } => t,
            Granularity::PerTensor => {
                // mixed precision needs blocks; whole-tensor falls back
                let inner = MsbQuantizer::wgm();
                return match pool {
                    Some(p) => inner.quantize_with_pool(w, cfg, p),
                    None => inner.quantize(w, cfg),
                };
            }
        };
        assert!(w.cols % t == 0);
        let bits_of = self.assign_bits(w, cfg, t);

        let inner = MsbQuantizer::wgm();
        let (window, lambda) = (cfg.window, cfg.lambda);
        let n_blocks = bits_of.len();
        let tiles: Vec<(Vec<f32>, Vec<f64>)> = match pool {
            Some(pool) if pool.threads() > 1 && n_blocks > 1 => {
                // tiles of consecutive blocks (the engine's sizing) so
                // per-job overhead stays amortized
                let tile = tile_size(n_blocks, pool.threads());
                let jobs: Vec<_> = (0..n_blocks)
                    .step_by(tile)
                    .map(|b0| {
                        let b1 = (b0 + tile).min(n_blocks);
                        let data = w.data[b0 * t..b1 * t].to_vec();
                        let bits: Vec<u32> = bits_of[b0..b1].to_vec();
                        let inner = inner.clone();
                        move || solve_run(&inner, &data, &bits, t, window, lambda)
                    })
                    .collect();
                pool_ordered_map(pool, jobs)
            }
            _ => vec![solve_run(&inner, &w.data, &bits_of, t, window, lambda)],
        };

        let mut dequant = Matrix::zeros(w.rows, w.cols);
        let mut bit_mass = 0.0f64;
        let mut off = 0usize;
        for (data, effs) in tiles {
            dequant.data[off..off + data.len()].copy_from_slice(&data);
            off += data.len();
            for eff in effs {
                bit_mass += eff * t as f64;
            }
        }
        QuantizedTensor {
            method: Quantizer::name(self).to_string(),
            rows: w.rows,
            cols: w.cols,
            dequant: finish_dequant(dequant, cfg),
            effective_bits: bit_mass / w.len() as f64,
            msb: None, // variable-width payload: native path not modeled
            packed: None,
        }
    }
}

impl Quantizer for MixedMsbQuantizer {
    fn name(&self) -> &'static str {
        "msb-mixed"
    }

    fn needs_calibration(&self) -> bool {
        false // diag_h is optional
    }

    fn quantize(&self, w: &Matrix, cfg: &QuantConfig) -> QuantizedTensor {
        self.run(w, cfg, None)
    }

    /// Mixed precision wraps the engine: the per-block solves (each its own
    /// width) fan out over the shared pool.
    fn quantize_with_pool(
        &self,
        w: &Matrix,
        cfg: &QuantConfig,
        pool: &ThreadPool,
    ) -> QuantizedTensor {
        self.run(w, cfg, Some(pool))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    /// Matrix with heterogeneous block sensitivity: some blocks carry 10x
    /// the energy.
    fn hetero(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::randn(rows, cols, &mut rng);
        for (bi, chunk) in w.data.chunks_mut(64).enumerate() {
            if bi % 7 == 0 {
                for v in chunk.iter_mut() {
                    *v *= 10.0;
                }
            }
        }
        w
    }

    #[test]
    fn budget_is_preserved() {
        let w = hetero(16, 256, 1);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let q = MixedMsbQuantizer::new(0.2).quantize(&w, &cfg);
        let uniform = MsbQuantizer::wgm().quantize(&w, &cfg);
        crate::testing::assert_close(q.effective_bits, uniform.effective_bits, 0.02, 0.0);
    }

    #[test]
    fn beats_uniform_on_weighted_error() {
        // mixed precision reallocates bits toward high-energy blocks, which
        // dominate the weighted (and here even the plain) SSE
        let w = hetero(32, 512, 2);
        let cfg = QuantConfig::block_wise(3, 64).unwrap().no_bf16();
        let mixed = MixedMsbQuantizer::new(0.15).quantize(&w, &cfg);
        let uniform = MsbQuantizer::wgm().quantize(&w, &cfg);
        assert!(
            mixed.mse(&w) < uniform.mse(&w),
            "mixed {} !< uniform {}",
            mixed.mse(&w),
            uniform.mse(&w)
        );
    }

    #[test]
    fn zero_hot_frac_equals_uniform() {
        let w = hetero(8, 128, 3);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let mixed = MixedMsbQuantizer::new(0.0).quantize(&w, &cfg);
        let uniform = MsbQuantizer::wgm().quantize(&w, &cfg);
        assert_eq!(mixed.dequant.data, uniform.dequant.data);
    }

    #[test]
    fn per_tensor_falls_back() {
        let w = hetero(8, 128, 4);
        let q = MixedMsbQuantizer::new(0.2).quantize(&w, &QuantConfig::per_tensor(6).unwrap());
        assert!(q.dequant.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn diag_h_changes_allocation() {
        let w = hetero(8, 128, 5);
        let cfg = QuantConfig::block_wise(3, 64).unwrap().no_bf16();
        let a = MixedMsbQuantizer::new(0.2).quantize(&w, &cfg);
        let mut d = vec![1.0f32; 128];
        for x in d.iter_mut().skip(64) {
            *x = 100.0;
        }
        let b = MixedMsbQuantizer::new(0.2).with_diag_h(d).quantize(&w, &cfg);
        assert_ne!(a.dequant.data, b.dequant.data);
    }
}
