//! Sensitivity-driven mixed-precision MSB — the BiLLM-inspired extension
//! the paper's §2.2 motivates: "under tight precision budgets, performance
//! depends ... on how representational capacity is allocated across groups
//! of heterogeneous sensitivity".
//!
//! Blocks are ranked by a sensitivity score (activation-weighted energy if
//! a Gram diagonal is available, else plain magnitude-variance); the top
//! `hot_frac` get one extra bit and an equal mass of the least sensitive
//! blocks gives one up, keeping the average bit budget at the base width.

use crate::tensor::Matrix;

use super::msb::MsbQuantizer;
use super::{finish_dequant, Granularity, QuantConfig, QuantizedTensor, Quantizer};

#[derive(Clone, Debug)]
pub struct MixedMsbQuantizer {
    pub hot_frac: f64,
    /// Optional diag(H) (len = cols) for activation-aware sensitivity.
    pub diag_h: Option<Vec<f32>>,
}

impl MixedMsbQuantizer {
    pub fn new(hot_frac: f64) -> Self {
        MixedMsbQuantizer { hot_frac: hot_frac.clamp(0.0, 0.5), diag_h: None }
    }

    pub fn with_diag_h(mut self, diag_h: Vec<f32>) -> Self {
        self.diag_h = Some(diag_h);
        self
    }

    /// Sensitivity of one block: Σ w² (· diag_h if available).
    fn sensitivity(&self, blk: &[f32], col0: usize, cols: usize) -> f64 {
        match &self.diag_h {
            Some(d) => blk
                .iter()
                .enumerate()
                .map(|(i, &v)| (v as f64) * (v as f64) * d[(col0 + i) % cols] as f64)
                .sum(),
            None => blk.iter().map(|&v| (v as f64) * (v as f64)).sum(),
        }
    }
}

impl Quantizer for MixedMsbQuantizer {
    fn name(&self) -> &'static str {
        "msb-mixed"
    }

    fn needs_calibration(&self) -> bool {
        false // diag_h is optional
    }

    fn quantize(&self, w: &Matrix, cfg: &QuantConfig) -> QuantizedTensor {
        let t = match cfg.granularity {
            Granularity::BlockWise { t } => t,
            Granularity::PerTensor => {
                // mixed precision needs blocks; whole-tensor falls back
                return MsbQuantizer::wgm().quantize(w, cfg);
            }
        };
        assert!(w.cols % t == 0);
        let n_blocks = w.len() / t;
        let n_hot = ((n_blocks as f64) * self.hot_frac) as usize;

        // rank blocks by sensitivity
        let mut order: Vec<usize> = (0..n_blocks).collect();
        let scores: Vec<f64> = w
            .row_blocks(t)
            .enumerate()
            .map(|(bi, blk)| self.sensitivity(blk, (bi * t) % w.cols, w.cols))
            .collect();
        order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
        // balance the *total* storage budget: promoting a block costs
        // 1 + L·16/t extra bits/weight (codes + doubled scale table) while
        // demoting refunds 1 + (L/2)·16/t — demote proportionally more.
        let l = cfg.levels() as f64;
        let cost_up = 1.0 + l * 16.0 / t as f64;
        let cost_down = 1.0 + (l / 2.0) * 16.0 / t as f64;
        let n_cold = (((n_hot as f64) * cost_up / cost_down).round() as usize)
            .min(n_blocks.saturating_sub(n_hot));
        let mut bits_of = vec![cfg.bits; n_blocks];
        for &bi in order.iter().take(n_hot) {
            bits_of[bi] = cfg.bits + 1;
        }
        for &bi in order.iter().rev().take(n_cold) {
            bits_of[bi] = cfg.bits.saturating_sub(1).max(1);
        }

        // quantize each block at its assigned width
        let inner = MsbQuantizer::wgm();
        let mut dequant = Matrix::zeros(w.rows, w.cols);
        let mut bit_mass = 0.0f64;
        for (bi, blk) in w.row_blocks(t).enumerate() {
            let bits = bits_of[bi];
            let bcfg = QuantConfig::block_wise(bits, t)
                .with_window(cfg.window)
                .with_lambda(cfg.lambda)
                .no_bf16();
            let bm = Matrix::from_vec(1, t, blk.to_vec());
            let q = inner.quantize(&bm, &bcfg);
            dequant.data[bi * t..(bi + 1) * t].copy_from_slice(&q.dequant.data);
            bit_mass += q.effective_bits * t as f64;
        }
        QuantizedTensor {
            method: self.name().to_string(),
            rows: w.rows,
            cols: w.cols,
            dequant: finish_dequant(dequant, cfg),
            effective_bits: bit_mass / w.len() as f64,
            msb: None, // variable-width payload: native path not modeled
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    /// Matrix with heterogeneous block sensitivity: some blocks carry 10x
    /// the energy.
    fn hetero(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::randn(rows, cols, &mut rng);
        for (bi, chunk) in w.data.chunks_mut(64).enumerate() {
            if bi % 7 == 0 {
                for v in chunk.iter_mut() {
                    *v *= 10.0;
                }
            }
        }
        w
    }

    #[test]
    fn budget_is_preserved() {
        let w = hetero(16, 256, 1);
        let cfg = QuantConfig::block_wise(4, 64);
        let q = MixedMsbQuantizer::new(0.2).quantize(&w, &cfg);
        let uniform = MsbQuantizer::wgm().quantize(&w, &cfg);
        crate::testing::assert_close(q.effective_bits, uniform.effective_bits, 0.02, 0.0);
    }

    #[test]
    fn beats_uniform_on_weighted_error() {
        // mixed precision reallocates bits toward high-energy blocks, which
        // dominate the weighted (and here even the plain) SSE
        let w = hetero(32, 512, 2);
        let cfg = QuantConfig::block_wise(3, 64).no_bf16();
        let mixed = MixedMsbQuantizer::new(0.15).quantize(&w, &cfg);
        let uniform = MsbQuantizer::wgm().quantize(&w, &cfg);
        assert!(
            mixed.mse(&w) < uniform.mse(&w),
            "mixed {} !< uniform {}",
            mixed.mse(&w),
            uniform.mse(&w)
        );
    }

    #[test]
    fn zero_hot_frac_equals_uniform() {
        let w = hetero(8, 128, 3);
        let cfg = QuantConfig::block_wise(4, 64).no_bf16();
        let mixed = MixedMsbQuantizer::new(0.0).quantize(&w, &cfg);
        let uniform = MsbQuantizer::wgm().quantize(&w, &cfg);
        assert_eq!(mixed.dequant.data, uniform.dequant.data);
    }

    #[test]
    fn per_tensor_falls_back() {
        let w = hetero(8, 128, 4);
        let q = MixedMsbQuantizer::new(0.2).quantize(&w, &QuantConfig::per_tensor(6));
        assert!(q.dequant.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn diag_h_changes_allocation() {
        let w = hetero(8, 128, 5);
        let cfg = QuantConfig::block_wise(3, 64).no_bf16();
        let a = MixedMsbQuantizer::new(0.2).quantize(&w, &cfg);
        let mut d = vec![1.0f32; 128];
        for x in d.iter_mut().skip(64) {
            *x = 100.0;
        }
        let b = MixedMsbQuantizer::new(0.2).with_diag_h(d).quantize(&w, &cfg);
        assert_ne!(a.dequant.data, b.dequant.data);
    }
}
