//! The block-partitioned quantization engine.
//!
//! Block-wise quantization (paper §4: independent `t`-element row groups)
//! is embarrassingly parallel, and *every* calibration-free method in the
//! zoo shares the same structure: slice the matrix into independent block
//! instances, quantize each, reassemble. This module owns that structure
//! once:
//!
//! * [`BlockPlan`] — the layout: per-tensor = one instance, block-wise =
//!   `rows·cols/t` instances of `t` consecutive elements per row;
//! * [`BlockQuantizer`] — the narrowed per-method trait: quantize one block
//!   (or, for methods with reusable scratch state like MSB, one *tile* of
//!   contiguous blocks);
//! * the drivers — [`quantize_serial`] (one tile covering every block) and
//!   [`quantize_pooled`] (tiles fanned out over the shared
//!   [`ThreadPool`] with deterministic, input-ordered reassembly).
//!
//! The engine centralizes what the methods used to duplicate: the bf16
//! decode finish, effective-bits accounting, and MSB `(codes, scales)`
//! payload assembly. Ported methods wire their public
//! [`Quantizer`](super::Quantizer) impl to the drivers with
//! `impl_quantizer_via_engine!`, which guarantees the public `quantize`
//! path *is* the engine path — serial and pooled execution are
//! bit-identical because every block is computed by the same code on the
//! same bytes, only scheduled differently.
//!
//! GPTQ stays outside the engine: its column-sequential error propagation
//! couples the whole matrix, so it cannot be block-partitioned.

use std::sync::mpsc;
use std::sync::Arc;

use crate::pool::ThreadPool;
use crate::tensor::Matrix;

use super::packing::{PackSpec, PackedTensor};
use super::{finish_dequant, Granularity, MsbPayload, QuantConfig, QuantizedTensor};

/// How a `rows × cols` matrix splits into independent block instances.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockPlan {
    pub rows: usize,
    pub cols: usize,
    /// Elements per independent block instance.
    pub block: usize,
    /// Number of block instances (`rows·cols / block`).
    pub n_blocks: usize,
    /// Whether the whole tensor is a single instance.
    pub per_tensor: bool,
}

impl BlockPlan {
    /// The layout implied by the config granularity.
    pub fn from_config(rows: usize, cols: usize, cfg: &QuantConfig) -> Self {
        match cfg.granularity {
            Granularity::PerTensor => BlockPlan::per_tensor(rows, cols),
            Granularity::BlockWise { t } => BlockPlan::block_wise(rows, cols, t),
        }
    }

    /// One instance spanning the whole tensor.
    pub fn per_tensor(rows: usize, cols: usize) -> Self {
        let block = (rows * cols).max(1);
        BlockPlan { rows, cols, block, n_blocks: usize::from(rows * cols > 0), per_tensor: true }
    }

    /// `t` consecutive elements per row form an instance; `t` must divide
    /// `cols` (the paper's row-aligned groups).
    pub fn block_wise(rows: usize, cols: usize, t: usize) -> Self {
        assert!(t > 0 && cols % t == 0, "block {t} must divide cols {cols}");
        BlockPlan { rows, cols, block: t, n_blocks: rows * cols / t, per_tensor: false }
    }

    /// Legacy flat chunking: `t`-element runs over the flattened tensor,
    /// with a short trailing block when `t` does not divide the element
    /// count and no row alignment — the pre-engine zoo behavior that
    /// BLOCKED-XNOR keeps so the Fig 2–5 sweeps can run matrices smaller
    /// than the block size.
    pub fn flat(rows: usize, cols: usize, t: usize) -> Self {
        assert!(t > 0, "flat block must be positive");
        BlockPlan { rows, cols, block: t, n_blocks: (rows * cols).div_ceil(t), per_tensor: false }
    }

    /// The MSB scale-table stripe: the per-tensor payload is organized per
    /// `cols` (one stripe per row), block-wise per `t`. This is the `block`
    /// field of [`MsbPayload`] and the storage-accounting denominator.
    pub fn payload_block(&self) -> usize {
        if self.per_tensor {
            self.cols
        } else {
            self.block
        }
    }

}

/// Blocks per pool job: ~4 tiles per worker so stragglers rebalance,
/// without degenerating to per-block jobs on large matrices. Shared by the
/// engine drivers and by engine wrappers with their own block loops
/// (mixed precision).
pub fn tile_size(n_blocks: usize, threads: usize) -> usize {
    let target_tiles = threads.max(1) * 4;
    n_blocks.div_ceil(target_tiles).max(1)
}

/// Per-block metadata returned by [`BlockQuantizer::quantize_block`].
/// Plain uniform/codebook methods return [`BlockMeta::default`]; MSB fills
/// the scale table (padded to the level count) and the i8 codes.
#[derive(Clone, Debug, Default)]
pub struct BlockMeta {
    /// MSB scales for this block, padded to `cfg.levels()` entries.
    pub scales: Vec<f32>,
    /// MSB i8 codes, one per element; `None` when not exportable (level
    /// count exceeds i8) or the method has no code payload.
    pub codes: Option<Vec<i8>>,
}

/// Concatenated metadata for a contiguous run of blocks (one tile).
#[derive(Clone, Debug)]
pub struct TileMeta {
    pub scales: Vec<f32>,
    pub codes: Option<Vec<i8>>,
}

impl TileMeta {
    pub fn new() -> Self {
        TileMeta { scales: Vec::new(), codes: Some(Vec::new()) }
    }

    /// Append one block's metadata; a single non-exportable block disables
    /// the code payload for the whole run.
    pub fn push(&mut self, m: BlockMeta) {
        self.append(TileMeta { scales: m.scales, codes: m.codes });
    }

    /// Concatenate another run's metadata (same disabling rule as `push`).
    fn append(&mut self, other: TileMeta) {
        self.scales.extend(other.scales);
        match other.codes {
            Some(cs) => {
                if let Some(out) = self.codes.as_mut() {
                    out.extend(cs);
                }
            }
            None => self.codes = None,
        }
    }
}

impl Default for TileMeta {
    fn default() -> Self {
        Self::new()
    }
}

/// A quantization method expressed per block — the narrow interface every
/// calibration-free method implements. The engine owns slicing, threading,
/// reassembly, bf16 finishing and payload/storage accounting.
pub trait BlockQuantizer: Send + Sync {
    fn name(&self) -> &'static str;

    /// The method's layout. Defaults to the config granularity; XNOR
    /// overrides (whole-tensor α ignores the granularity).
    fn plan(&self, rows: usize, cols: usize, cfg: &QuantConfig) -> BlockPlan {
        BlockPlan::from_config(rows, cols, cfg)
    }

    /// Quantize one block: write the dequantized values into `out`
    /// (`out.len() == data.len()`) and return the block's metadata.
    fn quantize_block(&self, data: &[f32], out: &mut [f32], cfg: &QuantConfig) -> BlockMeta;

    /// Quantize a contiguous run of `block`-sized blocks. Methods with
    /// reusable per-worker scratch state (MSB's sort/prefix/merge
    /// workspaces) override this; the default just loops
    /// [`BlockQuantizer::quantize_block`].
    fn quantize_tile(
        &self,
        data: &[f32],
        block: usize,
        out: &mut [f32],
        cfg: &QuantConfig,
    ) -> TileMeta {
        let mut meta = TileMeta::new();
        for (blk, o) in data.chunks(block).zip(out.chunks_mut(block)) {
            meta.push(self.quantize_block(blk, o, cfg));
        }
        meta
    }

    /// Storage cost in bits/weight for the whole tensor under `plan`.
    fn effective_bits(&self, cfg: &QuantConfig, plan: &BlockPlan) -> f64;

    /// Whether the engine should attach an [`MsbPayload`] built from the
    /// per-block metadata.
    fn emits_msb_payload(&self) -> bool {
        false
    }

    /// Deployable packed layout under `cfg`, or `None` when the method has
    /// no packed representation (the zero dummy, grids whose codes
    /// overflow i8). Methods returning `Some` must implement
    /// [`BlockQuantizer::decode_block`] and fill [`BlockMeta::codes`] /
    /// [`BlockMeta::scales`] when [`QuantConfig::emit_packed`] is set.
    fn pack_spec(&self, cfg: &QuantConfig) -> Option<PackSpec> {
        let _ = cfg;
        None
    }

    /// Inverse of the packed emission: reconstruct one block from its i8
    /// codes and scale-table entries using exactly the arithmetic
    /// `quantize_block` used, so decode(pack(W)) is bit-identical to the
    /// simulated dequant. Exception-listed exact zeros and the bf16
    /// finish are applied by the caller ([`decode_packed`]).
    fn decode_block(&self, codes: &[i8], scales: &[f32], out: &mut [f32]) {
        let _ = (codes, scales, out);
        unimplemented!("{}: no packed decode path", self.name());
    }
}

/// Serial engine driver: one tile covering every block. This is the
/// reference execution order; the pooled driver must match it bit-for-bit.
pub fn quantize_serial(q: &dyn BlockQuantizer, w: &Matrix, cfg: &QuantConfig) -> QuantizedTensor {
    let plan = q.plan(w.rows, w.cols, cfg);
    let mut dequant = Matrix::zeros(w.rows, w.cols);
    let meta = q.quantize_tile(&w.data, plan.block, &mut dequant.data, cfg);
    assemble(q, cfg, &plan, dequant, meta)
}

/// Tiling geometry for scheduling one layer's blocks as pool jobs — the
/// unit the model-global scheduler (`pipeline`) enqueues without blocking.
#[derive(Clone, Copy, Debug)]
pub struct TileLayout {
    pub plan: BlockPlan,
    /// Blocks per job (see [`tile_size`]).
    pub tile: usize,
    pub n_tiles: usize,
}

/// Compute the layout a `threads`-worker pool would execute for this
/// method/config/shape. Deterministic in `threads`, so results stay
/// bit-identical for a fixed worker count — and block independence makes
/// them identical across worker counts too (asserted by tests).
pub fn tile_layout(
    q: &dyn BlockQuantizer,
    rows: usize,
    cols: usize,
    cfg: &QuantConfig,
    threads: usize,
) -> TileLayout {
    let plan = q.plan(rows, cols, cfg);
    let tile = tile_size(plan.n_blocks, threads);
    let n_tiles = plan.n_blocks.div_ceil(tile.max(1)).max(1);
    TileLayout { plan, tile, n_tiles }
}

/// Quantize tile `ti` of `layout` (a contiguous run of blocks) out of the
/// full layer buffer; returns the tile's dequant chunk plus metadata. The
/// worker-side kernel of both the pooled driver and the global scheduler.
pub fn run_tile(
    q: &dyn BlockQuantizer,
    data: &[f32],
    cfg: &QuantConfig,
    layout: &TileLayout,
    ti: usize,
) -> (Vec<f32>, TileMeta) {
    let tile_elems = layout.tile * layout.plan.block;
    let start = ti * tile_elems;
    let end = ((ti + 1) * tile_elems).min(data.len());
    let mut out = vec![0.0f32; end - start];
    let meta = q.quantize_tile(&data[start..end], layout.plan.block, &mut out, cfg);
    (out, meta)
}

/// Input-ordered reassembly of per-tile outputs into the finished tensor:
/// identical to the serial driver's epilogue (bf16 finish, accounting,
/// payload assembly), so any scheduler that supplies tiles in input order
/// reproduces [`quantize_serial`] bit-for-bit.
pub fn assemble_tiles(
    q: &dyn BlockQuantizer,
    cfg: &QuantConfig,
    plan: &BlockPlan,
    tiles: impl IntoIterator<Item = (Vec<f32>, TileMeta)>,
) -> QuantizedTensor {
    let mut dequant = Matrix::zeros(plan.rows, plan.cols);
    let mut meta = TileMeta::new();
    let mut off = 0usize;
    for (out, m) in tiles {
        dequant.data[off..off + out.len()].copy_from_slice(&out);
        off += out.len();
        meta.append(m);
    }
    assemble(q, cfg, plan, dequant, meta)
}

/// Pooled engine driver: slices the plan into tiles, runs them on `pool`,
/// and reassembles in input order — deterministic and bit-identical to
/// [`quantize_serial`] regardless of worker count or completion order.
/// Worker panics are re-raised on the calling thread.
pub fn quantize_pooled(
    q: Arc<dyn BlockQuantizer>,
    w: &Matrix,
    cfg: &QuantConfig,
    pool: &ThreadPool,
) -> QuantizedTensor {
    let layout = tile_layout(&*q, w.rows, w.cols, cfg, pool.threads());
    if layout.plan.n_blocks <= 1 || pool.threads() <= 1 || layout.n_tiles <= 1 {
        return quantize_serial(&*q, w, cfg);
    }

    // One full copy of the layer: pool jobs need `'static` data. The memcpy
    // is orders of magnitude cheaper than the per-block solves it unblocks.
    let data: Arc<Vec<f32>> = Arc::new(w.data.clone());
    let shared_cfg = Arc::new(cfg.clone());
    let jobs: Vec<_> = (0..layout.n_tiles)
        .map(|ti| {
            let q = Arc::clone(&q);
            let data = Arc::clone(&data);
            let cfg = Arc::clone(&shared_cfg);
            move || run_tile(&*q, &data, &cfg, &layout, ti)
        })
        .collect();
    let tiles = pool_ordered_map(pool, jobs);
    assemble_tiles(&*q, cfg, &layout.plan, tiles)
}

/// Run `jobs` on `pool`, returning results in input order regardless of
/// completion order. The whole batch is enqueued with one
/// [`ThreadPool::submit_many`] call (one stripe-lock acquisition per
/// worker stripe rather than per job). Worker panics are caught per job
/// and re-raised here, so callers see the same panic they would on the
/// serial path.
pub fn pool_ordered_map<R, F>(pool: &ThreadPool, jobs: Vec<F>) -> Vec<R>
where
    R: Send + 'static,
    F: FnOnce() -> R + Send + 'static,
{
    let n = jobs.len();
    let (tx, rx) = mpsc::channel();
    pool.submit_many(jobs.into_iter().enumerate().map(|(i, job)| {
        let tx = tx.clone();
        move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            let _ = tx.send((i, r));
        }
    }));
    drop(tx);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let (i, r) = rx.recv().expect("engine job result lost");
        match r {
            Ok(v) => slots[i] = Some(v),
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }
    slots.into_iter().map(|o| o.expect("engine job slot unfilled")).collect()
}

/// Centralized finishing: bf16 decode round-trip, storage accounting, MSB
/// payload assembly and (when requested) packed-payload assembly from the
/// concatenated per-block metadata — all in deterministic plan order.
fn assemble(
    q: &dyn BlockQuantizer,
    cfg: &QuantConfig,
    plan: &BlockPlan,
    dequant: Matrix,
    meta: TileMeta,
) -> QuantizedTensor {
    let packed = match (cfg.emit_packed, q.pack_spec(cfg), &meta.codes) {
        (true, Some(spec), Some(codes)) => Some(PackedTensor::from_codes(
            q.name(),
            plan,
            &spec,
            cfg.bf16,
            codes,
            &meta.scales,
        )),
        _ => None,
    };
    let msb = if q.emits_msb_payload() {
        Some(MsbPayload {
            codes: meta.codes,
            scales: meta.scales,
            levels: cfg.levels(),
            block: plan.payload_block(),
        })
    } else {
        None
    };
    QuantizedTensor {
        method: q.name().to_string(),
        rows: plan.rows,
        cols: plan.cols,
        dequant: finish_dequant(dequant, cfg),
        effective_bits: q.effective_bits(cfg, plan),
        msb,
        packed,
    }
}

/// Reusable scratch for the packed decode path: the unpacked i8 code and
/// f32 scale buffers. §Perf: the decode loop used to allocate (and, for
/// bit-packed payloads, double-allocate via an intermediate symbol
/// vector) fresh buffers for every tensor; threading one scratch through
/// a model's layer loop ([`crate::pipeline::decode_packed_model`]) or a
/// bench's repeat loop reuses the high-water-mark allocation instead.
/// Pooled decodes move the buffers into `Arc`s for the tile jobs and
/// recover them once the tiles drain.
#[derive(Default)]
pub struct DecodeScratch {
    codes: Vec<i8>,
    scales: Vec<f32>,
}

/// Reconstruct the dequantized weights from a packed payload — the
/// serving-path inverse of the quantize drivers. Blocks are decoded via
/// the same [`BlockPlan`] geometry, fanned over `pool` in tiles with
/// input-ordered reassembly; serial and pooled decode are bit-identical,
/// and both reproduce the simulated-dequant output the payload was
/// emitted alongside exactly (`==` on every element; the one bit pattern
/// that can legitimately differ is the sign of a rounded-to-zero value,
/// which codes cannot carry and `-0.0 == 0.0` erases).
pub fn decode_packed(
    q: Arc<dyn BlockQuantizer>,
    pt: &PackedTensor,
    pool: Option<&ThreadPool>,
) -> Matrix {
    decode_packed_with_scratch(q, pt, pool, &mut DecodeScratch::default())
}

/// [`decode_packed`] with caller-owned scratch buffers — see
/// [`DecodeScratch`] for when reuse pays.
pub fn decode_packed_with_scratch(
    q: Arc<dyn BlockQuantizer>,
    pt: &PackedTensor,
    pool: Option<&ThreadPool>,
    scratch: &mut DecodeScratch,
) -> Matrix {
    let n = pt.n_elems();
    let mut out = Matrix::zeros(pt.rows, pt.cols);
    if n == 0 {
        return out;
    }
    let mut codes = std::mem::take(&mut scratch.codes);
    pt.unpacked_codes_into(&mut codes);
    let mut scales = std::mem::take(&mut scratch.scales);
    pt.scales_f32_into(&mut scales);
    let block = pt.block.max(1);
    let spb = pt.scales_per_block;
    let n_blocks = pt.n_blocks();
    let threads = pool.map_or(1, |p| p.threads());
    let tile = tile_size(n_blocks, threads);
    let n_tiles = n_blocks.div_ceil(tile).max(1);
    if threads <= 1 || n_tiles <= 1 {
        decode_blocks(&*q, &codes, &scales, block, spb, 0..n_blocks, &mut out.data);
        scratch.codes = codes;
        scratch.scales = scales;
    } else {
        let pool = pool.expect("threads > 1 implies a pool");
        let codes = Arc::new(codes);
        let scales = Arc::new(scales);
        let jobs: Vec<_> = (0..n_tiles)
            .map(|ti| {
                let q = Arc::clone(&q);
                let codes = Arc::clone(&codes);
                let scales = Arc::clone(&scales);
                move || {
                    let b0 = ti * tile;
                    let b1 = ((ti + 1) * tile).min(n_blocks);
                    let start = b0 * block;
                    let end = (b1 * block).min(codes.len());
                    let mut chunk = vec![0.0f32; end - start];
                    decode_blocks(&*q, &codes, &scales, block, spb, b0..b1, &mut chunk);
                    chunk
                }
            })
            .collect();
        let chunks = pool_ordered_map(pool, jobs);
        let mut off = 0usize;
        for c in chunks {
            out.data[off..off + c.len()].copy_from_slice(&c);
            off += c.len();
        }
        // every job has finished and dropped its clones (results arrive
        // only after the closure consumed them), so the buffers come back
        // for the next layer; fall through to fresh ones if not
        if let Ok(v) = Arc::try_unwrap(codes) {
            scratch.codes = v;
        }
        if let Ok(v) = Arc::try_unwrap(scales) {
            scratch.scales = v;
        }
    }
    for &z in &pt.zeros {
        out.data[z as usize] = 0.0;
    }
    if pt.bf16 {
        for v in &mut out.data {
            *v = crate::tensor::bf16::round(*v);
        }
    }
    out
}

/// Decode a contiguous run of blocks; `out` covers exactly blocks `range`
/// of the tensor (tail-block tolerant for flat plans).
fn decode_blocks(
    q: &dyn BlockQuantizer,
    codes: &[i8],
    scales: &[f32],
    block: usize,
    spb: usize,
    range: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let base = range.start * block;
    for bi in range {
        let s = bi * block;
        let e = (s + block).min(codes.len());
        let sc = &scales[bi * spb..(bi + 1) * spb];
        q.decode_block(&codes[s..e], sc, &mut out[s - base..e - base]);
    }
}

/// Wire a [`BlockQuantizer`] into the public [`Quantizer`] trait via the
/// engine drivers: `quantize` is the serial path, `quantize_with_pool` the
/// tiled one. (A blanket impl would collide under coherence with the
/// hand-written `Quantizer` impls for GPTQ / mixed / scaled, so each
/// ported method invokes this macro instead.)
macro_rules! impl_quantizer_via_engine {
    ($ty:ty) => {
        impl crate::quant::Quantizer for $ty {
            fn name(&self) -> &'static str {
                crate::quant::engine::BlockQuantizer::name(self)
            }

            fn quantize(
                &self,
                w: &crate::tensor::Matrix,
                cfg: &crate::quant::QuantConfig,
            ) -> crate::quant::QuantizedTensor {
                crate::quant::engine::quantize_serial(self, w, cfg)
            }

            fn quantize_with_pool(
                &self,
                w: &crate::tensor::Matrix,
                cfg: &crate::quant::QuantConfig,
                pool: &crate::pool::ThreadPool,
            ) -> crate::quant::QuantizedTensor {
                crate::quant::engine::quantize_pooled(
                    std::sync::Arc::new(self.clone()),
                    w,
                    cfg,
                    pool,
                )
            }
        }
    };
}
pub(crate) use impl_quantizer_via_engine;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::hqq::HqqQuantizer;
    use crate::quant::msb::MsbQuantizer;
    use crate::quant::nf4::Nf4Quantizer;
    use crate::quant::rtn::RtnQuantizer;
    use crate::quant::xnor::{XnorQuantizer, ZeroQuantizer};
    use crate::quant::Quantizer;
    use crate::stats::Rng;

    fn weight(rows: usize, cols: usize, seed: u64) -> Matrix {
        Matrix::randn(rows, cols, &mut Rng::new(seed))
    }

    #[test]
    fn plan_shapes() {
        let p = BlockPlan::per_tensor(16, 64);
        assert_eq!((p.block, p.n_blocks, p.payload_block()), (1024, 1, 64));
        let b = BlockPlan::block_wise(16, 128, 64);
        assert_eq!((b.block, b.n_blocks, b.payload_block()), (64, 32, 64));
        assert!(!b.per_tensor && p.per_tensor);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn plan_rejects_non_dividing_block() {
        BlockPlan::block_wise(4, 100, 64);
    }

    #[test]
    fn plan_from_config_follows_granularity() {
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        assert_eq!(BlockPlan::from_config(8, 256, &cfg), BlockPlan::block_wise(8, 256, 64));
        let cfg = QuantConfig::per_tensor(6).unwrap();
        assert_eq!(BlockPlan::from_config(8, 256, &cfg), BlockPlan::per_tensor(8, 256));
    }

    #[test]
    fn flat_plan_tolerates_short_tail() {
        // the Fig 2–5 sweeps run blocked-XNOR on matrices smaller than t
        let p = BlockPlan::flat(4, 5, 8);
        assert_eq!((p.block, p.n_blocks), (8, 3)); // 8, 8, 4 elements
        let w = weight(4, 5, 15);
        let cfg = QuantConfig::block_wise(4, 8).unwrap().no_bf16();
        let q = XnorQuantizer::blocked();
        let serial = q.quantize(&w, &cfg);
        assert!(serial.dequant.data.iter().all(|v| v.is_finite()));
        let pool = ThreadPool::new(2, 8);
        let pooled = q.quantize_with_pool(&w, &cfg, &pool);
        assert_eq!(serial.dequant.data, pooled.dequant.data);
    }

    /// Pre-refactor reference: the plain chunk-by-chunk serial loop every
    /// method used to hand-roll, built only from `quantize_block`. The
    /// engine (serial and pooled, default and overridden tile paths) must
    /// reproduce it bit-for-bit — this is the golden-equivalence gate for
    /// the ported methods.
    fn reference_quantize(
        q: &dyn BlockQuantizer,
        w: &Matrix,
        cfg: &QuantConfig,
    ) -> (Matrix, TileMeta) {
        let plan = q.plan(w.rows, w.cols, cfg);
        let mut dequant = Matrix::zeros(w.rows, w.cols);
        let mut meta = TileMeta::new();
        for (blk, o) in w.data.chunks(plan.block).zip(dequant.data.chunks_mut(plan.block)) {
            meta.push(q.quantize_block(blk, o, cfg));
        }
        (finish_dequant(dequant, cfg), meta)
    }

    fn ported_methods() -> Vec<Box<dyn Quantizer>> {
        vec![
            Box::new(RtnQuantizer::symmetric()),
            Box::new(RtnQuantizer::asymmetric()),
            Box::new(Nf4Quantizer::nf4()),
            Box::new(HqqQuantizer::default()),
            Box::new(XnorQuantizer::whole()),
            Box::new(XnorQuantizer::blocked()),
            Box::new(MsbQuantizer::wgm()),
            Box::new(MsbQuantizer::gg()),
            Box::new(MsbQuantizer::wgm_lo()),
            Box::new(ZeroQuantizer),
        ]
    }

    fn block_views() -> Vec<Box<dyn BlockQuantizer>> {
        vec![
            Box::new(RtnQuantizer::symmetric()),
            Box::new(RtnQuantizer::asymmetric()),
            Box::new(Nf4Quantizer::nf4()),
            Box::new(HqqQuantizer::default()),
            Box::new(XnorQuantizer::whole()),
            Box::new(XnorQuantizer::blocked()),
            Box::new(MsbQuantizer::wgm()),
            Box::new(MsbQuantizer::gg()),
            Box::new(MsbQuantizer::wgm_lo()),
            Box::new(ZeroQuantizer),
        ]
    }

    fn configs_for(name: &str) -> Vec<QuantConfig> {
        if name.starts_with("bnb") {
            // fixed 4-bit codebook
            vec![QuantConfig::block_wise(4, 64).unwrap(), QuantConfig::per_tensor(4).unwrap()]
        } else {
            vec![QuantConfig::block_wise(4, 64).unwrap(), QuantConfig::per_tensor(4).unwrap().with_window(16).unwrap()]
        }
    }

    #[test]
    fn engine_matches_per_block_reference() {
        let w = weight(8, 128, 11);
        for q in block_views() {
            for cfg in configs_for(BlockQuantizer::name(&*q)) {
                let via_engine = quantize_serial(&*q, &w, &cfg);
                let (ref_dequant, ref_meta) = reference_quantize(&*q, &w, &cfg);
                assert_eq!(
                    via_engine.dequant.data,
                    ref_dequant.data,
                    "{} dequant",
                    BlockQuantizer::name(&*q)
                );
                if q.emits_msb_payload() {
                    let p = via_engine.msb.expect("payload");
                    assert_eq!(p.scales, ref_meta.scales);
                    assert_eq!(p.codes, ref_meta.codes);
                }
            }
        }
    }

    #[test]
    fn pooled_is_bit_identical_to_serial() {
        let w = weight(16, 256, 12);
        for threads in [2usize, 3, 5] {
            let pool = ThreadPool::new(threads, threads * 4);
            for q in ported_methods() {
                for cfg in configs_for(Quantizer::name(&*q)) {
                    let serial = q.quantize(&w, &cfg);
                    let pooled = q.quantize_with_pool(&w, &cfg, &pool);
                    assert_eq!(
                        serial.dequant.data,
                        pooled.dequant.data,
                        "{} threads={threads}",
                        Quantizer::name(&*q)
                    );
                    assert_eq!(serial.effective_bits, pooled.effective_bits);
                    match (serial.msb, pooled.msb) {
                        (Some(a), Some(b)) => {
                            assert_eq!(a.scales, b.scales);
                            assert_eq!(a.codes, b.codes);
                            assert_eq!((a.levels, a.block), (b.levels, b.block));
                        }
                        (None, None) => {}
                        _ => panic!("payload presence diverged"),
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_uses_multiple_jobs() {
        let w = weight(8, 256, 13);
        let mut pool = ThreadPool::new(4, 16);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let _ = RtnQuantizer::symmetric().quantize_with_pool(&w, &cfg, &pool);
        pool.shutdown();
        let (submitted, completed) = pool.stats();
        assert!(submitted > 1, "expected tile fan-out, got {submitted} job(s)");
        assert_eq!(submitted, completed);
    }

    #[test]
    #[should_panic(expected = "fixed 4-bit")]
    fn pooled_propagates_worker_panics() {
        let w = weight(4, 256, 14);
        let pool = ThreadPool::new(2, 8);
        let cfg = QuantConfig::block_wise(3, 64).unwrap();
        let _ = Nf4Quantizer::nf4().quantize_with_pool(&w, &cfg, &pool);
    }

    #[test]
    fn pool_ordered_map_preserves_order() {
        let pool = ThreadPool::new(4, 8);
        let jobs: Vec<_> = (0..37u64)
            .map(|i| {
                move || {
                    if i % 5 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(300));
                    }
                    i * 3
                }
            })
            .collect();
        let out = pool_ordered_map(&pool, jobs);
        assert_eq!(out, (0..37u64).map(|i| i * 3).collect::<Vec<_>>());
    }

    fn packable_arcs() -> Vec<Arc<dyn BlockQuantizer>> {
        vec![
            Arc::new(RtnQuantizer::symmetric()),
            Arc::new(RtnQuantizer::asymmetric()),
            Arc::new(Nf4Quantizer::nf4()),
            Arc::new(HqqQuantizer::default()),
            Arc::new(XnorQuantizer::whole()),
            Arc::new(XnorQuantizer::blocked()),
            Arc::new(MsbQuantizer::wgm()),
            Arc::new(MsbQuantizer::gg()),
            Arc::new(MsbQuantizer::wgm_lo()),
        ]
    }

    /// The tentpole's hard anchor: decode(pack(W)) must be bit-identical
    /// to the simulated-dequant output for every engine-ported method,
    /// under both granularities, serial and pooled (quantize AND decode),
    /// including exact-zero elements (the exception list).
    #[test]
    fn packed_roundtrip_bit_identical_to_simulated() {
        let mut w = weight(16, 256, 21);
        for i in (0..w.len()).step_by(97) {
            w.data[i] = 0.0; // exercise the exact-zero exception list
        }
        let pool = ThreadPool::new(4, 16);
        for q in packable_arcs() {
            let name = BlockQuantizer::name(&*q);
            for cfg in configs_for(name) {
                let cfg = cfg.with_packed();
                let serial = quantize_serial(&*q, &w, &cfg);
                let pt = serial.packed.clone().unwrap_or_else(|| panic!("{name}: no payload"));
                let dec = decode_packed(Arc::clone(&q), &pt, None);
                assert_eq!(dec.data, serial.dequant.data, "{name} serial decode");
                let pooled = quantize_pooled(Arc::clone(&q), &w, &cfg, &pool);
                assert_eq!(pooled.packed.as_ref(), Some(&pt), "{name} pooled payload");
                let dec_p = decode_packed(Arc::clone(&q), &pt, Some(&pool));
                assert_eq!(dec_p.data, serial.dequant.data, "{name} pooled decode");
            }
        }
    }

    /// Turning emission on must not perturb the simulated output: the
    /// payload rides alongside the dequant path, not instead of it.
    #[test]
    fn pack_emission_does_not_change_dequant() {
        let w = weight(8, 256, 22);
        for q in packable_arcs() {
            for cfg in configs_for(BlockQuantizer::name(&*q)) {
                let plain = quantize_serial(&*q, &w, &cfg);
                let emitting = quantize_serial(&*q, &w, &cfg.clone().with_packed());
                assert!(plain.packed.is_none());
                assert_eq!(
                    plain.dequant.data,
                    emitting.dequant.data,
                    "{} emission changed dequant",
                    BlockQuantizer::name(&*q)
                );
            }
        }
    }

    /// Measured payload bytes must reproduce the theoretical accounting
    /// for the paper's 4-bit grid (6.00 bits/weight for MSB at t=64).
    #[test]
    fn packed_accounting_agrees_with_theoretical_bits() {
        let mut w = weight(8, 256, 23);
        for v in &mut w.data {
            if *v == 0.0 {
                *v = 0.5; // exact zeros would add exception-list bytes
            }
        }
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        for q in packable_arcs() {
            let name = BlockQuantizer::name(&*q);
            let qt = quantize_serial(&*q, &w, &cfg);
            let pt = qt.packed.unwrap_or_else(|| panic!("{name}: no payload"));
            crate::testing::assert_close(pt.effective_bits(), qt.effective_bits, 1e-12, 0.0);
        }
        // XNOR's 1-bit codes now pack 8 signs/byte — the measured payload
        // hits the 1 + 16/64 = 1.25 bits/weight theoretical exactly (the
        // nibble floor of 4.25 is gone)
        let qt = quantize_serial(&XnorQuantizer::blocked(), &w, &cfg);
        let pt = qt.packed.unwrap();
        crate::testing::assert_close(pt.effective_bits(), 1.25, 1e-12, 0.0);
    }

    /// Sub-nibble widths end to end: 2-bit MSB (u2 codes) and 1-bit XNOR
    /// (u1 codes) must round-trip decode(pack(W)) bit-identically and hit
    /// their theoretical storage exactly.
    #[test]
    fn sub_nibble_packed_roundtrip() {
        let mut w = weight(8, 256, 25);
        w.data[17] = 0.0; // exception-list coverage at 1-bit width
        let cfg = QuantConfig::block_wise(2, 64).unwrap().with_window(1).unwrap().with_packed();
        let cases: Vec<(Arc<dyn BlockQuantizer>, f64)> = vec![
            // MSB at b=2: L=2 scales/block → 2 + 2·16/64 = 2.5 bits/wt
            (Arc::new(MsbQuantizer::wgm()), 2.5),
            // blocked XNOR: 1 + 16/64 = 1.25 bits/wt
            (Arc::new(XnorQuantizer::blocked()), 1.25),
        ];
        let pool = ThreadPool::new(3, 12);
        for (q, want_bits) in cases {
            let name = BlockQuantizer::name(&*q);
            let serial = quantize_serial(&*q, &w, &cfg);
            let pt = serial.packed.clone().unwrap_or_else(|| panic!("{name}: no payload"));
            let zero_bits = pt.zeros.len() as f64 * 32.0 / w.len() as f64;
            crate::testing::assert_close(pt.effective_bits(), want_bits + zero_bits, 1e-12, 0.0);
            let dec = decode_packed(Arc::clone(&q), &pt, None);
            assert_eq!(dec.data, serial.dequant.data, "{name} serial decode");
            let dec_p = decode_packed(Arc::clone(&q), &pt, Some(&pool));
            assert_eq!(dec_p.data, serial.dequant.data, "{name} pooled decode");
            let pooled = quantize_pooled(Arc::clone(&q), &w, &cfg, &pool);
            assert_eq!(pooled.packed.as_ref(), Some(&pt), "{name} pooled payload");
        }
    }

    /// Randomized property: for random shapes, zero densities and
    /// methods, decode(pack(W)) == simulated dequant, and the payload is
    /// invariant to the worker count.
    #[test]
    fn packed_roundtrip_property() {
        let pool = ThreadPool::new(3, 12);
        crate::testing::check(
            "packed roundtrip",
            12,
            |rng| {
                let rows = 1 + rng.below(8);
                let cols = 64 * (1 + rng.below(4));
                let mut w = Matrix::randn(rows, cols, rng);
                for v in &mut w.data {
                    if rng.uniform() < 0.03 {
                        *v = 0.0;
                    }
                }
                (w, rng.below(3))
            },
            |(w, pick)| {
                let q: Arc<dyn BlockQuantizer> = match *pick {
                    0 => Arc::new(MsbQuantizer::wgm()),
                    1 => Arc::new(RtnQuantizer::symmetric()),
                    _ => Arc::new(HqqQuantizer::default()),
                };
                let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
                let serial = quantize_serial(&*q, w, &cfg);
                let pt = serial.packed.expect("payload");
                let pooled = quantize_pooled(Arc::clone(&q), w, &cfg, &pool);
                let dec = decode_packed(Arc::clone(&q), &pt, Some(&pool));
                pooled.packed.as_ref() == Some(&pt) && dec.data == serial.dequant.data
            },
        );
    }

    /// Scratch-threaded decode is bit-identical to the fresh-buffer path,
    /// and the pooled variant actually recovers its buffers from the tile
    /// jobs (no per-call reallocation of the code vector).
    #[test]
    fn decode_scratch_reuse_is_bit_identical() {
        let mut w = weight(8, 256, 26);
        w.data[5] = 0.0;
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let q: Arc<dyn BlockQuantizer> = Arc::new(MsbQuantizer::wgm());
        let qt = quantize_serial(&*q, &w, &cfg);
        let pt = qt.packed.unwrap();
        let pool = ThreadPool::new(3, 12);
        let mut scratch = DecodeScratch::default();
        for pass in 0..3 {
            let serial = decode_packed_with_scratch(Arc::clone(&q), &pt, None, &mut scratch);
            assert_eq!(serial.data, qt.dequant.data, "pass {pass} serial");
            let pooled = decode_packed_with_scratch(q.clone(), &pt, Some(&pool), &mut scratch);
            assert_eq!(pooled.data, qt.dequant.data, "pass {pass} pooled");
            // buffers came back from the jobs and keep their capacity
            assert!(scratch.codes.capacity() >= w.len(), "pass {pass}: codes not recovered");
        }
    }

    #[test]
    fn zero_dummy_has_no_pack_spec() {
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        assert!(ZeroQuantizer.pack_spec(&cfg).is_none());
        let w = weight(4, 64, 24);
        assert!(quantize_serial(&ZeroQuantizer, &w, &cfg).packed.is_none());
    }

    #[test]
    fn tile_meta_code_overflow_disables_payload() {
        let mut meta = TileMeta::new();
        meta.push(BlockMeta { scales: vec![1.0], codes: Some(vec![1]) });
        meta.push(BlockMeta { scales: vec![2.0], codes: None });
        meta.push(BlockMeta { scales: vec![3.0], codes: Some(vec![2]) });
        assert_eq!(meta.scales, vec![1.0, 2.0, 3.0]);
        assert!(meta.codes.is_none());
    }
}
