//! Optional equivalent-transformation layer on top of any quantizer — the
//! paper's future work (iii): "integrating optional calibration and
//! transformation modules on top of MSB PTQ ... without changing the core
//! formulation".
//!
//! AWQ-style per-input-channel rescaling: choose positive scales `s_j`,
//! quantize `W' = W·diag(s)`, and decode `Ŵ = quant(W')·diag(s)⁻¹`. The
//! transform is function-preserving by construction (it cancels exactly in
//! the decode), but it redistributes quantization error toward channels
//! the scale marks as unimportant. Two scale policies:
//!
//! * [`ScalePolicy::ActivationAware`] — `s_j ∝ E[x_j²]^α` from the GPTQ
//!   calibration Gram diagonal (AWQ's salient-channel statistic);
//! * [`ScalePolicy::WeightAware`] — `s_j ∝ mean|W_{:,j}|^{-α}`,
//!   calibration-free (equalizes column magnitudes).

use crate::tensor::Matrix;

use super::{QuantConfig, QuantizedTensor, Quantizer};

#[derive(Clone, Debug)]
pub enum ScalePolicy {
    /// Gram-diagonal driven: needs `diag(H)` (len = cols) from calibration.
    ActivationAware { diag_h: Vec<f32>, alpha: f64 },
    /// Column-magnitude equalization, calibration-free.
    WeightAware { alpha: f64 },
}

pub struct ScaledQuantizer<Q: Quantizer> {
    pub inner: Q,
    pub policy: ScalePolicy,
}

impl<Q: Quantizer> ScaledQuantizer<Q> {
    pub fn new(inner: Q, policy: ScalePolicy) -> Self {
        ScaledQuantizer { inner, policy }
    }

    /// Per-column scales, normalized to geometric mean 1 so the transformed
    /// matrix stays in the same overall magnitude regime.
    pub fn column_scales(&self, w: &Matrix) -> Vec<f32> {
        let cols = w.cols;
        let mut s = vec![1.0f64; cols];
        match &self.policy {
            ScalePolicy::ActivationAware { diag_h, alpha } => {
                assert_eq!(diag_h.len(), cols, "diag(H) len != cols");
                for (j, sj) in s.iter_mut().enumerate() {
                    *sj = (diag_h[j].max(1e-12) as f64).powf(*alpha / 2.0);
                }
            }
            ScalePolicy::WeightAware { alpha } => {
                for j in 0..cols {
                    let mean_abs: f64 = (0..w.rows)
                        .map(|r| w.at(r, j).abs() as f64)
                        .sum::<f64>()
                        / w.rows as f64;
                    s[j] = mean_abs.max(1e-12).powf(-alpha);
                }
            }
        }
        // normalize: geometric mean 1
        let log_mean: f64 = s.iter().map(|&x| x.ln()).sum::<f64>() / cols as f64;
        let norm = log_mean.exp();
        s.iter().map(|&x| (x / norm) as f32).collect()
    }
}

impl<Q: Quantizer> ScaledQuantizer<Q> {
    /// Shared transform harness: scale columns, quantize via `run`, undo
    /// the transform in the decoded weights (exact cancellation), fix up
    /// method label / storage accounting.
    fn quantize_via(
        &self,
        w: &Matrix,
        run: impl FnOnce(&Matrix) -> QuantizedTensor,
    ) -> QuantizedTensor {
        let s = self.column_scales(w);
        let mut scaled = w.clone();
        for r in 0..w.rows {
            let row = &mut scaled.data[r * w.cols..(r + 1) * w.cols];
            for (v, &sj) in row.iter_mut().zip(&s) {
                *v *= sj;
            }
        }
        let mut qt = run(&scaled);
        // undo the transform in the decoded weights (exact cancellation)
        for r in 0..w.rows {
            let row = &mut qt.dequant.data[r * w.cols..(r + 1) * w.cols];
            for (v, &sj) in row.iter_mut().zip(&s) {
                *v /= sj;
            }
        }
        qt.method = format!("{}+{}", qt.method, match self.policy {
            ScalePolicy::ActivationAware { .. } => "awq",
            ScalePolicy::WeightAware { .. } => "eq",
        });
        // per-column bf16 scale shared by all rows
        qt.effective_bits += 16.0 / w.rows as f64;
        // the MSB payload refers to the *transformed* weights; native
        // execution would need the s vector folded into the activations,
        // which the simulated path does not model — drop it (and the
        // packed payload, whose codes also describe the scaled matrix).
        qt.msb = None;
        qt.packed = None;
        qt
    }
}

impl<Q: Quantizer> Quantizer for ScaledQuantizer<Q> {
    fn name(&self) -> &'static str {
        // static name constraint: report the family; the inner method is in
        // the QuantizedTensor.method string
        "scaled"
    }

    fn needs_calibration(&self) -> bool {
        matches!(self.policy, ScalePolicy::ActivationAware { .. })
            || self.inner.needs_calibration()
    }

    fn quantize(&self, w: &Matrix, cfg: &QuantConfig) -> QuantizedTensor {
        self.quantize_via(w, |scaled| self.inner.quantize(scaled, cfg))
    }

    /// The transform wraps the engine: block-parallel inner quantization of
    /// the scaled matrix, same pre/post transform.
    fn quantize_with_pool(
        &self,
        w: &Matrix,
        cfg: &QuantConfig,
        pool: &crate::pool::ThreadPool,
    ) -> QuantizedTensor {
        self.quantize_via(w, |scaled| self.inner.quantize_with_pool(scaled, cfg, pool))
    }
}

/// Weighted reconstruction error tr(Δ diag(h) Δᵀ) — the proxy the transform
/// is supposed to improve (errors weighted by activation energy).
pub fn weighted_sse(w: &Matrix, q: &Matrix, diag_h: &[f32]) -> f64 {
    assert_eq!(w.cols, diag_h.len());
    let mut acc = 0.0f64;
    for r in 0..w.rows {
        for c in 0..w.cols {
            let d = (w.at(r, c) - q.at(r, c)) as f64;
            acc += d * d * diag_h[c] as f64;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::msb::MsbQuantizer;
    use crate::quant::rtn::RtnQuantizer;
    use crate::stats::Rng;

    fn skewed_diag(cols: usize, seed: u64) -> Vec<f32> {
        // a few hot channels, like real activation statistics
        let mut rng = Rng::new(seed);
        (0..cols)
            .map(|_| {
                let base = rng.uniform() as f32 + 0.1;
                if rng.uniform() < 0.05 {
                    base * 100.0
                } else {
                    base
                }
            })
            .collect()
    }

    #[test]
    fn scales_normalized_to_geomean_one() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(32, 64, &mut rng);
        let q = ScaledQuantizer::new(
            RtnQuantizer::symmetric(),
            ScalePolicy::WeightAware { alpha: 0.5 },
        );
        let s = q.column_scales(&w);
        let log_mean: f64 = s.iter().map(|&x| (x as f64).ln()).sum::<f64>() / 64.0;
        crate::testing::assert_close(log_mean.exp(), 1.0, 1e-4, 0.0);
        assert!(s.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn awq_improves_weighted_error() {
        // the transform's raison d'être: lower activation-weighted error
        let mut rng = Rng::new(2);
        let w = Matrix::randn(64, 128, &mut rng);
        let diag = skewed_diag(128, 3);
        let cfg = QuantConfig::block_wise(3, 64).unwrap().no_bf16();
        let plain = RtnQuantizer::symmetric().quantize(&w, &cfg);
        let scaled = ScaledQuantizer::new(
            RtnQuantizer::symmetric(),
            ScalePolicy::ActivationAware { diag_h: diag.clone(), alpha: 0.5 },
        )
        .quantize(&w, &cfg);
        let (a, b) = (
            weighted_sse(&w, &plain.dequant, &diag),
            weighted_sse(&w, &scaled.dequant, &diag),
        );
        assert!(b < a, "awq-weighted {b} !< plain {a}");
    }

    #[test]
    fn transform_composes_with_msb() {
        // future work (iii): the transform slots on top of MSB unchanged
        let mut rng = Rng::new(4);
        let w = Matrix::randn(32, 128, &mut rng);
        let diag = skewed_diag(128, 5);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let q = ScaledQuantizer::new(
            MsbQuantizer::wgm(),
            ScalePolicy::ActivationAware { diag_h: diag.clone(), alpha: 0.5 },
        )
        .quantize(&w, &cfg);
        assert_eq!(q.method, "msb-wgm+awq");
        assert!(q.dequant.data.iter().all(|v| v.is_finite()));
        // function preservation: unweighted error stays in the same regime
        let plain = MsbQuantizer::wgm().quantize(&w, &cfg);
        assert!(q.mse(&w) < plain.mse(&w) * 3.0);
    }

    #[test]
    fn weight_aware_is_calibration_free() {
        let q = ScaledQuantizer::new(
            MsbQuantizer::wgm(),
            ScalePolicy::WeightAware { alpha: 0.3 },
        );
        assert!(!q.needs_calibration());
        let q2 = ScaledQuantizer::new(
            RtnQuantizer::symmetric(),
            ScalePolicy::ActivationAware { diag_h: vec![1.0; 4], alpha: 0.5 },
        );
        assert!(q2.needs_calibration());
    }

    #[test]
    fn identity_scales_change_nothing() {
        let mut rng = Rng::new(6);
        let w = Matrix::randn(16, 64, &mut rng);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().no_bf16();
        let scaled = ScaledQuantizer::new(
            RtnQuantizer::symmetric(),
            ScalePolicy::ActivationAware { diag_h: vec![2.0; 64], alpha: 0.5 },
        )
        .quantize(&w, &cfg);
        let plain = RtnQuantizer::symmetric().quantize(&w, &cfg);
        for (a, b) in scaled.dequant.data.iter().zip(&plain.dequant.data) {
            crate::testing::assert_close(*a as f64, *b as f64, 1e-5, 1e-7);
        }
    }
}
