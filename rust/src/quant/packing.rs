//! Bit packing, storage accounting, and the deployable packed-payload type.
//!
//! The paper evaluates *simulated* quantization (decoded bf16) but reports
//! effective bits/weight from the storage layout: b-bit codes + bf16 scales
//! (§4.1: 6.00 bits/weight at b=4, L=8, t=64). This module owns both sides
//! of that story:
//!
//! * the accounting formulas the quantizers advertise
//!   ([`msb_effective_bits`] & friends), and
//! * [`PackedTensor`] — the real payload the engine emits: bit-packed
//!   codes at their true width (u1 for XNOR signs, u2 for 2-bit MSB,
//!   nibble-packed u4 for 3–4-bit codes, i8 bytes otherwise), a bf16 (or,
//!   for the BnB absmax, f32) scale table in deterministic [`BlockPlan`]
//!   order, and an exact-zero exception list. Its
//!   [`PackedTensor::effective_bits`] is *measured from the serialized
//!   bytes* and must agree with the theoretical `*_effective_bits` for
//!   both the paper's 4-bit grid and the sub-nibble widths.
//!
//! Decoding a packed tensor (`engine::decode_packed`) reproduces the
//! simulated-dequant weights bit-identically: scale metadata is rounded
//! through its storage dtype at quantize time, so the decode arithmetic is
//! the quantize arithmetic.

use super::engine::BlockPlan;
use crate::tensor::bf16;

/// Effective bits/weight for MSB: `b + L·16/t` block-wise (bf16 scales),
/// or `b + L·16/total` per-tensor (metadata amortized over the tensor).
/// Paper §4.1: b=4, L=8, t=64 → 6.00 bits/weight.
pub fn msb_effective_bits(
    bits: u32,
    levels: usize,
    block: usize,
    total: usize,
    per_tensor: bool,
) -> f64 {
    let denom = if per_tensor { total } else { block };
    bits as f64 + (levels as f64) * 16.0 / denom as f64
}

/// MSB with double quantization of the scales (Appendix G): scales become
/// `scale_bits`-bit codes + bf16 meta over `scale_block`-sized groups:
/// per-scale cost = scale_bits + 32·16/scale_block; paper: 6 + 32·16/2048
/// = 6.25 bits/scale → 4 + 8·6.25/64 = 4.78 bits/weight.
pub fn msb_dq_effective_bits(
    bits: u32,
    levels: usize,
    block: usize,
    scale_bits: u32,
    scale_levels: usize,
    scale_block: usize,
) -> f64 {
    let per_scale = scale_bits as f64 + (scale_levels as f64) * 16.0 / scale_block as f64;
    bits as f64 + (levels as f64) * per_scale / block as f64
}

/// RTN / uniform: b-bit codes + one bf16 scale (+ one bf16 zero-point if
/// asymmetric) per block.
pub fn uniform_effective_bits(bits: u32, block: usize, asymmetric: bool) -> f64 {
    let meta = if asymmetric { 32.0 } else { 16.0 };
    bits as f64 + meta / block as f64
}

/// BnB-style NF4/FP4: 4-bit codes + one f32 absmax per block (the bnb
/// layout keeps absmax in fp32 unless double-quantized).
pub fn nf4_effective_bits(block: usize) -> f64 {
    4.0 + 32.0 / block as f64
}

// ---------------------------------------------------------------------------
// Sub-byte packing: 1/2/4-bit symbols, LSB-first within each byte.
// ---------------------------------------------------------------------------

/// Pack `width`-bit unsigned symbols (width ∈ {1, 2, 4}) LSB-first within
/// each byte — the generalization of nibble packing that lets 1-bit XNOR
/// signs and 2-bit MSB codes escape the nibble floor. `width = 4` is
/// byte-compatible with the historical [`pack_nibbles`] layout (low
/// nibble first).
pub fn pack_bits(codes: &[u8], width: u32) -> Vec<u8> {
    assert!(matches!(width, 1 | 2 | 4), "unsupported pack width {width}");
    let per = (8 / width) as usize;
    let mask = (1u8 << width) - 1;
    let mut out = vec![0u8; codes.len().div_ceil(per)];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c <= mask, "symbol {c} exceeds {width}-bit width");
        out[i / per] |= (c & mask) << ((i % per) as u32 * width);
    }
    out
}

/// Inverse of [`pack_bits`]; `n` is the original symbol count.
pub fn unpack_bits(packed: &[u8], n: usize, width: u32) -> Vec<u8> {
    assert!(matches!(width, 1 | 2 | 4), "unsupported pack width {width}");
    let per = (8 / width) as usize;
    debug_assert_eq!(packed.len(), n.div_ceil(per), "packed len != ceil(n/{per})");
    let mask = (1u8 << width) - 1;
    (0..n).map(|i| (packed[i / per] >> ((i % per) as u32 * width)) & mask).collect()
}

/// Pack unsigned 4-bit values (0..16) two-per-byte, low nibble first.
pub fn pack_nibbles(codes: &[u8]) -> Vec<u8> {
    pack_bits(codes, 4)
}

/// Inverse of [`pack_nibbles`]; `n` is the original code count.
pub fn unpack_nibbles(packed: &[u8], n: usize) -> Vec<u8> {
    unpack_bits(packed, n, 4)
}

/// Storage width in bits for a logical code width: sub-nibble codes pack
/// tightly (1-bit XNOR signs, 2-bit MSB), 3–4-bit codes share the nibble
/// layout, anything wider stays i8 bytes (`None`).
pub fn storage_width(code_bits: u32) -> Option<u32> {
    match code_bits {
        1 => Some(1),
        2 => Some(2),
        3 | 4 => Some(4),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Code schemes: how per-element i8 codes map to packed unsigned symbols.
// ---------------------------------------------------------------------------

/// Mapping between a method's per-element i8 codes and the unsigned
/// symbols stored in a packed payload of `width` bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodeScheme {
    /// Codes are already unsigned grid indices `0..2^width` (RTN-asym /
    /// HQQ affine grids, NF4 codebook indices): symbol = code.
    Unsigned,
    /// Symmetric signed grid with a representable zero (RTN):
    /// symbol = `neg << (width-1) | |code|`.
    SignMagnitude,
    /// Sign + 1-based level index (MSB, XNOR): symbol =
    /// `neg << (width-1) | (|code| - 1)`. Code 0 (an exact-zero element)
    /// has no symbol of its own — sign-magnitude needs all `2^width`
    /// patterns for ±L levels — and is carried on the
    /// [`PackedTensor::zeros`] exception list instead.
    SignLevel,
}

impl CodeScheme {
    /// Stable on-disk id (the `.msbt` v2 layout record).
    pub fn id(self) -> i32 {
        match self {
            CodeScheme::Unsigned => 0,
            CodeScheme::SignMagnitude => 1,
            CodeScheme::SignLevel => 2,
        }
    }

    pub fn from_id(id: i32) -> Option<CodeScheme> {
        match id {
            0 => Some(CodeScheme::Unsigned),
            1 => Some(CodeScheme::SignMagnitude),
            2 => Some(CodeScheme::SignLevel),
            _ => None,
        }
    }

    /// Symbol for `code` under this scheme, `None` when the code must go
    /// on the exact-zero exception list ([`CodeScheme::SignLevel`] only).
    pub fn encode(self, code: i8, width: u32) -> Option<u8> {
        let neg = (code < 0) as u8;
        match self {
            CodeScheme::Unsigned => {
                debug_assert!(code >= 0);
                Some(code as u8)
            }
            CodeScheme::SignMagnitude => Some((neg << (width - 1)) | code.unsigned_abs()),
            CodeScheme::SignLevel => {
                if code == 0 {
                    None
                } else {
                    Some((neg << (width - 1)) | (code.unsigned_abs() - 1))
                }
            }
        }
    }

    /// Inverse of [`CodeScheme::encode`].
    pub fn decode(self, sym: u8, width: u32) -> i8 {
        match self {
            CodeScheme::Unsigned => sym as i8,
            CodeScheme::SignMagnitude => {
                let mag = (sym & ((1u8 << (width - 1)) - 1)) as i8;
                if (sym >> (width - 1)) & 1 == 1 {
                    -mag
                } else {
                    mag
                }
            }
            CodeScheme::SignLevel => {
                let mag = (sym & ((1u8 << (width - 1)) - 1)) as i8 + 1;
                if (sym >> (width - 1)) & 1 == 1 {
                    -mag
                } else {
                    mag
                }
            }
        }
    }
}

/// A method's packed-payload descriptor (see
/// [`BlockQuantizer::pack_spec`](super::engine::BlockQuantizer::pack_spec)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PackSpec {
    /// Logical bits per code symbol; ≤ 4 → nibble storage, else bytes.
    pub code_bits: u32,
    pub scheme: CodeScheme,
    /// Scale-table entries per block instance.
    pub scales_per_block: usize,
    /// Keep the scale table in f32 regardless of the bf16 protocol (the
    /// BnB layout stores absmax in fp32).
    pub f32_scales: bool,
}

// ---------------------------------------------------------------------------
// The packed payload.
// ---------------------------------------------------------------------------

/// Per-element code storage: bit-packed symbols for code widths ≤ 4
/// (1-bit and 2-bit codes pack tightly — no nibble floor), bytes
/// otherwise. All packed layouts are LSB-first within each byte.
#[derive(Clone, Debug, PartialEq)]
pub enum PackedCodes {
    /// Eight 1-bit symbols per byte (`ceil(n/8)` bytes): XNOR signs.
    U1(Vec<u8>),
    /// Four 2-bit symbols per byte (`ceil(n/4)` bytes): 2-bit MSB codes.
    U2(Vec<u8>),
    /// Two 4-bit symbols per byte, low nibble first (`ceil(n/2)` bytes).
    U4(Vec<u8>),
    /// One signed byte code per element (the raw i8 code, no scheme).
    I8(Vec<i8>),
}

impl PackedCodes {
    /// The stored symbol width in bits (8 for raw i8 codes).
    pub fn width(&self) -> u32 {
        match self {
            PackedCodes::U1(_) => 1,
            PackedCodes::U2(_) => 2,
            PackedCodes::U4(_) => 4,
            PackedCodes::I8(_) => 8,
        }
    }
}

/// Scale-table storage dtype.
#[derive(Clone, Debug, PartialEq)]
pub enum PackedScales {
    Bf16(Vec<u16>),
    F32(Vec<f32>),
}

/// A deployable packed tensor: codes + scale table + layout, emitted by
/// the engine in deterministic [`BlockPlan`] order. `decode(pack(W))` is
/// bit-identical to the simulated-dequant output (`engine::decode_packed`).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    /// `BlockQuantizer::name()` of the emitting method — the decode
    /// dispatch key (`registry::block_decoder`).
    pub method: String,
    pub rows: usize,
    pub cols: usize,
    /// Logical bits per code symbol.
    pub code_bits: u32,
    pub scheme: CodeScheme,
    /// Elements per scale group (the whole tensor when `per_tensor`).
    pub block: usize,
    pub scales_per_block: usize,
    pub per_tensor: bool,
    /// Whether decode finishes through the bf16 storage round-trip.
    pub bf16: bool,
    pub codes: PackedCodes,
    pub scales: PackedScales,
    /// Element indices decoded as exact zeros ([`CodeScheme::SignLevel`]
    /// nibble payloads only; their stored symbol is a placeholder).
    pub zeros: Vec<u32>,
}

impl PackedTensor {
    /// Assemble a payload from engine-emitted per-element i8 codes and the
    /// concatenated per-block scale table (both in `plan` order).
    pub fn from_codes(
        method: &str,
        plan: &BlockPlan,
        spec: &PackSpec,
        bf16_protocol: bool,
        codes: &[i8],
        scales: &[f32],
    ) -> PackedTensor {
        let n = plan.rows * plan.cols;
        debug_assert_eq!(codes.len(), n);
        debug_assert_eq!(scales.len(), plan.n_blocks * spec.scales_per_block);
        let mut zeros = Vec::new();
        let packed_codes = if let Some(width) = storage_width(spec.code_bits) {
            let mut symbols = Vec::with_capacity(n);
            for (i, &c) in codes.iter().enumerate() {
                match spec.scheme.encode(c, spec.code_bits) {
                    Some(s) => symbols.push(s),
                    None => {
                        zeros.push(i as u32);
                        symbols.push(0);
                    }
                }
            }
            let packed = pack_bits(&symbols, width);
            match width {
                1 => PackedCodes::U1(packed),
                2 => PackedCodes::U2(packed),
                _ => PackedCodes::U4(packed),
            }
        } else {
            PackedCodes::I8(codes.to_vec())
        };
        let packed_scales = if spec.f32_scales || !bf16_protocol {
            PackedScales::F32(scales.to_vec())
        } else {
            PackedScales::Bf16(scales.iter().map(|&s| bf16::encode(s)).collect())
        };
        PackedTensor {
            method: method.to_string(),
            rows: plan.rows,
            cols: plan.cols,
            code_bits: spec.code_bits,
            scheme: spec.scheme,
            block: plan.block,
            scales_per_block: spec.scales_per_block,
            per_tensor: plan.per_tensor,
            bf16: bf16_protocol,
            codes: packed_codes,
            scales: packed_scales,
            zeros,
        }
    }

    pub fn n_elems(&self) -> usize {
        self.rows * self.cols
    }

    /// Number of block instances (tail-tolerant for flat plans).
    pub fn n_blocks(&self) -> usize {
        self.n_elems().div_ceil(self.block.max(1))
    }

    /// Exact serialized payload size: code bytes + scale bytes + the
    /// exact-zero exception list (u32 each).
    pub fn payload_bytes(&self) -> usize {
        let code_bytes = match &self.codes {
            PackedCodes::U1(p) | PackedCodes::U2(p) | PackedCodes::U4(p) => p.len(),
            PackedCodes::I8(v) => v.len(),
        };
        let scale_bytes = match &self.scales {
            PackedScales::Bf16(v) => v.len() * 2,
            PackedScales::F32(v) => v.len() * 4,
        };
        code_bytes + scale_bytes + self.zeros.len() * 4
    }

    /// Measured storage cost in bits/weight. Agrees exactly with the
    /// theoretical `*_effective_bits` for 4-bit-code methods with no
    /// exact-zero exceptions (the paper's Table-1 grid).
    pub fn effective_bits(&self) -> f64 {
        self.payload_bytes() as f64 * 8.0 / self.n_elems().max(1) as f64
    }

    /// Scheme-decode the i8 codes for the element range starting at
    /// `start` (length `out.len()`) without materializing the whole code
    /// vector — the fused-kernel tile path ([`crate::kernels`]) walks the
    /// payload 64 elements at a time through this, and the full unpack
    /// below is built on it. Handles any bit alignment (flat plans and
    /// per-tensor rows need not start on byte boundaries).
    pub fn codes_range_into(&self, start: usize, out: &mut [i8]) {
        debug_assert!(start + out.len() <= self.n_elems(), "code range out of bounds");
        match &self.codes {
            PackedCodes::I8(v) => out.copy_from_slice(&v[start..start + out.len()]),
            PackedCodes::U1(p) | PackedCodes::U2(p) | PackedCodes::U4(p) => {
                let width = self.codes.width();
                let per = (8 / width) as usize;
                let mask = (1u8 << width) - 1;
                for (k, o) in out.iter_mut().enumerate() {
                    let i = start + k;
                    let sym = (p[i / per] >> ((i % per) as u32 * width)) & mask;
                    *o = self.scheme.decode(sym, self.code_bits);
                }
            }
        }
    }

    /// Per-element i8 codes, scheme-decoded from the stored symbols, into
    /// a reusable buffer (cleared and resized) — single pass, no
    /// intermediate symbol vector. Exception-listed positions carry a
    /// placeholder code; the decode driver overwrites them with exact
    /// zeros.
    pub fn unpacked_codes_into(&self, out: &mut Vec<i8>) {
        out.clear();
        out.resize(self.n_elems(), 0);
        self.codes_range_into(0, out);
    }

    /// Allocating wrapper over [`PackedTensor::unpacked_codes_into`].
    pub fn unpacked_codes(&self) -> Vec<i8> {
        let mut out = Vec::new();
        self.unpacked_codes_into(&mut out);
        out
    }

    /// The scale table decoded to f32 (the exact values quantize used)
    /// into a reusable buffer (cleared first).
    pub fn scales_f32_into(&self, out: &mut Vec<f32>) {
        out.clear();
        match &self.scales {
            PackedScales::Bf16(v) => out.extend(v.iter().map(|&b| bf16::decode(b))),
            PackedScales::F32(v) => out.extend_from_slice(v),
        }
    }

    /// Allocating wrapper over [`PackedTensor::scales_f32_into`].
    pub fn scales_f32(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.scales_f32_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn paper_storage_numbers() {
        // §4.1 "theoretical effective storage is 6.00 bits/weight without DQ"
        assert_close(msb_effective_bits(4, 8, 64, 0, false), 6.0, 1e-12, 0.0);
        // "or 4.78 bits/weight with DQ" (Appendix G: 6 + 32·16/2048 = 6.25)
        assert_close(msb_dq_effective_bits(4, 8, 64, 6, 32, 2048), 4.78125, 1e-12, 0.0);
        // per-tensor 6-bit on a 1M tensor: metadata negligible
        let pt = msb_effective_bits(6, 32, 0, 1 << 20, true);
        assert!(pt < 6.001);
    }

    #[test]
    fn uniform_and_nf4() {
        assert_close(uniform_effective_bits(4, 64, false), 4.25, 1e-12, 0.0);
        assert_close(uniform_effective_bits(4, 64, true), 4.5, 1e-12, 0.0);
        assert_close(nf4_effective_bits(64), 4.5, 1e-12, 0.0);
    }

    #[test]
    fn nibble_roundtrip() {
        crate::testing::check(
            "nibble pack/unpack",
            20,
            |rng| {
                let n = 1 + rng.below(100);
                (0..n).map(|_| rng.below(16) as u8).collect::<Vec<_>>()
            },
            |codes| unpack_nibbles(&pack_nibbles(codes), codes.len()) == *codes,
        );
    }

    #[test]
    fn odd_length_pack() {
        let codes = vec![1u8, 2, 3];
        let packed = pack_nibbles(&codes);
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack_nibbles(&packed, 3), codes);
    }

    #[test]
    fn bit_pack_roundtrip_all_widths() {
        crate::testing::check(
            "pack_bits/unpack_bits",
            30,
            |rng| {
                let width = [1u32, 2, 4][rng.below(3)];
                let n = 1 + rng.below(200);
                let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << width) as u8).collect();
                (codes, width)
            },
            |(codes, width)| {
                let packed = pack_bits(codes, *width);
                packed.len() == codes.len().div_ceil((8 / width) as usize)
                    && unpack_bits(&packed, codes.len(), *width) == *codes
            },
        );
    }

    #[test]
    fn bit_pack_goldens() {
        // 1-bit: LSB-first => 0b0110_1001 for [1,0,0,1,0,1,1,0]
        assert_eq!(pack_bits(&[1, 0, 0, 1, 0, 1, 1, 0], 1), vec![0b0110_1001]);
        // ragged tail pads with zeros
        assert_eq!(pack_bits(&[1, 1, 1], 1), vec![0b0000_0111]);
        // 2-bit: [3, 0, 2, 1] => 0b01_10_00_11
        assert_eq!(pack_bits(&[3, 0, 2, 1], 2), vec![0b0110_0011]);
        // width 4 stays byte-compatible with the historical nibble layout
        assert_eq!(pack_bits(&[1, 15, 0, 7, 9], 4), pack_nibbles(&[1, 15, 0, 7, 9]));
        assert_eq!(pack_bits(&[1, 15, 0, 7, 9], 4), vec![0xF1, 0x70, 0x09]);
    }

    #[test]
    fn storage_width_table() {
        assert_eq!(storage_width(1), Some(1));
        assert_eq!(storage_width(2), Some(2));
        assert_eq!(storage_width(3), Some(4));
        assert_eq!(storage_width(4), Some(4));
        assert_eq!(storage_width(5), None);
        assert_eq!(storage_width(8), None);
    }

    #[test]
    fn packed_size_halves() {
        let codes = vec![5u8; 1000];
        assert_eq!(pack_nibbles(&codes).len(), 500);
    }

    #[test]
    fn scheme_roundtrips() {
        // MSB at 4 bits: the FULL code range ±1..±8 must survive — the old
        // offset-binary nibble map lost +8
        for c in (-8i8..=8).filter(|&c| c != 0) {
            let s = CodeScheme::SignLevel.encode(c, 4).unwrap();
            assert!(s < 16);
            assert_eq!(CodeScheme::SignLevel.decode(s, 4), c, "code {c}");
        }
        assert_eq!(CodeScheme::SignLevel.encode(0, 4), None);
        // XNOR at 1 bit: ±1 in a single bit, zero on the exception list
        assert_eq!(CodeScheme::SignLevel.encode(1, 1), Some(0));
        assert_eq!(CodeScheme::SignLevel.encode(-1, 1), Some(1));
        assert_eq!(CodeScheme::SignLevel.decode(0, 1), 1);
        assert_eq!(CodeScheme::SignLevel.decode(1, 1), -1);
        // RTN symmetric 4-bit: -7..7 with a natural zero
        for c in -7i8..=7 {
            let s = CodeScheme::SignMagnitude.encode(c, 4).unwrap();
            assert!(s < 16);
            let back = CodeScheme::SignMagnitude.decode(s, 4);
            assert_eq!(back, if c == 0 { 0 } else { c });
        }
        // unsigned grids pass through
        for c in 0i8..16 {
            let s = CodeScheme::Unsigned.encode(c, 4).unwrap();
            assert_eq!(CodeScheme::Unsigned.decode(s, 4), c);
        }
    }

    #[test]
    fn scheme_ids_roundtrip() {
        for s in [CodeScheme::Unsigned, CodeScheme::SignMagnitude, CodeScheme::SignLevel] {
            assert_eq!(CodeScheme::from_id(s.id()), Some(s));
        }
        assert_eq!(CodeScheme::from_id(99), None);
    }

    #[test]
    fn packed_tensor_msb_accounting_is_exact() {
        // 8x128 at b=4, t=64: codes n/2 bytes + 8 bf16 scales per block
        // == the paper's 6.00 bits/weight, measured from real bytes.
        let plan = BlockPlan::block_wise(8, 128, 64);
        let spec = PackSpec {
            code_bits: 4,
            scheme: CodeScheme::SignLevel,
            scales_per_block: 8,
            f32_scales: false,
        };
        let codes: Vec<i8> = (0..8 * 128).map(|i| ((i % 8) as i8) + 1).collect();
        let scales = vec![0.5f32; plan.n_blocks * 8];
        let pt = PackedTensor::from_codes("msb-wgm", &plan, &spec, true, &codes, &scales);
        assert_eq!(pt.payload_bytes(), 8 * 128 / 2 + plan.n_blocks * 8 * 2);
        assert_close(pt.effective_bits(), 6.0, 1e-12, 0.0);
        assert!(pt.zeros.is_empty());
        assert_eq!(pt.unpacked_codes(), codes);
    }

    #[test]
    fn packed_tensor_zero_exceptions() {
        let plan = BlockPlan::block_wise(1, 8, 8);
        let spec = PackSpec {
            code_bits: 4,
            scheme: CodeScheme::SignLevel,
            scales_per_block: 8,
            f32_scales: false,
        };
        let codes: Vec<i8> = vec![1, 0, -8, 8, 0, 2, -1, 3];
        let scales = vec![1.0f32; 8];
        let pt = PackedTensor::from_codes("msb-wgm", &plan, &spec, true, &codes, &scales);
        assert_eq!(pt.zeros, vec![1, 4]);
        // exception positions come back as placeholders; everything else exact
        let back = pt.unpacked_codes();
        for (i, (&a, &b)) in codes.iter().zip(&back).enumerate() {
            if a != 0 {
                assert_eq!(a, b, "elem {i}");
            }
        }
        // each exception costs 4 bytes on top of the 6-bit layout
        assert_eq!(pt.payload_bytes(), 4 + 16 + 2 * 4);
    }

    #[test]
    fn packed_tensor_sub_nibble_widths() {
        // 1-bit XNOR signs: 64 codes in 8 bytes + one bf16 α = 1.25 b/wt
        let plan = BlockPlan::block_wise(1, 64, 64);
        let spec = PackSpec {
            code_bits: 1,
            scheme: CodeScheme::SignLevel,
            scales_per_block: 1,
            f32_scales: false,
        };
        let codes: Vec<i8> = (0..64).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
        let pt = PackedTensor::from_codes("xnor", &plan, &spec, true, &codes, &[0.7]);
        assert!(matches!(pt.codes, PackedCodes::U1(_)));
        assert_eq!(pt.payload_bytes(), 64 / 8 + 2);
        assert_close(pt.effective_bits(), 1.25, 1e-12, 0.0);
        assert_eq!(pt.unpacked_codes(), codes);

        // 2-bit MSB (L=2): 64 codes in 16 bytes + 2 bf16 scales = 2.5 b/wt
        let spec = PackSpec {
            code_bits: 2,
            scheme: CodeScheme::SignLevel,
            scales_per_block: 2,
            f32_scales: false,
        };
        let codes: Vec<i8> = (0..64).map(|i| [1, 2, -1, -2][i % 4]).collect();
        let pt = PackedTensor::from_codes("msb-wgm", &plan, &spec, true, &codes, &[0.5, 1.5]);
        assert!(matches!(pt.codes, PackedCodes::U2(_)));
        assert_eq!(pt.payload_bytes(), 64 / 4 + 2 * 2);
        assert_close(pt.effective_bits(), 2.5, 1e-12, 0.0);
        assert_eq!(pt.unpacked_codes(), codes);

        // exact zeros still ride the exception list at sub-nibble widths
        let codes: Vec<i8> = (0..64).map(|i| if i == 5 { 0 } else { 1 }).collect();
        let spec1 = PackSpec {
            code_bits: 1,
            scheme: CodeScheme::SignLevel,
            scales_per_block: 1,
            f32_scales: false,
        };
        let pt = PackedTensor::from_codes("xnor", &plan, &spec1, true, &codes, &[0.7]);
        assert_eq!(pt.zeros, vec![5]);
        let back = pt.unpacked_codes();
        for (i, (&a, &b)) in codes.iter().zip(&back).enumerate() {
            if a != 0 {
                assert_eq!(a, b, "elem {i}");
            }
        }
    }

    #[test]
    fn packed_tensor_byte_codes() {
        // per-tensor 6-bit MSB: 32 levels exceed a nibble → i8 byte codes
        let plan = BlockPlan::per_tensor(4, 16);
        let spec = PackSpec {
            code_bits: 6,
            scheme: CodeScheme::SignLevel,
            scales_per_block: 32,
            f32_scales: false,
        };
        let codes: Vec<i8> = (0..64).map(|i| (i % 32) as i8 - 16).collect();
        let scales = vec![0.25f32; 32];
        let pt = PackedTensor::from_codes("msb-wgm", &plan, &spec, true, &codes, &scales);
        assert!(matches!(pt.codes, PackedCodes::I8(_)));
        assert!(pt.zeros.is_empty(), "i8 codes carry zero natively");
        assert_eq!(pt.unpacked_codes(), codes);
        assert_eq!(pt.payload_bytes(), 64 + 32 * 2);
    }

    #[test]
    fn codes_range_matches_full_unpack_at_any_alignment() {
        // every width, every (start, len) including sub-byte starts: the
        // streamed range decode must agree with the full unpack
        let plan = BlockPlan::block_wise(1, 64, 64);
        let signs: Vec<i8> = (0..64).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
        let two: Vec<i8> = (0..64).map(|i| [1, 2, -1, -2][i % 4]).collect();
        let four: Vec<i8> = (0..64).map(|i| ((i % 8) as i8) + 1).collect();
        for (bits, spb, codes) in [(1u32, 1usize, signs), (2, 2, two), (4, 8, four)] {
            let spec = PackSpec {
                code_bits: bits,
                scheme: CodeScheme::SignLevel,
                scales_per_block: spb,
                f32_scales: false,
            };
            let scales = vec![1.0f32; spb];
            let pt = PackedTensor::from_codes("msb-wgm", &plan, &spec, true, &codes, &scales);
            let full = pt.unpacked_codes();
            assert_eq!(full, codes);
            for start in [0usize, 1, 3, 7, 9, 31] {
                for len in [1usize, 2, 5, 8, 33] {
                    if start + len > 64 {
                        continue;
                    }
                    let mut out = vec![0i8; len];
                    pt.codes_range_into(start, &mut out);
                    assert_eq!(out, full[start..start + len], "bits={bits} {start}+{len}");
                }
            }
        }
    }

    #[test]
    fn into_buffers_reuse_capacity() {
        let plan = BlockPlan::block_wise(1, 64, 64);
        let spec = PackSpec {
            code_bits: 4,
            scheme: CodeScheme::SignMagnitude,
            scales_per_block: 1,
            f32_scales: false,
        };
        let pt = PackedTensor::from_codes("rtn", &plan, &spec, true, &[2i8; 64], &[0.5]);
        let mut codes = Vec::with_capacity(256);
        let mut scales = Vec::with_capacity(256);
        pt.unpacked_codes_into(&mut codes);
        pt.scales_f32_into(&mut scales);
        assert_eq!(codes, pt.unpacked_codes());
        assert_eq!(scales, pt.scales_f32());
        assert!(codes.capacity() >= 256 && scales.capacity() >= 256, "buffers must be reused");
    }

    #[test]
    fn scales_round_through_bf16_storage() {
        let plan = BlockPlan::block_wise(1, 64, 64);
        let spec = PackSpec {
            code_bits: 4,
            scheme: CodeScheme::SignMagnitude,
            scales_per_block: 1,
            f32_scales: false,
        };
        let s = 0.123456789f32; // not bf16-representable
        let pt = PackedTensor::from_codes("rtn", &plan, &spec, true, &[1i8; 64], &[s]);
        assert_eq!(pt.scales_f32(), vec![crate::tensor::bf16::round(s)]);
        // f32 scales requested (BnB absmax / no-bf16 ablations) stay exact
        let spec_f32 = PackSpec { f32_scales: true, ..spec };
        let pt = PackedTensor::from_codes("bnb-nf4", &plan, &spec_f32, true, &[1i8; 64], &[s]);
        assert_eq!(pt.scales_f32(), vec![s]);
    }
}
