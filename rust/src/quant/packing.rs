//! Bit packing + storage accounting.
//!
//! The paper evaluates *simulated* quantization (decoded bf16), but reports
//! effective bits/weight from the storage layout: b-bit codes + bf16 scales.
//! This module provides both the accounting formulas and a real nibble
//! packer proving the 4-bit layout round-trips.

/// Effective bits/weight for MSB: `b + L·16/t` block-wise (bf16 scales),
/// or `b + L·16/total` per-tensor (metadata amortized over the tensor).
/// Paper §4.1: b=4, L=8, t=64 → 6.00 bits/weight.
pub fn msb_effective_bits(
    bits: u32,
    levels: usize,
    block: usize,
    total: usize,
    per_tensor: bool,
) -> f64 {
    let denom = if per_tensor { total } else { block };
    bits as f64 + (levels as f64) * 16.0 / denom as f64
}

/// MSB with double quantization of the scales (Appendix G): scales become
/// `scale_bits`-bit codes + bf16 meta over `scale_block`-sized groups:
/// per-scale cost = scale_bits + 32·16/scale_block; paper: 6 + 32·16/2048
/// = 6.25 bits/scale → 4 + 8·6.25/64 = 4.78 bits/weight.
pub fn msb_dq_effective_bits(
    bits: u32,
    levels: usize,
    block: usize,
    scale_bits: u32,
    scale_levels: usize,
    scale_block: usize,
) -> f64 {
    let per_scale = scale_bits as f64 + (scale_levels as f64) * 16.0 / scale_block as f64;
    bits as f64 + (levels as f64) * per_scale / block as f64
}

/// RTN / uniform: b-bit codes + one bf16 scale (+ one bf16 zero-point if
/// asymmetric) per block.
pub fn uniform_effective_bits(bits: u32, block: usize, asymmetric: bool) -> f64 {
    let meta = if asymmetric { 32.0 } else { 16.0 };
    bits as f64 + meta / block as f64
}

/// BnB-style NF4/FP4: 4-bit codes + one f32 absmax per block (the bnb
/// layout keeps absmax in fp32 unless double-quantized).
pub fn nf4_effective_bits(block: usize) -> f64 {
    4.0 + 32.0 / block as f64
}

// ---------------------------------------------------------------------------
// Nibble packing: two 4-bit codes per byte.
// ---------------------------------------------------------------------------

/// Pack unsigned 4-bit values (0..16) two-per-byte, low nibble first.
pub fn pack_nibbles(codes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        debug_assert!(pair.iter().all(|&c| c < 16));
        let lo = pair[0] & 0xF;
        let hi = if pair.len() == 2 { pair[1] & 0xF } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Inverse of [`pack_nibbles`]; `n` is the original code count.
pub fn unpack_nibbles(packed: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    for &b in packed {
        out.push(b & 0xF);
        if out.len() < n {
            out.push(b >> 4);
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

/// Map an MSB i8 code (sign·(level+1), |level|≤8) to an unsigned nibble:
/// 0 = zero, 1..8 = +levels, 9..15 + 8? We use offset binary: nibble =
/// code + 8 clamped to [0,15] with 8 meaning zero.
pub fn msb_code_to_nibble(code: i8) -> u8 {
    debug_assert!((-8..=7).contains(&(code.clamp(-8, 7))));
    (code.clamp(-8, 7) + 8) as u8
}

pub fn nibble_to_msb_code(nib: u8) -> i8 {
    (nib as i8) - 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn paper_storage_numbers() {
        // §4.1 "theoretical effective storage is 6.00 bits/weight without DQ"
        assert_close(msb_effective_bits(4, 8, 64, 0, false), 6.0, 1e-12, 0.0);
        // "or 4.78 bits/weight with DQ" (Appendix G: 6 + 32·16/2048 = 6.25)
        assert_close(msb_dq_effective_bits(4, 8, 64, 6, 32, 2048), 4.78125, 1e-12, 0.0);
        // per-tensor 6-bit on a 1M tensor: metadata negligible
        let pt = msb_effective_bits(6, 32, 0, 1 << 20, true);
        assert!(pt < 6.001);
    }

    #[test]
    fn uniform_and_nf4() {
        assert_close(uniform_effective_bits(4, 64, false), 4.25, 1e-12, 0.0);
        assert_close(uniform_effective_bits(4, 64, true), 4.5, 1e-12, 0.0);
        assert_close(nf4_effective_bits(64), 4.5, 1e-12, 0.0);
    }

    #[test]
    fn nibble_roundtrip() {
        crate::testing::check(
            "nibble pack/unpack",
            20,
            |rng| {
                let n = 1 + rng.below(100);
                (0..n).map(|_| rng.below(16) as u8).collect::<Vec<_>>()
            },
            |codes| unpack_nibbles(&pack_nibbles(codes), codes.len()) == *codes,
        );
    }

    #[test]
    fn odd_length_pack() {
        let codes = vec![1u8, 2, 3];
        let packed = pack_nibbles(&codes);
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack_nibbles(&packed, 3), codes);
    }

    #[test]
    fn msb_code_nibble_roundtrip() {
        for c in -8i8..=7 {
            assert_eq!(nibble_to_msb_code(msb_code_to_nibble(c)), c);
        }
    }

    #[test]
    fn packed_size_halves() {
        let codes = vec![5u8; 1000];
        assert_eq!(pack_nibbles(&codes).len(), 500);
    }
}
