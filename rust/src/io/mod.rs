//! IO substrate: the `.msbt` tensor container (shared with
//! `python/compile/msbt.py`), a dependency-free JSON parser for
//! `manifest.json`, and the typed manifest model.

pub mod json;
pub mod manifest;
pub mod msbt;
