//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//! The manifest defines the HLO executable ABI: parameter order, shapes and
//! quantizability flags — rust marshals literals in exactly this order.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::json::{self, Value};

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub quant: bool,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub seq: usize,
    pub params: Vec<ParamSpec>,
    pub weights_file: String,
    pub calib_file: String,
    pub fwd_hlo: String,
}

impl ModelSpec {
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn quantizable(&self) -> impl Iterator<Item = &ParamSpec> {
        self.params.iter().filter(|p| p.quant)
    }

    pub fn param(&self, name: &str) -> Option<&ParamSpec> {
        self.params.iter().find(|p| p.name == name)
    }
}

#[derive(Clone, Debug)]
pub struct ProbeSuiteMeta {
    pub name: String,
    pub n: usize,
}

#[derive(Clone, Debug)]
pub struct MsbKernelModel {
    pub name: String,
    pub hlo: String,
    pub batch: usize,
    pub levels: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub vocab: usize,
    pub msb_block: usize,
    pub eval_batch: usize,
    pub eval_streams: Vec<String>,
    pub probe_suites: Vec<ProbeSuiteMeta>,
    pub models: Vec<ModelSpec>,
    pub msb_kernel_model: Option<MsbKernelModel>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;
        Self::from_value(dir, &v)
    }

    fn from_value(dir: PathBuf, v: &Value) -> Result<Self> {
        let mut models = Vec::new();
        for m in v.req("models")?.as_arr().unwrap_or(&[]) {
            let mut params = Vec::new();
            for p in m.req("params")?.as_arr().unwrap_or(&[]) {
                params.push(ParamSpec {
                    name: p.req_str("name")?.to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect(),
                    quant: p.req("quant")?.as_bool().unwrap_or(false),
                });
            }
            models.push(ModelSpec {
                name: m.req_str("name")?.to_string(),
                d: m.req_usize("d")?,
                layers: m.req_usize("layers")?,
                heads: m.req_usize("heads")?,
                ff: m.req_usize("ff")?,
                seq: m.req_usize("seq")?,
                params,
                weights_file: m.req_str("weights")?.to_string(),
                calib_file: m.req_str("calib")?.to_string(),
                fwd_hlo: m.req_str("fwd_hlo")?.to_string(),
            });
        }
        let probe_suites = v
            .req("probe_suites")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|s| {
                Ok(ProbeSuiteMeta {
                    name: s.req_str("name")?.to_string(),
                    n: s.req_usize("n")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let msb_kernel_model = match v.get("msb_kernel_model") {
            Some(k) => Some(MsbKernelModel {
                name: k.req_str("name")?.to_string(),
                hlo: k.req_str("hlo")?.to_string(),
                batch: k.req_usize("batch")?,
                levels: k.req_usize("levels")?,
            }),
            None => None,
        };
        Ok(Manifest {
            dir,
            seed: v.req_usize("seed")? as u64,
            vocab: v.req_usize("vocab")?,
            msb_block: v.req_usize("msb_block")?,
            eval_batch: v.req_usize("eval_batch")?,
            eval_streams: v
                .req("eval_streams")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| s.as_str().map(String::from))
                .collect(),
            probe_suites,
            models,
            msb_kernel_model,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest"))
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "seed": 1234, "vocab": 97, "msb_block": 64, "eval_batch": 8,
        "eval_streams": ["eval_wk", "eval_pt"],
        "probe_suites": [{"name": "cloze", "n": 100}],
        "models": [{
            "name": "tiny", "d": 64, "layers": 2, "heads": 2, "ff": 256,
            "seq": 96,
            "params": [
                {"name": "tok_emb", "shape": [97, 64], "quant": false},
                {"name": "layer0.wq", "shape": [64, 64], "quant": true}
            ],
            "weights": "tiny_weights.msbt",
            "calib": "tiny_calib.msbt",
            "fwd_hlo": "tiny_fwd.hlo.txt"
        }]
    }"#;

    #[test]
    fn parses_sample() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_value(PathBuf::from("/tmp"), &v).unwrap();
        assert_eq!(m.vocab, 97);
        assert_eq!(m.models.len(), 1);
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.params.len(), 2);
        assert_eq!(tiny.quantizable().count(), 1);
        assert_eq!(tiny.total_params(), 97 * 64 + 64 * 64);
        assert!(m.model("nope").is_err());
        assert!(m.msb_kernel_model.is_none());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.vocab > 90);
        assert_eq!(m.msb_block, 64);
        for model in &m.models {
            // ABI sanity: every quantizable matrix is 2-D with cols % block == 0
            for p in model.quantizable() {
                assert_eq!(p.shape.len(), 2, "{}", p.name);
                assert_eq!(p.shape[1] % m.msb_block, 0, "{}", p.name);
            }
            assert!(m.path(&model.weights_file).exists());
            assert!(m.path(&model.fwd_hlo).exists());
        }
    }
}
