//! Minimal JSON parser/writer (no serde offline). Covers the full JSON
//! grammar minus exotic number forms; good for `manifest.json`,
//! `training_log.json`, and bench outputs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers with decent error messages.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("'{key}' not a string"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow::anyhow!("'{key}' not a number"))
    }
}

pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.num(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn obj(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn arr(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", c as char),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        bail!("bad utf8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                    self.pos += len;
                }
            }
        }
    }

    fn num(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Value::Num(text.parse()?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize (stable key order via BTreeMap).
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, x);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(out, k);
                out.push(':');
                write_value(out, x);
            }
            out.push('}');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""hi\n""#).unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let v = parse(
            r#"{"vocab": 97, "models": [{"name": "tiny", "params":
            [{"name": "tok_emb", "shape": [97, 64], "quant": false}]}]}"#,
        )
        .unwrap();
        assert_eq!(v.req_usize("vocab").unwrap(), 97);
        let m = &v.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.req_str("name").unwrap(), "tiny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn writer_escapes() {
        let v = Value::Str("a\"b\\c\nd".into());
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }
}
