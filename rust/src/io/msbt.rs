//! `.msbt` tensor container — byte-compatible with `python/compile/msbt.py`:
//!
//! ```text
//! magic b"MSBT" | version u32 | count u32 | count * record
//! record: name_len u16, name, dtype u8, ndim u8, dims u32*, nbytes u64, data
//! ```
//! All integers little-endian. dtype: 0=f32, 1=i32, 2=bf16(u16), 3=i8,
//! 4=u4 (v2+: two 4-bit codes per byte, low nibble first), 5=u2 (v3+:
//! four 2-bit codes per byte), 6=u1 (v3+: eight 1-bit codes per byte) —
//! all packed dtypes are LSB-first within each byte.
//!
//! Format v2 generalized v1's `nbytes == n·sizeof(dtype)` invariant to a
//! per-dtype byte count so packed sub-byte dtypes fit (`U4`: `nbytes ==
//! ceil(n/2)` with `n` the *logical* element count, the `dims` product);
//! v3 adds the sub-nibble `U2`/`U1` dtypes (`ceil(n/4)` / `ceil(n/8)`
//! bytes) so 1- and 2-bit code payloads stop paying the nibble floor.
//! The writer emits v3; the reader accepts v1 and v2 files unchanged
//! (older versions never contain the newer dtypes).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

/// Current container version written by [`write_file`].
pub const FORMAT_VERSION: u32 = 3;

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Bf16(Vec<u16>),
    I8(Vec<i8>),
    /// Nibble-packed 4-bit codes: `n` logical elements in `ceil(n/2)`
    /// bytes, low nibble first.
    U4 { n: usize, packed: Vec<u8> },
    /// Bit-packed 2-bit codes: `n` logical elements in `ceil(n/4)` bytes.
    U2 { n: usize, packed: Vec<u8> },
    /// Bit-packed 1-bit codes: `n` logical elements in `ceil(n/8)` bytes.
    U1 { n: usize, packed: Vec<u8> },
}

impl TensorData {
    /// Logical element count (≠ byte count for packed dtypes).
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::Bf16(v) => v.len(),
            TensorData::I8(v) => v.len(),
            TensorData::U4 { n, .. } | TensorData::U2 { n, .. } | TensorData::U1 { n, .. } => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype_code(&self) -> u8 {
        match self {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
            TensorData::Bf16(_) => 2,
            TensorData::I8(_) => 3,
            TensorData::U4 { .. } => 4,
            TensorData::U2 { .. } => 5,
            TensorData::U1 { .. } => 6,
        }
    }
}

/// Serialized byte count for `n` elements of dtype `code` (the v2+
/// generalization of the v1 `n * sizeof` rule).
fn dtype_nbytes(code: u8, n: usize) -> Option<usize> {
    match code {
        0 | 1 => Some(n * 4),
        2 => Some(n * 2),
        3 => Some(n),
        4 => Some(n.div_ceil(2)),
        5 => Some(n.div_ceil(4)),
        6 => Some(n.div_ceil(8)),
        _ => None,
    }
}

/// The minimum container version that may contain dtype `code`.
fn dtype_min_version(code: u8) -> u32 {
    match code {
        4 => 2,
        5 | 6 => 3,
        _ => 1,
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::I32(data) }
    }

    pub fn bf16(dims: Vec<usize>, data: Vec<u16>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::Bf16(data) }
    }

    pub fn i8(dims: Vec<usize>, data: Vec<i8>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::I8(data) }
    }

    /// Nibble-packed 4-bit codes; `dims` is the logical element shape and
    /// `packed` holds `ceil(n/2)` bytes.
    pub fn u4(dims: Vec<usize>, packed: Vec<u8>) -> Self {
        let n = dims.iter().product::<usize>();
        assert_eq!(n.div_ceil(2), packed.len(), "u4 byte count");
        Tensor { dims, data: TensorData::U4 { n, packed } }
    }

    /// Bit-packed 2-bit codes; `packed` holds `ceil(n/4)` bytes.
    pub fn u2(dims: Vec<usize>, packed: Vec<u8>) -> Self {
        let n = dims.iter().product::<usize>();
        assert_eq!(n.div_ceil(4), packed.len(), "u2 byte count");
        Tensor { dims, data: TensorData::U2 { n, packed } }
    }

    /// Bit-packed 1-bit codes; `packed` holds `ceil(n/8)` bytes.
    pub fn u1(dims: Vec<usize>, packed: Vec<u8>) -> Self {
        let n = dims.iter().product::<usize>();
        assert_eq!(n.div_ceil(8), packed.len(), "u1 byte count");
        Tensor { dims, data: TensorData::U1 { n, packed } }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got dtype {}", other.dtype_code()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got dtype {}", other.dtype_code()),
        }
    }

    pub fn as_bf16(&self) -> Result<&[u16]> {
        match &self.data {
            TensorData::Bf16(v) => Ok(v),
            other => bail!("expected bf16 tensor, got dtype {}", other.dtype_code()),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            other => bail!("expected i8 tensor, got dtype {}", other.dtype_code()),
        }
    }

    /// The packed nibble bytes of a `U4` tensor.
    pub fn as_u4(&self) -> Result<&[u8]> {
        match &self.data {
            TensorData::U4 { packed, .. } => Ok(packed),
            other => bail!("expected u4 tensor, got dtype {}", other.dtype_code()),
        }
    }

    /// The packed bytes of a `U2` tensor.
    pub fn as_u2(&self) -> Result<&[u8]> {
        match &self.data {
            TensorData::U2 { packed, .. } => Ok(packed),
            other => bail!("expected u2 tensor, got dtype {}", other.dtype_code()),
        }
    }

    /// The packed bytes of a `U1` tensor.
    pub fn as_u1(&self) -> Result<&[u8]> {
        match &self.data {
            TensorData::U1 { packed, .. } => Ok(packed),
            other => bail!("expected u1 tensor, got dtype {}", other.dtype_code()),
        }
    }

    /// 2-D f32 tensors convert to the quantizers' [`Matrix`].
    pub fn to_matrix(&self) -> Result<crate::tensor::Matrix> {
        if self.dims.len() != 2 {
            bail!("to_matrix on {}-d tensor", self.dims.len());
        }
        Ok(crate::tensor::Matrix::from_vec(
            self.dims[0],
            self.dims[1],
            self.as_f32()?.to_vec(),
        ))
    }

    /// Like [`Tensor::to_matrix`] but consumes the tensor, moving the f32
    /// buffer instead of copying it (the pipeline's zero-copy path).
    pub fn into_matrix(self) -> Result<crate::tensor::Matrix> {
        if self.dims.len() != 2 {
            bail!("into_matrix on {}-d tensor", self.dims.len());
        }
        let (rows, cols) = (self.dims[0], self.dims[1]);
        match self.data {
            TensorData::F32(v) => Ok(crate::tensor::Matrix::from_vec(rows, cols, v)),
            other => bail!("expected f32 tensor, got dtype {}", other.dtype_code()),
        }
    }
}

/// BTreeMap keeps deterministic write order (stable artifacts & tests).
pub type TensorMap = BTreeMap<String, Tensor>;

pub fn read_file(path: impl AsRef<Path>) -> Result<TensorMap> {
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    read_bytes(&bytes)
}

pub fn read_bytes(bytes: &[u8]) -> Result<TensorMap> {
    let mut r = Cursor { bytes, pos: 0 };
    let magic = r.take(4)?;
    if magic != b"MSBT" {
        bail!("bad magic {:?}", &magic[..4.min(magic.len())]);
    }
    let version = r.u32()?;
    if version == 0 || version > FORMAT_VERSION {
        bail!("unsupported msbt version {version}");
    }
    let count = r.u32()? as usize;
    let mut out = TensorMap::new();
    for _ in 0..count {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())?;
        let dtype = r.u8()?;
        let ndim = r.u8()? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.u32()? as usize);
        }
        let nbytes = r.u64()? as usize;
        let raw = r.take(nbytes)?;
        let n: usize = dims.iter().product();
        if version < dtype_min_version(dtype) {
            bail!(
                "{name}: dtype {dtype} requires msbt v{}, file is v{version}",
                dtype_min_version(dtype)
            );
        }
        match dtype_nbytes(dtype, n) {
            Some(expect) if expect == nbytes => {}
            Some(expect) => bail!("{name}: dtype {dtype} expects {expect} bytes, got {nbytes}"),
            None => bail!("{name}: unknown dtype {dtype}"),
        }
        let data = match dtype {
            0 => TensorData::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            1 => TensorData::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            2 => TensorData::Bf16(
                raw.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect(),
            ),
            3 => TensorData::I8(raw.iter().map(|&b| b as i8).collect()),
            4 => TensorData::U4 { n, packed: raw.to_vec() },
            5 => TensorData::U2 { n, packed: raw.to_vec() },
            6 => TensorData::U1 { n, packed: raw.to_vec() },
            _ => unreachable!("dtype validated above"),
        };
        out.insert(name, Tensor { dims, data });
    }
    Ok(out)
}

pub fn write_file(path: impl AsRef<Path>, tensors: &TensorMap) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(&path)
            .with_context(|| format!("creating {}", path.as_ref().display()))?,
    );
    ensure!(tensors.len() <= u32::MAX as usize, "too many tensors: {}", tensors.len());
    f.write_all(b"MSBT")?;
    f.write_all(&FORMAT_VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        ensure!(
            name.len() <= u16::MAX as usize,
            "tensor name too long ({} bytes): {:.64}…",
            name.len(),
            name
        );
        ensure!(t.dims.len() <= u8::MAX as usize, "{name}: too many dims ({})", t.dims.len());
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&[t.data.dtype_code(), t.dims.len() as u8])?;
        for &d in &t.dims {
            ensure!(d <= u32::MAX as usize, "{name}: dim {d} exceeds u32");
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                f.write_all(&((v.len() * 4) as u64).to_le_bytes())?;
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                f.write_all(&((v.len() * 4) as u64).to_le_bytes())?;
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::Bf16(v) => {
                f.write_all(&((v.len() * 2) as u64).to_le_bytes())?;
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I8(v) => {
                f.write_all(&(v.len() as u64).to_le_bytes())?;
                let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
                f.write_all(&bytes)?;
            }
            TensorData::U4 { packed, .. }
            | TensorData::U2 { packed, .. }
            | TensorData::U1 { packed, .. } => {
                f.write_all(&(packed.len() as u64).to_le_bytes())?;
                f.write_all(packed)?;
            }
        }
    }
    Ok(())
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("msbt truncated at {} (+{n})", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("w".into(), Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        m.insert("codes".into(), Tensor::i8(vec![4], vec![-3, 0, 1, 7]));
        m.insert("ids".into(), Tensor::i32(vec![2], vec![-1, 2_000_000]));
        m.insert("scales".into(), Tensor::bf16(vec![3], vec![0x3F80, 0x4000, 0xBF80]));
        m.insert(
            "nibbles".into(),
            Tensor::u4(vec![5], crate::quant::packing::pack_nibbles(&[1, 15, 0, 7, 9])),
        );
        m.insert(
            "crumbs".into(),
            Tensor::u2(vec![6], crate::quant::packing::pack_bits(&[3, 0, 2, 1, 1, 2], 2)),
        );
        let bits = crate::quant::packing::pack_bits(&[1, 0, 1, 1, 0, 0, 1, 0, 1, 1], 1);
        m.insert("bits".into(), Tensor::u1(vec![10], bits));
        m
    }

    #[test]
    fn roundtrip_memory() {
        let m = sample();
        let dir = std::env::temp_dir().join(format!("msbt_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.msbt");
        write_file(&p, &m).unwrap();
        let back = read_file(&p).unwrap();
        assert_eq!(m, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn golden_layout() {
        // must match python/tests/test_msbt.py::test_byte_layout_golden
        let mut m = TensorMap::new();
        m.insert("ab".into(), Tensor::f32(vec![1], vec![1.0]));
        let dir = std::env::temp_dir().join(format!("msbt_g_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.msbt");
        write_file(&p, &m).unwrap();
        let raw = std::fs::read(&p).unwrap();
        assert_eq!(&raw[..4], b"MSBT");
        assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 3);
        assert_eq!(u32::from_le_bytes(raw[8..12].try_into().unwrap()), 1);
        assert_eq!(u16::from_le_bytes(raw[12..14].try_into().unwrap()), 2);
        assert_eq!(&raw[14..16], b"ab");
        assert_eq!(raw[16], 0); // f32
        assert_eq!(raw[17], 1); // ndim
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn u4_golden_layout() {
        // pin the packed-dtype record: 5 logical elements in 3 bytes
        let mut m = TensorMap::new();
        m.insert("c".into(), Tensor::u4(vec![5], vec![0xF1, 0x70, 0x09]));
        let dir = std::env::temp_dir().join(format!("msbt_u4_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("u4.msbt");
        write_file(&p, &m).unwrap();
        let raw = std::fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 3); // v3
        assert_eq!(raw[15], 4); // dtype u4
        assert_eq!(raw[16], 1); // ndim
        assert_eq!(u32::from_le_bytes(raw[17..21].try_into().unwrap()), 5); // logical n
        assert_eq!(u64::from_le_bytes(raw[21..29].try_into().unwrap()), 3); // nbytes
        assert_eq!(&raw[29..32], &[0xF1, 0x70, 0x09]);
        let back = read_file(&p).unwrap();
        assert_eq!(back.get("c").unwrap().data.len(), 5);
        assert_eq!(back.get("c").unwrap().as_u4().unwrap(), &[0xF1, 0x70, 0x09]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sub_nibble_golden_layout() {
        // pin the v3 sub-nibble record: u1 packs 10 logical bits in 2
        // bytes, LSB-first (u2 round-trips via `sample()` above)
        let mut m = TensorMap::new();
        m.insert("b".into(), Tensor::u1(vec![10], vec![0b0100_1101, 0b0000_0011]));
        let dir = std::env::temp_dir().join(format!("msbt_u1_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("u1.msbt");
        write_file(&p, &m).unwrap();
        let raw = std::fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 3); // v3
        assert_eq!(raw[15], 6); // dtype u1
        assert_eq!(u32::from_le_bytes(raw[17..21].try_into().unwrap()), 10); // logical n
        assert_eq!(u64::from_le_bytes(raw[21..29].try_into().unwrap()), 2); // nbytes
        assert_eq!(&raw[29..31], &[0b0100_1101, 0b0000_0011]);
        let back = read_file(&p).unwrap();
        assert_eq!(back.get("b").unwrap().data.len(), 10);
        assert_eq!(back.get("b").unwrap().as_u1().unwrap(), &[0b0100_1101, 0b0000_0011]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn older_versions_reject_newer_dtypes() {
        // a v2 file must not contain the v3 sub-nibble dtypes
        for dtype in [5u8, 6] {
            let mut raw: Vec<u8> = Vec::new();
            raw.extend_from_slice(b"MSBT");
            raw.extend_from_slice(&2u32.to_le_bytes()); // version 2
            raw.extend_from_slice(&1u32.to_le_bytes());
            raw.extend_from_slice(&1u16.to_le_bytes());
            raw.extend_from_slice(b"c");
            raw.push(dtype);
            raw.push(1);
            raw.extend_from_slice(&4u32.to_le_bytes());
            raw.extend_from_slice(&1u64.to_le_bytes());
            raw.push(0x1B);
            let err = read_bytes(&raw).unwrap_err();
            assert!(format!("{err:#}").contains("requires msbt v3"), "{err:#}");
        }
    }

    /// v1 files (no u4 dtype, `nbytes == n·sizeof`) must keep reading —
    /// existing artifacts predate the v2 writer.
    #[test]
    fn reads_v1_files() {
        let mut raw: Vec<u8> = Vec::new();
        raw.extend_from_slice(b"MSBT");
        raw.extend_from_slice(&1u32.to_le_bytes()); // version 1
        raw.extend_from_slice(&1u32.to_le_bytes()); // count
        raw.extend_from_slice(&2u16.to_le_bytes());
        raw.extend_from_slice(b"ab");
        raw.push(0); // f32
        raw.push(1); // ndim
        raw.extend_from_slice(&2u32.to_le_bytes());
        raw.extend_from_slice(&8u64.to_le_bytes());
        raw.extend_from_slice(&1.5f32.to_le_bytes());
        raw.extend_from_slice(&(-2.0f32).to_le_bytes());
        let m = read_bytes(&raw).unwrap();
        assert_eq!(m.get("ab").unwrap().as_f32().unwrap(), &[1.5, -2.0]);
    }

    #[test]
    fn v1_rejects_u4() {
        let mut raw: Vec<u8> = Vec::new();
        raw.extend_from_slice(b"MSBT");
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&1u16.to_le_bytes());
        raw.extend_from_slice(b"c");
        raw.push(4); // u4 in a v1 file: invalid
        raw.push(1);
        raw.extend_from_slice(&2u32.to_le_bytes());
        raw.extend_from_slice(&1u64.to_le_bytes());
        raw.push(0x21);
        let err = read_bytes(&raw).unwrap_err();
        assert!(format!("{err:#}").contains("requires msbt v2"), "{err:#}");
    }

    #[test]
    fn rejects_bad_magic_and_future_version() {
        assert!(read_bytes(b"NOPE\0\0\0\0").is_err());
        let mut raw: Vec<u8> = Vec::new();
        raw.extend_from_slice(b"MSBT");
        raw.extend_from_slice(&99u32.to_le_bytes());
        raw.extend_from_slice(&0u32.to_le_bytes());
        assert!(read_bytes(&raw).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let m = sample();
        let dir = std::env::temp_dir().join(format!("msbt_tr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.msbt");
        write_file(&p, &m).unwrap();
        let raw = std::fs::read(&p).unwrap();
        for cut in [5, 13, raw.len() - 1] {
            assert!(read_bytes(&raw[..cut]).is_err(), "cut {cut}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_rejects_oversized_names() {
        let mut m = TensorMap::new();
        m.insert("x".repeat(70_000), Tensor::f32(vec![1], vec![0.0]));
        let dir = std::env::temp_dir().join(format!("msbt_nm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = write_file(dir.join("n.msbt"), &m).unwrap_err();
        assert!(format!("{err:#}").contains("name too long"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_unwritable_path_has_context() {
        let m = TensorMap::new();
        let err = write_file("/nonexistent_dir_msbt/x.msbt", &m).unwrap_err();
        assert!(format!("{err:#}").contains("/nonexistent_dir_msbt/x.msbt"), "{err:#}");
    }

    #[test]
    fn to_matrix() {
        let t = Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let m = t.to_matrix().unwrap();
        assert_eq!(m.at(1, 0), 3.0);
        let t1 = Tensor::f32(vec![4], vec![0.0; 4]);
        assert!(t1.to_matrix().is_err());
        let owned = Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]).into_matrix().unwrap();
        assert_eq!(owned.at(0, 1), 2.0);
        assert!(Tensor::i32(vec![1, 1], vec![3]).into_matrix().is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::i32(vec![1], vec![5]);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
        assert!(t.as_u4().is_err());
        assert!(t.as_u2().is_err());
        assert!(t.as_u1().is_err());
        assert!(t.as_bf16().is_err());
    }
}
