//! `.msbt` tensor container — byte-compatible with `python/compile/msbt.py`:
//!
//! ```text
//! magic b"MSBT" | version u32 | count u32 | count * record
//! record: name_len u16, name, dtype u8, ndim u8, dims u32*, nbytes u64, data
//! ```
//! All integers little-endian. dtype: 0=f32, 1=i32, 2=bf16(u16), 3=i8.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Bf16(Vec<u16>),
    I8(Vec<i8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::Bf16(v) => v.len(),
            TensorData::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype_code(&self) -> u8 {
        match self {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
            TensorData::Bf16(_) => 2,
            TensorData::I8(_) => 3,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::I32(data) }
    }

    pub fn i8(dims: Vec<usize>, data: Vec<i8>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::I8(data) }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got dtype {}", other.dtype_code()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got dtype {}", other.dtype_code()),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            other => bail!("expected i8 tensor, got dtype {}", other.dtype_code()),
        }
    }

    /// 2-D f32 tensors convert to the quantizers' [`Matrix`].
    pub fn to_matrix(&self) -> Result<crate::tensor::Matrix> {
        if self.dims.len() != 2 {
            bail!("to_matrix on {}-d tensor", self.dims.len());
        }
        Ok(crate::tensor::Matrix::from_vec(
            self.dims[0],
            self.dims[1],
            self.as_f32()?.to_vec(),
        ))
    }
}

/// BTreeMap keeps deterministic write order (stable artifacts & tests).
pub type TensorMap = BTreeMap<String, Tensor>;

pub fn read_file(path: impl AsRef<Path>) -> Result<TensorMap> {
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    read_bytes(&bytes)
}

pub fn read_bytes(bytes: &[u8]) -> Result<TensorMap> {
    let mut r = Cursor { bytes, pos: 0 };
    let magic = r.take(4)?;
    if magic != b"MSBT" {
        bail!("bad magic {:?}", &magic[..4.min(magic.len())]);
    }
    let version = r.u32()?;
    if version != 1 {
        bail!("unsupported msbt version {version}");
    }
    let count = r.u32()? as usize;
    let mut out = TensorMap::new();
    for _ in 0..count {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())?;
        let dtype = r.u8()?;
        let ndim = r.u8()? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.u32()? as usize);
        }
        let nbytes = r.u64()? as usize;
        let raw = r.take(nbytes)?;
        let n: usize = dims.iter().product();
        let data = match dtype {
            0 => {
                if nbytes != n * 4 {
                    bail!("{name}: f32 byte count mismatch");
                }
                TensorData::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            1 => {
                if nbytes != n * 4 {
                    bail!("{name}: i32 byte count mismatch");
                }
                TensorData::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            2 => {
                if nbytes != n * 2 {
                    bail!("{name}: bf16 byte count mismatch");
                }
                TensorData::Bf16(
                    raw.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect(),
                )
            }
            3 => {
                if nbytes != n {
                    bail!("{name}: i8 byte count mismatch");
                }
                TensorData::I8(raw.iter().map(|&b| b as i8).collect())
            }
            d => bail!("{name}: unknown dtype {d}"),
        };
        out.insert(name, Tensor { dims, data });
    }
    Ok(out)
}

pub fn write_file(path: impl AsRef<Path>, tensors: &TensorMap) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    f.write_all(b"MSBT")?;
    f.write_all(&1u32.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&[t.data.dtype_code(), t.dims.len() as u8])?;
        for &d in &t.dims {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                f.write_all(&((v.len() * 4) as u64).to_le_bytes())?;
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                f.write_all(&((v.len() * 4) as u64).to_le_bytes())?;
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::Bf16(v) => {
                f.write_all(&((v.len() * 2) as u64).to_le_bytes())?;
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I8(v) => {
                f.write_all(&(v.len() as u64).to_le_bytes())?;
                let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
                f.write_all(&bytes)?;
            }
        }
    }
    Ok(())
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("msbt truncated at {} (+{n})", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("w".into(), Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        m.insert("codes".into(), Tensor::i8(vec![4], vec![-3, 0, 1, 7]));
        m.insert("ids".into(), Tensor::i32(vec![2], vec![-1, 2_000_000]));
        m
    }

    #[test]
    fn roundtrip_memory() {
        let m = sample();
        let dir = std::env::temp_dir().join(format!("msbt_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.msbt");
        write_file(&p, &m).unwrap();
        let back = read_file(&p).unwrap();
        assert_eq!(m, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn golden_layout() {
        // must match python/tests/test_msbt.py::test_byte_layout_golden
        let mut m = TensorMap::new();
        m.insert("ab".into(), Tensor::f32(vec![1], vec![1.0]));
        let dir = std::env::temp_dir().join(format!("msbt_g_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.msbt");
        write_file(&p, &m).unwrap();
        let raw = std::fs::read(&p).unwrap();
        assert_eq!(&raw[..4], b"MSBT");
        assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 1);
        assert_eq!(u32::from_le_bytes(raw[8..12].try_into().unwrap()), 1);
        assert_eq!(u16::from_le_bytes(raw[12..14].try_into().unwrap()), 2);
        assert_eq!(&raw[14..16], b"ab");
        assert_eq!(raw[16], 0); // f32
        assert_eq!(raw[17], 1); // ndim
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_bytes(b"NOPE\0\0\0\0").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let m = sample();
        let dir = std::env::temp_dir().join(format!("msbt_tr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.msbt");
        write_file(&p, &m).unwrap();
        let raw = std::fs::read(&p).unwrap();
        for cut in [5, 13, raw.len() - 1] {
            assert!(read_bytes(&raw[..cut]).is_err(), "cut {cut}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn to_matrix() {
        let t = Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let m = t.to_matrix().unwrap();
        assert_eq!(m.at(1, 0), 3.0);
        let t1 = Tensor::f32(vec![4], vec![0.0; 4]);
        assert!(t1.to_matrix().is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::i32(vec![1], vec![5]);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }
}
