//! Support for the `harness = false` bench binaries (criterion is not in
//! the offline crate set): timing, table printing, machine-readable result
//! emission, and the shared proxy instances. Hidden from the public API
//! surface.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::io::json::Value;
use crate::stats::Rng;
use crate::tensor::Matrix;

/// `MSB_BENCH_FAST=1` shrinks instances for smoke runs.
pub fn fast_mode() -> bool {
    std::env::var("MSB_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Wall-clock one invocation.
pub fn time_once<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Median-of-k wall clock (k kept small: these are macro-benches).
pub fn time_median<R>(k: usize, mut f: impl FnMut() -> R) -> f64 {
    let mut times: Vec<f64> = (0..k.max(1))
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// The Table-2/4/6 proxy instance: the paper uses the first linear weight
/// of Llama-3.2-1B (2048-wide). We use the first gate projection of our
/// `base` model when artifacts exist, padded/tiled to the requested width,
/// else a heavy-tailed synthetic of the same shape.
pub fn proxy_matrix(rows: usize, cols: usize) -> Matrix {
    let arts_path = crate::artifacts_dir().join("base_weights.msbt");
    if let Ok(tensors) = crate::io::msbt::read_file(&arts_path) {
        if let Some(t) = tensors.get("layer0.w_gate") {
            if let Ok(m) = t.to_matrix() {
                // tile the real trained weights up to the requested shape so
                // the distribution (not the dims) is what the paper's proxy
                // instance contributes
                let mut out = Matrix::zeros(rows, cols);
                for r in 0..rows {
                    for c in 0..cols {
                        out.data[r * cols + c] = m.at(r % m.rows, c % m.cols);
                    }
                }
                // break exact periodicity (repeats would distort grouping)
                let mut rng = Rng::new(0xBEEF);
                for v in out.data.iter_mut() {
                    *v *= 1.0 + 0.01 * rng.normal() as f32;
                }
                return out;
            }
        }
    }
    let mut rng = Rng::new(0xBEEF);
    Matrix::weightlike(rows, cols, &mut rng)
}

/// Where a bench's machine-readable output lands: `MSB_BENCH_JSON`
/// overrides, else `BENCH_<name>.json` in the working directory.
pub fn bench_json_path(name: &str) -> std::path::PathBuf {
    std::env::var_os("MSB_BENCH_JSON")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from(format!("BENCH_{name}.json")))
}

/// Env-independent core of [`write_bench_json`]: serialize
/// `{schema, fast, results: {key: num}}` (plus an optional provenance
/// `note` and a per-key `sources` map naming the bench binary that
/// produced each result) to an explicit path.
fn write_bench_json_full(
    path: &std::path::Path,
    name: &str,
    results: &BTreeMap<String, f64>,
    fast: bool,
    note: Option<&str>,
    sources: &BTreeMap<String, String>,
) -> std::io::Result<()> {
    let mut obj = BTreeMap::new();
    obj.insert("schema".to_string(), Value::Str(format!("msb-bench/{name}/v1")));
    obj.insert("fast".to_string(), Value::Bool(fast));
    if let Some(n) = note {
        obj.insert("note".to_string(), Value::Str(n.to_string()));
    }
    obj.insert(
        "results".to_string(),
        Value::Obj(results.iter().map(|(k, &v)| (k.clone(), Value::Num(v))).collect()),
    );
    if !sources.is_empty() {
        obj.insert(
            "sources".to_string(),
            Value::Obj(sources.iter().map(|(k, v)| (k.clone(), Value::Str(v.clone()))).collect()),
        );
    }
    std::fs::write(path, crate::io::json::to_string(&Value::Obj(obj)))
}

/// Serialize `{schema, fast, results}` to an explicit path.
pub fn write_bench_json_to(
    path: &std::path::Path,
    name: &str,
    results: &BTreeMap<String, f64>,
) -> std::io::Result<()> {
    write_bench_json_full(path, name, results, fast_mode(), None, &BTreeMap::new())
}

/// Persist a bench's results as JSON so the repo's perf trajectory
/// accumulates across commits instead of evaporating in CI logs. Returns
/// the written path (see [`bench_json_path`]).
pub fn write_bench_json(
    name: &str,
    results: &BTreeMap<String, f64>,
) -> std::io::Result<std::path::PathBuf> {
    let path = bench_json_path(name);
    write_bench_json_to(&path, name, results)?;
    Ok(path)
}

/// Env-independent core of [`merge_bench_json`]: union `results` with any
/// keys already at `path` (fresh `results` win on conflict), then write.
/// Provenance survives the union: the `fast` flag is the OR of this run
/// and the file's prior flag (any smoke-mode contribution taints the
/// merged numbers), a prior `note` field is carried forward, and every
/// key this run contributes is stamped with `source` (the producing bench
/// binary) in the `sources` map — prior stamps survive for keys this run
/// does not touch.
pub fn merge_bench_json_to(
    path: &std::path::Path,
    name: &str,
    source: &str,
    results: &BTreeMap<String, f64>,
) -> std::io::Result<()> {
    let mut merged = results.clone();
    let mut fast = fast_mode();
    let mut note = None;
    let mut sources: BTreeMap<String, String> =
        results.keys().map(|k| (k.clone(), source.to_string())).collect();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(v) = crate::io::json::parse(&text) {
            if let Some(Value::Obj(old)) = v.get("results") {
                for (k, val) in old {
                    if let Some(x) = val.as_f64() {
                        merged.entry(k.clone()).or_insert(x);
                    }
                }
            }
            if let Some(Value::Obj(old)) = v.get("sources") {
                for (k, val) in old {
                    if let Some(s) = val.as_str() {
                        sources.entry(k.clone()).or_insert_with(|| s.to_string());
                    }
                }
            }
            fast |= v.get("fast").and_then(Value::as_bool).unwrap_or(false);
            note = v.get("note").and_then(Value::as_str).map(String::from);
        }
    }
    // stamps for keys that no longer have a result are dropped: the
    // sources map describes exactly the merged result set
    sources.retain(|k, _| merged.contains_key(k));
    write_bench_json_full(path, name, &merged, fast, note.as_deref(), &sources)
}

/// Like [`write_bench_json`], but union with any keys already in the
/// file (fresh `results` win on conflict). Lets several bench binaries
/// contribute to one trajectory file — `perf_hotpath` and the
/// `table3_quant_time` scheduler arm both land in `BENCH_perf.json` — and
/// `source` names the contributing binary so each merged key stays
/// attributable (`sources` map in the file).
/// The `fast` taint is sticky by design: a merged file may still carry
/// smoke-contributed keys you cannot distinguish, so the only way to
/// certify a clean full-mode trajectory is to delete the file and rerun
/// `make bench-all` without `MSB_BENCH_FAST`.
pub fn merge_bench_json(
    name: &str,
    source: &str,
    results: &BTreeMap<String, f64>,
) -> std::io::Result<std::path::PathBuf> {
    let path = bench_json_path(name);
    merge_bench_json_to(&path, name, source, results)?;
    Ok(path)
}

/// Simple fixed-width row printer for paper-shaped tables.
pub fn row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| format!("{c:>12}"))
        .collect::<Vec<_>>()
        .join(" ")
}

pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proxy_matrix_shape_and_distribution() {
        let m = proxy_matrix(64, 128);
        assert_eq!((m.rows, m.cols), (64, 128));
        let s = crate::stats::summarize(&m.data);
        assert!(s.var > 0.0);
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(3, || (0..1000).sum::<usize>());
        assert!(t >= 0.0);
    }

    #[test]
    fn merge_bench_json_unions_results() {
        let dir = std::env::temp_dir().join(format!("msb_bench_merge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("merged.json");
        let mut first = BTreeMap::new();
        first.insert("msb-wgm".to_string(), 100.0);
        first.insert("shared".to_string(), 1.0);
        write_bench_json_to(&path, "perf", &first).unwrap();
        let mut second = BTreeMap::new();
        second.insert("sched-global-bps".to_string(), 7.0);
        second.insert("shared".to_string(), 2.0); // fresh value wins
        merge_bench_json_to(&path, "perf", "table3_quant_time", &second).unwrap();
        let v = crate::io::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let r = v.req("results").unwrap();
        assert_eq!(r.get("msb-wgm").and_then(Value::as_f64), Some(100.0));
        assert_eq!(r.get("sched-global-bps").and_then(Value::as_f64), Some(7.0));
        assert_eq!(r.get("shared").and_then(Value::as_f64), Some(2.0));
        // merging onto a missing file is a plain write
        let fresh = dir.join("fresh.json");
        merge_bench_json_to(&fresh, "perf", "table3_quant_time", &second).unwrap();
        let v = crate::io::json::parse(&std::fs::read_to_string(&fresh).unwrap()).unwrap();
        assert_eq!(v.req_str("schema").unwrap(), "msb-bench/perf/v1");
        // provenance survives the union: a prior fast-mode flag taints the
        // merged file and a note field is carried forward
        let prov = dir.join("prov.json");
        write_bench_json_full(&prov, "perf", &first, true, Some("seed note"), &BTreeMap::new())
            .unwrap();
        merge_bench_json_to(&prov, "perf", "table3_quant_time", &second).unwrap();
        let v = crate::io::json::parse(&std::fs::read_to_string(&prov).unwrap()).unwrap();
        assert_eq!(v.get("fast").and_then(Value::as_bool), Some(true));
        assert_eq!(v.get("note").and_then(Value::as_str), Some("seed note"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_bench_json_stamps_key_provenance() {
        let dir = std::env::temp_dir().join(format!("msb_bench_src_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sources.json");
        let mut first = BTreeMap::new();
        first.insert("gemv-fused-bps".to_string(), 10.0);
        first.insert("shared".to_string(), 1.0);
        merge_bench_json_to(&path, "perf", "perf_gemv", &first).unwrap();
        let mut second = BTreeMap::new();
        second.insert("forward-logits-bps".to_string(), 3.0);
        second.insert("shared".to_string(), 2.0);
        merge_bench_json_to(&path, "perf", "perf_forward", &second).unwrap();
        let v = crate::io::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let s = v.req("sources").unwrap();
        // untouched keys keep their original stamp; refreshed keys are
        // re-attributed to the binary that produced the fresh value
        assert_eq!(s.get("gemv-fused-bps").and_then(Value::as_str), Some("perf_gemv"));
        assert_eq!(s.get("forward-logits-bps").and_then(Value::as_str), Some("perf_forward"));
        assert_eq!(s.get("shared").and_then(Value::as_str), Some("perf_forward"));
        // every merged result key is stamped
        if let Some(Value::Obj(r)) = v.get("results") {
            for k in r.keys() {
                assert!(s.get(k).is_some(), "unstamped result key {k}");
            }
        } else {
            panic!("results object missing");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_json_roundtrips() {
        // write_bench_json_to takes the path directly: no process-global
        // env mutation from inside the parallel test harness
        let dir = std::env::temp_dir().join(format!("msb_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        let mut results = BTreeMap::new();
        results.insert("msb-wgm".to_string(), 1234.5);
        results.insert("rtn".to_string(), 99999.0);
        write_bench_json_to(&path, "perf", &results).unwrap();
        let v = crate::io::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.req_str("schema").unwrap(), "msb-bench/perf/v1");
        let r = v.req("results").unwrap();
        assert_eq!(r.get("msb-wgm").and_then(Value::as_f64), Some(1234.5));
        assert_eq!(r.get("rtn").and_then(Value::as_f64), Some(99999.0));
        std::fs::remove_dir_all(&dir).ok();
    }
}
