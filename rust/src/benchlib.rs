//! Support for the `harness = false` bench binaries (criterion is not in
//! the offline crate set): timing, table printing, and the shared proxy
//! instances. Hidden from the public API surface.

use std::time::Instant;

use crate::stats::Rng;
use crate::tensor::Matrix;

/// `MSB_BENCH_FAST=1` shrinks instances for smoke runs.
pub fn fast_mode() -> bool {
    std::env::var("MSB_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Wall-clock one invocation.
pub fn time_once<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Median-of-k wall clock (k kept small: these are macro-benches).
pub fn time_median<R>(k: usize, mut f: impl FnMut() -> R) -> f64 {
    let mut times: Vec<f64> = (0..k.max(1))
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// The Table-2/4/6 proxy instance: the paper uses the first linear weight
/// of Llama-3.2-1B (2048-wide). We use the first gate projection of our
/// `base` model when artifacts exist, padded/tiled to the requested width,
/// else a heavy-tailed synthetic of the same shape.
pub fn proxy_matrix(rows: usize, cols: usize) -> Matrix {
    let arts_path = crate::artifacts_dir().join("base_weights.msbt");
    if let Ok(tensors) = crate::io::msbt::read_file(&arts_path) {
        if let Some(t) = tensors.get("layer0.w_gate") {
            if let Ok(m) = t.to_matrix() {
                // tile the real trained weights up to the requested shape so
                // the distribution (not the dims) is what the paper's proxy
                // instance contributes
                let mut out = Matrix::zeros(rows, cols);
                for r in 0..rows {
                    for c in 0..cols {
                        out.data[r * cols + c] = m.at(r % m.rows, c % m.cols);
                    }
                }
                // break exact periodicity (repeats would distort grouping)
                let mut rng = Rng::new(0xBEEF);
                for v in out.data.iter_mut() {
                    *v *= 1.0 + 0.01 * rng.normal() as f32;
                }
                return out;
            }
        }
    }
    let mut rng = Rng::new(0xBEEF);
    Matrix::weightlike(rows, cols, &mut rng)
}

/// Simple fixed-width row printer for paper-shaped tables.
pub fn row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| format!("{c:>12}"))
        .collect::<Vec<_>>()
        .join(" ")
}

pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proxy_matrix_shape_and_distribution() {
        let m = proxy_matrix(64, 128);
        assert_eq!((m.rows, m.cols), (64, 128));
        let s = crate::stats::summarize(&m.data);
        assert!(s.var > 0.0);
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(3, || (0..1000).sum::<usize>());
        assert!(t >= 0.0);
    }
}
