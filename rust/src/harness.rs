//! End-to-end harness glue shared by the CLI, the examples and the
//! Table-1 bench: load artifacts, quantize a model with a method, run the
//! PJRT evaluation (PPL over the three held-out streams + the 7 QA suites),
//! and report the paper-shaped row.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::eval::{self, qa::ProbeSuite};
use crate::io::manifest::{Manifest, ModelSpec};
use crate::io::msbt::{self, TensorMap};
use crate::pipeline::{self, Method, QuantizedModel};
use crate::quant::QuantConfig;
use crate::runtime::ModelRunner;

/// Everything loaded from artifacts/ once.
pub struct Artifacts {
    pub manifest: Manifest,
    pub tokens: TensorMap,
    pub probes: Vec<ProbeSuite>,
}

impl Artifacts {
    pub fn load() -> Result<Self> {
        let manifest = Manifest::load(crate::artifacts_dir())?;
        let tokens = msbt::read_file(manifest.path("corpus_tokens.msbt"))
            .context("loading corpus_tokens.msbt")?;
        let probe_tensors =
            msbt::read_file(manifest.path("probes.msbt")).context("loading probes.msbt")?;
        let names: Vec<String> =
            manifest.probe_suites.iter().map(|s| s.name.clone()).collect();
        let probes = eval::load_probe_suites(&probe_tensors, &names)?;
        Ok(Artifacts { manifest, tokens, probes })
    }

    pub fn weights(&self, spec: &ModelSpec) -> Result<TensorMap> {
        msbt::read_file(self.manifest.path(&spec.weights_file))
            .with_context(|| format!("loading {}", spec.weights_file))
    }

    pub fn calib(&self, spec: &ModelSpec) -> Result<TensorMap> {
        msbt::read_file(self.manifest.path(&spec.calib_file))
            .with_context(|| format!("loading {}", spec.calib_file))
    }

    pub fn eval_stream(&self, name: &str) -> Result<&[i32]> {
        self.tokens
            .get(name)
            .with_context(|| format!("stream '{name}' missing"))?
            .as_i32()
    }
}

/// One Table-1 cell set: per-stream PPL, per-suite QA, and the averages.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub model: String,
    pub method: String,
    pub bits: u32,
    pub ppl: Vec<(String, f64)>,
    pub qa: Vec<(String, f64)>,
    pub quant_seconds: f64,
    pub eval_seconds: f64,
    pub effective_bits: f64,
}

impl EvalReport {
    pub fn avg_ppl(&self) -> f64 {
        self.ppl.iter().map(|p| p.1).sum::<f64>() / self.ppl.len().max(1) as f64
    }

    pub fn avg_qa(&self) -> f64 {
        self.qa.iter().map(|q| q.1).sum::<f64>() / self.qa.len().max(1) as f64
    }

    pub fn row(&self) -> String {
        format!(
            "{:<6} {:<8} {:>2}b  QA {:.3}  PPL {:>8.2}   (quant {:.1}s, eval {:.1}s, {:.2} bits/w)",
            self.model,
            self.method,
            self.bits,
            self.avg_qa(),
            self.avg_ppl(),
            self.quant_seconds,
            self.eval_seconds,
            self.effective_bits
        )
    }
}

/// Quantize `model` with `method` under `cfg` and evaluate it end-to-end.
/// `runner` is reused across calls (weights swapped, executable cached).
pub fn eval_quantized(
    arts: &Artifacts,
    spec: &ModelSpec,
    runner: &mut ModelRunner,
    base_weights: &TensorMap,
    method: Method,
    cfg: &QuantConfig,
    threads: usize,
) -> Result<EvalReport> {
    let calib;
    let calib_ref = if method.needs_calibration() {
        calib = arts.calib(spec)?;
        Some(&calib)
    } else {
        None
    };
    // pipeline::quantize consumes its weight map (pass-through tensors are
    // moved, quantized ones solved in place); the harness keeps the caller's
    // base set borrowable across repeated evals, so clone here.
    let opts = pipeline::QuantizeOptions::new().with_threads(threads);
    let qm: QuantizedModel =
        pipeline::quantize(spec, base_weights.clone(), calib_ref, method, cfg, &opts)?;
    runner.update_weights(&qm.weights)?;

    let t0 = Instant::now();
    let mut ppl = Vec::new();
    for stream_name in &arts.manifest.eval_streams {
        let stream = arts.eval_stream(stream_name)?;
        ppl.push((stream_name.clone(), eval::perplexity(runner, stream)?));
    }
    let mut qa = Vec::new();
    for suite in &arts.probes {
        let score = eval::score_suite(runner, suite)?;
        qa.push((suite.name.clone(), score.accuracy()));
    }
    Ok(EvalReport {
        model: spec.name.clone(),
        method: method.name().to_string(),
        bits: cfg.bits,
        ppl,
        qa,
        quant_seconds: qm.wall_seconds,
        eval_seconds: t0.elapsed().as_secs_f64(),
        effective_bits: if qm.layers.is_empty() { 16.0 } else { qm.mean_effective_bits() },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_load_if_present() {
        if !crate::artifacts_dir().join("manifest.json").exists() {
            return;
        }
        let arts = Artifacts::load().unwrap();
        assert_eq!(arts.probes.len(), arts.manifest.probe_suites.len());
        for s in &arts.manifest.eval_streams {
            assert!(arts.eval_stream(s).unwrap().len() > 1000);
        }
        // probes decoded sanely
        for suite in &arts.probes {
            assert!(!suite.probes.is_empty());
            for p in &suite.probes {
                assert!(p.answer < p.candidates.len());
                assert!(!p.prompt.is_empty());
            }
        }
    }
}
