//! # msb-quant
//!
//! Reproduction of *"Calibration and Transformation-Free Weight-Only LLMs
//! Quantization via Dynamic Grouping"* (MSB PTQ) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: the MSB objective
//!   and its four CPU solvers ([`msb`]), the baseline quantizer zoo
//!   ([`quant`]), the quantization pipeline coordinator ([`pipeline`]), the
//!   PJRT-backed evaluation runtime ([`runtime`], [`eval`], [`server`]), and
//!   a fused CPU transformer forward pass for XLA-free token scoring
//!   ([`forward`]).
//! * **Layer 2** — a JAX transformer lowered at build time to HLO text
//!   (`python/compile/model.py` → `artifacts/*_fwd.hlo.txt`).
//! * **Layer 1** — a Pallas MSB dequant-matmul kernel
//!   (`python/compile/kernels/msb_dequant.py`) embedded in the
//!   `small_fwd_msb` executable.
//!
//! Python never runs on the request path: after `make artifacts`, everything
//! here is self-contained.
//!
//! Quick taste (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use msb_quant::{quant, quant::Quantizer, stats, tensor::Matrix};
//! # fn main() -> msb_quant::Result<()> {
//! let mut rng = stats::Rng::new(7);
//! let w = Matrix::randn(256, 256, &mut rng);
//! let cfg = quant::QuantConfig::block_wise(4, 64)?.with_window(1)?;
//! let q = quant::msb::MsbQuantizer::wgm().quantize(&w, &cfg);
//! println!("4-bit block-wise MSE = {}", q.mse(&w));
//! # Ok(()) }
//! ```

pub mod cli;
pub mod eval;
pub mod forward;
pub mod harness;
pub mod io;
pub mod kernels;
pub mod la;
pub mod msb;
pub mod pipeline;
pub mod pool;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod stats;
pub mod tensor;

#[doc(hidden)]
pub mod benchlib;
#[doc(hidden)]
pub mod testing;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Default artifacts directory (overridable via `MSB_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("MSB_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
