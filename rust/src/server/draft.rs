//! Prompt-lookup drafting for self-speculative greedy decode.
//!
//! [`Drafter`] is the calibration-free draft source behind
//! `EvalServer::spawn_batched`'s speculative mode: **no draft model**,
//! just an n-gram suffix index over the stream's own committed tokens
//! (prompt + everything greedy decode has produced so far). When the
//! current context suffix recurred earlier in the stream, the tokens
//! that followed it last time become the draft — on repetitive text
//! (code, templated prose, self-repeating greedy loops) that guess is
//! often exactly what the model would emit, and each accepted draft
//! token saves one full `step_batch` decode step.
//!
//! Correctness never depends on draft quality. The scheduler feeds
//! `[next, draft...]` as one multi-token chunk, reads every position's
//! argmax from the same fused pass, and keeps only the longest prefix
//! that matches what greedy decode would have chosen anyway
//! ([`longest_accept`]); a wrong draft costs wasted positions (rolled
//! back page-wise by `KvArena::truncate_stream`), never a wrong token.
//!
//! The index is commit-monotone: draft tokens enter the context only
//! *after* verification, so the index never needs rollback.

use std::collections::HashMap;

/// Default n-gram order for the scheduler's per-stream drafters: suffix
/// matches are tried longest-first from this order down to 1.
pub const DEFAULT_NGRAM: usize = 3;

/// Per-stream prompt-lookup index: for each n-gram order `n`, a map
/// from (hashed) n-gram to the start of its most recent occurrence
/// **that has a continuation**. N-grams ending at the context's last
/// position are not indexed until the following token arrives, so a
/// lookup hit always has at least one token to replay — and the current
/// suffix can never match itself.
pub struct Drafter {
    max_ngram: usize,
    /// `maps[n - 1]`: key of an n-gram → start of its latest
    /// continuation-bearing occurrence.
    maps: Vec<HashMap<u64, usize>>,
    /// Committed tokens (prompt + verified generations), append-only.
    ctx: Vec<i32>,
}

impl Drafter {
    pub fn new(max_ngram: usize) -> Drafter {
        let m = max_ngram.max(1);
        Drafter { max_ngram: m, maps: (0..m).map(|_| HashMap::new()).collect(), ctx: Vec::new() }
    }

    /// FNV-1a over the token values. Collisions only cost accept rate
    /// (a candidate is re-checked against the real tokens before use),
    /// never correctness, and the fold is deterministic across runs.
    fn key(gram: &[i32]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &t in gram {
            h ^= u64::from(t as u32);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }

    /// Append committed tokens, indexing incrementally: when position
    /// `p` arrives, every n-gram *ending at `p - 1`* just gained a
    /// continuation and is (re-)recorded, overwriting older occurrences
    /// so lookups replay the most recent repetition.
    pub fn extend(&mut self, toks: &[i32]) {
        for &t in toks {
            let p = self.ctx.len();
            for n in 1..=self.max_ngram.min(p) {
                let start = p - n;
                self.maps[n - 1].insert(Self::key(&self.ctx[start..p]), start);
            }
            self.ctx.push(t);
        }
    }

    /// Propose up to `k` lookahead tokens: find the most recent earlier
    /// occurrence of the longest matching context suffix (n-gram order
    /// high → low) and replay what followed it. Returns an empty draft
    /// when no suffix recurs — drafting never fabricates tokens, so
    /// every proposed token already passed the scheduler's vocabulary
    /// checks when it was first committed.
    pub fn propose(&self, k: usize) -> Vec<i32> {
        let len = self.ctx.len();
        if k == 0 || len == 0 {
            return Vec::new();
        }
        for n in (1..=self.max_ngram.min(len)).rev() {
            let suffix = &self.ctx[len - n..];
            let Some(&s) = self.maps[n - 1].get(&Self::key(suffix)) else { continue };
            // hash keys can collide: replay only a verified match
            if &self.ctx[s..s + n] != suffix {
                continue;
            }
            let cont = &self.ctx[s + n..];
            debug_assert!(!cont.is_empty(), "indexed n-grams always have a continuation");
            return cont[..cont.len().min(k)].to_vec();
        }
        Vec::new()
    }

    /// Committed tokens seen so far.
    pub fn len(&self) -> usize {
        self.ctx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ctx.is_empty()
    }
}

/// The verification rule, shared by the scheduler and the tests: given
/// the drafted tokens and the model's greedy prediction for each drafted
/// position (`preds[i]` = argmax after accepting `draft[..i]`), the
/// number of draft tokens accepted is the longest matching prefix.
/// Everything after the first mismatch is discarded — those positions
/// were computed from a wrong prefix, so their logits are meaningless.
pub fn longest_accept(draft: &[i32], preds: &[i32]) -> usize {
    draft.iter().zip(preds).take_while(|(d, p)| d == p).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    #[test]
    fn empty_and_unseen_contexts_propose_nothing() {
        let mut d = Drafter::new(3);
        assert!(d.is_empty());
        assert!(d.propose(4).is_empty());
        d.extend(&[1, 2, 3]);
        assert_eq!(d.len(), 3);
        // no suffix has recurred yet
        assert!(d.propose(4).is_empty());
        assert!(d.propose(0).is_empty());
    }

    #[test]
    fn repeated_suffix_replays_its_continuation() {
        let mut d = Drafter::new(3);
        // ... a b c X ... a b c -> should propose X next
        d.extend(&[9, 1, 2, 3, 7, 8, 1, 2, 3]);
        assert_eq!(d.propose(1), vec![7]);
        assert_eq!(d.propose(3), vec![7, 8, 1]);
        // k caps the replay even when more context follows the match
        assert_eq!(d.propose(2), vec![7, 8]);
    }

    #[test]
    fn longest_ngram_wins_over_shorter_matches() {
        let mut d = Drafter::new(3);
        // 1-gram "5" recurs with continuation 100; the 2-gram "4 5"
        // recurs with continuation 200 — the longer match must win
        d.extend(&[5, 100, 4, 5, 200, 4, 5]);
        assert_eq!(d.propose(1), vec![200]);
    }

    #[test]
    fn most_recent_occurrence_wins() {
        let mut d = Drafter::new(1);
        d.extend(&[5, 10, 5, 20, 5]);
        // both "5 -> 10" and "5 -> 20" exist; the later one is replayed
        assert_eq!(d.propose(1), vec![20]);
    }

    #[test]
    fn the_current_suffix_never_matches_itself() {
        let mut d = Drafter::new(2);
        d.extend(&[1, 2]);
        // "1 2" exists only as the current (continuation-less) suffix
        assert!(d.propose(4).is_empty());
        d.extend(&[3]);
        // now "2" has continuation 3... but the suffix is "3" which has
        // no earlier occurrence
        assert!(d.propose(4).is_empty());
        d.extend(&[2]);
        // suffix "2" recurred at position 1 with continuation 3
        assert_eq!(d.propose(2), vec![3, 2]);
    }

    /// Property: every proposal is a verbatim replay of a context
    /// substring whose preceding n-gram equals the current suffix.
    #[test]
    fn fuzz_proposals_replay_real_context_substrings() {
        let mut rng = Rng::new(0x5bec);
        for trial in 0..50 {
            let mut d = Drafter::new(1 + rng.below(4));
            let len = 5 + rng.below(60);
            let toks: Vec<i32> = (0..len).map(|_| rng.below(6) as i32).collect();
            d.extend(&toks);
            let k = 1 + rng.below(6);
            let prop = d.propose(k);
            assert!(prop.len() <= k, "trial {trial}: draft longer than requested");
            if prop.is_empty() {
                continue;
            }
            // the proposal must occur somewhere in toks as a contiguous run
            let found = toks.windows(prop.len()).any(|w| w == prop.as_slice());
            assert!(found, "trial {trial}: proposal {prop:?} not a substring of {toks:?}");
        }
    }

    #[test]
    fn longest_accept_is_the_matching_prefix() {
        assert_eq!(longest_accept(&[], &[]), 0);
        assert_eq!(longest_accept(&[1, 2, 3], &[1, 2, 3]), 3);
        assert_eq!(longest_accept(&[1, 2, 3], &[1, 9, 3]), 1);
        assert_eq!(longest_accept(&[1, 2], &[9, 2]), 0);
        // preds shorter than the draft: only the covered prefix counts
        assert_eq!(longest_accept(&[1, 2, 3], &[1, 2]), 2);
    }
}
