//! Batched evaluation service: a long-lived server thread owns the PJRT
//! executable (device buffers are not Sync) and drains a request channel,
//! coalescing up to `batch` sequences per forward pass — the classic
//! dynamic-batching loop, exercised by `examples/serve_eval.rs`.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::LogitsFn;

/// One scoring request: a (≤ seq)-token sequence; the response is the
/// per-position next-token logprob of the sequence under the model.
pub struct Request {
    pub tokens: Vec<i32>,
    pub resp: Sender<Response>,
}

/// Channel protocol: scoring work or an explicit stop (so `shutdown` does
/// not depend on every client handle being dropped first).
enum Msg {
    Score(Request),
    Stop,
}

#[derive(Clone, Debug)]
pub struct Response {
    /// logprob of tokens[p] given tokens[..p], for p in 1..len.
    pub logprobs: Vec<f64>,
    /// Which batch this request rode in (telemetry).
    pub batch_id: u64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub max_batch_fill: usize,
}

/// Client handle: cloneable, thread-safe.
#[derive(Clone)]
pub struct EvalClient {
    tx: Sender<Msg>,
}

impl EvalClient {
    /// Blocking scoring call.
    pub fn score(&self, tokens: Vec<i32>) -> Result<Response> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Score(Request { tokens, resp: tx }))
            .map_err(|_| anyhow::anyhow!("server gone"))?;
        Ok(rx.recv()?)
    }
}

pub struct EvalServer {
    handle: Option<JoinHandle<ServerStats>>,
    tx: Option<Sender<Msg>>,
}

impl EvalServer {
    /// Spawn the server thread around a model. `linger` is how long the
    /// batcher waits to fill a batch before dispatching a partial one.
    pub fn spawn<M>(model: M, linger: Duration) -> (EvalServer, EvalClient)
    where
        M: LogitsFn + Send + 'static,
    {
        Self::spawn_with(move || model, linger)
    }

    /// Spawn with a factory that *builds the model inside the server
    /// thread* — required for PJRT-backed models ([`crate::runtime::ModelRunner`]
    /// holds non-`Send` device handles; only the factory crosses threads).
    pub fn spawn_with<M, F>(factory: F, linger: Duration) -> (EvalServer, EvalClient)
    where
        M: LogitsFn + 'static,
        F: FnOnce() -> M + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let client = EvalClient { tx: tx.clone() };
        let handle = std::thread::Builder::new()
            .name("msb-eval-server".into())
            .spawn(move || serve(factory(), rx, linger))
            .expect("spawn server");
        (EvalServer { handle: Some(handle), tx: Some(tx) }, client)
    }

    /// Stop the server and collect telemetry. Safe to call with client
    /// handles still alive: an explicit stop message ends the loop.
    pub fn shutdown(mut self) -> ServerStats {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Stop);
        }
        self.handle.take().map(|h| h.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for EvalServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Stop);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve<M: LogitsFn>(model: M, rx: Receiver<Msg>, linger: Duration) -> ServerStats {
    let (b, t, v) = (model.batch(), model.seq(), model.vocab());
    let mut stats = ServerStats::default();
    let mut batch_id = 0u64;
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(Msg::Score(r)) => r,
            Ok(Msg::Stop) | Err(_) => return stats,
        };
        let mut pending = vec![first];
        // linger to coalesce more
        let mut stop_after = false;
        let deadline = Instant::now() + linger;
        while pending.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Score(r)) => pending.push(r),
                Ok(Msg::Stop) => {
                    stop_after = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // assemble the batch
        let mut tokens = vec![0i32; b * t];
        for (row, req) in pending.iter().enumerate() {
            let n = req.tokens.len().min(t);
            tokens[row * t..row * t + n].copy_from_slice(&req.tokens[..n]);
        }
        let logits = match model.logits(&tokens) {
            Ok(l) => l,
            Err(_) => continue, // drop the batch; clients see closed channel
        };
        let lp = crate::eval::LogProbs::new(&logits, v);
        batch_id += 1;
        stats.batches += 1;
        stats.requests += pending.len() as u64;
        stats.max_batch_fill = stats.max_batch_fill.max(pending.len());
        for (row, req) in pending.into_iter().enumerate() {
            let n = req.tokens.len().min(t);
            let mut logprobs = Vec::with_capacity(n.saturating_sub(1));
            for p in 1..n {
                logprobs.push(lp.logp(row * t + p - 1, req.tokens[p] as usize));
            }
            let _ = req.resp.send(Response { logprobs, batch_id });
        }
        if stop_after {
            return stats;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::mock::SuccessorModel;

    fn model() -> SuccessorModel {
        SuccessorModel { batch: 4, seq: 8, vocab: 16, boost: 6.0 }
    }

    #[test]
    fn single_request_roundtrip() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        let r = client.score(vec![1, 2, 3, 4]).unwrap();
        assert_eq!(r.logprobs.len(), 3);
        // successor tokens are high-probability
        assert!(r.logprobs.iter().all(|&lp| lp > -0.5), "{:?}", r.logprobs);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn batching_coalesces_concurrent_requests() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(50));
        let mut handles = Vec::new();
        for i in 0..4 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                c.score(vec![i, i + 1, i + 2]).unwrap()
            }));
        }
        let responses: Vec<Response> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches < 4, "requests must coalesce: {stats:?}");
        // at least two shared a batch id
        let ids: Vec<u64> = responses.iter().map(|r| r.batch_id).collect();
        let mut sorted = ids.clone();
        sorted.dedup();
        assert!(stats.max_batch_fill >= 2);
    }

    #[test]
    fn overlong_sequences_truncate() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        let r = client.score((0..50).collect()).unwrap();
        assert_eq!(r.logprobs.len(), 7); // seq=8 -> 7 predictions
        drop(client);
        server.shutdown();
    }

    #[test]
    fn shutdown_idempotent_via_drop() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        drop(client);
        drop(server); // must not hang
    }
}
