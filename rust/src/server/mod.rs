//! Batched serving: long-lived server threads that own the model and
//! drain a request channel with dynamic batching.
//!
//! * [`EvalServer`] — token scoring. [`EvalServer::spawn`] is the static
//!   batcher (one full forward per drain, padded to the model's `batch`;
//!   required for PJRT-backed models whose device buffers are not Sync).
//!   [`EvalServer::spawn_batched`] is the continuous-batching decode
//!   scheduler over a [`crate::forward::ForwardModel`]: requests become
//!   *streams* in a paged [`crate::forward::KvArena`], every coalesced
//!   [`step_batch`](crate::forward::ForwardModel::step_batch) advances
//!   all live streams at once (chunked prefill, so a long prompt never
//!   stalls running decodes), finished streams retire and their pages
//!   recycle immediately, and FIFO admission with a max-waiting-steps
//!   fairness bound fills freed slots between steps. Each stream's
//!   logprobs are bit-identical to its solo unbatched run. The batched
//!   scheduler also serves greedy *generation* ([`EvalClient::generate`]),
//!   optionally with self-speculative decode: a per-stream [`draft`]
//!   prompt-lookup index proposes lookahead tokens that ride the same
//!   `step_batch` chunk, every position is verified against its own
//!   argmax in that one fused pass, and rejected tails roll back
//!   page-wise via
//!   [`KvArena::truncate_stream`](crate::forward::KvArena::truncate_stream)
//!   — generated tokens are bit-identical to plain greedy decode, only
//!   the step count changes.
//! * [`GemvServer`] — the fused packed-weight loop: holds a
//!   [`FusedModel`] (codes + scale tables, never decoded f32 buffers) and
//!   coalesces same-layer matvec requests into one
//!   `PackedLinear::gemm_pooled` call, so each block tile is decoded once
//!   per batch instead of once per request; exercised by
//!   `serve_eval fused`.

pub mod draft;

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::forward::{argmax_row, argmax_rows, ForwardModel, KvArena, StreamSlot};
use crate::pool::ThreadPool;
use crate::runtime::{FusedModel, LogitsFn};

/// One scoring request: a (≤ seq)-token sequence; the response is the
/// per-position next-token logprob of the sequence under the model.
pub struct Request {
    pub tokens: Vec<i32>,
    pub resp: Sender<Response>,
}

/// One greedy-generation request: a non-empty (≤ seq) prompt plus a
/// budget of new tokens. Served only by the continuous batcher
/// ([`EvalServer::spawn_batched`]); the static batcher has no stream
/// state to decode with and rejects it.
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub resp: Sender<GenResponse>,
}

/// Channel protocol: scoring or generation work, or an explicit stop (so
/// `shutdown` does not depend on every client handle being dropped
/// first).
enum Msg {
    Score(Request),
    Generate(GenRequest),
    Stop,
}

#[derive(Clone, Debug)]
pub struct Response {
    /// logprob of tokens[p] given tokens[..p], for p in 1..len.
    pub logprobs: Vec<f64>,
    /// Which batch this request rode in (telemetry).
    pub batch_id: u64,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    /// Greedy continuation of the prompt, in order. May be shorter than
    /// `max_new` when the context window runs out first.
    pub tokens: Vec<i32>,
    /// The coalesced step at which the stream retired (telemetry).
    pub batch_id: u64,
}

#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: u64,
    /// Forward dispatches (static batches, or coalesced decode steps).
    pub batches: u64,
    pub max_batch_fill: usize,
    // -- continuous batching ([`EvalServer::spawn_batched`]) only --
    /// Requests admitted into a stream slot.
    pub admitted: u64,
    /// Streams that finished and returned their pages.
    pub retired: u64,
    /// `step_width_hist[w - 1]` = coalesced steps that ran `w` streams.
    pub step_width_hist: Vec<u64>,
    /// Longest admission queue wait observed, in coalesced steps.
    pub max_wait_steps: u64,
    /// KV arena high-water mark, in pages / bytes, against its capacity.
    pub peak_pages: usize,
    pub total_pages: usize,
    pub peak_page_bytes: usize,
    /// Pages still held by live streams at shutdown — 0 unless the loop
    /// exited with streams in flight (page-balance telemetry).
    pub leaked_pages: usize,
    // -- speculative decode only --
    /// Draft tokens fed for verification.
    pub drafted: u64,
    /// Draft tokens accepted; each one saved a full decode step.
    pub accepted: u64,
}

impl ServerStats {
    /// Fraction of drafted tokens accepted, or `None` before any draft.
    pub fn accept_rate(&self) -> Option<f64> {
        (self.drafted > 0).then(|| self.accepted as f64 / self.drafted as f64)
    }
}

/// Client handle: cloneable, thread-safe.
#[derive(Clone)]
pub struct EvalClient {
    tx: Sender<Msg>,
}

impl EvalClient {
    /// Blocking scoring call.
    pub fn score(&self, tokens: Vec<i32>) -> Result<Response> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Score(Request { tokens, resp: tx }))
            .map_err(|_| anyhow::anyhow!("server gone"))?;
        Ok(rx.recv()?)
    }

    /// Blocking greedy-generation call: up to `max_new` tokens continuing
    /// `prompt` (fewer when the context window runs out first). Only the
    /// continuous batcher ([`EvalServer::spawn_batched`]) serves this;
    /// against the static batcher the call errors. Whether the server
    /// runs speculative decode is invisible here — the tokens are
    /// bit-identical either way.
    pub fn generate(&self, prompt: Vec<i32>, max_new: usize) -> Result<GenResponse> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Generate(GenRequest { prompt, max_new, resp: tx }))
            .map_err(|_| anyhow::anyhow!("server gone"))?;
        Ok(rx.recv()?)
    }
}

/// Knobs of the continuous-batching scheduler
/// ([`EvalServer::spawn_batched`]).
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Concurrent streams (slots); also sizes the KV page arena so every
    /// slot can reach the full context window.
    pub max_streams: usize,
    /// Positions per KV page ([`crate::forward::KvArena`]).
    pub kv_page_tokens: usize,
    /// Most tokens fed per stream per coalesced step. Chunked prefill: a
    /// long prompt advances `prefill_chunk` tokens at a time, so streams
    /// already decoding keep producing a token every step instead of
    /// stalling behind one full-prompt pass.
    pub prefill_chunk: usize,
    /// Fairness bound: once the oldest waiting request has queued this
    /// many steps, the chunk cap is lifted for running streams so they
    /// drain (and free slots) as fast as possible. The tradeoff is
    /// explicit — brief extra per-step latency for bounded queue wait.
    pub max_waiting_steps: u64,
    /// How long an idle server waits for more arrivals before stepping a
    /// partial batch (same role as the static batcher's linger).
    pub linger: Duration,
    /// Self-speculative greedy decode for generation streams: draft
    /// lookahead tokens from each stream's [`draft::Drafter`] ride the
    /// decode chunk and are verified in the same fused pass. Exact —
    /// affects step counts, never tokens. Scoring requests are untouched.
    pub speculative: bool,
    /// Cap on draft tokens per stream per step; the adaptive per-stream
    /// length moves within `1..=draft_len` (halve on reject, +1 on full
    /// accept). Also capped by the step's chunk budget so the fairness
    /// bound keeps holding.
    pub draft_len: usize,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig {
            max_streams: 4,
            kv_page_tokens: 16,
            prefill_chunk: 8,
            max_waiting_steps: 32,
            linger: Duration::from_millis(1),
            speculative: false,
            draft_len: 4,
        }
    }
}

pub struct EvalServer {
    handle: Option<JoinHandle<ServerStats>>,
    tx: Option<Sender<Msg>>,
}

impl EvalServer {
    /// Spawn the server thread around a model. `linger` is how long the
    /// batcher waits to fill a batch before dispatching a partial one.
    pub fn spawn<M>(model: M, linger: Duration) -> (EvalServer, EvalClient)
    where
        M: LogitsFn + Send + 'static,
    {
        Self::spawn_with(move || model, linger)
    }

    /// Spawn with a factory that *builds the model inside the server
    /// thread* — required for PJRT-backed models ([`crate::runtime::ModelRunner`]
    /// holds non-`Send` device handles; only the factory crosses threads).
    pub fn spawn_with<M, F>(factory: F, linger: Duration) -> (EvalServer, EvalClient)
    where
        M: LogitsFn + 'static,
        F: FnOnce() -> M + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let client = EvalClient { tx: tx.clone() };
        let handle = std::thread::Builder::new()
            .name("msb-eval-server".into())
            .spawn(move || serve(factory(), rx, linger))
            .expect("spawn server");
        (EvalServer { handle: Some(handle), tx: Some(tx) }, client)
    }

    /// Spawn the continuous-batching decode scheduler over a fused CPU
    /// forward model. Same [`EvalClient`] protocol as
    /// [`EvalServer::spawn`] — a request is a token sequence, the
    /// response its per-position logprobs — but requests are served as
    /// concurrent *streams* sharing every projection `gemm` through
    /// [`ForwardModel::step_batch`] and a paged KV arena, instead of
    /// padded rows of one fixed-shape forward. Each response is
    /// bit-identical to the same request scored alone.
    pub fn spawn_batched(
        model: ForwardModel,
        cfg: BatchConfig,
    ) -> Result<(EvalServer, EvalClient)> {
        let arena = model.kv_arena(cfg.max_streams.max(1), cfg.kv_page_tokens.max(1))?;
        let (tx, rx) = channel::<Msg>();
        let client = EvalClient { tx: tx.clone() };
        let handle = std::thread::Builder::new()
            .name("msb-batch-server".into())
            .spawn(move || serve_batched(model, arena, rx, cfg))
            .expect("spawn batch server");
        Ok((EvalServer { handle: Some(handle), tx: Some(tx) }, client))
    }

    /// Stop the server and collect telemetry. Safe to call with client
    /// handles still alive: an explicit stop message ends the loop.
    pub fn shutdown(mut self) -> ServerStats {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Stop);
        }
        self.handle.take().map(|h| h.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for EvalServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Stop);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve<M: LogitsFn>(model: M, rx: Receiver<Msg>, linger: Duration) -> ServerStats {
    let (b, t, v) = (model.batch(), model.seq(), model.vocab());
    let mut stats = ServerStats::default();
    let mut batch_id = 0u64;
    loop {
        // block for the first request
        let first = loop {
            match rx.recv() {
                Ok(Msg::Score(r)) => break r,
                // generation needs the continuous batcher's stream state;
                // dropping the sender tells the client "unsupported"
                Ok(Msg::Generate(_)) => continue,
                Ok(Msg::Stop) | Err(_) => return stats,
            }
        };
        let mut pending = vec![first];
        // linger to coalesce more
        let mut stop_after = false;
        let deadline = Instant::now() + linger;
        while pending.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Score(r)) => pending.push(r),
                Ok(Msg::Generate(_)) => continue,
                Ok(Msg::Stop) => {
                    stop_after = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // assemble the batch
        let mut tokens = vec![0i32; b * t];
        for (row, req) in pending.iter().enumerate() {
            let n = req.tokens.len().min(t);
            tokens[row * t..row * t + n].copy_from_slice(&req.tokens[..n]);
        }
        let logits = match model.logits(&tokens) {
            Ok(l) => l,
            Err(_) => continue, // drop the batch; clients see closed channel
        };
        let lp = crate::eval::LogProbs::new(&logits, v);
        batch_id += 1;
        stats.batches += 1;
        stats.requests += pending.len() as u64;
        stats.max_batch_fill = stats.max_batch_fill.max(pending.len());
        for (row, req) in pending.into_iter().enumerate() {
            let n = req.tokens.len().min(t);
            let mut logprobs = Vec::with_capacity(n.saturating_sub(1));
            for p in 1..n {
                logprobs.push(lp.logp(row * t + p - 1, req.tokens[p] as usize));
            }
            let _ = req.resp.send(Response { logprobs, batch_id });
        }
        if stop_after {
            return stats;
        }
    }
}

/// What a stream owes its client when it retires.
enum Reply {
    Score(Sender<Response>),
    Gen(Sender<GenResponse>),
}

/// Decode-side state of a generation stream.
struct GenState {
    /// Greedy tokens emitted so far (the response payload).
    generated: Vec<i32>,
    /// Budget after context-window clamping: at most
    /// `seq - prompt_len + 1` tokens fit (the final token is chosen from
    /// the last in-window logits row and never fed back).
    max_new: usize,
    /// Prompt-lookup index over the committed tokens (prompt + verified
    /// generations) — the speculative draft source.
    drafter: draft::Drafter,
    /// Adaptive draft length in `1..=cfg.draft_len`: halved on any
    /// reject, +1 on a full accept, so streams the drafter reads well
    /// speculate deep and hostile streams pay ~1 wasted position.
    draft_len: usize,
}

/// One live stream of the continuous batcher: the request it came from,
/// how far it has decoded, and the running logprob/generation state.
struct Active {
    id: crate::forward::StreamId,
    /// Committed tokens: the (truncated) request for scoring streams;
    /// prompt + verified greedy output for generation streams. Draft
    /// tokens never enter here until they pass verification.
    tokens: Vec<i32>,
    /// Positions already fed through `step_batch` (== the stream's KV
    /// length; speculative rejects roll both back together).
    fed: usize,
    logprobs: Vec<f64>,
    /// Logits row of position `fed - 1` — scores the next chunk's first
    /// token exactly as the full-slab `LogProbs` indexing would, and is
    /// the argmax source for a generation stream's next committed token.
    last_row: Option<Vec<f32>>,
    gen: Option<GenState>,
    reply: Reply,
}

/// Per-step feeding plan for one stream: how the staged chunk is to be
/// interpreted when its logits come back.
enum Plan {
    /// Scoring/prefill chunk of committed tokens.
    Committed,
    /// Decode chunk `[next, draft...]` with `k` draft tokens to verify.
    Decode { k: usize },
}

fn serve_batched(
    model: ForwardModel,
    mut arena: KvArena,
    rx: Receiver<Msg>,
    cfg: BatchConfig,
) -> ServerStats {
    let (seq, vocab) = (model.spec().seq, model.spec().vocab);
    let max_streams = cfg.max_streams.max(1);
    let prefill_chunk = cfg.prefill_chunk.max(1);
    let draft_cap = cfg.draft_len.max(1);
    let mut stats = ServerStats::default();
    let mut waiting: VecDeque<(Msg, u64)> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut step_idx = 0u64;
    let mut stop = false;
    loop {
        // Ingest: block (with linger) only when there is nothing to run;
        // otherwise drain whatever has arrived between steps.
        if !stop {
            if active.is_empty() && waiting.is_empty() {
                match rx.recv() {
                    Ok(m @ (Msg::Score(_) | Msg::Generate(_))) => waiting.push_back((m, step_idx)),
                    Ok(Msg::Stop) | Err(_) => break,
                }
                let deadline = Instant::now() + cfg.linger;
                while waiting.len() < max_streams {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(m @ (Msg::Score(_) | Msg::Generate(_))) => {
                            waiting.push_back((m, step_idx));
                        }
                        Ok(Msg::Stop) => {
                            stop = true;
                            break;
                        }
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            stop = true;
                            break;
                        }
                    }
                }
            } else {
                loop {
                    match rx.try_recv() {
                        Ok(m @ (Msg::Score(_) | Msg::Generate(_))) => {
                            waiting.push_back((m, step_idx));
                        }
                        Ok(Msg::Stop) | Err(TryRecvError::Disconnected) => {
                            stop = true;
                            break;
                        }
                        Err(TryRecvError::Empty) => break,
                    }
                }
            }
        }

        // FIFO admission into open slots. Requests already queued when
        // the stop arrived still run; only the channel closes.
        while active.len() < max_streams {
            let Some((msg, enqueued)) = waiting.pop_front() else { break };
            stats.max_wait_steps = stats.max_wait_steps.max(step_idx - enqueued);
            match msg {
                Msg::Score(req) => {
                    let mut tokens = req.tokens;
                    tokens.truncate(seq);
                    if tokens.is_empty() {
                        // same contract as the static batcher: no predictions
                        stats.requests += 1;
                        let _ = req
                            .resp
                            .send(Response { logprobs: Vec::new(), batch_id: step_idx });
                        continue;
                    }
                    if tokens.iter().any(|&t| t < 0 || t as usize >= vocab) {
                        // reject at admission (sender drops; client sees a
                        // closed channel) instead of poisoning a whole
                        // coalesced step
                        stats.requests += 1;
                        continue;
                    }
                    stats.admitted += 1;
                    active.push(Active {
                        id: arena.alloc_stream(),
                        tokens,
                        fed: 0,
                        logprobs: Vec::new(),
                        last_row: None,
                        gen: None,
                        reply: Reply::Score(req.resp),
                    });
                }
                Msg::Generate(req) => {
                    let mut prompt = req.prompt;
                    prompt.truncate(seq);
                    if prompt.is_empty() || req.max_new == 0 {
                        stats.requests += 1;
                        let _ = req
                            .resp
                            .send(GenResponse { tokens: Vec::new(), batch_id: step_idx });
                        continue;
                    }
                    if prompt.iter().any(|&t| t < 0 || t as usize >= vocab) {
                        stats.requests += 1;
                        continue;
                    }
                    stats.admitted += 1;
                    // the final token comes off the last in-window logits
                    // row without being fed back, hence the +1
                    let max_new = req.max_new.min(seq - prompt.len() + 1);
                    let mut drafter = draft::Drafter::new(draft::DEFAULT_NGRAM);
                    drafter.extend(&prompt);
                    active.push(Active {
                        id: arena.alloc_stream(),
                        tokens: prompt,
                        fed: 0,
                        logprobs: Vec::new(),
                        last_row: None,
                        gen: Some(GenState {
                            generated: Vec::new(),
                            max_new,
                            drafter,
                            draft_len: draft_cap,
                        }),
                        reply: Reply::Gen(req.resp),
                    });
                }
                Msg::Stop => unreachable!("Stop is never queued"),
            }
        }
        if active.is_empty() {
            if stop {
                break;
            }
            continue;
        }

        // Generation commit pass: a decode-phase generation stream whose
        // chunk is fully fed owes exactly one committed token — the
        // argmax of its last logits row (bit-identical to what plain
        // greedy decode picks, speculative or not). Streams whose budget
        // is spent retire here: the final token is never fed back.
        let mut finished = Vec::new();
        for (ai, a) in active.iter_mut().enumerate() {
            let Some(g) = a.gen.as_mut() else { continue };
            if a.fed < a.tokens.len() {
                continue; // still prefilling
            }
            if g.generated.len() >= g.max_new {
                finished.push(ai);
                continue;
            }
            let row = a.last_row.as_ref().expect("decode phase keeps a last row");
            let next = argmax_row(row) as i32;
            a.tokens.push(next);
            g.generated.push(next);
            g.drafter.extend(&[next]);
            if g.generated.len() >= g.max_new {
                finished.push(ai);
            }
        }
        for ai in finished.into_iter().rev() {
            let a = active.swap_remove(ai);
            arena.free_stream(a.id);
            stats.requests += 1;
            stats.retired += 1;
            if let (Reply::Gen(tx), Some(g)) = (a.reply, a.gen) {
                let _ = tx.send(GenResponse { tokens: g.generated, batch_id: step_idx });
            }
        }
        if active.is_empty() {
            if stop && waiting.is_empty() {
                break;
            }
            continue;
        }

        // Fairness: a starved waiter lifts the chunk cap so running
        // streams drain (and free their slots) as fast as possible.
        let oldest_wait = waiting.front().map_or(0, |(_, e)| step_idx - e);
        let chunk = if oldest_wait >= cfg.max_waiting_steps { seq } else { prefill_chunk };

        // Stage every stream's chunk. Scoring/prefill chunks copy the
        // committed slice; a decode-phase generation stream stages
        // `[next, draft...]` — the drafts are *uncommitted* guesses from
        // its prompt-lookup index, so they live only in this buffer. The
        // draft length is capped by the chunk budget (fairness bound
        // unchanged), the remaining token budget, and the context window.
        let mut plans: Vec<Plan> = Vec::with_capacity(active.len());
        let mut chunks: Vec<Vec<i32>> = Vec::with_capacity(active.len());
        for a in active.iter_mut() {
            match a.gen.as_mut() {
                Some(g) if !g.generated.is_empty() => {
                    let next = *a.tokens.last().expect("decode stream has tokens");
                    let mut staged = vec![next];
                    if cfg.speculative {
                        let cap = g
                            .draft_len
                            .min(chunk.saturating_sub(1))
                            .min(g.max_new - g.generated.len())
                            .min(seq - a.fed - 1);
                        staged.extend(g.drafter.propose(cap));
                    }
                    plans.push(Plan::Decode { k: staged.len() - 1 });
                    chunks.push(staged);
                }
                _ => {
                    let w = chunk.min(a.tokens.len() - a.fed);
                    plans.push(Plan::Committed);
                    chunks.push(a.tokens[a.fed..a.fed + w].to_vec());
                }
            }
        }
        let slots: Vec<StreamSlot<'_>> = active
            .iter()
            .zip(&chunks)
            .map(|(a, c)| StreamSlot { id: a.id, tokens: c })
            .collect();
        let outs = match model.step_batch(&mut arena, &slots) {
            Ok(o) => o,
            Err(_) => {
                // defensive: tokens are pre-validated and the arena is
                // sized for max_streams full-context streams, so this is
                // unreachable in normal operation — fail the affected
                // streams, keep serving
                for a in active.drain(..) {
                    arena.free_stream(a.id);
                }
                continue;
            }
        };
        step_idx += 1;
        stats.batches += 1;
        let width = active.len();
        stats.max_batch_fill = stats.max_batch_fill.max(width);
        if stats.step_width_hist.len() < width {
            stats.step_width_hist.resize(width, 0);
        }
        stats.step_width_hist[width - 1] += 1;

        // Per-stream output processing.
        let mut done = Vec::new();
        for (ai, out) in outs.into_iter().enumerate() {
            let a = &mut active[ai];
            let w = out.len() / vocab;
            match plans[ai] {
                // Speculative verification: row i's argmax is the true
                // greedy successor of chunk[..=i], read from the same
                // fused pass that computed it — acceptance is exact by
                // construction. Rejected positions hold logits of a
                // wrong prefix; their pages roll back below.
                Plan::Decode { k } => {
                    let staged = &chunks[ai];
                    let g = a.gen.as_mut().expect("decode plan implies gen state");
                    let preds: Vec<i32> =
                        argmax_rows(&out, vocab).into_iter().map(|p| p as i32).collect();
                    let j = draft::longest_accept(&staged[1..], &preds);
                    stats.drafted += k as u64;
                    stats.accepted += j as u64;
                    // accepted drafts are exactly the tokens plain greedy
                    // would have committed, and their KV entries are
                    // already in place from the fused pass
                    a.tokens.extend_from_slice(&staged[1..1 + j]);
                    g.generated.extend_from_slice(&staged[1..1 + j]);
                    g.drafter.extend(&staged[1..1 + j]);
                    if k > 0 {
                        g.draft_len = if j == k {
                            (g.draft_len + 1).min(draft_cap)
                        } else {
                            (g.draft_len / 2).max(1)
                        };
                    }
                    a.last_row = Some(out[j * vocab..(j + 1) * vocab].to_vec());
                    a.fed += 1 + j;
                    if j < k {
                        // page-level rollback of the rejected tail
                        arena
                            .truncate_stream(a.id, a.fed)
                            .expect("rollback within the stream's fed length");
                    }
                }
                Plan::Committed if a.gen.is_some() => {
                    // generation prefill: advance; the commit pass above
                    // turns the last row into the first generated token
                    a.last_row = Some(out[(w - 1) * vocab..w * vocab].to_vec());
                    a.fed += w;
                }
                // Scoring logprob assembly: the chunk's first token is
                // scored by the previous chunk's last row, the rest by
                // this chunk's rows — identical f64 math to the one-slab
                // unbatched path.
                Plan::Committed => {
                    if a.fed > 0 {
                        let last = a.last_row.as_ref().expect("fed > 0 keeps a last row");
                        let lp = crate::eval::LogProbs::new(last, vocab);
                        a.logprobs.push(lp.logp(0, a.tokens[a.fed] as usize));
                    }
                    let lp = crate::eval::LogProbs::new(&out, vocab);
                    for i in 1..w {
                        a.logprobs.push(lp.logp(i - 1, a.tokens[a.fed + i] as usize));
                    }
                    a.last_row = Some(out[(w - 1) * vocab..w * vocab].to_vec());
                    a.fed += w;
                    if a.fed == a.tokens.len() {
                        done.push(ai);
                    }
                }
            }
        }
        // Retire finished scoring streams; their pages recycle
        // immediately, and the freed slots admit waiters on the next loop
        // turn. (Generation streams retire in the commit pass.)
        for ai in done.into_iter().rev() {
            let a = active.swap_remove(ai);
            arena.free_stream(a.id);
            stats.requests += 1;
            stats.retired += 1;
            if let Reply::Score(tx) = a.reply {
                let _ = tx.send(Response { logprobs: a.logprobs, batch_id: step_idx });
            }
        }
        if stop && active.is_empty() && waiting.is_empty() {
            break;
        }
    }
    stats.peak_pages = arena.peak_pages();
    stats.total_pages = arena.total_pages();
    stats.peak_page_bytes = arena.peak_bytes();
    stats.leaked_pages = arena.pages_in_use();
    stats
}

// ---------------------------------------------------------------------------
// Fused packed-weight serving.
// ---------------------------------------------------------------------------

/// One fused matvec request: an activation vector for a named packed
/// layer; the response is `y = W·x` computed directly on the codes.
struct GemvRequest {
    layer: String,
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
}

enum GemvMsg {
    Infer(GemvRequest),
    Stop,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct GemvStats {
    pub requests: u64,
    /// Fused `gemm` dispatches — coalescing makes this < `requests`.
    pub batches: u64,
    pub max_batch_fill: usize,
}

/// Client handle for [`GemvServer`]: cloneable, thread-safe.
#[derive(Clone)]
pub struct GemvClient {
    tx: Sender<GemvMsg>,
}

impl GemvClient {
    /// Blocking fused-matvec call against a packed layer.
    pub fn infer(&self, layer: &str, x: Vec<f32>) -> Result<Vec<f32>> {
        let (tx, rx) = channel();
        self.tx
            .send(GemvMsg::Infer(GemvRequest { layer: layer.to_string(), x, resp: tx }))
            .map_err(|_| anyhow::anyhow!("gemv server gone"))?;
        rx.recv()?
    }
}

/// A long-lived server thread that owns a [`FusedModel`] — the packed
/// payloads, never decoded f32 weights — plus a [`ThreadPool`] for row
/// striping, and drains matvec requests with dynamic batching: requests
/// arriving within `linger` coalesce per layer into one batched
/// `gemm_pooled`, amortizing each block tile's decode across the batch.
/// Responses are bit-identical to serial per-request `gemv` (the fused
/// kernels' determinism contract), regardless of batch composition.
pub struct GemvServer {
    handle: Option<JoinHandle<GemvStats>>,
    tx: Option<Sender<GemvMsg>>,
}

impl GemvServer {
    /// Spawn the serving thread. `threads` sizes the row-striping pool,
    /// `batch_cap` bounds how many requests one dispatch coalesces.
    pub fn spawn(
        model: FusedModel,
        threads: usize,
        batch_cap: usize,
        linger: Duration,
    ) -> (GemvServer, GemvClient) {
        let (tx, rx) = channel::<GemvMsg>();
        let client = GemvClient { tx: tx.clone() };
        let (threads, cap) = (threads.max(1), batch_cap.max(1));
        let handle = std::thread::Builder::new()
            .name("msb-gemv-server".into())
            .spawn(move || serve_gemv(model, rx, threads, cap, linger))
            .expect("spawn gemv server");
        (GemvServer { handle: Some(handle), tx: Some(tx) }, client)
    }

    /// Stop the server and collect telemetry (safe with live clients).
    pub fn shutdown(mut self) -> GemvStats {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(GemvMsg::Stop);
        }
        self.handle.take().map(|h| h.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for GemvServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(GemvMsg::Stop);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_gemv(
    model: FusedModel,
    rx: Receiver<GemvMsg>,
    threads: usize,
    batch_cap: usize,
    linger: Duration,
) -> GemvStats {
    let pool = ThreadPool::new(threads, threads * 4);
    let mut stats = GemvStats::default();
    loop {
        let first = match rx.recv() {
            Ok(GemvMsg::Infer(r)) => r,
            Ok(GemvMsg::Stop) | Err(_) => return stats,
        };
        let mut pending = vec![first];
        let mut stop_after = false;
        let deadline = Instant::now() + linger;
        while pending.len() < batch_cap {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(GemvMsg::Infer(r)) => pending.push(r),
                Ok(GemvMsg::Stop) => {
                    stop_after = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.requests += pending.len() as u64;

        // group by layer so one fused gemm serves each group
        let mut groups: BTreeMap<String, Vec<GemvRequest>> = BTreeMap::new();
        for r in pending {
            groups.entry(r.layer.clone()).or_default().push(r);
        }
        for (layer, reqs) in groups {
            let Some(l) = model.linear(&layer) else {
                for r in reqs {
                    let _ = r.resp.send(Err(anyhow::anyhow!("no packed layer '{layer}'")));
                }
                continue;
            };
            let (cols, rows) = (l.cols(), l.rows());
            let mut valid = Vec::with_capacity(reqs.len());
            for r in reqs {
                if r.x.len() == cols {
                    valid.push(r);
                } else {
                    let msg = anyhow::anyhow!("{layer}: x len {} != cols {cols}", r.x.len());
                    let _ = r.resp.send(Err(msg));
                }
            }
            if valid.is_empty() {
                continue;
            }
            let batch = valid.len();
            let mut xs = vec![0.0f32; batch * cols];
            for (b, r) in valid.iter().enumerate() {
                xs[b * cols..(b + 1) * cols].copy_from_slice(&r.x);
            }
            // the batch buffer is handed to the jobs as-is (gemm_shared):
            // assembling it above was the only copy
            let ys = l.gemm_shared(std::sync::Arc::new(xs), batch, &pool);
            stats.batches += 1;
            stats.max_batch_fill = stats.max_batch_fill.max(batch);
            for (b, r) in valid.into_iter().enumerate() {
                let _ = r.resp.send(Ok(ys[b * rows..(b + 1) * rows].to_vec()));
            }
        }
        if stop_after {
            return stats;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::mock::SuccessorModel;

    fn model() -> SuccessorModel {
        SuccessorModel { batch: 4, seq: 8, vocab: 16, boost: 6.0 }
    }

    #[test]
    fn single_request_roundtrip() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        let r = client.score(vec![1, 2, 3, 4]).unwrap();
        assert_eq!(r.logprobs.len(), 3);
        // successor tokens are high-probability
        assert!(r.logprobs.iter().all(|&lp| lp > -0.5), "{:?}", r.logprobs);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn batching_coalesces_concurrent_requests() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(50));
        let mut handles = Vec::new();
        for i in 0..4 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                c.score(vec![i, i + 1, i + 2]).unwrap()
            }));
        }
        let responses: Vec<Response> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches < 4, "requests must coalesce: {stats:?}");
        // at least two shared a batch id
        let ids: Vec<u64> = responses.iter().map(|r| r.batch_id).collect();
        let mut sorted = ids.clone();
        sorted.dedup();
        assert!(stats.max_batch_fill >= 2);
    }

    #[test]
    fn overlong_sequences_truncate() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        let r = client.score((0..50).collect()).unwrap();
        assert_eq!(r.logprobs.len(), 7); // seq=8 -> 7 predictions
        drop(client);
        server.shutdown();
    }

    #[test]
    fn shutdown_idempotent_via_drop() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        drop(client);
        drop(server); // must not hang
    }

    // -----------------------------------------------------------------------
    // continuous batching over the forward backend
    // -----------------------------------------------------------------------

    /// An rtn-packed artifact for a batch-1 forward spec (affine decode,
    /// so the same payload serves both MAC modes).
    fn forward_payload() -> (crate::forward::ForwardSpec, crate::io::msbt::TensorMap) {
        use crate::forward::synth;
        use crate::pipeline::{quantize, Method, QuantizeOptions};
        use crate::quant::QuantConfig;
        let fs = crate::forward::ForwardSpec::new(40, 32, 2, 4, 48, 8, 1).unwrap();
        let spec = synth::model_spec(&fs, "srv-batch");
        let weights = synth::synth_weights(&fs, 21);
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        let qm = quantize(&spec, weights, None, Method::Rtn, &cfg, &opts).unwrap();
        (fs, qm.export_packed().unwrap())
    }

    /// Satellite: interleaved multi-stream requests through the
    /// continuous batcher return bit-identical logprobs to unbatched solo
    /// runs, at threads {1,4} and MacMode {F32, Int8}, with more requests
    /// than stream slots so admission queuing and retirement both fire.
    #[test]
    fn batched_eval_server_bit_identical_to_solo() {
        use crate::forward::{synth, ForwardModel};
        use crate::kernels::MacMode;
        let (fs, map) = forward_payload();
        // uneven lengths; one overlong request exercises truncation
        let reqs: Vec<Vec<i32>> = [5usize, 8, 3, 6, 10, 4]
            .iter()
            .enumerate()
            .map(|(i, &len)| synth::synth_tokens(&fs, len, 50 + i as u64))
            .collect();
        for mac in [MacMode::F32, MacMode::Int8] {
            for threads in [1usize, 4] {
                let build = || {
                    ForwardModel::from_packed_map_with(fs.clone(), &map, mac)
                        .unwrap()
                        .with_threads(threads)
                };
                // solo references through the unbatched server (batch=1
                // spec: every request rides alone)
                let (solo_srv, solo_cli) =
                    EvalServer::spawn(build(), Duration::from_millis(1));
                let solo: Vec<Vec<f64>> = reqs
                    .iter()
                    .map(|t| solo_cli.score(t.clone()).unwrap().logprobs)
                    .collect();
                drop(solo_cli);
                solo_srv.shutdown();

                // 3 slots for 6 requests: admission queue + retirement
                // churn; page_tokens 3 leaves partial pages; chunk 2
                // forces multi-step prefill
                let bcfg = BatchConfig {
                    max_streams: 3,
                    kv_page_tokens: 3,
                    prefill_chunk: 2,
                    max_waiting_steps: 4,
                    linger: Duration::from_millis(40),
                    ..BatchConfig::default()
                };
                let (srv, cli) = EvalServer::spawn_batched(build(), bcfg).unwrap();
                let mut handles = Vec::new();
                for t in &reqs {
                    let c = cli.clone();
                    let t = t.clone();
                    handles.push(std::thread::spawn(move || c.score(t).unwrap()));
                }
                let got: Vec<Response> =
                    handles.into_iter().map(|h| h.join().unwrap()).collect();
                for (i, r) in got.iter().enumerate() {
                    assert_eq!(
                        r.logprobs, solo[i],
                        "request {i}: batched != solo (mac {mac:?}, threads {threads})"
                    );
                }
                drop(cli);
                let stats = srv.shutdown();
                assert_eq!(stats.admitted, 6, "{stats:?}");
                assert_eq!(stats.retired, 6, "every stream must retire: {stats:?}");
                assert_eq!(stats.requests, 6);
                assert!(stats.max_batch_fill >= 2, "streams must coalesce: {stats:?}");
                assert!(
                    stats.step_width_hist.iter().skip(1).sum::<u64>() > 0,
                    "some step must run >1 stream: {stats:?}"
                );
                assert!(stats.peak_pages > 0 && stats.peak_pages <= stats.total_pages);
                assert!(stats.peak_page_bytes > 0);
            }
        }
    }

    #[test]
    fn batched_server_edge_requests() {
        use crate::forward::ForwardModel;
        let (fs, map) = forward_payload();
        let model = ForwardModel::from_packed_map(fs, &map).unwrap();
        let (srv, cli) =
            EvalServer::spawn_batched(model, BatchConfig::default()).unwrap();
        // empty request: empty logprobs, same as the static batcher
        assert!(cli.score(vec![]).unwrap().logprobs.is_empty());
        // out-of-vocab tokens are rejected (closed channel), and the
        // server keeps serving afterwards
        assert!(cli.score(vec![1, 999]).is_err());
        let ok = cli.score(vec![1, 2, 3]).unwrap();
        assert_eq!(ok.logprobs.len(), 2);
        drop(cli);
        let stats = srv.shutdown();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.admitted, 1, "only the valid non-empty request ran: {stats:?}");
    }

    // -----------------------------------------------------------------------
    // greedy generation + speculative decode
    // -----------------------------------------------------------------------

    /// Like [`forward_payload`] but with a caller-chosen context window,
    /// so generation has room to decode.
    fn forward_payload_seq(
        seq: usize,
    ) -> (crate::forward::ForwardSpec, crate::io::msbt::TensorMap) {
        use crate::forward::synth;
        use crate::pipeline::{quantize, Method, QuantizeOptions};
        use crate::quant::QuantConfig;
        let fs = crate::forward::ForwardSpec::new(40, 32, 2, 4, 48, seq, 1).unwrap();
        let spec = synth::model_spec(&fs, "srv-gen");
        let weights = synth::synth_weights(&fs, 21);
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        let qm = quantize(&spec, weights, None, Method::Rtn, &cfg, &opts).unwrap();
        (fs, qm.export_packed().unwrap())
    }

    /// Ground-truth greedy decode: solo `step` calls, one token at a
    /// time, sharing the scheduler's argmax and budget-clamping rules.
    fn solo_greedy(
        model: &crate::forward::ForwardModel,
        prompt: &[i32],
        max_new: usize,
    ) -> Vec<i32> {
        let (seq, vocab) = (model.spec().seq, model.spec().vocab);
        let mut toks = prompt.to_vec();
        toks.truncate(seq);
        assert!(!toks.is_empty() && max_new > 0);
        let eff = max_new.min(seq - toks.len() + 1);
        let mut kv = model.kv_state();
        let mut row = model.step(&mut kv, &toks).unwrap();
        let mut out = Vec::with_capacity(eff);
        loop {
            let next = crate::forward::argmax_row(&row[row.len() - vocab..]) as i32;
            out.push(next);
            if out.len() == eff {
                return out;
            }
            row = model.step(&mut kv, &[next]).unwrap();
        }
    }

    fn run_generate(
        model: crate::forward::ForwardModel,
        cfg: BatchConfig,
        jobs: &[(Vec<i32>, usize)],
    ) -> (Vec<Vec<i32>>, ServerStats) {
        let (srv, cli) = EvalServer::spawn_batched(model, cfg).unwrap();
        let mut handles = Vec::new();
        for (prompt, max_new) in jobs {
            let c = cli.clone();
            let (p, m) = (prompt.clone(), *max_new);
            handles.push(std::thread::spawn(move || c.generate(p, m).unwrap().tokens));
        }
        let outs = handles.into_iter().map(|h| h.join().unwrap()).collect();
        drop(cli);
        (outs, srv.shutdown())
    }

    /// Exact mirror of the single-stream speculative schedule: given the
    /// known greedy continuation `gen`, replay the scheduler's drafter
    /// state, chunk caps and adaptive draft length to predict its
    /// `step_batch` count and drafted/accepted totals. Valid whenever the
    /// stream never shares a step with a starved waiter (no chunk lift),
    /// which holds for any single-job run.
    fn simulate_single_stream(
        prompt: &[i32],
        gen: &[i32],
        seq: usize,
        chunk: usize,
        draft_cap: usize,
    ) -> (u64, u64, u64) {
        let mut d = draft::Drafter::new(draft::DEFAULT_NGRAM);
        d.extend(prompt);
        let eff = gen.len();
        let mut fed = prompt.len();
        let mut steps = prompt.len().div_ceil(chunk) as u64;
        let mut c = 0usize;
        let mut draft_len = draft_cap;
        let (mut drafted, mut accepted) = (0u64, 0u64);
        loop {
            // commit pass: one argmax token per fully-fed chunk
            d.extend(&gen[c..=c]);
            c += 1;
            if c >= eff {
                return (steps, drafted, accepted);
            }
            let cap = draft_len
                .min(chunk.saturating_sub(1))
                .min(eff - c)
                .min(seq - fed - 1);
            let prop = d.propose(cap);
            let k = prop.len();
            // verification accepts exactly the prefix matching the true
            // greedy continuation (preds under a correct prefix ARE the
            // continuation)
            let j = prop.iter().zip(&gen[c..]).take_while(|(a, b)| a == b).count();
            drafted += k as u64;
            accepted += j as u64;
            d.extend(&gen[c..c + j]);
            c += j;
            if k > 0 {
                draft_len = if j == k {
                    (draft_len + 1).min(draft_cap)
                } else {
                    (draft_len / 2).max(1)
                };
            }
            fed += 1 + j;
            steps += 1;
            if c >= eff {
                return (steps, drafted, accepted);
            }
        }
    }

    /// Scan deterministic candidate prompts until the exact simulation
    /// predicts at least one accepted draft token under this model.
    /// Greedy decode on the tiny synthetic payloads falls into loops
    /// quickly, so a recurring suffix with a correct continuation shows
    /// up within a few candidates; the panic is a loud fixture failure,
    /// never a flake (everything here is deterministic).
    fn find_accepting_workload(
        model: &crate::forward::ForwardModel,
        chunk: usize,
        draft_cap: usize,
        max_new: usize,
    ) -> (Vec<i32>, usize, (u64, u64, u64)) {
        use crate::forward::synth;
        let fs = model.spec();
        for seed in 0..32u64 {
            let plen = 4 + (seed as usize % 5);
            let mut prompt = synth::synth_tokens(fs, plen, 17 + seed);
            if seed % 2 == 1 {
                // doubled prompt: guaranteed recurring suffixes to prime
                // the n-gram index before decode even starts
                let copy = prompt.clone();
                prompt.extend_from_slice(&copy);
            }
            let gen = solo_greedy(model, &prompt, max_new);
            let sim = simulate_single_stream(&prompt, &gen, fs.seq, chunk, draft_cap);
            if sim.2 >= 1 {
                return (prompt, max_new, sim);
            }
        }
        panic!("no candidate prompt produced an accepted draft — widen the scan");
    }

    /// Tentpole: speculative generation is token-for-token bit-identical
    /// to plain generation and to solo greedy decode, across MAC modes
    /// and thread counts, on a workload the drafter provably accepts on
    /// (found by exact simulation per model) plus plain random prompts
    /// checking the no-match path stays exact.
    #[test]
    fn speculative_generation_bit_identical_to_plain_and_solo() {
        use crate::forward::{synth, ForwardModel};
        use crate::kernels::MacMode;
        let (fs, map) = forward_payload_seq(32);
        for mac in [MacMode::F32, MacMode::Int8] {
            for threads in [1usize, 4] {
                let build = || {
                    ForwardModel::from_packed_map_with(fs.clone(), &map, mac)
                        .unwrap()
                        .with_threads(threads)
                };
                let (wp, wm, _) = find_accepting_workload(&build(), 3, 3, 12);
                let jobs: Vec<(Vec<i32>, usize)> = vec![
                    (wp, wm),
                    (synth::synth_tokens(&fs, 6, 11), 10),
                    (synth::synth_tokens(&fs, 3, 13), 40), // clamped by the window
                ];
                let solo: Vec<Vec<i32>> =
                    jobs.iter().map(|(p, m)| solo_greedy(&build(), p, *m)).collect();
                let base = BatchConfig {
                    max_streams: 2,
                    kv_page_tokens: 4,
                    prefill_chunk: 3,
                    linger: Duration::from_millis(30),
                    ..BatchConfig::default()
                };
                let (plain, pstats) = run_generate(build(), base.clone(), &jobs);
                let spec_cfg = BatchConfig { speculative: true, draft_len: 3, ..base };
                let (spec, sstats) = run_generate(build(), spec_cfg, &jobs);
                for (i, want) in solo.iter().enumerate() {
                    assert_eq!(
                        &plain[i], want,
                        "job {i}: plain batched != solo (mac {mac:?}, threads {threads})"
                    );
                    assert_eq!(
                        &spec[i], want,
                        "job {i}: speculative != solo (mac {mac:?}, threads {threads})"
                    );
                }
                assert_eq!(pstats.drafted, 0, "plain decode must not draft");
                assert!(sstats.drafted > 0, "drafter never fired: {sstats:?}");
                assert!(sstats.accepted <= sstats.drafted);
                assert!(sstats.accept_rate().is_some());
                assert_eq!(sstats.leaked_pages, 0, "rollback leaked pages: {sstats:?}");
                assert_eq!(pstats.retired, jobs.len() as u64);
                assert_eq!(sstats.retired, jobs.len() as u64);
            }
        }
    }

    /// Satellite (fuzz): randomized prompts, budgets, draft lengths and
    /// page sizes — speculative output stays bit-equal to plain output,
    /// and the arena page balance is restored after every wave.
    #[test]
    fn fuzz_randomized_speculative_schedules_match_plain() {
        use crate::forward::ForwardModel;
        use crate::stats::Rng;
        let (fs, map) = forward_payload_seq(24);
        let mut rng = Rng::new(0x59EC);
        for trial in 0..6 {
            let n_jobs = 1 + rng.below(3);
            let jobs: Vec<(Vec<i32>, usize)> = (0..n_jobs)
                .map(|_| {
                    let plen = 1 + rng.below(10);
                    let mut p: Vec<i32> =
                        (0..plen).map(|_| rng.below(fs.vocab) as i32).collect();
                    if rng.below(2) == 0 && plen >= 2 {
                        // double the prompt: guaranteed recurring suffixes
                        let copy = p.clone();
                        p.extend_from_slice(&copy);
                    }
                    (p, 1 + rng.below(20))
                })
                .collect();
            let cfg = BatchConfig {
                max_streams: 1 + rng.below(3),
                kv_page_tokens: 1 + rng.below(4),
                prefill_chunk: 1 + rng.below(4),
                linger: Duration::from_millis(20),
                ..BatchConfig::default()
            };
            let build = || ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
            let (plain, pstats) = run_generate(build(), cfg.clone(), &jobs);
            let spec_cfg =
                BatchConfig { speculative: true, draft_len: 1 + rng.below(5), ..cfg };
            let (spec, sstats) = run_generate(build(), spec_cfg, &jobs);
            assert_eq!(spec, plain, "trial {trial}: speculative diverged from plain");
            assert_eq!(pstats.leaked_pages, 0, "trial {trial}: plain leaked");
            assert_eq!(sstats.leaked_pages, 0, "trial {trial}: speculative leaked");
            assert!(sstats.accepted <= sstats.drafted, "trial {trial}: {sstats:?}");
        }
    }

    /// The single-stream speculative schedule is *exactly* predictable
    /// from the solo-greedy continuation: mirror the scheduler and assert
    /// the live server reports the same step/drafted/accepted counts —
    /// and strictly fewer `step_batch` calls than plain decode once
    /// anything is accepted, within the page-rollback headroom bound.
    #[test]
    fn single_stream_speculative_matches_exact_simulation() {
        use crate::forward::ForwardModel;
        let (fs, map) = forward_payload_seq(32);
        let build = || ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let (chunk, draft_cap, max_new) = (3usize, 3usize, 16usize);
        let (prompt, m, (steps_sim, drafted_sim, accepted_sim)) =
            find_accepting_workload(&build(), chunk, draft_cap, max_new);
        assert!(accepted_sim >= 1);
        let gen = solo_greedy(&build(), &prompt, m);
        let cfg = BatchConfig {
            max_streams: 2,
            kv_page_tokens: 4,
            prefill_chunk: chunk,
            linger: Duration::from_millis(5),
            ..BatchConfig::default()
        };
        let jobs = vec![(prompt.clone(), m)];
        let (plain, pstats) = run_generate(build(), cfg.clone(), &jobs);
        let spec_cfg = BatchConfig { speculative: true, draft_len: draft_cap, ..cfg };
        let (spec, sstats) = run_generate(build(), spec_cfg, &jobs);
        assert_eq!(plain[0], gen);
        assert_eq!(spec[0], gen);
        // plain decode: one step per prefill chunk, one per fed-back token
        let plain_steps = (prompt.len().div_ceil(chunk) + gen.len() - 1) as u64;
        assert_eq!(pstats.batches, plain_steps);
        assert_eq!(sstats.batches, steps_sim, "scheduler diverged from the exact mirror");
        assert_eq!(sstats.drafted, drafted_sim);
        assert_eq!(sstats.accepted, accepted_sim);
        assert!(
            sstats.batches < pstats.batches,
            "accepted drafts must save whole steps: {sstats:?} vs {pstats:?}"
        );
        // rollback headroom: at most ceil(draft_len / page_tokens) extra
        // pages over the non-speculative peak
        assert!(
            sstats.peak_pages <= pstats.peak_pages + draft_cap.div_ceil(cfg.kv_page_tokens),
            "speculative peak pages out of bound: {sstats:?} vs {pstats:?}"
        );
    }

    #[test]
    fn generation_edge_requests() {
        use crate::forward::ForwardModel;
        let (fs, map) = forward_payload();
        let model = ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let solo = solo_greedy(&model, &[1, 2, 3], 2);
        let (srv, cli) = EvalServer::spawn_batched(
            model,
            BatchConfig { speculative: true, ..BatchConfig::default() },
        )
        .unwrap();
        // empty prompt / zero budget: empty generation, not an error
        assert!(cli.generate(vec![], 5).unwrap().tokens.is_empty());
        assert!(cli.generate(vec![1, 2], 0).unwrap().tokens.is_empty());
        // out-of-vocab prompt: rejected (closed channel), server survives
        assert!(cli.generate(vec![1, 999], 3).is_err());
        // budget clamps to the context window: seq=8, prompt 3 -> <= 6 new
        let clamped = cli.generate(vec![1, 2, 3], 100).unwrap();
        assert_eq!(clamped.tokens.len(), 6);
        assert_eq!(cli.generate(vec![1, 2, 3], 2).unwrap().tokens, solo);
        // scoring and generation interleave on the same server
        assert_eq!(cli.score(vec![1, 2, 3]).unwrap().logprobs.len(), 2);
        drop(cli);
        let stats = srv.shutdown();
        assert_eq!(stats.leaked_pages, 0);
        assert_eq!(stats.requests, 6);

        // the static batcher has no stream state: generation errors
        let (ssrv, scli) = EvalServer::spawn(
            crate::eval::mock::SuccessorModel { batch: 2, seq: 8, vocab: 16, boost: 6.0 },
            Duration::from_millis(1),
        );
        assert!(scli.generate(vec![1, 2], 3).is_err());
        assert_eq!(scli.score(vec![1, 2, 3]).unwrap().logprobs.len(), 2);
        drop(scli);
        ssrv.shutdown();
    }

    // -----------------------------------------------------------------------
    // fused packed-weight serving
    // -----------------------------------------------------------------------

    fn fused_model_with(
        method: crate::pipeline::Method,
        mac: crate::kernels::MacMode,
    ) -> FusedModel {
        use crate::io::manifest::{ModelSpec, ParamSpec};
        use crate::io::msbt::{Tensor, TensorMap};
        use crate::pipeline::{quantize, QuantizeOptions};
        use crate::quant::QuantConfig;
        let spec = ModelSpec {
            name: "g".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![
                ParamSpec { name: "wq".into(), shape: vec![24, 64], quant: true },
                ParamSpec { name: "wv".into(), shape: vec![16, 128], quant: true },
            ],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        };
        let mut rng = crate::stats::Rng::new(81);
        let mut weights = TensorMap::new();
        for (name, r, c) in [("wq", 24usize, 64usize), ("wv", 16, 128)] {
            let m = crate::tensor::Matrix::randn(r, c, &mut rng);
            weights.insert(name.into(), Tensor::f32(vec![r, c], m.data));
        }
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let opts = QuantizeOptions::new().with_packed();
        let qm = quantize(&spec, weights, None, method, &cfg, &opts).unwrap();
        FusedModel::from_packed_map_with(&qm.export_packed().unwrap(), mac).unwrap()
    }

    fn fused_model() -> FusedModel {
        fused_model_with(crate::pipeline::Method::Wgm, crate::kernels::MacMode::F32)
    }

    fn probe(cols: usize, seed: u64) -> Vec<f32> {
        let mut x = vec![0.0f32; cols];
        crate::stats::Rng::new(seed).fill_normal(&mut x, 1.0);
        x
    }

    #[test]
    fn gemv_server_roundtrip_is_bit_identical_to_serial() {
        let fm = fused_model();
        let expect: BTreeMap<String, (Vec<f32>, Vec<f32>)> = fm
            .linears()
            .iter()
            .map(|(name, l)| {
                let x = probe(l.cols(), 90);
                let y = l.gemv(&x);
                (name.clone(), (x, y))
            })
            .collect();
        let (server, client) = GemvServer::spawn(fm, 2, 4, Duration::from_millis(1));
        for (name, (x, y)) in &expect {
            let got = client.infer(name, x.clone()).unwrap();
            assert_eq!(&got, y, "{name}: served != serial gemv");
        }
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.requests, expect.len() as u64);
    }

    #[test]
    fn gemv_server_coalesces_same_layer_requests() {
        let fm = fused_model();
        let cols = fm.linear("wq").unwrap().cols();
        let serial: Vec<Vec<f32>> =
            (0..4).map(|i| fm.linear("wq").unwrap().gemv(&probe(cols, 100 + i))).collect();
        let (server, client) = GemvServer::spawn(fm, 2, 8, Duration::from_millis(50));
        let mut handles = Vec::new();
        for i in 0..4u64 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                c.infer("wq", probe(cols, 100 + i)).unwrap()
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), serial[i], "request {i}");
        }
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches < 4, "same-layer requests must coalesce: {stats:?}");
        assert!(stats.max_batch_fill >= 2);
    }

    /// Batching fairness: requests interleaved across two layers — a
    /// majority layer and a minority one — all complete (the per-drain
    /// layer grouping serves every group, so the minority layer cannot
    /// starve behind the busy one), coalescing still happens, and every
    /// response is bit-identical to the unbatched `gemv` of the same
    /// handle. Runs in both f32 and int8 MAC modes.
    #[test]
    fn gemv_server_interleaved_layers_fair_and_bit_identical() {
        use crate::kernels::MacMode;
        for mac in [MacMode::F32, MacMode::Int8] {
            // rtn: affine decode, so the same fixture serves both modes
            let fm = fused_model_with(crate::pipeline::Method::Rtn, mac);
            let plan: Vec<(&str, u64)> = vec![
                ("wq", 200),
                ("wv", 201),
                ("wq", 202),
                ("wq", 203),
                ("wv", 204),
                ("wq", 205),
                ("wq", 206),
                ("wq", 207),
            ];
            let expect: Vec<Vec<f32>> = plan
                .iter()
                .map(|(layer, seed)| {
                    let l = fm.linear(layer).unwrap();
                    l.gemv(&probe(l.cols(), *seed))
                })
                .collect();
            let cols: BTreeMap<&str, usize> =
                [("wq", fm.linear("wq").unwrap().cols()), ("wv", fm.linear("wv").unwrap().cols())]
                    .into();
            let (server, client) = GemvServer::spawn(fm, 2, 8, Duration::from_millis(50));
            let mut handles = Vec::new();
            for (layer, seed) in &plan {
                let c = client.clone();
                let x = probe(cols[layer], *seed);
                let layer = *layer;
                handles.push(std::thread::spawn(move || c.infer(layer, x).unwrap()));
            }
            for (i, h) in handles.into_iter().enumerate() {
                // a successful join IS the no-starvation check: the
                // minority layer's requests came back too
                assert_eq!(
                    h.join().unwrap(),
                    expect[i],
                    "request {i} (mac={}): served != unbatched gemv",
                    mac.name()
                );
            }
            drop(client);
            let stats = server.shutdown();
            assert_eq!(stats.requests, 8, "mac={}", mac.name());
            assert!(
                stats.batches < 8,
                "interleaved requests must coalesce (mac={}): {stats:?}",
                mac.name()
            );
            assert!(stats.max_batch_fill >= 2, "mac={}", mac.name());
        }
    }

    #[test]
    fn gemv_server_rejects_bad_requests_without_dying() {
        let fm = fused_model();
        let cols = fm.linear("wq").unwrap().cols();
        let (server, client) = GemvServer::spawn(fm, 1, 4, Duration::from_millis(1));
        assert!(client.infer("nope", probe(8, 1)).is_err());
        assert!(client.infer("wq", probe(cols + 1, 2)).is_err());
        // the server survives bad requests and keeps serving good ones
        assert_eq!(client.infer("wq", probe(cols, 3)).unwrap().len(), 24);
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 3);
    }
}
