//! Batched serving: long-lived server threads that own the model and
//! drain a request channel with dynamic batching.
//!
//! * [`EvalServer`] — token scoring. [`EvalServer::spawn`] is the static
//!   batcher (one full forward per drain, padded to the model's `batch`;
//!   required for PJRT-backed models whose device buffers are not Sync).
//!   [`EvalServer::spawn_batched`] is the continuous-batching decode
//!   scheduler over a [`crate::forward::ForwardModel`]: requests become
//!   *streams* in a paged [`crate::forward::KvArena`], every coalesced
//!   [`step_batch`](crate::forward::ForwardModel::step_batch) advances
//!   all live streams at once (chunked prefill, so a long prompt never
//!   stalls running decodes), finished streams retire and their pages
//!   recycle immediately, and FIFO admission with a max-waiting-steps
//!   fairness bound fills freed slots between steps. Each stream's
//!   logprobs are bit-identical to its solo unbatched run. The batched
//!   scheduler also serves greedy *generation* ([`EvalClient::generate`]),
//!   optionally with self-speculative decode: a per-stream [`draft`]
//!   prompt-lookup index proposes lookahead tokens that ride the same
//!   `step_batch` chunk, every position is verified against its own
//!   argmax in that one fused pass, and rejected tails roll back
//!   page-wise via
//!   [`KvArena::truncate_stream`](crate::forward::KvArena::truncate_stream)
//!   — generated tokens are bit-identical to plain greedy decode, only
//!   the step count changes.
//! * [`GemvServer`] — the fused packed-weight loop: holds a
//!   [`FusedModel`] (codes + scale tables, never decoded f32 buffers) and
//!   coalesces same-layer matvec requests into one
//!   `PackedLinear::gemm_pooled` call, so each block tile is decoded once
//!   per batch instead of once per request; exercised by
//!   `serve_eval fused`.
//!
//! # Fault tolerance
//!
//! Every reply is a `Result<_, `[`ServerError`]`>`: invalid requests,
//! overload shedding, deadline expiry, quarantined streams and shutdown
//! all surface as typed errors instead of silently-closed channels. The
//! continuous batcher wraps the fused step and the drafter in
//! `catch_unwind`: a panic (or non-finite logits) quarantines *only* the
//! faulting stream — survivors are rolled back page-wise and replayed
//! solo, so their outputs stay bit-identical to the no-fault run — and a
//! drafter fault demotes its stream to plain greedy decode. Admission is
//! bounded ([`BatchConfig::max_waiting`]) and deadline-checked both at
//! admission and between steps. All of it is driven deterministically by
//! the [`faults`] injection harness (`--inject` on `msb serve-bench` /
//! `serve_eval`).

#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod draft;
pub mod faults;

use std::any::Any;
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use self::faults::FaultPlan;
use crate::forward::{argmax_row, argmax_rows, ForwardModel, KvArena, StreamSlot};
use crate::pool::ThreadPool;
use crate::runtime::{FusedModel, LogitsFn};

/// Typed serving errors: every terminal reply a client can receive that
/// is not a successful response. Clients surface these through `anyhow`
/// (`err.downcast_ref::<ServerError>()` recovers the variant).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServerError {
    /// The request can never be served honestly (overlong sequence,
    /// out-of-vocab token, empty prompt, zero budget): rejected up front
    /// at admission, before it touches a stream slot.
    InvalidRequest(String),
    /// Load shedding: the bounded waiting queue
    /// ([`BatchConfig::max_waiting`]) was full when the request arrived.
    Overloaded { waiting: usize, limit: usize },
    /// The request's deadline passed — in the waiting queue, at
    /// admission, or between coalesced steps mid-flight (the stream's
    /// pages are freed immediately).
    DeadlineExceeded,
    /// The stream hit an internal fault (a panic inside the fused step,
    /// or non-finite logits) and was quarantined; the payload describes
    /// the fault. Sibling streams are unaffected.
    StreamFaulted(String),
    /// The server is draining: in-flight streams finish, everything else
    /// is refused.
    ShuttingDown,
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            ServerError::Overloaded { waiting, limit } => {
                write!(f, "overloaded: {waiting} requests waiting (limit {limit})")
            }
            ServerError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServerError::StreamFaulted(m) => write!(f, "stream faulted: {m}"),
            ServerError::ShuttingDown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for ServerError {}

/// Best-effort text of a panic payload (panics carry `&str` or `String`
/// in practice; anything else gets a placeholder).
fn panic_text(p: &(dyn Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// One scoring request: a (≤ seq)-token sequence; the response is the
/// per-position next-token logprob of the sequence under the model.
pub struct Request {
    pub tokens: Vec<i32>,
    /// Refuse the request (with [`ServerError::DeadlineExceeded`]) once
    /// this instant passes — checked in the queue, at admission, and
    /// between coalesced steps.
    pub deadline: Option<Instant>,
    pub resp: Sender<Result<Response, ServerError>>,
}

/// One greedy-generation request: a non-empty (≤ seq) prompt plus a
/// budget of new tokens. Served only by the continuous batcher
/// ([`EvalServer::spawn_batched`]); the static batcher has no stream
/// state to decode with and rejects it.
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Same contract as [`Request::deadline`].
    pub deadline: Option<Instant>,
    pub resp: Sender<Result<GenResponse, ServerError>>,
}

/// Channel protocol: scoring or generation work, or an explicit stop (so
/// `shutdown` does not depend on every client handle being dropped
/// first).
enum Msg {
    Score(Request),
    Generate(GenRequest),
    Stop,
}

#[derive(Clone, Debug)]
pub struct Response {
    /// logprob of tokens[p] given tokens[..p], for p in 1..len.
    pub logprobs: Vec<f64>,
    /// Which batch this request rode in (telemetry).
    pub batch_id: u64,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    /// Greedy continuation of the prompt, in order. May be shorter than
    /// `max_new` when the context window runs out first.
    pub tokens: Vec<i32>,
    /// The coalesced step at which the stream retired (telemetry).
    pub batch_id: u64,
}

#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: u64,
    /// Forward dispatches (static batches, or coalesced decode steps).
    pub batches: u64,
    pub max_batch_fill: usize,
    // -- continuous batching ([`EvalServer::spawn_batched`]) only --
    /// Requests admitted into a stream slot.
    pub admitted: u64,
    /// Streams that finished and returned their pages.
    pub retired: u64,
    /// `step_width_hist[w - 1]` = coalesced steps that ran `w` streams.
    pub step_width_hist: Vec<u64>,
    /// Longest admission queue wait observed, in coalesced steps.
    pub max_wait_steps: u64,
    /// KV arena high-water mark, in pages / bytes, against its capacity.
    pub peak_pages: usize,
    pub total_pages: usize,
    pub peak_page_bytes: usize,
    /// Pages still held by live streams at shutdown — 0 unless the loop
    /// exited with streams in flight (page-balance telemetry).
    pub leaked_pages: usize,
    // -- fault tolerance --
    /// Requests refused up front with [`ServerError::InvalidRequest`].
    pub rejected: u64,
    /// Requests shed at the channel edge ([`ServerError::Overloaded`]).
    pub shed: u64,
    /// Requests whose deadline expired (queued or mid-flight).
    pub deadline_missed: u64,
    /// Streams quarantined with [`ServerError::StreamFaulted`].
    pub faulted: u64,
    /// Generation streams demoted to plain greedy decode after a drafter
    /// fault (the stream itself survives and completes).
    pub degraded: u64,
    // -- speculative decode only --
    /// Draft tokens fed for verification.
    pub drafted: u64,
    /// Draft tokens accepted; each one saved a full decode step.
    pub accepted: u64,
}

impl ServerStats {
    /// Fraction of drafted tokens accepted, or `None` before any draft.
    pub fn accept_rate(&self) -> Option<f64> {
        (self.drafted > 0).then(|| self.accepted as f64 / self.drafted as f64)
    }
}

/// A submitted request that has not been waited on yet — the
/// non-blocking half of the client API. One thread can submit many
/// requests in send order (FIFO channel → FIFO admission, so admission
/// ordinals are deterministic — the fault-injection tests address
/// streams that way) and collect the replies later.
pub struct Pending<T> {
    rx: Receiver<Result<T, ServerError>>,
}

impl<T> Pending<T> {
    /// Block until the server replies. Typed failures
    /// ([`ServerError`]) come back as downcastable `anyhow` errors; a
    /// dropped reply (server thread died) is its own error.
    pub fn wait(self) -> Result<T> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow::Error::from(e)),
            Err(_) => Err(anyhow::anyhow!("server dropped the request")),
        }
    }
}

/// Client handle: cloneable, thread-safe.
#[derive(Clone)]
pub struct EvalClient {
    tx: Sender<Msg>,
}

impl EvalClient {
    /// Non-blocking scoring submission; pair with [`Pending::wait`].
    pub fn submit_score(
        &self,
        tokens: Vec<i32>,
        deadline: Option<Instant>,
    ) -> Result<Pending<Response>> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Score(Request { tokens, deadline, resp: tx }))
            .map_err(|_| anyhow::anyhow!("server gone"))?;
        Ok(Pending { rx })
    }

    /// Blocking scoring call.
    pub fn score(&self, tokens: Vec<i32>) -> Result<Response> {
        self.submit_score(tokens, None)?.wait()
    }

    /// Blocking scoring call that the server refuses (with
    /// [`ServerError::DeadlineExceeded`]) once `deadline` passes —
    /// whether the request is still queued or already mid-flight.
    pub fn score_deadline(&self, tokens: Vec<i32>, deadline: Instant) -> Result<Response> {
        self.submit_score(tokens, Some(deadline))?.wait()
    }

    /// Non-blocking generation submission; pair with [`Pending::wait`].
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        deadline: Option<Instant>,
    ) -> Result<Pending<GenResponse>> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Generate(GenRequest { prompt, max_new, deadline, resp: tx }))
            .map_err(|_| anyhow::anyhow!("server gone"))?;
        Ok(Pending { rx })
    }

    /// Blocking greedy-generation call: up to `max_new` tokens continuing
    /// `prompt` (fewer when the context window runs out first). Only the
    /// continuous batcher ([`EvalServer::spawn_batched`]) serves this;
    /// against the static batcher the call errors. Whether the server
    /// runs speculative decode is invisible here — the tokens are
    /// bit-identical either way.
    pub fn generate(&self, prompt: Vec<i32>, max_new: usize) -> Result<GenResponse> {
        self.submit_generate(prompt, max_new, None)?.wait()
    }

    /// [`EvalClient::generate`] with a deadline (same contract as
    /// [`EvalClient::score_deadline`]).
    pub fn generate_deadline(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        deadline: Instant,
    ) -> Result<GenResponse> {
        self.submit_generate(prompt, max_new, Some(deadline))?.wait()
    }
}

/// Knobs of the continuous-batching scheduler
/// ([`EvalServer::spawn_batched`]).
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Concurrent streams (slots); also sizes the KV page arena so every
    /// slot can reach the full context window.
    pub max_streams: usize,
    /// Positions per KV page ([`crate::forward::KvArena`]).
    pub kv_page_tokens: usize,
    /// Most tokens fed per stream per coalesced step. Chunked prefill: a
    /// long prompt advances `prefill_chunk` tokens at a time, so streams
    /// already decoding keep producing a token every step instead of
    /// stalling behind one full-prompt pass.
    pub prefill_chunk: usize,
    /// Fairness bound: once the oldest waiting request has queued this
    /// many steps, the chunk cap is lifted for running streams so they
    /// drain (and free slots) as fast as possible. The tradeoff is
    /// explicit — brief extra per-step latency for bounded queue wait.
    pub max_waiting_steps: u64,
    /// How long an idle server waits for more arrivals before stepping a
    /// partial batch (same role as the static batcher's linger).
    pub linger: Duration,
    /// Self-speculative greedy decode for generation streams: draft
    /// lookahead tokens from each stream's [`draft::Drafter`] ride the
    /// decode chunk and are verified in the same fused pass. Exact —
    /// affects step counts, never tokens. Scoring requests are untouched.
    pub speculative: bool,
    /// Cap on draft tokens per stream per step; the adaptive per-stream
    /// length moves within `1..=draft_len` (halve on reject, +1 on full
    /// accept). Also capped by the step's chunk budget so the fairness
    /// bound keeps holding.
    pub draft_len: usize,
    /// Admission-control bound on the waiting queue: requests arriving
    /// while this many are already queued are shed immediately with
    /// [`ServerError::Overloaded`] instead of growing the queue without
    /// bound.
    pub max_waiting: usize,
    /// Deterministic fault-injection script (empty by default — the
    /// no-fault fast path only pays a branch per seam). See
    /// [`faults::FaultPlan`].
    pub faults: FaultPlan,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig {
            max_streams: 4,
            kv_page_tokens: 16,
            prefill_chunk: 8,
            max_waiting_steps: 32,
            linger: Duration::from_millis(1),
            speculative: false,
            draft_len: 4,
            max_waiting: 256,
            faults: FaultPlan::default(),
        }
    }
}

pub struct EvalServer {
    handle: Option<JoinHandle<ServerStats>>,
    tx: Option<Sender<Msg>>,
}

impl EvalServer {
    /// Spawn the server thread around a model. `linger` is how long the
    /// batcher waits to fill a batch before dispatching a partial one.
    pub fn spawn<M>(model: M, linger: Duration) -> (EvalServer, EvalClient)
    where
        M: LogitsFn + Send + 'static,
    {
        Self::spawn_with(move || model, linger)
    }

    /// Spawn with a factory that *builds the model inside the server
    /// thread* — required for PJRT-backed models ([`crate::runtime::ModelRunner`]
    /// holds non-`Send` device handles; only the factory crosses threads).
    pub fn spawn_with<M, F>(factory: F, linger: Duration) -> (EvalServer, EvalClient)
    where
        M: LogitsFn + 'static,
        F: FnOnce() -> M + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let client = EvalClient { tx: tx.clone() };
        let handle = std::thread::Builder::new()
            .name("msb-eval-server".into())
            .spawn(move || serve(factory(), rx, linger))
            .unwrap_or_else(|e| panic!("spawn server thread: {e}"));
        (EvalServer { handle: Some(handle), tx: Some(tx) }, client)
    }

    /// Spawn the continuous-batching decode scheduler over a fused CPU
    /// forward model. Same [`EvalClient`] protocol as
    /// [`EvalServer::spawn`] — a request is a token sequence, the
    /// response its per-position logprobs — but requests are served as
    /// concurrent *streams* sharing every projection `gemm` through
    /// [`ForwardModel::step_batch`] and a paged KV arena, instead of
    /// padded rows of one fixed-shape forward. Each response is
    /// bit-identical to the same request scored alone.
    pub fn spawn_batched(
        model: ForwardModel,
        cfg: BatchConfig,
    ) -> Result<(EvalServer, EvalClient)> {
        let arena = model.kv_arena(cfg.max_streams.max(1), cfg.kv_page_tokens.max(1))?;
        let (tx, rx) = channel::<Msg>();
        let client = EvalClient { tx: tx.clone() };
        let handle = std::thread::Builder::new()
            .name("msb-batch-server".into())
            .spawn(move || serve_batched(model, arena, rx, cfg))
            .unwrap_or_else(|e| panic!("spawn batch server thread: {e}"));
        Ok((EvalServer { handle: Some(handle), tx: Some(tx) }, client))
    }

    /// Stop the server and collect telemetry. Safe to call with client
    /// handles still alive: an explicit stop message ends the loop. The
    /// continuous batcher drains: in-flight streams finish, queued and
    /// late requests are refused with [`ServerError::ShuttingDown`].
    /// Returns `Err` when the server thread itself died of a panic — a
    /// dead server is never mistaken for a clean zero-stat run (the
    /// panic payload rides the error).
    pub fn shutdown(mut self) -> Result<ServerStats> {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Stop);
        }
        match self.handle.take() {
            Some(h) => h.join().map_err(|p| {
                anyhow::anyhow!("server thread panicked: {}", panic_text(p.as_ref()))
            }),
            None => Err(anyhow::anyhow!("server already shut down")),
        }
    }
}

impl Drop for EvalServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Stop);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Typed refusal of a generation request on the static batcher, which
/// has no stream state to decode with.
fn refuse_static_generate(g: GenRequest, stats: &mut ServerStats) {
    stats.requests += 1;
    stats.rejected += 1;
    let _ = g.resp.send(Err(ServerError::InvalidRequest(
        "generation requires the continuous batcher (spawn_batched)".into(),
    )));
}

fn serve<M: LogitsFn>(model: M, rx: Receiver<Msg>, linger: Duration) -> ServerStats {
    let (b, t, v) = (model.batch(), model.seq(), model.vocab());
    let mut stats = ServerStats::default();
    let mut batch_id = 0u64;
    loop {
        // block for the first request
        let first = loop {
            match rx.recv() {
                Ok(Msg::Score(r)) => break r,
                Ok(Msg::Generate(g)) => refuse_static_generate(g, &mut stats),
                Ok(Msg::Stop) | Err(_) => return stats,
            }
        };
        let mut pending = vec![first];
        // linger to coalesce more
        let mut stop_after = false;
        let deadline = Instant::now() + linger;
        while pending.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Score(r)) => pending.push(r),
                Ok(Msg::Generate(g)) => refuse_static_generate(g, &mut stats),
                Ok(Msg::Stop) => {
                    stop_after = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Up-front validation and deadline enforcement: a request that
        // cannot be served honestly gets a typed refusal instead of
        // riding (and possibly poisoning) the batch. The static batcher
        // keeps its documented fixed-shape truncation contract, so
        // tokens are validated post-truncation.
        let now = Instant::now();
        let mut batch: Vec<Request> = Vec::with_capacity(pending.len());
        for req in pending {
            if req.deadline.is_some_and(|d| now >= d) {
                stats.requests += 1;
                stats.deadline_missed += 1;
                let _ = req.resp.send(Err(ServerError::DeadlineExceeded));
                continue;
            }
            let n = req.tokens.len().min(t);
            if let Some(&bad) = req.tokens[..n].iter().find(|&&tok| tok < 0 || tok as usize >= v)
            {
                stats.requests += 1;
                stats.rejected += 1;
                let _ = req.resp.send(Err(ServerError::InvalidRequest(format!(
                    "token {bad} outside the vocab (0..{v})"
                ))));
                continue;
            }
            batch.push(req);
        }
        if batch.is_empty() {
            if stop_after {
                return stats;
            }
            continue;
        }

        // assemble the batch
        let mut tokens = vec![0i32; b * t];
        for (row, req) in batch.iter().enumerate() {
            let n = req.tokens.len().min(t);
            tokens[row * t..row * t + n].copy_from_slice(&req.tokens[..n]);
        }
        // Panic isolation: a fault inside the forward (poisoned weights,
        // kernel bug) fails this batch with a typed error instead of
        // killing the server thread and every future request with it.
        let outcome = match catch_unwind(AssertUnwindSafe(|| model.logits(&tokens))) {
            Ok(Ok(l)) => Ok(l),
            Ok(Err(e)) => Err(format!("forward error: {e:#}")),
            Err(p) => Err(format!("panic in forward: {}", panic_text(p.as_ref()))),
        };
        let logits = match outcome {
            Ok(l) => l,
            Err(fault) => {
                stats.requests += batch.len() as u64;
                stats.faulted += batch.len() as u64;
                for req in batch {
                    let _ = req.resp.send(Err(ServerError::StreamFaulted(fault.clone())));
                }
                if stop_after {
                    return stats;
                }
                continue;
            }
        };
        let lp = crate::eval::LogProbs::new(&logits, v);
        batch_id += 1;
        stats.batches += 1;
        stats.requests += batch.len() as u64;
        stats.max_batch_fill = stats.max_batch_fill.max(batch.len());
        for (row, req) in batch.into_iter().enumerate() {
            let n = req.tokens.len().min(t);
            let mut logprobs = Vec::with_capacity(n.saturating_sub(1));
            for p in 1..n {
                logprobs.push(lp.logp(row * t + p - 1, req.tokens[p] as usize));
            }
            let _ = req.resp.send(Ok(Response { logprobs, batch_id }));
        }
        if stop_after {
            return stats;
        }
    }
}

/// What a stream owes its client when it retires.
enum Reply {
    Score(Sender<Result<Response, ServerError>>),
    Gen(Sender<Result<GenResponse, ServerError>>),
}

impl Reply {
    /// Terminal typed failure, scoring or generation alike.
    fn send_err(self, e: ServerError) {
        match self {
            Reply::Score(tx) => {
                let _ = tx.send(Err(e));
            }
            Reply::Gen(tx) => {
                let _ = tx.send(Err(e));
            }
        }
    }
}

/// Decode-side state of a generation stream.
struct GenState {
    /// Greedy tokens emitted so far (the response payload).
    generated: Vec<i32>,
    /// Budget after context-window clamping: at most
    /// `seq - prompt_len + 1` tokens fit (the final token is chosen from
    /// the last in-window logits row and never fed back).
    max_new: usize,
    /// Prompt-lookup index over the committed tokens (prompt + verified
    /// generations) — the speculative draft source.
    drafter: draft::Drafter,
    /// Adaptive draft length in `1..=cfg.draft_len`: halved on any
    /// reject, +1 on a full accept, so streams the drafter reads well
    /// speculate deep and hostile streams pay ~1 wasted position.
    draft_len: usize,
    /// Set after a drafter fault: the stream finishes on plain greedy
    /// decode (graceful degradation — a drafter bug costs speed, never
    /// the stream, and the output is bit-identical anyway).
    degraded: bool,
}

/// One live stream of the continuous batcher: the request it came from,
/// how far it has decoded, and the running logprob/generation state.
struct Active {
    id: crate::forward::StreamId,
    /// Admission ordinal (0-based, FIFO): how [`FaultPlan`] addresses
    /// streams, and stable across the stream's whole life.
    ordinal: u64,
    /// The request's deadline; checked between coalesced steps.
    deadline: Option<Instant>,
    /// Committed tokens: the request for scoring streams; prompt +
    /// verified greedy output for generation streams. Draft tokens never
    /// enter here until they pass verification.
    tokens: Vec<i32>,
    /// Positions already fed through `step_batch` (== the stream's KV
    /// length; speculative rejects roll both back together).
    fed: usize,
    logprobs: Vec<f64>,
    /// Logits row of position `fed - 1` — scores the next chunk's first
    /// token exactly as the full-slab `LogProbs` indexing would, and is
    /// the argmax source for a generation stream's next committed token.
    last_row: Option<Vec<f32>>,
    gen: Option<GenState>,
    reply: Reply,
}

/// Per-step feeding plan for one stream: how the staged chunk is to be
/// interpreted when its logits come back.
enum Plan {
    /// Scoring/prefill chunk of committed tokens.
    Committed,
    /// Decode chunk `[next, draft...]` with `k` draft tokens to verify.
    Decode { k: usize },
}

/// Post-step fate of one stream, decided index-aligned with `active`
/// and applied in a single descending `swap_remove` sweep (so earlier
/// removals never shift later indices).
enum Fate {
    Keep,
    /// Scoring stream fully fed: reply with its logprobs.
    Retire,
    /// Internal fault attributed to this stream: free its pages, reply
    /// [`ServerError::StreamFaulted`] with the payload.
    Quarantine(String),
}

/// `true` once `d` has passed (requests without a deadline never expire).
fn expired(d: Option<Instant>, now: Instant) -> bool {
    d.is_some_and(|d| now >= d)
}

fn msg_deadline(m: &Msg) -> Option<Instant> {
    match m {
        Msg::Score(r) => r.deadline,
        Msg::Generate(r) => r.deadline,
        Msg::Stop => None,
    }
}

/// Terminal typed reply for a request that never reaches a stream slot.
fn reject_msg(msg: Msg, err: ServerError, stats: &mut ServerStats) {
    match msg {
        Msg::Score(req) => {
            let _ = req.resp.send(Err(err));
        }
        Msg::Generate(req) => {
            let _ = req.resp.send(Err(err));
        }
        Msg::Stop => return,
    }
    stats.requests += 1;
}

/// Admission control at the channel edge: queue the request, or shed it
/// with [`ServerError::Overloaded`] when the waiting line is full.
fn enqueue(
    m: Msg,
    waiting: &mut VecDeque<(Msg, u64)>,
    step_idx: u64,
    max_waiting: usize,
    stats: &mut ServerStats,
) {
    if waiting.len() >= max_waiting {
        stats.shed += 1;
        let err = ServerError::Overloaded { waiting: waiting.len(), limit: max_waiting };
        reject_msg(m, err, stats);
    } else {
        waiting.push_back((m, step_idx));
    }
}

/// One fused step under a panic shield: a panic anywhere inside
/// `step_batch` (kernel, arena invariant, injected fault) becomes an
/// `Err` carrying the payload instead of killing the scheduler thread.
/// The injection seam fires inside the shield, so scripted panics take
/// exactly the path a real one would.
fn catch_step(
    model: &ForwardModel,
    arena: &mut KvArena,
    slots: &[StreamSlot<'_>],
    plan: &FaultPlan,
    step: u64,
    ordinals: &[u64],
) -> Result<Vec<Vec<f32>>, String> {
    let attempt = catch_unwind(AssertUnwindSafe(|| {
        plan.maybe_panic(step, ordinals);
        model.step_batch(arena, slots)
    }));
    match attempt {
        Ok(Ok(outs)) => Ok(outs),
        Ok(Err(e)) => Err(format!("step error: {e:#}")),
        Err(p) => Err(format!("panic in fused step: {}", panic_text(p.as_ref()))),
    }
}

fn serve_batched(
    model: ForwardModel,
    mut arena: KvArena,
    rx: Receiver<Msg>,
    cfg: BatchConfig,
) -> ServerStats {
    let (seq, vocab) = (model.spec().seq, model.spec().vocab);
    let max_streams = cfg.max_streams.max(1);
    let prefill_chunk = cfg.prefill_chunk.max(1);
    let draft_cap = cfg.draft_len.max(1);
    let max_waiting = cfg.max_waiting.max(1);
    let mut stats = ServerStats::default();
    let mut waiting: VecDeque<(Msg, u64)> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut step_idx = 0u64;
    let mut stop = false;
    loop {
        // Ingest: block (with linger) only when there is nothing to run;
        // otherwise drain whatever has arrived between steps. Arrivals
        // beyond the waiting bound shed immediately; after a stop the
        // server drains — in-flight streams finish, everything else is
        // refused.
        if !stop {
            if active.is_empty() && waiting.is_empty() {
                match rx.recv() {
                    Ok(m @ (Msg::Score(_) | Msg::Generate(_))) => {
                        enqueue(m, &mut waiting, step_idx, max_waiting, &mut stats);
                    }
                    Ok(Msg::Stop) | Err(_) => break,
                }
                let deadline = Instant::now() + cfg.linger;
                while waiting.len() < max_streams {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(m @ (Msg::Score(_) | Msg::Generate(_))) => {
                            enqueue(m, &mut waiting, step_idx, max_waiting, &mut stats);
                        }
                        Ok(Msg::Stop) => {
                            stop = true;
                            break;
                        }
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            stop = true;
                            break;
                        }
                    }
                }
            } else {
                loop {
                    match rx.try_recv() {
                        Ok(m @ (Msg::Score(_) | Msg::Generate(_))) => {
                            enqueue(m, &mut waiting, step_idx, max_waiting, &mut stats);
                        }
                        Ok(Msg::Stop) | Err(TryRecvError::Disconnected) => {
                            stop = true;
                            break;
                        }
                        Err(TryRecvError::Empty) => break,
                    }
                }
            }
        } else {
            loop {
                match rx.try_recv() {
                    Ok(m @ (Msg::Score(_) | Msg::Generate(_))) => {
                        reject_msg(m, ServerError::ShuttingDown, &mut stats);
                    }
                    Ok(Msg::Stop) => {}
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
        }
        if stop && !waiting.is_empty() {
            // Drain semantics: only already-admitted streams finish, so
            // shutdown latency is bounded by the in-flight work; the
            // waiting line is refused, not silently run.
            for (m, _) in waiting.drain(..) {
                reject_msg(m, ServerError::ShuttingDown, &mut stats);
            }
        }

        // Deadlines expire in the queue too — sweep before admission so
        // an expired waiter neither occupies a slot nor pays a prefill.
        let queue_now = Instant::now();
        if waiting.iter().any(|(m, _)| expired(msg_deadline(m), queue_now)) {
            let mut kept = VecDeque::with_capacity(waiting.len());
            for (m, e) in waiting.drain(..) {
                if expired(msg_deadline(&m), queue_now) {
                    stats.deadline_missed += 1;
                    reject_msg(m, ServerError::DeadlineExceeded, &mut stats);
                } else {
                    kept.push_back((m, e));
                }
            }
            waiting = kept;
        }

        // FIFO admission into open slots, validating up front: a request
        // that cannot be served honestly is refused with a typed
        // [`ServerError::InvalidRequest`] instead of silently truncated
        // or dropped with a closed channel.
        while active.len() < max_streams {
            let Some((msg, enqueued)) = waiting.pop_front() else { break };
            stats.max_wait_steps = stats.max_wait_steps.max(step_idx - enqueued);
            if expired(msg_deadline(&msg), Instant::now()) {
                stats.deadline_missed += 1;
                reject_msg(msg, ServerError::DeadlineExceeded, &mut stats);
                continue;
            }
            match msg {
                Msg::Score(req) => {
                    if req.tokens.len() > seq {
                        stats.rejected += 1;
                        stats.requests += 1;
                        let _ = req.resp.send(Err(ServerError::InvalidRequest(format!(
                            "request length {} exceeds the context window ({seq})",
                            req.tokens.len()
                        ))));
                        continue;
                    }
                    if req.tokens.is_empty() {
                        // same contract as the static batcher: no predictions
                        stats.requests += 1;
                        let _ = req
                            .resp
                            .send(Ok(Response { logprobs: Vec::new(), batch_id: step_idx }));
                        continue;
                    }
                    if let Some(&bad) =
                        req.tokens.iter().find(|&&t| t < 0 || t as usize >= vocab)
                    {
                        stats.rejected += 1;
                        stats.requests += 1;
                        let _ = req.resp.send(Err(ServerError::InvalidRequest(format!(
                            "token {bad} outside the vocab (0..{vocab})"
                        ))));
                        continue;
                    }
                    let ordinal = stats.admitted;
                    stats.admitted += 1;
                    active.push(Active {
                        id: arena.alloc_stream(),
                        ordinal,
                        deadline: req.deadline,
                        tokens: req.tokens,
                        fed: 0,
                        logprobs: Vec::new(),
                        last_row: None,
                        gen: None,
                        reply: Reply::Score(req.resp),
                    });
                }
                Msg::Generate(req) => {
                    if req.prompt.is_empty() || req.max_new == 0 {
                        stats.rejected += 1;
                        stats.requests += 1;
                        let _ = req.resp.send(Err(ServerError::InvalidRequest(
                            "generation needs a non-empty prompt and max_new > 0".into(),
                        )));
                        continue;
                    }
                    if req.prompt.len() > seq {
                        stats.rejected += 1;
                        stats.requests += 1;
                        let _ = req.resp.send(Err(ServerError::InvalidRequest(format!(
                            "prompt length {} exceeds the context window ({seq})",
                            req.prompt.len()
                        ))));
                        continue;
                    }
                    if let Some(&bad) =
                        req.prompt.iter().find(|&&t| t < 0 || t as usize >= vocab)
                    {
                        stats.rejected += 1;
                        stats.requests += 1;
                        let _ = req.resp.send(Err(ServerError::InvalidRequest(format!(
                            "token {bad} outside the vocab (0..{vocab})"
                        ))));
                        continue;
                    }
                    let ordinal = stats.admitted;
                    stats.admitted += 1;
                    // the final token comes off the last in-window logits
                    // row without being fed back, hence the +1; a budget
                    // beyond the window clamps (documented), it does not
                    // reject
                    let max_new = req.max_new.min(seq - req.prompt.len() + 1);
                    let mut drafter = draft::Drafter::new(draft::DEFAULT_NGRAM);
                    drafter.extend(&req.prompt);
                    active.push(Active {
                        id: arena.alloc_stream(),
                        ordinal,
                        deadline: req.deadline,
                        tokens: req.prompt,
                        fed: 0,
                        logprobs: Vec::new(),
                        last_row: None,
                        gen: Some(GenState {
                            generated: Vec::new(),
                            max_new,
                            drafter,
                            draft_len: draft_cap,
                            degraded: false,
                        }),
                        reply: Reply::Gen(req.resp),
                    });
                }
                Msg::Stop => unreachable!("Stop is never queued"),
            }
        }
        if active.is_empty() {
            if stop {
                break;
            }
            continue;
        }

        // Generation commit pass: a decode-phase generation stream whose
        // chunk is fully fed owes exactly one committed token — the
        // argmax of its last logits row (bit-identical to what plain
        // greedy decode picks, speculative or not). Streams whose budget
        // is spent retire here: the final token is never fed back.
        let mut finished = Vec::new();
        for (ai, a) in active.iter_mut().enumerate() {
            let Some(g) = a.gen.as_mut() else { continue };
            if a.fed < a.tokens.len() {
                continue; // still prefilling
            }
            if g.generated.len() >= g.max_new {
                finished.push(ai);
                continue;
            }
            let Some(row) = a.last_row.as_ref() else {
                unreachable!("decode phase keeps a last row")
            };
            let next = argmax_row(row) as i32;
            a.tokens.push(next);
            g.generated.push(next);
            if !g.degraded {
                g.drafter.extend(&[next]);
            }
            if g.generated.len() >= g.max_new {
                finished.push(ai);
            }
        }
        for ai in finished.into_iter().rev() {
            let a = active.swap_remove(ai);
            arena.free_stream(a.id);
            stats.requests += 1;
            stats.retired += 1;
            if let (Reply::Gen(tx), Some(g)) = (a.reply, a.gen) {
                let _ = tx.send(Ok(GenResponse { tokens: g.generated, batch_id: step_idx }));
            }
        }

        // Mid-flight deadline enforcement: an expired stream is cut
        // between steps — its pages come back immediately and the slot
        // admits a waiter next turn, so one slow client can't hold a
        // slot past its own budget.
        let now = Instant::now();
        if active.iter().any(|a| expired(a.deadline, now)) {
            for ai in (0..active.len()).rev() {
                if expired(active[ai].deadline, now) {
                    let a = active.swap_remove(ai);
                    arena.free_stream(a.id);
                    stats.requests += 1;
                    stats.deadline_missed += 1;
                    a.reply.send_err(ServerError::DeadlineExceeded);
                }
            }
        }
        if active.is_empty() {
            if stop && waiting.is_empty() {
                break;
            }
            continue;
        }

        // Fairness: a starved waiter lifts the chunk cap so running
        // streams drain (and free their slots) as fast as possible.
        let oldest_wait = waiting.front().map_or(0, |(_, e)| step_idx - e);
        let chunk = if oldest_wait >= cfg.max_waiting_steps { seq } else { prefill_chunk };

        // Stage every stream's chunk. Scoring/prefill chunks copy the
        // committed slice; a decode-phase generation stream stages
        // `[next, draft...]` — the drafts are *uncommitted* guesses from
        // its prompt-lookup index, so they live only in this buffer. The
        // draft length is capped by the chunk budget (fairness bound
        // unchanged), the remaining token budget, and the context window.
        let mut plans: Vec<Plan> = Vec::with_capacity(active.len());
        let mut chunks: Vec<Vec<i32>> = Vec::with_capacity(active.len());
        for a in active.iter_mut() {
            match a.gen.as_mut() {
                Some(g) if !g.generated.is_empty() => {
                    let Some(&next) = a.tokens.last() else {
                        unreachable!("decode stream has tokens")
                    };
                    let mut staged = vec![next];
                    if cfg.speculative && !g.degraded {
                        let cap = g
                            .draft_len
                            .min(chunk.saturating_sub(1))
                            .min(g.max_new - g.generated.len())
                            .min(seq - a.fed - 1);
                        // Drafter shield: the drafter is heuristic
                        // scaffolding, so a panic in it demotes the
                        // stream to plain greedy decode (same tokens,
                        // more steps) instead of faulting anything.
                        let ordinal = a.ordinal;
                        let proposed = catch_unwind(AssertUnwindSafe(|| {
                            cfg.faults.maybe_panic_draft(step_idx, ordinal);
                            g.drafter.propose(cap)
                        }));
                        match proposed {
                            Ok(d) => staged.extend(d),
                            Err(_) => {
                                g.degraded = true;
                                stats.degraded += 1;
                            }
                        }
                    }
                    plans.push(Plan::Decode { k: staged.len() - 1 });
                    chunks.push(staged);
                }
                _ => {
                    let w = chunk.min(a.tokens.len() - a.fed);
                    plans.push(Plan::Committed);
                    chunks.push(a.tokens[a.fed..a.fed + w].to_vec());
                }
            }
        }
        // Deterministic fault pressure (no-op without an injection plan).
        cfg.faults.stall();
        let slots: Vec<StreamSlot<'_>> = active
            .iter()
            .zip(&chunks)
            .map(|(a, c)| StreamSlot { id: a.id, tokens: c })
            .collect();
        let ordinals: Vec<u64> = active.iter().map(|a| a.ordinal).collect();
        let round = step_idx;
        let attempt = catch_step(&model, &mut arena, &slots, &cfg.faults, round, &ordinals);
        let outcomes: Vec<Result<Vec<f32>, String>> = match attempt {
            Ok(outs) if outs.len() == active.len() => outs.into_iter().map(Ok).collect(),
            Ok(outs) => {
                // contract breach — fault every stream rather than risk
                // misattributing rows across streams
                let msg =
                    format!("step returned {} outputs for {} streams", outs.len(), active.len());
                active.iter().map(|_| Err(msg.clone())).collect()
            }
            Err(batch_fault) => {
                // Panic isolation: the coalesced step died. No stream's
                // `fed` has advanced (arena lengths only move at the end
                // of a clean fused pass), so truncating each stream back
                // to `fed` restores its pre-step KV bookkeeping exactly.
                // Replaying every stream solo is bit-identical to the
                // coalesced step by the per-stream identity contract, so
                // whichever stream fails alone is the faulty one — it is
                // quarantined below while its siblings keep their rows.
                let mut v: Vec<Result<Vec<f32>, String>> = Vec::with_capacity(active.len());
                for (ai, a) in active.iter().enumerate() {
                    if let Err(e) = arena.truncate_stream(a.id, a.fed) {
                        v.push(Err(format!("{batch_fault}; pre-replay rollback failed: {e:#}")));
                        continue;
                    }
                    let solo = [StreamSlot { id: a.id, tokens: &chunks[ai] }];
                    match catch_step(&model, &mut arena, &solo, &cfg.faults, round, &[a.ordinal])
                    {
                        Ok(outs) => match outs.into_iter().next() {
                            Some(rows) => v.push(Ok(rows)),
                            None => v.push(Err("solo replay returned no logits".into())),
                        },
                        Err(fault) => v.push(Err(fault)),
                    }
                }
                v
            }
        };
        step_idx += 1;
        stats.batches += 1;
        let width = active.len();
        stats.max_batch_fill = stats.max_batch_fill.max(width);
        if stats.step_width_hist.len() < width {
            stats.step_width_hist.resize(width, 0);
        }
        stats.step_width_hist[width - 1] += 1;

        // Per-stream output processing, index-aligned with `active`.
        let mut fates: Vec<Fate> = Vec::with_capacity(active.len());
        for (ai, outcome) in outcomes.into_iter().enumerate() {
            let a = &mut active[ai];
            let mut out = match outcome {
                Ok(rows) => rows,
                Err(fault) => {
                    fates.push(Fate::Quarantine(fault));
                    continue;
                }
            };
            // NaN quarantine: scripted poison lands here; a real
            // non-finite activation surfacing in the logits takes the
            // same door.
            cfg.faults.poison_logits(round, a.ordinal, &mut out);
            if out.iter().any(|v| !v.is_finite()) {
                fates.push(Fate::Quarantine(format!("non-finite logits at step {round}")));
                continue;
            }
            let w = out.len() / vocab;
            match plans[ai] {
                // Speculative verification: row i's argmax is the true
                // greedy successor of chunk[..=i], read from the same
                // fused pass that computed it — acceptance is exact by
                // construction. Rejected positions hold logits of a
                // wrong prefix; their pages roll back below.
                Plan::Decode { k } => {
                    let staged = &chunks[ai];
                    let Some(g) = a.gen.as_mut() else {
                        unreachable!("decode plan implies gen state")
                    };
                    let preds: Vec<i32> =
                        argmax_rows(&out, vocab).into_iter().map(|p| p as i32).collect();
                    let j = draft::longest_accept(&staged[1..], &preds);
                    stats.drafted += k as u64;
                    stats.accepted += j as u64;
                    // accepted drafts are exactly the tokens plain greedy
                    // would have committed, and their KV entries are
                    // already in place from the fused pass
                    a.tokens.extend_from_slice(&staged[1..1 + j]);
                    g.generated.extend_from_slice(&staged[1..1 + j]);
                    if !g.degraded {
                        g.drafter.extend(&staged[1..1 + j]);
                    }
                    if k > 0 {
                        g.draft_len = if j == k {
                            (g.draft_len + 1).min(draft_cap)
                        } else {
                            (g.draft_len / 2).max(1)
                        };
                    }
                    a.last_row = Some(out[j * vocab..(j + 1) * vocab].to_vec());
                    a.fed += 1 + j;
                    if j < k {
                        // page-level rollback of the rejected tail
                        if let Err(e) = arena.truncate_stream(a.id, a.fed) {
                            fates.push(Fate::Quarantine(format!(
                                "speculative rollback failed: {e:#}"
                            )));
                            continue;
                        }
                    }
                    fates.push(Fate::Keep);
                }
                Plan::Committed if a.gen.is_some() => {
                    // generation prefill: advance; the commit pass above
                    // turns the last row into the first generated token
                    a.last_row = Some(out[(w - 1) * vocab..w * vocab].to_vec());
                    a.fed += w;
                    fates.push(Fate::Keep);
                }
                // Scoring logprob assembly: the chunk's first token is
                // scored by the previous chunk's last row, the rest by
                // this chunk's rows — identical f64 math to the one-slab
                // unbatched path.
                Plan::Committed => {
                    if a.fed > 0 {
                        let Some(last) = a.last_row.as_ref() else {
                            unreachable!("fed > 0 keeps a last row")
                        };
                        let lp = crate::eval::LogProbs::new(last, vocab);
                        a.logprobs.push(lp.logp(0, a.tokens[a.fed] as usize));
                    }
                    let lp = crate::eval::LogProbs::new(&out, vocab);
                    for i in 1..w {
                        a.logprobs.push(lp.logp(i - 1, a.tokens[a.fed + i] as usize));
                    }
                    a.last_row = Some(out[(w - 1) * vocab..w * vocab].to_vec());
                    a.fed += w;
                    fates.push(if a.fed == a.tokens.len() { Fate::Retire } else { Fate::Keep });
                }
            }
        }
        // One descending sweep applies every fate; retired and
        // quarantined pages recycle immediately, and the freed slots
        // admit waiters on the next loop turn. (Generation streams
        // retire in the commit pass.)
        for (ai, fate) in fates.into_iter().enumerate().rev() {
            match fate {
                Fate::Keep => {}
                Fate::Retire => {
                    let a = active.swap_remove(ai);
                    arena.free_stream(a.id);
                    stats.requests += 1;
                    stats.retired += 1;
                    if let Reply::Score(tx) = a.reply {
                        let _ = tx.send(Ok(Response { logprobs: a.logprobs, batch_id: step_idx }));
                    }
                }
                Fate::Quarantine(fault) => {
                    let a = active.swap_remove(ai);
                    arena.free_stream(a.id);
                    stats.requests += 1;
                    stats.faulted += 1;
                    a.reply.send_err(ServerError::StreamFaulted(fault));
                    debug_assert!(arena.balanced(), "page imbalance after quarantine");
                }
            }
        }
        if stop && active.is_empty() && waiting.is_empty() {
            break;
        }
    }
    // Refuse anything that raced the stop message into the channel.
    while let Ok(m) = rx.try_recv() {
        reject_msg(m, ServerError::ShuttingDown, &mut stats);
    }
    stats.peak_pages = arena.peak_pages();
    stats.total_pages = arena.total_pages();
    stats.peak_page_bytes = arena.peak_bytes();
    stats.leaked_pages = arena.pages_in_use();
    stats
}

// ---------------------------------------------------------------------------
// Fused packed-weight serving.
// ---------------------------------------------------------------------------

/// One fused matvec request: an activation vector for a named packed
/// layer; the response is `y = W·x` computed directly on the codes.
struct GemvRequest {
    layer: String,
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>, ServerError>>,
}

enum GemvMsg {
    Infer(GemvRequest),
    Stop,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct GemvStats {
    pub requests: u64,
    /// Fused `gemm` dispatches — coalescing makes this < `requests`.
    pub batches: u64,
    pub max_batch_fill: usize,
    /// Requests refused up front ([`ServerError::InvalidRequest`]).
    pub rejected: u64,
    /// Requests that died to a panic in the fused gemm
    /// ([`ServerError::StreamFaulted`]).
    pub faulted: u64,
}

/// Client handle for [`GemvServer`]: cloneable, thread-safe.
#[derive(Clone)]
pub struct GemvClient {
    tx: Sender<GemvMsg>,
}

impl GemvClient {
    /// Blocking fused-matvec call against a packed layer. Refusals and
    /// faults surface as a typed [`ServerError`] inside the `anyhow`
    /// chain (`downcast_ref::<ServerError>` to branch on them).
    pub fn infer(&self, layer: &str, x: Vec<f32>) -> Result<Vec<f32>> {
        let (tx, rx) = channel();
        self.tx
            .send(GemvMsg::Infer(GemvRequest { layer: layer.to_string(), x, resp: tx }))
            .map_err(|_| anyhow::anyhow!("gemv server gone"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("gemv server dropped the request"))?
            .map_err(anyhow::Error::from)
    }
}

/// A long-lived server thread that owns a [`FusedModel`] — the packed
/// payloads, never decoded f32 weights — plus a [`ThreadPool`] for row
/// striping, and drains matvec requests with dynamic batching: requests
/// arriving within `linger` coalesce per layer into one batched
/// `gemm_pooled`, amortizing each block tile's decode across the batch.
/// Responses are bit-identical to serial per-request `gemv` (the fused
/// kernels' determinism contract), regardless of batch composition.
pub struct GemvServer {
    handle: Option<JoinHandle<GemvStats>>,
    tx: Option<Sender<GemvMsg>>,
}

impl GemvServer {
    /// Spawn the serving thread. `threads` sizes the row-striping pool,
    /// `batch_cap` bounds how many requests one dispatch coalesces.
    pub fn spawn(
        model: FusedModel,
        threads: usize,
        batch_cap: usize,
        linger: Duration,
    ) -> (GemvServer, GemvClient) {
        let (tx, rx) = channel::<GemvMsg>();
        let client = GemvClient { tx: tx.clone() };
        let (threads, cap) = (threads.max(1), batch_cap.max(1));
        let handle = std::thread::Builder::new()
            .name("msb-gemv-server".into())
            .spawn(move || serve_gemv(model, rx, threads, cap, linger))
            .unwrap_or_else(|e| panic!("spawn gemv server thread: {e}"));
        (GemvServer { handle: Some(handle), tx: Some(tx) }, client)
    }

    /// Stop the server and collect telemetry (safe with live clients).
    /// A server thread that died to a panic surfaces that panic's
    /// payload as the error — it is never mistaken for a clean
    /// zero-stat run.
    pub fn shutdown(mut self) -> Result<GemvStats> {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(GemvMsg::Stop);
        }
        match self.handle.take() {
            Some(h) => h.join().map_err(|p| {
                anyhow::anyhow!("gemv server thread panicked: {}", panic_text(p.as_ref()))
            }),
            None => Err(anyhow::anyhow!("gemv server already shut down")),
        }
    }
}

impl Drop for GemvServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(GemvMsg::Stop);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_gemv(
    model: FusedModel,
    rx: Receiver<GemvMsg>,
    threads: usize,
    batch_cap: usize,
    linger: Duration,
) -> GemvStats {
    let pool = ThreadPool::new(threads, threads * 4);
    let mut stats = GemvStats::default();
    loop {
        let first = match rx.recv() {
            Ok(GemvMsg::Infer(r)) => r,
            Ok(GemvMsg::Stop) | Err(_) => return stats,
        };
        let mut pending = vec![first];
        let mut stop_after = false;
        let deadline = Instant::now() + linger;
        while pending.len() < batch_cap {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(GemvMsg::Infer(r)) => pending.push(r),
                Ok(GemvMsg::Stop) => {
                    stop_after = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.requests += pending.len() as u64;

        // group by layer so one fused gemm serves each group
        let mut groups: BTreeMap<String, Vec<GemvRequest>> = BTreeMap::new();
        for r in pending {
            groups.entry(r.layer.clone()).or_default().push(r);
        }
        for (layer, reqs) in groups {
            let Some(l) = model.linear(&layer) else {
                for r in reqs {
                    stats.rejected += 1;
                    let _ = r.resp.send(Err(ServerError::InvalidRequest(format!(
                        "no packed layer '{layer}'"
                    ))));
                }
                continue;
            };
            let (cols, rows) = (l.cols(), l.rows());
            let mut valid = Vec::with_capacity(reqs.len());
            for r in reqs {
                if r.x.len() == cols {
                    valid.push(r);
                } else {
                    stats.rejected += 1;
                    let _ = r.resp.send(Err(ServerError::InvalidRequest(format!(
                        "{layer}: x len {} != cols {cols}",
                        r.x.len()
                    ))));
                }
            }
            if valid.is_empty() {
                continue;
            }
            let batch = valid.len();
            let mut xs = vec![0.0f32; batch * cols];
            for (b, r) in valid.iter().enumerate() {
                xs[b * cols..(b + 1) * cols].copy_from_slice(&r.x);
            }
            // the batch buffer is handed to the jobs as-is (gemm_shared):
            // assembling it above was the only copy. A panic inside the
            // fused kernels faults this one batch, not the server: the
            // pool recovers poisoned stripes, so the next batch runs.
            let ys = catch_unwind(AssertUnwindSafe(|| {
                l.gemm_shared(std::sync::Arc::new(xs), batch, &pool)
            }));
            stats.batches += 1;
            stats.max_batch_fill = stats.max_batch_fill.max(batch);
            match ys {
                Ok(ys) => {
                    for (b, r) in valid.into_iter().enumerate() {
                        let _ = r.resp.send(Ok(ys[b * rows..(b + 1) * rows].to_vec()));
                    }
                }
                Err(p) => {
                    let msg = format!("panic in fused gemm: {}", panic_text(p.as_ref()));
                    for r in valid {
                        stats.faulted += 1;
                        let _ = r.resp.send(Err(ServerError::StreamFaulted(msg.clone())));
                    }
                }
            }
        }
        if stop_after {
            return stats;
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::eval::mock::SuccessorModel;

    fn model() -> SuccessorModel {
        SuccessorModel { batch: 4, seq: 8, vocab: 16, boost: 6.0 }
    }

    #[test]
    fn single_request_roundtrip() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        let r = client.score(vec![1, 2, 3, 4]).unwrap();
        assert_eq!(r.logprobs.len(), 3);
        // successor tokens are high-probability
        assert!(r.logprobs.iter().all(|&lp| lp > -0.5), "{:?}", r.logprobs);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn batching_coalesces_concurrent_requests() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(50));
        let mut handles = Vec::new();
        for i in 0..4 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                c.score(vec![i, i + 1, i + 2]).unwrap()
            }));
        }
        let responses: Vec<Response> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        drop(client);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches < 4, "requests must coalesce: {stats:?}");
        // at least two shared a batch id
        let ids: Vec<u64> = responses.iter().map(|r| r.batch_id).collect();
        let mut sorted = ids.clone();
        sorted.dedup();
        assert!(stats.max_batch_fill >= 2);
    }

    #[test]
    fn overlong_sequences_truncate() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        let r = client.score((0..50).collect()).unwrap();
        assert_eq!(r.logprobs.len(), 7); // seq=8 -> 7 predictions
        drop(client);
        server.shutdown().unwrap();
    }

    #[test]
    fn shutdown_idempotent_via_drop() {
        let (server, client) = EvalServer::spawn(model(), Duration::from_millis(1));
        drop(client);
        drop(server); // must not hang
    }

    // -----------------------------------------------------------------------
    // continuous batching over the forward backend
    // -----------------------------------------------------------------------

    /// An rtn-packed artifact for a batch-1 forward spec (affine decode,
    /// so the same payload serves both MAC modes).
    fn forward_payload() -> (crate::forward::ForwardSpec, crate::io::msbt::TensorMap) {
        use crate::forward::synth;
        use crate::pipeline::{quantize, Method, QuantizeOptions};
        use crate::quant::QuantConfig;
        let fs = crate::forward::ForwardSpec::new(40, 32, 2, 4, 48, 8, 1).unwrap();
        let spec = synth::model_spec(&fs, "srv-batch");
        let weights = synth::synth_weights(&fs, 21);
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        let qm = quantize(&spec, weights, None, Method::Rtn, &cfg, &opts).unwrap();
        (fs, qm.export_packed().unwrap())
    }

    /// Satellite: interleaved multi-stream requests through the
    /// continuous batcher return bit-identical logprobs to unbatched solo
    /// runs, at threads {1,4} and MacMode {F32, Int8}, with more requests
    /// than stream slots so admission queuing and retirement both fire.
    #[test]
    fn batched_eval_server_bit_identical_to_solo() {
        use crate::forward::{synth, ForwardModel};
        use crate::kernels::MacMode;
        let (fs, map) = forward_payload();
        // uneven lengths, all within the window (overlong requests are
        // refused up front now, covered separately)
        let reqs: Vec<Vec<i32>> = [5usize, 8, 3, 6, 7, 4]
            .iter()
            .enumerate()
            .map(|(i, &len)| synth::synth_tokens(&fs, len, 50 + i as u64))
            .collect();
        for mac in [MacMode::F32, MacMode::Int8] {
            for threads in [1usize, 4] {
                let build = || {
                    ForwardModel::from_packed_map_with(fs.clone(), &map, mac)
                        .unwrap()
                        .with_threads(threads)
                };
                // solo references through the unbatched server (batch=1
                // spec: every request rides alone)
                let (solo_srv, solo_cli) =
                    EvalServer::spawn(build(), Duration::from_millis(1));
                let solo: Vec<Vec<f64>> = reqs
                    .iter()
                    .map(|t| solo_cli.score(t.clone()).unwrap().logprobs)
                    .collect();
                drop(solo_cli);
                solo_srv.shutdown().unwrap();

                // 3 slots for 6 requests: admission queue + retirement
                // churn; page_tokens 3 leaves partial pages; chunk 2
                // forces multi-step prefill
                let bcfg = BatchConfig {
                    max_streams: 3,
                    kv_page_tokens: 3,
                    prefill_chunk: 2,
                    max_waiting_steps: 4,
                    linger: Duration::from_millis(40),
                    ..BatchConfig::default()
                };
                let (srv, cli) = EvalServer::spawn_batched(build(), bcfg).unwrap();
                let mut handles = Vec::new();
                for t in &reqs {
                    let c = cli.clone();
                    let t = t.clone();
                    handles.push(std::thread::spawn(move || c.score(t).unwrap()));
                }
                let got: Vec<Response> =
                    handles.into_iter().map(|h| h.join().unwrap()).collect();
                for (i, r) in got.iter().enumerate() {
                    assert_eq!(
                        r.logprobs, solo[i],
                        "request {i}: batched != solo (mac {mac:?}, threads {threads})"
                    );
                }
                drop(cli);
                let stats = srv.shutdown().unwrap();
                assert_eq!(stats.admitted, 6, "{stats:?}");
                assert_eq!(stats.retired, 6, "every stream must retire: {stats:?}");
                assert_eq!(stats.requests, 6);
                assert!(stats.max_batch_fill >= 2, "streams must coalesce: {stats:?}");
                assert!(
                    stats.step_width_hist.iter().skip(1).sum::<u64>() > 0,
                    "some step must run >1 stream: {stats:?}"
                );
                assert!(stats.peak_pages > 0 && stats.peak_pages <= stats.total_pages);
                assert!(stats.peak_page_bytes > 0);
            }
        }
    }

    #[test]
    fn batched_server_edge_requests() {
        use crate::forward::ForwardModel;
        let (fs, map) = forward_payload();
        let model = ForwardModel::from_packed_map(fs, &map).unwrap();
        let (srv, cli) =
            EvalServer::spawn_batched(model, BatchConfig::default()).unwrap();
        // empty request: empty logprobs, same as the static batcher
        assert!(cli.score(vec![]).unwrap().logprobs.is_empty());
        // out-of-vocab tokens are rejected with a typed error, and the
        // server keeps serving afterwards
        let err = cli.score(vec![1, 999]).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<ServerError>(),
                Some(ServerError::InvalidRequest(_))
            ),
            "{err:#}"
        );
        let ok = cli.score(vec![1, 2, 3]).unwrap();
        assert_eq!(ok.logprobs.len(), 2);
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.admitted, 1, "only the valid non-empty request ran: {stats:?}");
        assert_eq!(stats.rejected, 1, "{stats:?}");
    }

    // -----------------------------------------------------------------------
    // greedy generation + speculative decode
    // -----------------------------------------------------------------------

    /// Like [`forward_payload`] but with a caller-chosen context window,
    /// so generation has room to decode.
    fn forward_payload_seq(
        seq: usize,
    ) -> (crate::forward::ForwardSpec, crate::io::msbt::TensorMap) {
        use crate::forward::synth;
        use crate::pipeline::{quantize, Method, QuantizeOptions};
        use crate::quant::QuantConfig;
        let fs = crate::forward::ForwardSpec::new(40, 32, 2, 4, 48, seq, 1).unwrap();
        let spec = synth::model_spec(&fs, "srv-gen");
        let weights = synth::synth_weights(&fs, 21);
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        let qm = quantize(&spec, weights, None, Method::Rtn, &cfg, &opts).unwrap();
        (fs, qm.export_packed().unwrap())
    }

    /// Ground-truth greedy decode: solo `step` calls, one token at a
    /// time, sharing the scheduler's argmax and budget-clamping rules.
    fn solo_greedy(
        model: &crate::forward::ForwardModel,
        prompt: &[i32],
        max_new: usize,
    ) -> Vec<i32> {
        let (seq, vocab) = (model.spec().seq, model.spec().vocab);
        let mut toks = prompt.to_vec();
        toks.truncate(seq);
        assert!(!toks.is_empty() && max_new > 0);
        let eff = max_new.min(seq - toks.len() + 1);
        let mut kv = model.kv_state();
        let mut row = model.step(&mut kv, &toks).unwrap();
        let mut out = Vec::with_capacity(eff);
        loop {
            let next = crate::forward::argmax_row(&row[row.len() - vocab..]) as i32;
            out.push(next);
            if out.len() == eff {
                return out;
            }
            row = model.step(&mut kv, &[next]).unwrap();
        }
    }

    fn run_generate(
        model: crate::forward::ForwardModel,
        cfg: BatchConfig,
        jobs: &[(Vec<i32>, usize)],
    ) -> (Vec<Vec<i32>>, ServerStats) {
        let (srv, cli) = EvalServer::spawn_batched(model, cfg).unwrap();
        let mut handles = Vec::new();
        for (prompt, max_new) in jobs {
            let c = cli.clone();
            let (p, m) = (prompt.clone(), *max_new);
            handles.push(std::thread::spawn(move || c.generate(p, m).unwrap().tokens));
        }
        let outs = handles.into_iter().map(|h| h.join().unwrap()).collect();
        drop(cli);
        (outs, srv.shutdown().unwrap())
    }

    /// Exact mirror of the single-stream speculative schedule: given the
    /// known greedy continuation `gen`, replay the scheduler's drafter
    /// state, chunk caps and adaptive draft length to predict its
    /// `step_batch` count and drafted/accepted totals. Valid whenever the
    /// stream never shares a step with a starved waiter (no chunk lift),
    /// which holds for any single-job run.
    fn simulate_single_stream(
        prompt: &[i32],
        gen: &[i32],
        seq: usize,
        chunk: usize,
        draft_cap: usize,
    ) -> (u64, u64, u64) {
        let mut d = draft::Drafter::new(draft::DEFAULT_NGRAM);
        d.extend(prompt);
        let eff = gen.len();
        let mut fed = prompt.len();
        let mut steps = prompt.len().div_ceil(chunk) as u64;
        let mut c = 0usize;
        let mut draft_len = draft_cap;
        let (mut drafted, mut accepted) = (0u64, 0u64);
        loop {
            // commit pass: one argmax token per fully-fed chunk
            d.extend(&gen[c..=c]);
            c += 1;
            if c >= eff {
                return (steps, drafted, accepted);
            }
            let cap = draft_len
                .min(chunk.saturating_sub(1))
                .min(eff - c)
                .min(seq - fed - 1);
            let prop = d.propose(cap);
            let k = prop.len();
            // verification accepts exactly the prefix matching the true
            // greedy continuation (preds under a correct prefix ARE the
            // continuation)
            let j = prop.iter().zip(&gen[c..]).take_while(|(a, b)| a == b).count();
            drafted += k as u64;
            accepted += j as u64;
            d.extend(&gen[c..c + j]);
            c += j;
            if k > 0 {
                draft_len = if j == k {
                    (draft_len + 1).min(draft_cap)
                } else {
                    (draft_len / 2).max(1)
                };
            }
            fed += 1 + j;
            steps += 1;
            if c >= eff {
                return (steps, drafted, accepted);
            }
        }
    }

    /// Scan deterministic candidate prompts until the exact simulation
    /// predicts at least one accepted draft token under this model.
    /// Greedy decode on the tiny synthetic payloads falls into loops
    /// quickly, so a recurring suffix with a correct continuation shows
    /// up within a few candidates; the panic is a loud fixture failure,
    /// never a flake (everything here is deterministic).
    fn find_accepting_workload(
        model: &crate::forward::ForwardModel,
        chunk: usize,
        draft_cap: usize,
        max_new: usize,
    ) -> (Vec<i32>, usize, (u64, u64, u64)) {
        use crate::forward::synth;
        let fs = model.spec();
        for seed in 0..32u64 {
            let plen = 4 + (seed as usize % 5);
            let mut prompt = synth::synth_tokens(fs, plen, 17 + seed);
            if seed % 2 == 1 {
                // doubled prompt: guaranteed recurring suffixes to prime
                // the n-gram index before decode even starts
                let copy = prompt.clone();
                prompt.extend_from_slice(&copy);
            }
            let gen = solo_greedy(model, &prompt, max_new);
            let sim = simulate_single_stream(&prompt, &gen, fs.seq, chunk, draft_cap);
            if sim.2 >= 1 {
                return (prompt, max_new, sim);
            }
        }
        panic!("no candidate prompt produced an accepted draft — widen the scan");
    }

    /// Tentpole: speculative generation is token-for-token bit-identical
    /// to plain generation and to solo greedy decode, across MAC modes
    /// and thread counts, on a workload the drafter provably accepts on
    /// (found by exact simulation per model) plus plain random prompts
    /// checking the no-match path stays exact.
    #[test]
    fn speculative_generation_bit_identical_to_plain_and_solo() {
        use crate::forward::{synth, ForwardModel};
        use crate::kernels::MacMode;
        let (fs, map) = forward_payload_seq(32);
        for mac in [MacMode::F32, MacMode::Int8] {
            for threads in [1usize, 4] {
                let build = || {
                    ForwardModel::from_packed_map_with(fs.clone(), &map, mac)
                        .unwrap()
                        .with_threads(threads)
                };
                let (wp, wm, _) = find_accepting_workload(&build(), 3, 3, 12);
                let jobs: Vec<(Vec<i32>, usize)> = vec![
                    (wp, wm),
                    (synth::synth_tokens(&fs, 6, 11), 10),
                    (synth::synth_tokens(&fs, 3, 13), 40), // clamped by the window
                ];
                let solo: Vec<Vec<i32>> =
                    jobs.iter().map(|(p, m)| solo_greedy(&build(), p, *m)).collect();
                let base = BatchConfig {
                    max_streams: 2,
                    kv_page_tokens: 4,
                    prefill_chunk: 3,
                    linger: Duration::from_millis(30),
                    ..BatchConfig::default()
                };
                let (plain, pstats) = run_generate(build(), base.clone(), &jobs);
                let spec_cfg = BatchConfig { speculative: true, draft_len: 3, ..base };
                let (spec, sstats) = run_generate(build(), spec_cfg, &jobs);
                for (i, want) in solo.iter().enumerate() {
                    assert_eq!(
                        &plain[i], want,
                        "job {i}: plain batched != solo (mac {mac:?}, threads {threads})"
                    );
                    assert_eq!(
                        &spec[i], want,
                        "job {i}: speculative != solo (mac {mac:?}, threads {threads})"
                    );
                }
                assert_eq!(pstats.drafted, 0, "plain decode must not draft");
                assert!(sstats.drafted > 0, "drafter never fired: {sstats:?}");
                assert!(sstats.accepted <= sstats.drafted);
                assert!(sstats.accept_rate().is_some());
                assert_eq!(sstats.leaked_pages, 0, "rollback leaked pages: {sstats:?}");
                assert_eq!(pstats.retired, jobs.len() as u64);
                assert_eq!(sstats.retired, jobs.len() as u64);
            }
        }
    }

    /// Satellite (fuzz): randomized prompts, budgets, draft lengths and
    /// page sizes — speculative output stays bit-equal to plain output,
    /// and the arena page balance is restored after every wave.
    #[test]
    fn fuzz_randomized_speculative_schedules_match_plain() {
        use crate::forward::ForwardModel;
        use crate::stats::Rng;
        let (fs, map) = forward_payload_seq(24);
        let mut rng = Rng::new(0x59EC);
        for trial in 0..6 {
            let n_jobs = 1 + rng.below(3);
            let jobs: Vec<(Vec<i32>, usize)> = (0..n_jobs)
                .map(|_| {
                    let plen = 1 + rng.below(10);
                    let mut p: Vec<i32> =
                        (0..plen).map(|_| rng.below(fs.vocab) as i32).collect();
                    if rng.below(2) == 0 && plen >= 2 {
                        // double the prompt: guaranteed recurring suffixes
                        let copy = p.clone();
                        p.extend_from_slice(&copy);
                    }
                    (p, 1 + rng.below(20))
                })
                .collect();
            let cfg = BatchConfig {
                max_streams: 1 + rng.below(3),
                kv_page_tokens: 1 + rng.below(4),
                prefill_chunk: 1 + rng.below(4),
                linger: Duration::from_millis(20),
                ..BatchConfig::default()
            };
            let build = || ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
            let (plain, pstats) = run_generate(build(), cfg.clone(), &jobs);
            let spec_cfg =
                BatchConfig { speculative: true, draft_len: 1 + rng.below(5), ..cfg };
            let (spec, sstats) = run_generate(build(), spec_cfg, &jobs);
            assert_eq!(spec, plain, "trial {trial}: speculative diverged from plain");
            assert_eq!(pstats.leaked_pages, 0, "trial {trial}: plain leaked");
            assert_eq!(sstats.leaked_pages, 0, "trial {trial}: speculative leaked");
            assert!(sstats.accepted <= sstats.drafted, "trial {trial}: {sstats:?}");
        }
    }

    /// The single-stream speculative schedule is *exactly* predictable
    /// from the solo-greedy continuation: mirror the scheduler and assert
    /// the live server reports the same step/drafted/accepted counts —
    /// and strictly fewer `step_batch` calls than plain decode once
    /// anything is accepted, within the page-rollback headroom bound.
    #[test]
    fn single_stream_speculative_matches_exact_simulation() {
        use crate::forward::ForwardModel;
        let (fs, map) = forward_payload_seq(32);
        let build = || ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let (chunk, draft_cap, max_new) = (3usize, 3usize, 16usize);
        let (prompt, m, (steps_sim, drafted_sim, accepted_sim)) =
            find_accepting_workload(&build(), chunk, draft_cap, max_new);
        assert!(accepted_sim >= 1);
        let gen = solo_greedy(&build(), &prompt, m);
        let cfg = BatchConfig {
            max_streams: 2,
            kv_page_tokens: 4,
            prefill_chunk: chunk,
            linger: Duration::from_millis(5),
            ..BatchConfig::default()
        };
        let jobs = vec![(prompt.clone(), m)];
        let (plain, pstats) = run_generate(build(), cfg.clone(), &jobs);
        let spec_cfg = BatchConfig { speculative: true, draft_len: draft_cap, ..cfg };
        let (spec, sstats) = run_generate(build(), spec_cfg, &jobs);
        assert_eq!(plain[0], gen);
        assert_eq!(spec[0], gen);
        // plain decode: one step per prefill chunk, one per fed-back token
        let plain_steps = (prompt.len().div_ceil(chunk) + gen.len() - 1) as u64;
        assert_eq!(pstats.batches, plain_steps);
        assert_eq!(sstats.batches, steps_sim, "scheduler diverged from the exact mirror");
        assert_eq!(sstats.drafted, drafted_sim);
        assert_eq!(sstats.accepted, accepted_sim);
        assert!(
            sstats.batches < pstats.batches,
            "accepted drafts must save whole steps: {sstats:?} vs {pstats:?}"
        );
        // rollback headroom: at most ceil(draft_len / page_tokens) extra
        // pages over the non-speculative peak
        assert!(
            sstats.peak_pages <= pstats.peak_pages + draft_cap.div_ceil(cfg.kv_page_tokens),
            "speculative peak pages out of bound: {sstats:?} vs {pstats:?}"
        );
    }

    #[test]
    fn generation_edge_requests() {
        use crate::forward::ForwardModel;
        let (fs, map) = forward_payload();
        let model = ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let solo = solo_greedy(&model, &[1, 2, 3], 2);
        let (srv, cli) = EvalServer::spawn_batched(
            model,
            BatchConfig { speculative: true, ..BatchConfig::default() },
        )
        .unwrap();
        // empty prompt / zero budget / out-of-vocab prompt: all refused
        // up front with a typed error, server survives every one
        for bad in [(vec![], 5usize), (vec![1, 2], 0), (vec![1, 999], 3)] {
            let err = cli.generate(bad.0, bad.1).unwrap_err();
            assert!(
                matches!(
                    err.downcast_ref::<ServerError>(),
                    Some(ServerError::InvalidRequest(_))
                ),
                "{err:#}"
            );
        }
        // budget clamps to the context window: seq=8, prompt 3 -> <= 6 new
        let clamped = cli.generate(vec![1, 2, 3], 100).unwrap();
        assert_eq!(clamped.tokens.len(), 6);
        assert_eq!(cli.generate(vec![1, 2, 3], 2).unwrap().tokens, solo);
        // scoring and generation interleave on the same server
        assert_eq!(cli.score(vec![1, 2, 3]).unwrap().logprobs.len(), 2);
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.leaked_pages, 0);
        assert_eq!(stats.requests, 6);
        assert_eq!(stats.rejected, 3, "{stats:?}");
        assert_eq!(stats.admitted, 3, "{stats:?}");
        assert_eq!(stats.retired, 3, "{stats:?}");

        // the static batcher has no stream state: generation errors
        let (ssrv, scli) = EvalServer::spawn(
            crate::eval::mock::SuccessorModel { batch: 2, seq: 8, vocab: 16, boost: 6.0 },
            Duration::from_millis(1),
        );
        let serr = scli.generate(vec![1, 2], 3).unwrap_err();
        assert!(
            matches!(
                serr.downcast_ref::<ServerError>(),
                Some(ServerError::InvalidRequest(_))
            ),
            "{serr:#}"
        );
        assert_eq!(scli.score(vec![1, 2, 3]).unwrap().logprobs.len(), 2);
        drop(scli);
        ssrv.shutdown().unwrap();
    }

    // -----------------------------------------------------------------------
    // fused packed-weight serving
    // -----------------------------------------------------------------------

    fn fused_model_with(
        method: crate::pipeline::Method,
        mac: crate::kernels::MacMode,
    ) -> FusedModel {
        use crate::io::manifest::{ModelSpec, ParamSpec};
        use crate::io::msbt::{Tensor, TensorMap};
        use crate::pipeline::{quantize, QuantizeOptions};
        use crate::quant::QuantConfig;
        let spec = ModelSpec {
            name: "g".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![
                ParamSpec { name: "wq".into(), shape: vec![24, 64], quant: true },
                ParamSpec { name: "wv".into(), shape: vec![16, 128], quant: true },
            ],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        };
        let mut rng = crate::stats::Rng::new(81);
        let mut weights = TensorMap::new();
        for (name, r, c) in [("wq", 24usize, 64usize), ("wv", 16, 128)] {
            let m = crate::tensor::Matrix::randn(r, c, &mut rng);
            weights.insert(name.into(), Tensor::f32(vec![r, c], m.data));
        }
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let opts = QuantizeOptions::new().with_packed();
        let qm = quantize(&spec, weights, None, method, &cfg, &opts).unwrap();
        FusedModel::from_packed_map_with(&qm.export_packed().unwrap(), mac).unwrap()
    }

    fn fused_model() -> FusedModel {
        fused_model_with(crate::pipeline::Method::Wgm, crate::kernels::MacMode::F32)
    }

    fn probe(cols: usize, seed: u64) -> Vec<f32> {
        let mut x = vec![0.0f32; cols];
        crate::stats::Rng::new(seed).fill_normal(&mut x, 1.0);
        x
    }

    #[test]
    fn gemv_server_roundtrip_is_bit_identical_to_serial() {
        let fm = fused_model();
        let expect: BTreeMap<String, (Vec<f32>, Vec<f32>)> = fm
            .linears()
            .iter()
            .map(|(name, l)| {
                let x = probe(l.cols(), 90);
                let y = l.gemv(&x);
                (name.clone(), (x, y))
            })
            .collect();
        let (server, client) = GemvServer::spawn(fm, 2, 4, Duration::from_millis(1));
        for (name, (x, y)) in &expect {
            let got = client.infer(name, x.clone()).unwrap();
            assert_eq!(&got, y, "{name}: served != serial gemv");
        }
        drop(client);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, expect.len() as u64);
    }

    #[test]
    fn gemv_server_coalesces_same_layer_requests() {
        let fm = fused_model();
        let cols = fm.linear("wq").unwrap().cols();
        let serial: Vec<Vec<f32>> =
            (0..4).map(|i| fm.linear("wq").unwrap().gemv(&probe(cols, 100 + i))).collect();
        let (server, client) = GemvServer::spawn(fm, 2, 8, Duration::from_millis(50));
        let mut handles = Vec::new();
        for i in 0..4u64 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                c.infer("wq", probe(cols, 100 + i)).unwrap()
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), serial[i], "request {i}");
        }
        drop(client);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches < 4, "same-layer requests must coalesce: {stats:?}");
        assert!(stats.max_batch_fill >= 2);
    }

    /// Batching fairness: requests interleaved across two layers — a
    /// majority layer and a minority one — all complete (the per-drain
    /// layer grouping serves every group, so the minority layer cannot
    /// starve behind the busy one), coalescing still happens, and every
    /// response is bit-identical to the unbatched `gemv` of the same
    /// handle. Runs in both f32 and int8 MAC modes.
    #[test]
    fn gemv_server_interleaved_layers_fair_and_bit_identical() {
        use crate::kernels::MacMode;
        for mac in [MacMode::F32, MacMode::Int8] {
            // rtn: affine decode, so the same fixture serves both modes
            let fm = fused_model_with(crate::pipeline::Method::Rtn, mac);
            let plan: Vec<(&str, u64)> = vec![
                ("wq", 200),
                ("wv", 201),
                ("wq", 202),
                ("wq", 203),
                ("wv", 204),
                ("wq", 205),
                ("wq", 206),
                ("wq", 207),
            ];
            let expect: Vec<Vec<f32>> = plan
                .iter()
                .map(|(layer, seed)| {
                    let l = fm.linear(layer).unwrap();
                    l.gemv(&probe(l.cols(), *seed))
                })
                .collect();
            let cols: BTreeMap<&str, usize> =
                [("wq", fm.linear("wq").unwrap().cols()), ("wv", fm.linear("wv").unwrap().cols())]
                    .into();
            let (server, client) = GemvServer::spawn(fm, 2, 8, Duration::from_millis(50));
            let mut handles = Vec::new();
            for (layer, seed) in &plan {
                let c = client.clone();
                let x = probe(cols[layer], *seed);
                let layer = *layer;
                handles.push(std::thread::spawn(move || c.infer(layer, x).unwrap()));
            }
            for (i, h) in handles.into_iter().enumerate() {
                // a successful join IS the no-starvation check: the
                // minority layer's requests came back too
                assert_eq!(
                    h.join().unwrap(),
                    expect[i],
                    "request {i} (mac={}): served != unbatched gemv",
                    mac.name()
                );
            }
            drop(client);
            let stats = server.shutdown().unwrap();
            assert_eq!(stats.requests, 8, "mac={}", mac.name());
            assert!(
                stats.batches < 8,
                "interleaved requests must coalesce (mac={}): {stats:?}",
                mac.name()
            );
            assert!(stats.max_batch_fill >= 2, "mac={}", mac.name());
        }
    }

    #[test]
    fn gemv_server_rejects_bad_requests_without_dying() {
        let fm = fused_model();
        let cols = fm.linear("wq").unwrap().cols();
        let (server, client) = GemvServer::spawn(fm, 1, 4, Duration::from_millis(1));
        for err in [
            client.infer("nope", probe(8, 1)).unwrap_err(),
            client.infer("wq", probe(cols + 1, 2)).unwrap_err(),
        ] {
            assert!(
                matches!(
                    err.downcast_ref::<ServerError>(),
                    Some(ServerError::InvalidRequest(_))
                ),
                "{err:#}"
            );
        }
        // the server survives bad requests and keeps serving good ones
        assert_eq!(client.infer("wq", probe(cols, 3)).unwrap().len(), 24);
        drop(client);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.rejected, 2, "{stats:?}");
    }

    // -----------------------------------------------------------------------
    // fault tolerance (deterministic injection)
    // -----------------------------------------------------------------------

    /// Submit scoring requests from one thread — FIFO channel + FIFO
    /// admission makes the admission ordinals exactly the submission
    /// order — and collect every outcome.
    fn run_scores(cli: &EvalClient, reqs: &[Vec<i32>]) -> Vec<Result<Response>> {
        let pending: Vec<Pending<Response>> = reqs
            .iter()
            .map(|t| cli.submit_score(t.clone(), None).unwrap())
            .collect();
        pending.into_iter().map(|p| p.wait()).collect()
    }

    fn assert_stream_faulted(r: &Result<Response>, needle: &str, ctx: &str) {
        let err = r.as_ref().unwrap_err();
        match err.downcast_ref::<ServerError>() {
            Some(ServerError::StreamFaulted(m)) => {
                assert!(m.contains(needle), "{ctx}: fault payload missing '{needle}': {m}")
            }
            other => panic!("{ctx}: expected StreamFaulted, got {other:?} / {err:#}"),
        }
    }

    /// Acceptance grid: a scripted panic inside the fused step at round 1
    /// against admission ordinal 1 kills ONLY that stream — the siblings'
    /// logprobs stay bit-identical to a clean run, the arena leaks no
    /// pages, and the server answers new requests afterwards — across
    /// MacMode {F32, Int8} x threads {1, 4}.
    #[test]
    fn fault_injection_grid_quarantines_only_the_faulted_stream() {
        use crate::forward::{synth, ForwardModel};
        use crate::kernels::MacMode;
        let (fs, map) = forward_payload();
        let reqs: Vec<Vec<i32>> = [5usize, 7, 6]
            .iter()
            .enumerate()
            .map(|(i, &len)| synth::synth_tokens(&fs, len, 90 + i as u64))
            .collect();
        // chunk 2 keeps every stream alive through round 1 (where the
        // fault is scripted); the linger window lets all three requests
        // join the first admission wave
        let cfg = |faults: FaultPlan| BatchConfig {
            max_streams: 3,
            kv_page_tokens: 3,
            prefill_chunk: 2,
            linger: Duration::from_millis(200),
            faults,
            ..BatchConfig::default()
        };
        for mac in [MacMode::F32, MacMode::Int8] {
            for threads in [1usize, 4] {
                let ctx = format!("mac {mac:?}, threads {threads}");
                let build = || {
                    ForwardModel::from_packed_map_with(fs.clone(), &map, mac)
                        .unwrap()
                        .with_threads(threads)
                };
                let (srv, cli) =
                    EvalServer::spawn_batched(build(), cfg(FaultPlan::new())).unwrap();
                let clean: Vec<Vec<f64>> = run_scores(&cli, &reqs)
                    .into_iter()
                    .map(|r| r.unwrap().logprobs)
                    .collect();
                drop(cli);
                srv.shutdown().unwrap();

                let plan = FaultPlan::new().panic_at(1, 1);
                let (srv, cli) = EvalServer::spawn_batched(build(), cfg(plan)).unwrap();
                let got = run_scores(&cli, &reqs);
                assert_stream_faulted(&got[1], "injected fault", &ctx);
                for i in [0usize, 2] {
                    assert_eq!(
                        got[i].as_ref().unwrap().logprobs,
                        clean[i],
                        "survivor {i} diverged from the clean run ({ctx})"
                    );
                }
                // the server keeps serving after the quarantine
                let after = cli.score(reqs[0].clone()).unwrap();
                assert_eq!(after.logprobs, clean[0], "post-fault request ({ctx})");
                drop(cli);
                let stats = srv.shutdown().unwrap();
                assert_eq!(stats.faulted, 1, "{ctx}: {stats:?}");
                assert_eq!(stats.admitted, 4, "{ctx}: {stats:?}");
                assert_eq!(stats.retired, 3, "{ctx}: {stats:?}");
                assert_eq!(stats.requests, 4, "{ctx}: {stats:?}");
                assert_eq!(stats.leaked_pages, 0, "{ctx}: {stats:?}");
            }
        }
    }

    /// Scripted NaN logits take the non-finite detector's door: the
    /// poisoned stream is quarantined, its sibling is untouched.
    #[test]
    fn fault_nan_logits_quarantine_the_poisoned_stream() {
        use crate::forward::{synth, ForwardModel};
        let (fs, map) = forward_payload();
        let reqs: Vec<Vec<i32>> = [5usize, 6]
            .iter()
            .enumerate()
            .map(|(i, &len)| synth::synth_tokens(&fs, len, 90 + i as u64))
            .collect();
        let cfg = |faults: FaultPlan| BatchConfig {
            max_streams: 2,
            kv_page_tokens: 3,
            prefill_chunk: 2,
            linger: Duration::from_millis(200),
            faults,
            ..BatchConfig::default()
        };
        let build = || ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let (srv, cli) = EvalServer::spawn_batched(build(), cfg(FaultPlan::new())).unwrap();
        let clean: Vec<Vec<f64>> =
            run_scores(&cli, &reqs).into_iter().map(|r| r.unwrap().logprobs).collect();
        drop(cli);
        srv.shutdown().unwrap();

        let plan = FaultPlan::new().nan_at(1, 0);
        let (srv, cli) = EvalServer::spawn_batched(build(), cfg(plan)).unwrap();
        let got = run_scores(&cli, &reqs);
        assert_stream_faulted(&got[0], "non-finite", "nan injection");
        assert_eq!(got[1].as_ref().unwrap().logprobs, clean[1], "sibling diverged");
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.faulted, 1, "{stats:?}");
        assert_eq!(stats.leaked_pages, 0, "{stats:?}");
    }

    /// Deadlines are enforced both before a request ever occupies a slot
    /// and between coalesced steps once it is running.
    #[test]
    fn fault_deadline_checked_at_admission_and_mid_flight() {
        use crate::forward::{synth, ForwardModel};
        let (fs, map) = forward_payload_seq(64);
        let model = ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let cfg = BatchConfig {
            prefill_chunk: 2,
            faults: FaultPlan::new().with_step_delay(Duration::from_millis(30)),
            ..BatchConfig::default()
        };
        let (srv, cli) = EvalServer::spawn_batched(model, cfg).unwrap();
        // already expired: refused before touching a stream slot
        let err = cli.score_deadline(vec![1, 2, 3], Instant::now()).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServerError>(), Some(ServerError::DeadlineExceeded)),
            "{err:#}"
        );
        // expires mid-flight: 40 new tokens at >= 30ms per step cannot
        // finish inside 100ms, so the stream is cut between steps
        let prompt = synth::synth_tokens(&fs, 4, 7);
        let err = cli
            .generate_deadline(prompt, 40, Instant::now() + Duration::from_millis(100))
            .unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServerError>(), Some(ServerError::DeadlineExceeded)),
            "{err:#}"
        );
        // deadline-free requests still serve
        assert_eq!(cli.score(vec![1, 2, 3]).unwrap().logprobs.len(), 2);
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.deadline_missed, 2, "{stats:?}");
        assert_eq!(stats.requests, 3, "{stats:?}");
        assert_eq!(stats.leaked_pages, 0, "{stats:?}");
    }

    /// Admission control: with one slot, two waiting spots and a stalled
    /// step, six back-to-back requests resolve deterministically into
    /// three served and three shed with [`ServerError::Overloaded`].
    #[test]
    fn fault_overload_sheds_excess_requests() {
        use crate::forward::{synth, ForwardModel};
        let (fs, map) = forward_payload();
        let model = ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        // 4-token prompts at chunk 2 take two rounds each; the 60ms
        // stall guarantees requests 1..6 are all drained while request 0
        // is still stepping, so the queue decides: 2 wait, 3 shed.
        let cfg = BatchConfig {
            max_streams: 1,
            prefill_chunk: 2,
            max_waiting: 2,
            linger: Duration::from_millis(1),
            faults: FaultPlan::new().with_step_delay(Duration::from_millis(60)),
            ..BatchConfig::default()
        };
        let (srv, cli) = EvalServer::spawn_batched(model, cfg).unwrap();
        let reqs: Vec<Vec<i32>> =
            (0..6u64).map(|i| synth::synth_tokens(&fs, 4, 30 + i)).collect();
        let results = run_scores(&cli, &reqs);
        for (i, r) in results.iter().enumerate() {
            if i < 3 {
                assert!(r.is_ok(), "request {i} should have served: {r:?}");
            } else {
                let err = r.as_ref().unwrap_err();
                assert!(
                    matches!(
                        err.downcast_ref::<ServerError>(),
                        Some(ServerError::Overloaded { limit: 2, .. })
                    ),
                    "request {i}: {err:#}"
                );
            }
        }
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.shed, 3, "{stats:?}");
        assert_eq!(stats.admitted, 3, "{stats:?}");
        assert_eq!(stats.retired, 3, "{stats:?}");
        assert_eq!(stats.requests, 6, "{stats:?}");
    }

    /// Every class of unservable request is refused up front with
    /// [`ServerError::InvalidRequest`] — no silent truncation, no closed
    /// channels — and the server keeps serving.
    #[test]
    fn fault_invalid_requests_rejected_up_front() {
        use crate::forward::ForwardModel;
        let (fs, map) = forward_payload(); // seq = 8, vocab = 48
        let model = ForwardModel::from_packed_map(fs, &map).unwrap();
        let (srv, cli) = EvalServer::spawn_batched(model, BatchConfig::default()).unwrap();
        let errs = [
            cli.score((0..9).collect()).unwrap_err(), // overlong
            cli.score(vec![1, 999]).unwrap_err(),     // out-of-vocab
            cli.generate(vec![], 5).unwrap_err(),     // empty prompt
            cli.generate(vec![1, 2], 0).unwrap_err(), // zero budget
            cli.generate((0..9).collect(), 2).unwrap_err(), // overlong prompt
        ];
        for err in &errs {
            assert!(
                matches!(err.downcast_ref::<ServerError>(), Some(ServerError::InvalidRequest(_))),
                "{err:#}"
            );
        }
        assert_eq!(cli.score(vec![1, 2, 3]).unwrap().logprobs.len(), 2);
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.rejected, 5, "{stats:?}");
        assert_eq!(stats.admitted, 1, "{stats:?}");
        assert_eq!(stats.requests, 6, "{stats:?}");
    }

    /// Shutdown drains: the in-flight generation finishes bit-identical
    /// to solo greedy decode while concurrent new work is refused with
    /// [`ServerError::ShuttingDown`].
    #[test]
    fn fault_drain_finishes_in_flight_and_rejects_new() {
        use crate::forward::{synth, ForwardModel};
        let (fs, map) = forward_payload_seq(32);
        let build = || ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let prompt = synth::synth_tokens(&fs, 4, 9);
        let want = solo_greedy(&build(), &prompt, 10);
        let cfg = BatchConfig {
            prefill_chunk: 4,
            faults: FaultPlan::new().with_step_delay(Duration::from_millis(25)),
            ..BatchConfig::default()
        };
        let (srv, cli) = EvalServer::spawn_batched(build(), cfg).unwrap();
        let gen = cli.submit_generate(prompt, 10, None).unwrap();
        // let the stream get going, then stop the server while it runs
        std::thread::sleep(Duration::from_millis(40));
        let drainer = std::thread::spawn(move || srv.shutdown().unwrap());
        std::thread::sleep(Duration::from_millis(30));
        // new work during the drain is refused...
        let err = cli.score(vec![1, 2, 3]).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServerError>(), Some(ServerError::ShuttingDown)),
            "{err:#}"
        );
        // ...while the in-flight stream still finishes, exactly
        assert_eq!(gen.wait().unwrap().tokens, want);
        let stats = drainer.join().unwrap();
        assert_eq!(stats.retired, 1, "{stats:?}");
        assert_eq!(stats.requests, 2, "{stats:?}");
        assert_eq!(stats.leaked_pages, 0, "{stats:?}");
    }

    /// A drafter panic demotes its stream to plain greedy decode: no
    /// draft is ever proposed, the output is unchanged, nothing faults.
    #[test]
    fn fault_drafter_panic_demotes_stream_to_plain_decode() {
        use crate::forward::ForwardModel;
        let (fs, map) = forward_payload_seq(32);
        let build = || ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let (chunk, draft_cap) = (4usize, 3usize);
        let (prompt, max_new, _) = find_accepting_workload(&build(), chunk, draft_cap, 12);
        let want = solo_greedy(&build(), &prompt, max_new);
        let cfg = |faults: FaultPlan| BatchConfig {
            speculative: true,
            draft_len: draft_cap,
            prefill_chunk: chunk,
            faults,
            ..BatchConfig::default()
        };
        let jobs = vec![(prompt.clone(), max_new)];
        let (out, stats) = run_generate(build(), cfg(FaultPlan::new()), &jobs);
        assert_eq!(out[0], want);
        assert!(stats.drafted > 0, "workload must draft: {stats:?}");
        assert_eq!(stats.degraded, 0, "{stats:?}");

        // the first decode staging happens right after the last prefill
        // round — a drafter panic there means no proposal ever lands
        let demote_round = prompt.len().div_ceil(chunk) as u64;
        let plan = FaultPlan::new().draft_panic_at(demote_round, 0);
        let (out, stats) = run_generate(build(), cfg(plan), &jobs);
        assert_eq!(out[0], want, "demoted stream must still decode exactly");
        assert_eq!(stats.drafted, 0, "demotion must precede any draft: {stats:?}");
        assert_eq!(stats.degraded, 1, "{stats:?}");
        assert_eq!(stats.faulted, 0, "{stats:?}");
        assert_eq!(stats.retired, 1, "{stats:?}");
    }

    /// Speculative decode under a scripted mid-decode panic: the
    /// faulting generation stream is quarantined (pages freed), its
    /// sibling finishes bit-identical to solo greedy decode.
    #[test]
    fn fault_panic_during_speculative_decode_spares_the_sibling() {
        use crate::forward::{synth, ForwardModel};
        let (fs, map) = forward_payload_seq(32);
        let build = || ForwardModel::from_packed_map(fs.clone(), &map).unwrap();
        let jobs: Vec<(Vec<i32>, usize)> = vec![
            (synth::synth_tokens(&fs, 6, 11), 10),
            (synth::synth_tokens(&fs, 6, 12), 10),
        ];
        let want1 = solo_greedy(&build(), &jobs[1].0, jobs[1].1);
        // 6-token prompts at chunk 3 prefill through round 1, and decode
        // commits at most 3 tokens per round — so both streams are still
        // decoding at round 4, where stream 0's panic is scripted
        let cfg = BatchConfig {
            max_streams: 2,
            kv_page_tokens: 4,
            prefill_chunk: 3,
            linger: Duration::from_millis(100),
            speculative: true,
            draft_len: 3,
            faults: FaultPlan::new().panic_at(4, 0),
            ..BatchConfig::default()
        };
        let (srv, cli) = EvalServer::spawn_batched(build(), cfg).unwrap();
        let pending: Vec<Pending<GenResponse>> = jobs
            .iter()
            .map(|(p, m)| cli.submit_generate(p.clone(), *m, None).unwrap())
            .collect();
        let results: Vec<Result<GenResponse>> =
            pending.into_iter().map(|p| p.wait()).collect();
        let err = results[0].as_ref().unwrap_err();
        match err.downcast_ref::<ServerError>() {
            Some(ServerError::StreamFaulted(m)) => {
                assert!(m.contains("injected fault"), "{m}")
            }
            other => panic!("expected StreamFaulted, got {other:?} / {err:#}"),
        }
        assert_eq!(results[1].as_ref().unwrap().tokens, want1, "sibling diverged");
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.faulted, 1, "{stats:?}");
        assert_eq!(stats.retired, 1, "{stats:?}");
        assert_eq!(stats.leaked_pages, 0, "{stats:?}");
    }

    /// A panic outside the shielded regions (here: model setup inside
    /// the server thread) kills the server — and `shutdown` surfaces
    /// that panic instead of reporting a clean zero-stat run.
    #[test]
    fn fault_dead_server_thread_surfaces_its_panic() {
        struct PanickyModel;
        impl LogitsFn for PanickyModel {
            fn batch(&self) -> usize {
                panic!("injected construction fault")
            }
            fn seq(&self) -> usize {
                8
            }
            fn vocab(&self) -> usize {
                16
            }
            fn logits(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
                anyhow::bail!("unreachable")
            }
        }
        let (srv, cli) = EvalServer::spawn(PanickyModel, Duration::from_millis(1));
        assert!(cli.score(vec![1, 2]).is_err(), "a dead server must not answer");
        let err = srv.shutdown().unwrap_err();
        assert!(err.to_string().contains("injected construction fault"), "{err:#}");
    }

    /// Static batcher: a panic inside the forward faults that batch with
    /// a typed error and the server keeps serving afterwards.
    #[test]
    fn fault_static_batcher_quarantines_panicking_forward() {
        struct PanicOnToken {
            inner: SuccessorModel,
        }
        impl LogitsFn for PanicOnToken {
            fn batch(&self) -> usize {
                self.inner.batch()
            }
            fn seq(&self) -> usize {
                self.inner.seq()
            }
            fn vocab(&self) -> usize {
                self.inner.vocab()
            }
            fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
                assert!(!tokens.contains(&7), "injected forward fault");
                self.inner.logits(tokens)
            }
        }
        let (srv, cli) =
            EvalServer::spawn(PanicOnToken { inner: model() }, Duration::from_millis(1));
        let err = cli.score(vec![1, 7]).unwrap_err();
        match err.downcast_ref::<ServerError>() {
            Some(ServerError::StreamFaulted(m)) => {
                assert!(m.contains("injected forward fault"), "{m}")
            }
            other => panic!("expected StreamFaulted, got {other:?} / {err:#}"),
        }
        assert_eq!(cli.score(vec![1, 2, 3]).unwrap().logprobs.len(), 2);
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 2, "{stats:?}");
        assert_eq!(stats.faulted, 1, "{stats:?}");
    }

    /// Static batcher: typed refusals for invalid and expired requests.
    #[test]
    fn fault_static_batcher_rejects_invalid_and_expired_requests() {
        let (srv, cli) = EvalServer::spawn(model(), Duration::from_millis(1));
        let err = cli.score(vec![1, 999]).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServerError>(), Some(ServerError::InvalidRequest(_))),
            "{err:#}"
        );
        let err = cli.score_deadline(vec![1, 2, 3], Instant::now()).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServerError>(), Some(ServerError::DeadlineExceeded)),
            "{err:#}"
        );
        assert_eq!(cli.score(vec![1, 2, 3]).unwrap().logprobs.len(), 2);
        drop(cli);
        let stats = srv.shutdown().unwrap();
        assert_eq!(stats.requests, 3, "{stats:?}");
        assert_eq!(stats.rejected, 1, "{stats:?}");
        assert_eq!(stats.deadline_missed, 1, "{stats:?}");
    }
}
