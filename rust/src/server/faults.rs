//! Deterministic fault injection for the serving layer.
//!
//! A [`FaultPlan`] scripts faults at exact scheduler rounds so the
//! fault-tolerance machinery — per-stream panic isolation, stream
//! quarantine, deadline enforcement — is exercised by *hard-asserted
//! tests* instead of hoped-for behavior. The plan is carried by
//! [`crate::server::BatchConfig`] (and threaded through
//! [`crate::runtime::BackendBuilder`]), consulted by the continuous
//! batcher at fixed seams, and is exposed on the CLI as
//! `msb serve-bench --inject` / `serve_eval --inject`.
//!
//! Streams are addressed by **admission ordinal**: the 0-based index a
//! request gets when it is admitted into a stream slot (FIFO admission
//! makes this the request send order when one thread submits). Rounds
//! are the scheduler's coalesced-step counter, starting at 0.
//!
//! Everything here is deterministic: a fault either fires at its exact
//! `(round, stream)` coordinate or — when the target is not active at
//! that round — not at all. No randomness, no time dependence (the only
//! time-shaped knob, [`FaultPlan::with_step_delay`], *stretches* rounds
//! uniformly to create deadline pressure; it never reorders anything).

use std::time::Duration;

use anyhow::{bail, Result};

/// One scripted fault at a `(round, stream-ordinal)` coordinate.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Fault {
    /// Panic inside the fused step (caught by the scheduler's
    /// `catch_unwind`, quarantining only this stream).
    Panic { step: u64, stream: u64 },
    /// Overwrite the stream's step logits with NaN — simulating a
    /// NaN-poisoned projection surfacing in the output; the scheduler's
    /// non-finite detector must quarantine the stream.
    Nan { step: u64, stream: u64 },
    /// Panic inside the drafter's propose call — the scheduler must
    /// demote the stream to plain greedy decode, never kill it.
    DraftPanic { step: u64, stream: u64 },
}

/// A deterministic script of serving-layer faults. Empty by default
/// (the scheduler's fast path never pays for an empty plan beyond a
/// branch per seam).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    /// Artificial stall before every coalesced step — deadline
    /// pressure: with a stall of `d`, any request whose deadline is
    /// closer than `steps_left * d` will expire mid-flight.
    step_delay: Duration,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// No faults scripted and no step delay.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty() && self.step_delay.is_zero()
    }

    /// Script a panic inside the fused step at round `step` while the
    /// stream with admission ordinal `stream` is being stepped.
    pub fn panic_at(mut self, step: u64, stream: u64) -> FaultPlan {
        self.faults.push(Fault::Panic { step, stream });
        self
    }

    /// Script NaN logits for stream `stream` at round `step`.
    pub fn nan_at(mut self, step: u64, stream: u64) -> FaultPlan {
        self.faults.push(Fault::Nan { step, stream });
        self
    }

    /// Script a drafter panic for stream `stream` at round `step`.
    pub fn draft_panic_at(mut self, step: u64, stream: u64) -> FaultPlan {
        self.faults.push(Fault::DraftPanic { step, stream });
        self
    }

    /// Stall every scheduler round by `d` (deadline pressure).
    pub fn with_step_delay(mut self, d: Duration) -> FaultPlan {
        self.step_delay = d;
        self
    }

    /// Parse a comma-separated injection spec, the `--inject` format:
    ///
    /// * `panic@STEP:STREAM` — scripted panic in the fused step
    /// * `nan@STEP:STREAM` — NaN logits for one stream
    /// * `draft-panic@STEP:STREAM` — drafter panic (demotes the stream)
    /// * `delay@MILLIS` — per-step stall in milliseconds
    ///
    /// Example: `--inject panic@3:1,nan@5:0,delay@10`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((kind, coord)) = part.split_once('@') else {
                bail!("fault '{part}': expected KIND@ARGS (e.g. panic@3:1, delay@10)");
            };
            if kind == "delay" {
                let ms: u64 = coord
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault '{part}': bad millisecond count"))?;
                plan.step_delay = Duration::from_millis(ms);
                continue;
            }
            let Some((step, stream)) = coord.split_once(':') else {
                bail!("fault '{part}': expected {kind}@STEP:STREAM");
            };
            let step: u64 = step
                .parse()
                .map_err(|_| anyhow::anyhow!("fault '{part}': bad step number '{step}'"))?;
            let stream: u64 = stream
                .parse()
                .map_err(|_| anyhow::anyhow!("fault '{part}': bad stream ordinal '{stream}'"))?;
            plan.faults.push(match kind {
                "panic" => Fault::Panic { step, stream },
                "nan" => Fault::Nan { step, stream },
                "draft-panic" => Fault::DraftPanic { step, stream },
                other => bail!("unknown fault kind '{other}' (panic|nan|draft-panic|delay)"),
            });
        }
        Ok(plan)
    }

    /// Human-readable echo of the plan for CLI banners.
    pub fn describe(&self) -> String {
        let mut parts: Vec<String> = self
            .faults
            .iter()
            .map(|f| match f {
                Fault::Panic { step, stream } => format!("panic@{step}:{stream}"),
                Fault::Nan { step, stream } => format!("nan@{step}:{stream}"),
                Fault::DraftPanic { step, stream } => format!("draft-panic@{step}:{stream}"),
            })
            .collect();
        if !self.step_delay.is_zero() {
            parts.push(format!("delay@{}", self.step_delay.as_millis()));
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(",")
        }
    }

    // -- scheduler seams -------------------------------------------------

    /// Panic if a [`Fault::Panic`] is scripted for round `step` against
    /// any of `ordinals`. Called *inside* the scheduler's `catch_unwind`
    /// region, immediately before the fused step, so the injected panic
    /// takes exactly the path a real kernel/arena panic would. The same
    /// coordinate match makes the faulting stream re-panic in its solo
    /// isolation replay (so the scheduler can attribute the fault) while
    /// siblings replay clean.
    pub fn maybe_panic(&self, step: u64, ordinals: &[u64]) {
        for f in &self.faults {
            if let Fault::Panic { step: s, stream } = f {
                if *s == step && ordinals.contains(stream) {
                    panic!("injected fault: scripted panic at step {s} for stream {stream}");
                }
            }
        }
    }

    /// Panic if a [`Fault::DraftPanic`] is scripted for `(step, ordinal)`.
    /// Called inside the `catch_unwind` around the drafter's propose.
    pub fn maybe_panic_draft(&self, step: u64, ordinal: u64) {
        for f in &self.faults {
            if let Fault::DraftPanic { step: s, stream } = f {
                if *s == step && *stream == ordinal {
                    panic!(
                        "injected fault: scripted drafter panic at step {s} for stream {stream}"
                    );
                }
            }
        }
    }

    /// Overwrite `row` with NaN if a [`Fault::Nan`] is scripted for
    /// `(step, ordinal)`. Returns whether poison was applied.
    pub fn poison_logits(&self, step: u64, ordinal: u64, row: &mut [f32]) -> bool {
        for f in &self.faults {
            if let Fault::Nan { step: s, stream } = f {
                if *s == step && *stream == ordinal {
                    row.fill(f32::NAN);
                    return true;
                }
            }
        }
        false
    }

    /// Stall one scheduler round (no-op without a scripted delay).
    pub fn stall(&self) {
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let p = FaultPlan::new();
        assert!(p.is_empty());
        p.maybe_panic(0, &[0, 1, 2]);
        p.maybe_panic_draft(5, 1);
        let mut row = vec![1.0f32; 4];
        assert!(!p.poison_logits(0, 0, &mut row));
        assert!(row.iter().all(|v| v.is_finite()));
        assert_eq!(p.describe(), "none");
    }

    #[test]
    fn parse_roundtrips_through_describe() {
        let p = FaultPlan::parse("panic@3:1, nan@5:0,draft-panic@2:2,delay@10").unwrap();
        assert!(!p.is_empty());
        assert_eq!(p.describe(), "panic@3:1,nan@5:0,draft-panic@2:2,delay@10");
        assert_eq!(FaultPlan::parse(&p.describe()).unwrap(), p);
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::new());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in ["panic", "panic@x:1", "panic@1:y", "panic@1", "zap@1:2", "delay@ms"] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn panic_fires_only_at_its_exact_coordinate() {
        let p = FaultPlan::new().panic_at(3, 1);
        p.maybe_panic(2, &[0, 1]); // wrong round
        p.maybe_panic(3, &[0, 2]); // right round, target absent
        let r = std::panic::catch_unwind(|| p.maybe_panic(3, &[0, 1]));
        let payload = r.expect_err("must panic at its coordinate");
        let msg = payload.downcast_ref::<String>().unwrap();
        assert!(msg.contains("injected fault"), "{msg}");
        assert!(msg.contains("stream 1"), "{msg}");
    }

    #[test]
    fn draft_panic_targets_one_stream() {
        let p = FaultPlan::new().draft_panic_at(1, 0);
        p.maybe_panic_draft(1, 1);
        p.maybe_panic_draft(0, 0);
        assert!(std::panic::catch_unwind(|| p.maybe_panic_draft(1, 0)).is_err());
    }

    #[test]
    fn nan_poison_hits_the_addressed_row_only() {
        let p = FaultPlan::new().nan_at(2, 1);
        let mut a = vec![1.0f32; 3];
        let mut b = vec![1.0f32; 3];
        assert!(!p.poison_logits(2, 0, &mut a));
        assert!(p.poison_logits(2, 1, &mut b));
        assert!(a.iter().all(|v| v.is_finite()));
        assert!(b.iter().all(|v| v.is_nan()));
    }
}
