//! Tensor substrate: a minimal row-major f32 matrix plus the bf16 round-trip
//! the paper's simulated-quantization protocol requires ("all quantized
//! values are decoded and stored in bfloat16", §4.1).

pub mod bf16;

use crate::stats::Rng;

/// Row-major 2-D f32 tensor. Deliberately simple: quantizers operate on
/// flat slices; shape only matters for block granularity and the runtime.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// N(0,1) matrix — the Appendix D synthetic instances.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, 1.0);
        m
    }

    /// Heavy-tailed weight-like matrix (Gaussian bulk + sparse outliers).
    pub fn weightlike(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_weightlike(&mut m.data, 0.05, 0.002);
        m
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Iterate over row-aligned blocks of `t` consecutive elements — the
    /// paper's block-wise granularity ("t-element groups per row"). `t`
    /// must divide `cols`.
    pub fn row_blocks(&self, t: usize) -> impl Iterator<Item = &[f32]> {
        assert!(t > 0 && self.cols % t == 0, "block {} !| cols {}", t, self.cols);
        self.data.chunks_exact(t)
    }

    /// Total squared reconstruction error vs another matrix.
    pub fn sse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        crate::stats::sse(&self.data, &other.data)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Round every element through bfloat16 (paper's decode-to-bf16 step).
    pub fn to_bf16_roundtrip(&self) -> Matrix {
        let mut out = self.clone();
        for v in &mut out.data {
            *v = bf16::round(*v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(1, 2), 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.len(), 6);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Matrix::from_vec(2, 2, vec![1.0; 5]);
    }

    #[test]
    fn row_blocks_cover_everything() {
        let m = Matrix::from_vec(2, 4, (0..8).map(|i| i as f32).collect());
        let blocks: Vec<&[f32]> = m.row_blocks(2).collect();
        assert_eq!(blocks.len(), 4);
        assert_eq!(blocks[0], &[0., 1.]);
        assert_eq!(blocks[3], &[6., 7.]);
    }

    #[test]
    #[should_panic]
    fn row_blocks_requires_divisibility() {
        let m = Matrix::zeros(2, 5);
        let _ = m.row_blocks(2).count();
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Rng::new(9);
        let m = Matrix::randn(100, 100, &mut rng);
        let s = crate::stats::summarize(&m.data);
        assert!(s.mean.abs() < 0.05);
        assert!((s.var - 1.0).abs() < 0.1);
    }

    #[test]
    fn sse_zero_for_identical() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(10, 10, &mut rng);
        assert_eq!(m.sse(&m), 0.0);
    }

    #[test]
    fn bf16_roundtrip_close() {
        let mut rng = Rng::new(2);
        let m = Matrix::randn(32, 32, &mut rng);
        let r = m.to_bf16_roundtrip();
        // bf16 has ~3 decimal digits; relative error < 1%
        for (a, b) in m.data.iter().zip(&r.data) {
            assert!((a - b).abs() <= a.abs() * 0.01 + 1e-6);
        }
    }
}
