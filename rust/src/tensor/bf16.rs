//! bfloat16 round-trip helpers (no `half` crate offline). bf16 is the top 16
//! bits of an IEEE-754 f32 with round-to-nearest-even on the cut.

/// Encode an f32 to its bf16 bit pattern (round-to-nearest-even).
#[inline]
pub fn encode(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserve sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round-to-nearest-even on bit 16
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(round_bit - 1 + lsb)) >> 16) as u16
}

/// Decode a bf16 bit pattern back to f32 (exact).
#[inline]
pub fn decode(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 -> bf16 -> f32 (the paper's "decoded and stored in bfloat16").
#[inline]
pub fn round(x: f32) -> f32 {
    decode(encode(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_preserved() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0] {
            assert_eq!(round(v), v, "{v}");
        }
    }

    #[test]
    fn relative_error_bound() {
        let mut rng = crate::stats::Rng::new(11);
        for _ in 0..10_000 {
            let x = (rng.normal() as f32) * 10.0;
            let r = round(x);
            assert!((x - r).abs() <= x.abs() / 128.0 + f32::MIN_POSITIVE);
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between bf16(1.0) and the next up;
        // nearest-even resolves down to 1.0 (even mantissa).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(round(halfway), 1.0);
        // just above halfway rounds up
        let above = f32::from_bits(0x3F80_8001);
        assert!(round(above) > 1.0);
    }

    #[test]
    fn nan_and_inf() {
        assert!(round(f32::NAN).is_nan());
        assert_eq!(round(f32::INFINITY), f32::INFINITY);
        assert_eq!(round(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn idempotent() {
        let mut rng = crate::stats::Rng::new(12);
        for _ in 0..1000 {
            let x = rng.normal() as f32;
            assert_eq!(round(round(x)), round(x));
        }
    }
}
