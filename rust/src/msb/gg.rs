//! Algorithm 2 — Greedy Grouping (paper §3.3.2), plus the shared
//! adjacent-merge machinery reused by WGM (Algorithm 3) and WGM-LO
//! (Algorithm 4).
//!
//! Sorted non-zero magnitudes start as singleton groups; we repeatedly
//! apply the cheapest adjacent merge until `target` groups remain. Two
//! kernels compute "cheapest":
//!
//! * **Scan** — a flat delta-array argmin scan over the live adjacencies.
//!   The block-wise hot path merges ≤64 singletons down to 8 per
//!   64-element block; at that size the whole delta array is
//!   cache-resident and a branch-light linear scan beats heap push/pop
//!   and stale-entry skipping by a wide margin (ablated in
//!   `benches/perf_hotpath.rs`).
//! * **Heap** — a min-heap of merge deltas with lazy invalidation via
//!   per-group generation counters (the paper's "ignore array"), which
//!   wins asymptotically on large per-tensor instances.
//!
//! [`greedy_merge_ws`] dispatches on the live-group count
//! ([`SCAN_KERNEL_MAX`]); both kernels select merges by the same total
//! order — `(delta cost via f64 total_cmp, leftmost group first)` — so
//! they produce **bit-identical groupings** (asserted in tests).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::grouping::Grouping;
use super::objective::{CostParams, Prefix};

/// f64 ordered via total_cmp so it can live in a BinaryHeap.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Cost(f64);

impl Eq for Cost {}

impl PartialOrd for Cost {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Cost {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Entry {
    cost: Cost,
    left: u32,
    lgen: u32,
    rgen: u32,
}

const NONE: u32 = u32::MAX;

/// Initial group counts at or below this take the scan kernel; above it
/// the heap's O(g log g) wins. 64-element blocks (g₀ ≤ 64) always scan;
/// per-tensor instances (g₀ = n/window, thousands+) always heap.
pub const SCAN_KERNEL_MAX: usize = 128;

/// Which adjacent-merge kernel to run. [`MergeKernel::Auto`] picks by
/// instance size; the forced variants exist for the golden-equivalence
/// tests and the `perf_hotpath` ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeKernel {
    Auto,
    Scan,
    Heap,
}

/// Reusable buffers for [`greedy_merge_ws`] — the block-wise hot path runs
/// one merge per 64-element block, so per-call allocation dominates without
/// this (§Perf).
#[derive(Default)]
pub struct MergeWorkspace {
    start: Vec<u32>,
    end: Vec<u32>,
    prev: Vec<u32>,
    next: Vec<u32>,
    gen: Vec<u32>,
    heap: BinaryHeap<Reverse<Entry>>,
    delta: Vec<f64>,
}

/// Cost increase of merging adjacent groups `a` and `b` (O(1) via prefix
/// sums). Shared by both kernels so their selection keys are bit-equal.
#[inline]
fn merge_delta(
    prefix: &Prefix,
    params: &CostParams,
    start: &[u32],
    end: &[u32],
    a: usize,
    b: usize,
) -> f64 {
    prefix.cost(start[a] as usize, end[b] as usize, params)
        - prefix.cost(start[a] as usize, end[a] as usize, params)
        - prefix.cost(start[b] as usize, end[b] as usize, params)
}

/// Merge adjacent groups of `initial` (a valid [`Grouping`] over `prefix`)
/// until at most `target` remain, greedily by smallest cost increase.
pub fn greedy_merge(
    prefix: &Prefix,
    initial: Grouping,
    target: usize,
    params: &CostParams,
) -> Grouping {
    let mut ws = MergeWorkspace::default();
    let mut bounds = Vec::new();
    greedy_merge_ws(&mut ws, prefix, initial.intervals(), target, params, &mut bounds);
    if bounds.is_empty() {
        return initial;
    }
    Grouping::new(bounds)
}

/// Workspace variant: `initial` is an interval iterator; the resulting
/// bounds land in `out_bounds` (cleared first). If the initial partition
/// already satisfies `target`, `out_bounds` receives it unchanged.
/// Dispatches between the scan and heap kernels by instance size.
pub fn greedy_merge_ws(
    ws: &mut MergeWorkspace,
    prefix: &Prefix,
    initial: impl Iterator<Item = (usize, usize)>,
    target: usize,
    params: &CostParams,
    out_bounds: &mut Vec<usize>,
) {
    greedy_merge_ws_kernel(ws, prefix, initial, target, params, out_bounds, MergeKernel::Auto)
}

/// [`greedy_merge_ws`] with an explicit kernel choice (tests / ablation).
pub fn greedy_merge_ws_kernel(
    ws: &mut MergeWorkspace,
    prefix: &Prefix,
    initial: impl Iterator<Item = (usize, usize)>,
    target: usize,
    params: &CostParams,
    out_bounds: &mut Vec<usize>,
    kernel: MergeKernel,
) {
    let target = target.max(1);
    ws.start.clear();
    ws.end.clear();
    for (s, e) in initial {
        ws.start.push(s as u32);
        ws.end.push(e as u32);
    }
    let g0 = ws.start.len();
    out_bounds.clear();
    if g0 <= target {
        out_bounds.extend(ws.end.iter().map(|&e| e as usize));
        return;
    }
    let scan = match kernel {
        MergeKernel::Auto => g0 <= SCAN_KERNEL_MAX,
        MergeKernel::Scan => true,
        MergeKernel::Heap => false,
    };
    if scan {
        scan_merge(ws, prefix, target, params, out_bounds);
    } else {
        heap_merge(ws, prefix, target, params, out_bounds);
    }
}

/// Scan kernel: live groups stay compacted in `ws.start`/`ws.end` and the
/// adjacency deltas in a flat `ws.delta` array; every round is one linear
/// argmin plus two delta refreshes and an O(g) compaction memmove —
/// trivially cache-resident for block-sized instances.
fn scan_merge(
    ws: &mut MergeWorkspace,
    prefix: &Prefix,
    target: usize,
    params: &CostParams,
    out_bounds: &mut Vec<usize>,
) {
    let start = &mut ws.start;
    let end = &mut ws.end;
    let delta = &mut ws.delta;
    let mut len = start.len();
    delta.clear();
    delta.reserve(len - 1);
    for a in 0..len - 1 {
        delta.push(merge_delta(prefix, params, start, end, a, a + 1));
    }
    while len > target {
        // first-index argmin under f64 total order — the same selection
        // rule as the heap's (cost, leftmost-slot) entry ordering
        let mut k = 0usize;
        let mut best = delta[0];
        for (i, &d) in delta.iter().enumerate().skip(1) {
            if d.total_cmp(&best) == std::cmp::Ordering::Less {
                best = d;
                k = i;
            }
        }
        // merge k+1 into k, compact, refresh the two touched adjacencies
        end[k] = end[k + 1];
        start.remove(k + 1);
        end.remove(k + 1);
        delta.remove(k);
        len -= 1;
        if k > 0 {
            delta[k - 1] = merge_delta(prefix, params, start, end, k - 1, k);
        }
        if k + 1 < len {
            delta[k] = merge_delta(prefix, params, start, end, k, k + 1);
        }
    }
    out_bounds.reserve(len);
    out_bounds.extend(end.iter().map(|&e| e as usize));
}

/// Heap kernel: min-heap of merge deltas with lazy invalidation via
/// per-group generation counters (stale entries are skipped on pop — the
/// paper's "ignore array").
fn heap_merge(
    ws: &mut MergeWorkspace,
    prefix: &Prefix,
    target: usize,
    params: &CostParams,
    out_bounds: &mut Vec<usize>,
) {
    let start = &mut ws.start;
    let end = &mut ws.end;
    let g0 = start.len();
    let prev = &mut ws.prev;
    let next = &mut ws.next;
    let gen = &mut ws.gen;
    prev.clear();
    next.clear();
    gen.clear();
    prev.extend((0..g0 as u32).map(|i| i.wrapping_sub(1)));
    next.extend(1..=g0 as u32);
    prev[0] = NONE;
    next[g0 - 1] = NONE;
    gen.resize(g0, 0);

    let heap = &mut ws.heap;
    heap.clear();
    for a in 0..g0 - 1 {
        heap.push(Reverse(Entry {
            cost: Cost(merge_delta(prefix, params, start, end, a, a + 1)),
            left: a as u32,
            lgen: 0,
            rgen: 0,
        }));
    }

    let mut alive = g0;
    while alive > target {
        let Some(Reverse(e)) = heap.pop() else { break };
        let a = e.left as usize;
        // lazy invalidation: stale generation => the paper's "ignore array"
        if gen[a] != e.lgen {
            continue;
        }
        let b = next[a];
        if b == NONE {
            continue;
        }
        let b = b as usize;
        if gen[b] != e.rgen {
            continue;
        }

        // merge b into a
        end[a] = end[b];
        gen[a] = gen[a].wrapping_add(1);
        gen[b] = gen[b].wrapping_add(1); // kills entries referencing b
        let nb = next[b];
        next[a] = nb;
        if nb != NONE {
            prev[nb as usize] = a as u32;
        }
        alive -= 1;

        // refresh the two affected adjacencies
        let pa = prev[a];
        if pa != NONE {
            let pa = pa as usize;
            heap.push(Reverse(Entry {
                cost: Cost(merge_delta(prefix, params, start, end, pa, a)),
                left: pa as u32,
                lgen: gen[pa],
                rgen: gen[a],
            }));
        }
        if nb != NONE {
            let nb = nb as usize;
            heap.push(Reverse(Entry {
                cost: Cost(merge_delta(prefix, params, start, end, a, nb)),
                left: a as u32,
                lgen: gen[a],
                rgen: gen[nb],
            }));
        }
    }

    // walk the live list to emit bounds
    out_bounds.reserve(alive);
    let mut cur = 0usize; // slot 0 is always the head (never merged away)
    loop {
        out_bounds.push(end[cur] as usize);
        match next[cur] {
            NONE => break,
            n => cur = n as usize,
        }
    }
}

/// Algorithm 2: singleton initialization.
pub fn solve(prefix: &Prefix, max_groups: usize, params: &CostParams) -> Grouping {
    let n = prefix.len();
    assert!(n > 0, "empty instance");
    let singles = Grouping::new((1..=n).collect());
    greedy_merge(prefix, singles, max_groups, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msb::dg;
    use crate::msb::objective::SortedMags;
    use crate::testing::hostile_magnitudes;

    fn solve_values(values: &[f32], g: usize, lambda: f64) -> (Prefix, Grouping) {
        let sm = SortedMags::from_values(values);
        let p = Prefix::new(&sm.mags);
        let params = CostParams::unnormalized(lambda);
        let grouping = solve(&p, g, &params);
        (p, grouping)
    }

    #[test]
    fn reaches_target_group_count() {
        let vals: Vec<f32> = (1..=100).map(|i| i as f32 * 0.1).collect();
        let (_, g) = solve_values(&vals, 8, 0.0);
        assert_eq!(g.num_groups(), 8);
        assert_eq!(g.n(), 100);
    }

    #[test]
    fn separates_obvious_clusters() {
        let mut vals = vec![0.1f32; 50];
        vals.extend(vec![9.0f32; 50]);
        let (_, g) = solve_values(&vals, 2, 0.0);
        assert_eq!(g.bounds, vec![50, 100]);
    }

    #[test]
    fn target_one_merges_all() {
        let vals: Vec<f32> = (1..=37).map(|i| i as f32).collect();
        let (_, g) = solve_values(&vals, 1, 0.0);
        assert_eq!(g.bounds, vec![37]);
    }

    #[test]
    fn target_larger_than_n_keeps_singletons() {
        let vals = [1.0f32, 2.0, 3.0];
        let (_, g) = solve_values(&vals, 10, 0.0);
        assert_eq!(g.num_groups(), 3);
    }

    /// Both kernels for every instance below the dispatch threshold (and
    /// the heap above it) must emit the exact same bounds — the
    /// bit-identity guarantee the scan kernel ships under.
    #[test]
    fn scan_and_heap_kernels_bit_identical() {
        crate::testing::check(
            "scan == heap on hostile magnitudes",
            40,
            |rng| {
                let n = 2 + rng.below(SCAN_KERNEL_MAX + 64);
                let window = 1 + rng.below(4);
                (hostile_magnitudes(rng, n), 1 + rng.below(16), window)
            },
            |(vals, g_target, window)| {
                let sm = SortedMags::from_values(vals);
                if sm.mags.is_empty() {
                    return true;
                }
                let p = Prefix::new(&sm.mags);
                let params = CostParams::unnormalized(0.01);
                let n = sm.mags.len();
                let win = *window;
                let n_init = n.div_ceil(win);
                let initial = move || (0..n_init).map(move |i| (i * win, ((i + 1) * win).min(n)));
                let mut ws = MergeWorkspace::default();
                let mut out = Vec::new();
                let mut runs: Vec<Vec<usize>> = Vec::new();
                for kernel in [MergeKernel::Scan, MergeKernel::Heap, MergeKernel::Auto] {
                    greedy_merge_ws_kernel(
                        &mut ws,
                        &p,
                        initial(),
                        *g_target,
                        &params,
                        &mut out,
                        kernel,
                    );
                    runs.push(out.clone());
                }
                runs[0] == runs[1] && runs[2] == runs[0]
            },
        );
    }

    /// Ties are where kernel equivalence usually breaks: constant inputs
    /// make every merge delta identical, so selection order is decided
    /// purely by the leftmost-first rule both kernels must share.
    #[test]
    fn kernels_agree_on_all_tied_deltas() {
        let vals = vec![1.0f32; 64];
        let sm = SortedMags::from_values(&vals);
        let p = Prefix::new(&sm.mags);
        let params = CostParams::unnormalized(0.25);
        let singles = (0..64).map(|i| (i, i + 1));
        let mut ws = MergeWorkspace::default();
        let (mut scan, mut heap) = (Vec::new(), Vec::new());
        let s = singles.clone();
        greedy_merge_ws_kernel(&mut ws, &p, s, 8, &params, &mut scan, MergeKernel::Scan);
        greedy_merge_ws_kernel(&mut ws, &p, singles, 8, &params, &mut heap, MergeKernel::Heap);
        assert_eq!(scan, heap);
        assert_eq!(scan.len(), 8);
        assert_eq!(*scan.last().unwrap(), 64);
    }

    #[test]
    fn partition_is_valid_on_hostile_inputs() {
        crate::testing::check(
            "gg produces valid partitions",
            30,
            |rng| {
                let n = 5 + rng.below(300);
                (hostile_magnitudes(rng, n), 1 + rng.below(16))
            },
            |(vals, g_target)| {
                let sm = SortedMags::from_values(vals);
                if sm.mags.is_empty() {
                    return true;
                }
                let p = Prefix::new(&sm.mags);
                let g = solve(&p, *g_target, &CostParams::unnormalized(0.01));
                g.validate();
                g.n() == sm.mags.len() && g.num_groups() <= *g_target.max(&1)
            },
        );
    }

    #[test]
    fn near_oracle_on_small_instances() {
        // GG is a heuristic; on small instances it should be within a small
        // factor of the DG optimum at matched group counts.
        crate::testing::check(
            "gg within 1.35x of dg",
            20,
            |rng| {
                let n = 8 + rng.below(40);
                let vals: Vec<f32> =
                    (0..n).map(|_| rng.normal().abs() as f32 + 1e-5).collect();
                vals
            },
            |vals| {
                let sm = SortedMags::from_values(vals);
                let p = Prefix::new(&sm.mags);
                let params = CostParams::unnormalized(0.0);
                let gg = solve(&p, 4, &params);
                let opt = dg::solve_exact_groups(&p, 4, &params);
                let (a, b) = (gg.sse(&p), opt.sse(&p));
                b == 0.0 || a <= b * 1.35 + 1e-9
            },
        );
    }

    #[test]
    fn merge_monotone_cost_with_zero_lambda() {
        // with λ=0 every merge only adds variance => SSE grows as target
        // shrinks, never the group count
        let mut rng = crate::stats::Rng::new(5);
        let vals: Vec<f32> = (0..200).map(|_| rng.normal().abs() as f32).collect();
        let sm = SortedMags::from_values(&vals);
        let p = Prefix::new(&sm.mags);
        let params = CostParams::unnormalized(0.0);
        let mut last = 0.0;
        for target in (1..=64).rev() {
            let g = solve(&p, target, &params);
            let sse = g.sse(&p);
            assert!(sse + 1e-9 >= last, "target {target}");
            last = sse;
        }
    }
}
