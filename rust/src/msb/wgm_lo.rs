//! Algorithm 4 — Local-Optimizing Windowed Greedy Merging (paper §3.3.4):
//! equal-range binning over [w_min, w_max] gives a distribution-shaped
//! initialization with few groups (numerically similar values land in the
//! same bin); greedy merging then runs on a much smaller instance, and a
//! stochastic local search over adjacent group boundaries repairs the
//! boundary artifacts the unbalanced bins introduce.

use super::gg::greedy_merge;
use super::grouping::Grouping;
use super::objective::{CostParams, Prefix};
use crate::stats::Rng;

/// Equal-range binning of the sorted magnitudes into at most `bins`
/// intervals: bin width Δ = (max − min)/bins, element with magnitude m maps
/// to bin ⌊(m − min)/Δ⌋. Empty bins vanish (bounds are deduped).
pub fn equal_range_bounds(sorted_mags: &[f32], bins: usize) -> Grouping {
    let n = sorted_mags.len();
    assert!(n > 0 && bins > 0);
    let lo = sorted_mags[0] as f64;
    let hi = sorted_mags[n - 1] as f64;
    if hi <= lo {
        return Grouping::whole(n);
    }
    let width = (hi - lo) / bins as f64;
    let mut bounds = Vec::new();
    let mut cur_bin = 0usize;
    for (i, &m) in sorted_mags.iter().enumerate() {
        let b = (((m as f64 - lo) / width) as usize).min(bins - 1);
        if b != cur_bin {
            bounds.push(i);
            cur_bin = b;
        }
    }
    bounds.push(n);
    Grouping::new(bounds)
}

/// Stochastic local boundary optimization: propose moving one internal
/// boundary uniformly within ±`range`; accept iff the two adjacent groups'
/// total cost decreases. Terminates after `max_iters` sweeps or `patience`
/// consecutive sweeps without improvement / with improvement below `eps`.
pub fn local_optimize(
    grouping: &mut Grouping,
    prefix: &Prefix,
    params: &CostParams,
    range: usize,
    max_iters: usize,
    patience: usize,
    rng: &mut Rng,
) -> usize {
    let eps = 1e-12;
    let mut stale = 0usize;
    let mut accepted = 0usize;
    for _ in 0..max_iters {
        let mut improved = 0.0f64;
        let g = grouping.num_groups();
        if g < 2 {
            break;
        }
        for k in 0..g - 1 {
            // boundary between group k and k+1 is bounds[k]
            let left_start = if k == 0 { 0 } else { grouping.bounds[k - 1] };
            let bound = grouping.bounds[k];
            let right_end = grouping.bounds[k + 1];
            // propose a shifted boundary, keeping both groups non-empty
            let lo = left_start + 1;
            let hi = right_end; // exclusive
            if hi - lo < 2 {
                continue;
            }
            let span = range.max(1);
            let offset = (rng.below(2 * span + 1)) as i64 - span as i64;
            let proposal = (bound as i64 + offset).clamp(lo as i64, hi as i64 - 1) as usize;
            if proposal == bound {
                continue;
            }
            let before = prefix.cost(left_start, bound, params)
                + prefix.cost(bound, right_end, params);
            let after = prefix.cost(left_start, proposal, params)
                + prefix.cost(proposal, right_end, params);
            if after + eps < before {
                grouping.bounds[k] = proposal;
                improved += before - after;
                accepted += 1;
            }
        }
        if improved <= eps {
            stale += 1;
            if stale >= patience {
                break;
            }
        } else {
            stale = 0;
        }
    }
    accepted
}

#[allow(clippy::too_many_arguments)]
pub fn solve(
    sorted_mags: &[f32],
    prefix: &Prefix,
    max_groups: usize,
    bins: usize,
    range: usize,
    max_iters: usize,
    patience: usize,
    params: &CostParams,
) -> Grouping {
    assert!(!sorted_mags.is_empty(), "empty instance");
    let initial = equal_range_bounds(sorted_mags, bins.max(1));
    let mut g = greedy_merge(prefix, initial, max_groups, params);
    // deterministic seed derived from the instance (solver stays a pure
    // function of its inputs)
    let mut rng = Rng::new(0xA11CE ^ ((sorted_mags.len() as u64) << 8));
    local_optimize(&mut g, prefix, params, range, max_iters, patience, &mut rng);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msb::objective::SortedMags;
    use crate::msb::wgm;

    #[test]
    fn equal_range_respects_bins() {
        let mags: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let g = equal_range_bounds(&mags, 10);
        assert!(g.num_groups() <= 10);
        assert_eq!(g.n(), 100);
        // uniform data => roughly balanced bins
        for (i, j) in g.intervals() {
            assert!(j - i >= 5, "{:?}", g.bounds);
        }
    }

    #[test]
    fn equal_range_constant_input() {
        let mags = vec![2.5f32; 64];
        let g = equal_range_bounds(&mags, 8);
        assert_eq!(g.num_groups(), 1);
    }

    #[test]
    fn equal_range_skewed_input_unbalanced() {
        // heavy skew: most mass in the lowest bin (the paper's motivation
        // for the post-merge local search)
        let mut mags: Vec<f32> = (0..990).map(|i| i as f32 * 1e-4).collect();
        mags.extend((0..10).map(|i| 10.0 + i as f32));
        let g = equal_range_bounds(&mags, 16);
        let sizes: Vec<usize> = g.intervals().map(|(i, j)| j - i).collect();
        assert!(sizes[0] > 900, "{sizes:?}");
    }

    #[test]
    fn local_opt_only_improves() {
        let mut rng = crate::stats::Rng::new(3);
        let vals: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        let sm = SortedMags::from_values(&vals);
        let p = Prefix::new(&sm.mags);
        let params = CostParams::unnormalized(0.1);
        // deliberately bad grouping: uniform windows
        let mut g = wgm::window_bounds(sm.mags.len(), 61);
        let before = g.cost(&p, &params);
        local_optimize(&mut g, &p, &params, 8, 50, 5, &mut rng);
        let after = g.cost(&p, &params);
        assert!(after <= before);
        g.validate();
    }

    #[test]
    fn solve_beats_or_matches_plain_merge_from_bins() {
        crate::testing::check(
            "wgm-lo local search helps",
            10,
            |rng| {
                let n = 256 + rng.below(512);
                let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                vals
            },
            |vals| {
                let sm = SortedMags::from_values(vals);
                let p = Prefix::new(&sm.mags);
                let params = CostParams::unnormalized(0.0);
                let bins = equal_range_bounds(&sm.mags, 64);
                let plain = greedy_merge(&p, bins, 8, &params).sse(&p);
                let lo = solve(&sm.mags, &p, 8, 64, 16, 30, 4, &params).sse(&p);
                lo <= plain + 1e-9
            },
        );
    }

    #[test]
    fn solve_valid_partition() {
        let mut rng = crate::stats::Rng::new(23);
        let vals: Vec<f32> = (0..2000).map(|_| rng.normal() as f32).collect();
        let sm = SortedMags::from_values(&vals);
        let p = Prefix::new(&sm.mags);
        let g = solve(&sm.mags, &p, 32, 256, 16, 12, 3, &CostParams::unnormalized(0.75));
        g.validate();
        assert!(g.num_groups() <= 32);
        assert_eq!(g.n(), sm.mags.len());
    }

    #[test]
    fn deterministic() {
        let mut rng = crate::stats::Rng::new(29);
        let vals: Vec<f32> = (0..800).map(|_| rng.normal() as f32).collect();
        let sm = SortedMags::from_values(&vals);
        let p = Prefix::new(&sm.mags);
        let params = CostParams::unnormalized(0.2);
        let a = solve(&sm.mags, &p, 16, 128, 8, 12, 3, &params);
        let b = solve(&sm.mags, &p, 16, 128, 8, 12, 3, &params);
        assert_eq!(a, b);
    }
}
