//! The grouping objective: sorted magnitudes, prefix sums, and the O(1)
//! interval cost `|A_i|·Var(|A_i|) + λ/|A_i|` (eq. 2) / its §3.4 normalized
//! form. All solvers consume this module.

/// Non-zero magnitudes sorted ascending, with the permutation back to the
/// original positions, and the positions of exact zeros (the paper's
/// zero-loss special group).
#[derive(Clone, Debug, Default)]
pub struct SortedMags {
    /// |values| of non-zero entries, ascending.
    pub mags: Vec<f32>,
    /// `order[i]` = original index of sorted position `i`.
    pub order: Vec<u32>,
    /// Original indices of exact zeros.
    pub zeros: Vec<u32>,
    /// scratch: (magnitude bit pattern, original index) pairs. Magnitudes
    /// are non-negative, so the IEEE-754 bit pattern is order-isomorphic to
    /// the float — we sort u32 keys (and radix-sort large instances).
    pairs: Vec<(u32, u32)>,
    /// radix scratch
    radix_tmp: Vec<(u32, u32)>,
}

/// Above this size, LSD radix sort beats the comparison sort (§Perf).
const RADIX_MIN: usize = 1 << 14;

impl SortedMags {
    pub fn from_values(values: &[f32]) -> Self {
        let mut sm = SortedMags::default();
        sm.rebuild(values);
        sm
    }

    /// Re-fill from `values`, reusing all internal buffers (the block-wise
    /// hot path calls this once per 64-element block).
    pub fn rebuild(&mut self, values: &[f32]) {
        assert!(values.len() < u32::MAX as usize);
        self.pairs.clear();
        self.zeros.clear();
        for (i, &v) in values.iter().enumerate() {
            if v == 0.0 {
                self.zeros.push(i as u32);
            } else {
                self.pairs.push((v.abs().to_bits(), i as u32));
            }
        }
        if self.pairs.len() >= RADIX_MIN {
            radix_sort_pairs(&mut self.pairs, &mut self.radix_tmp);
        } else {
            // stable: preserves original order among exact duplicates
            self.pairs.sort_by_key(|p| p.0);
        }
        self.mags.clear();
        self.order.clear();
        self.mags.extend(self.pairs.iter().map(|p| f32::from_bits(p.0)));
        self.order.extend(self.pairs.iter().map(|p| p.1));
    }

    pub fn len(&self) -> usize {
        self.mags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mags.is_empty()
    }
}

/// Stable LSD radix sort on the u32 key (4 passes, 256 buckets).
fn radix_sort_pairs(pairs: &mut Vec<(u32, u32)>, tmp: &mut Vec<(u32, u32)>) {
    let n = pairs.len();
    tmp.clear();
    tmp.resize(n, (0, 0));
    let mut src_is_pairs = true;
    for pass in 0..4 {
        let shift = pass * 8;
        let (src, dst): (&[(u32, u32)], &mut [(u32, u32)]) = if src_is_pairs {
            (&pairs[..], &mut tmp[..])
        } else {
            (&tmp[..], &mut pairs[..])
        };
        let mut counts = [0usize; 256];
        for &(k, _) in src {
            counts[((k >> shift) & 0xFF) as usize] += 1;
        }
        let mut offsets = [0usize; 256];
        let mut acc = 0;
        for (o, &c) in offsets.iter_mut().zip(&counts) {
            *o = acc;
            acc += c;
        }
        for &p in src {
            let b = ((p.0 >> shift) & 0xFF) as usize;
            dst[offsets[b]] = p;
            offsets[b] += 1;
        }
        src_is_pairs = !src_is_pairs;
    }
    // 4 passes => data ends back in `pairs`
    debug_assert!(src_is_pairs);
}

/// Objective parameters shared by all solvers.
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    pub lambda: f64,
    /// §3.4: scale the variance term by |A_i|/|A|.
    pub normalized: bool,
    /// |A| — total non-zero count (used by the normalized form).
    pub total: usize,
}

impl CostParams {
    pub fn unnormalized(lambda: f64) -> Self {
        CostParams { lambda, normalized: false, total: 0 }
    }
}

/// Prefix sums of sorted magnitudes and their squares (f64 accumulation —
/// catastrophic cancellation in `s2 - s1²/n` is the classic failure here).
#[derive(Clone, Debug, Default)]
pub struct Prefix {
    pub s1: Vec<f64>,
    pub s2: Vec<f64>,
}

impl Prefix {
    pub fn new(sorted_mags: &[f32]) -> Self {
        let mut p = Prefix::default();
        p.rebuild(sorted_mags);
        p
    }

    /// Re-fill from a sorted magnitude slice, reusing the buffers.
    pub fn rebuild(&mut self, sorted_mags: &[f32]) {
        self.s1.clear();
        self.s2.clear();
        self.s1.reserve(sorted_mags.len() + 1);
        self.s2.reserve(sorted_mags.len() + 1);
        self.s1.push(0.0);
        self.s2.push(0.0);
        let (mut a1, mut a2) = (0.0f64, 0.0f64);
        for &m in sorted_mags {
            let m = m as f64;
            a1 += m;
            a2 += m * m;
            self.s1.push(a1);
            self.s2.push(a2);
        }
    }

    pub fn len(&self) -> usize {
        self.s1.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mean magnitude of interval [i, j) — the group's optimal scale α*.
    #[inline]
    pub fn mean(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < j && j <= self.len());
        (self.s1[j] - self.s1[i]) / (j - i) as f64
    }

    /// `|A_i|·Var` of interval [i, j): Σx² − (Σx)²/n. Clamped at 0 (float
    /// noise on constant intervals can go slightly negative).
    #[inline]
    pub fn sse(&self, i: usize, j: usize) -> f64 {
        let n = (j - i) as f64;
        let d1 = self.s1[j] - self.s1[i];
        let d2 = self.s2[j] - self.s2[i];
        (d2 - d1 * d1 / n).max(0.0)
    }

    /// Full interval cost under `params` (eq. 2 or the §3.4 normalized form).
    #[inline]
    pub fn cost(&self, i: usize, j: usize, p: &CostParams) -> f64 {
        let var_term = if p.normalized {
            debug_assert!(p.total > 0);
            self.sse(i, j) / p.total as f64
        } else {
            self.sse(i, j)
        };
        var_term + p.lambda / (j - i) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn sorting_and_zeros() {
        let sm = SortedMags::from_values(&[-2.0, 0.0, 1.0, -0.5, 0.0]);
        assert_eq!(sm.mags, vec![0.5, 1.0, 2.0]);
        assert_eq!(sm.order, vec![3, 2, 0]);
        assert_eq!(sm.zeros, vec![1, 4]);
    }

    #[test]
    fn prefix_mean_matches_naive() {
        let mags = [0.5f32, 1.0, 2.0, 4.0];
        let p = Prefix::new(&mags);
        assert_close(p.mean(0, 4), 7.5 / 4.0, 1e-12, 0.0);
        assert_close(p.mean(1, 3), 1.5, 1e-12, 0.0);
    }

    #[test]
    fn sse_equals_xnor_identity() {
        // eq (1)/§3.2: ||A - α*B*||² = ||A||² − ||A||₁²/|A| for magnitudes
        let mags = [0.5f32, 1.0, 2.0, 4.0];
        let p = Prefix::new(&mags);
        let l1: f64 = mags.iter().map(|&x| x as f64).sum();
        let l2: f64 = mags.iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert_close(p.sse(0, 4), l2 - l1 * l1 / 4.0, 1e-12, 0.0);
    }

    #[test]
    fn sse_matches_direct_variance() {
        let mut rng = crate::stats::Rng::new(3);
        let mut mags: Vec<f32> = (0..200).map(|_| (rng.normal().abs() as f32) + 1e-6).collect();
        mags.sort_by(|a, b| a.total_cmp(b));
        let p = Prefix::new(&mags);
        for (i, j) in [(0, 200), (10, 30), (150, 151), (0, 1)] {
            let seg = &mags[i..j];
            let n = seg.len() as f64;
            let mean = seg.iter().map(|&x| x as f64).sum::<f64>() / n;
            let var = seg.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>();
            assert_close(p.sse(i, j), var, 1e-9, 1e-12);
        }
    }

    #[test]
    fn singleton_cost_is_pure_penalty() {
        let p = Prefix::new(&[1.0, 2.0, 3.0]);
        let params = CostParams::unnormalized(0.7);
        assert_close(p.cost(1, 2, &params), 0.7, 1e-12, 0.0);
    }

    #[test]
    fn normalized_cost_scales_variance() {
        let mags = [1.0f32, 3.0];
        let p = Prefix::new(&mags);
        let un = CostParams { lambda: 0.0, normalized: false, total: 2 };
        let no = CostParams { lambda: 0.0, normalized: true, total: 2 };
        assert_close(p.cost(0, 2, &no), p.cost(0, 2, &un) / 2.0, 1e-12, 0.0);
    }

    #[test]
    fn constant_interval_zero_variance() {
        let p = Prefix::new(&[2.0f32; 1000]);
        assert_eq!(p.sse(0, 1000), 0.0);
    }

    #[test]
    fn radix_matches_comparison_sort() {
        // force both paths over the same data and compare
        let mut rng = crate::stats::Rng::new(99);
        let n = super::RADIX_MIN + 137;
        let vals: Vec<f32> = (0..n)
            .map(|_| {
                let v = rng.normal() as f32;
                if rng.uniform() < 0.01 {
                    0.0
                } else {
                    v
                }
            })
            .collect();
        let big = SortedMags::from_values(&vals); // radix path
        // comparison path: chunk under threshold then merge manually
        let mut pairs: Vec<(f32, u32)> = vals
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, &v)| (v.abs(), i as u32))
            .collect();
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        assert_eq!(big.mags, pairs.iter().map(|p| p.0).collect::<Vec<_>>());
        assert_eq!(big.order, pairs.iter().map(|p| p.1).collect::<Vec<_>>());
    }

    #[test]
    fn rebuild_reuses_and_resets() {
        let mut sm = SortedMags::from_values(&[3.0, -1.0, 0.0]);
        assert_eq!(sm.mags, vec![1.0, 3.0]);
        sm.rebuild(&[0.5]);
        assert_eq!(sm.mags, vec![0.5]);
        assert_eq!(sm.order, vec![0]);
        assert!(sm.zeros.is_empty());
        let mut p = Prefix::new(&[1.0, 2.0]);
        p.rebuild(&[4.0]);
        assert_eq!(p.len(), 1);
        assert_eq!(p.mean(0, 1), 4.0);
    }

    #[test]
    fn nan_sorted_last() {
        let sm = SortedMags::from_values(&[1.0, f32::NAN, 0.5]);
        assert_eq!(sm.mags.len(), 3);
        assert!(sm.mags[2].is_nan());
    }
}
