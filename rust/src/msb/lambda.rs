//! λ boundary theory (paper Appendix C): fast estimates of the λ range that
//! spans "finest admissible partition" (λ_min) to "single group" (λ_max),
//! and the interpretable reparameterization λ = Λ(λ̃), λ̃ ∈ [0, 1].
//!
//! λ only *selects the group count* for Algorithm 1; GG/WGM take the group
//! count externally, which is why Tables 5/10 and Fig 6 find it inert — a
//! finding our benches reproduce.

/// Fast λ_min estimate: (|a₁| − |a₂|)² / 3n over the two smallest sorted
/// magnitudes (eq. 7).
pub fn lambda_min(sorted_mags: &[f32]) -> f64 {
    let n = sorted_mags.len();
    if n < 2 {
        return 0.0;
    }
    let d = (sorted_mags[0] as f64 - sorted_mags[1] as f64).abs();
    d * d / (3.0 * n as f64)
}

/// Fast λ_max estimate: n(μ₁ − μ₂)²/12 with the halves split at k = n/2
/// (Appendix C closing bound).
pub fn lambda_max(sorted_mags: &[f32]) -> f64 {
    let n = sorted_mags.len();
    if n < 2 {
        return 0.0;
    }
    let k = n / 2;
    let mean = |s: &[f32]| s.iter().map(|&x| x as f64).sum::<f64>() / s.len() as f64;
    let mu1 = mean(&sorted_mags[..k]);
    let mu2 = mean(&sorted_mags[k..]);
    let d = mu1 - mu2;
    n as f64 * d * d / 12.0
}

/// Λ(λ̃) = λ_min + λ̃ (λ_max − λ_min); the paper's default is λ̃ = 0.75.
pub fn lambda_of(tilde: f64, sorted_mags: &[f32]) -> f64 {
    let lo = lambda_min(sorted_mags);
    let hi = lambda_max(sorted_mags);
    lo + tilde.clamp(0.0, 1.0) * (hi - lo).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msb::objective::{CostParams, SortedMags};
    use crate::msb::{dg, Prefix};

    fn sorted(vals: &[f32]) -> Vec<f32> {
        SortedMags::from_values(vals).mags
    }

    #[test]
    fn bounds_ordered() {
        let mut rng = crate::stats::Rng::new(1);
        let vals: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        let mags = sorted(&vals);
        let (lo, hi) = (lambda_min(&mags), lambda_max(&mags));
        assert!(lo >= 0.0);
        assert!(hi > lo, "{lo} vs {hi}");
    }

    #[test]
    fn tilde_map_endpoints() {
        let mags = sorted(&[0.1, 0.2, 1.0, 5.0, 9.0, 9.5]);
        crate::testing::assert_close(lambda_of(0.0, &mags), lambda_min(&mags), 1e-12, 0.0);
        crate::testing::assert_close(lambda_of(1.0, &mags), lambda_max(&mags), 1e-12, 0.0);
        // clamping
        assert_eq!(lambda_of(-3.0, &mags), lambda_of(0.0, &mags));
        assert_eq!(lambda_of(7.0, &mags), lambda_of(1.0, &mags));
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(lambda_min(&[1.0]), 0.0);
        assert_eq!(lambda_max(&[]), 0.0);
        let constant = vec![2.0f32; 50];
        assert_eq!(lambda_max(&constant), 0.0);
    }

    #[test]
    fn above_lambda_max_dg_picks_one_group() {
        // the theory's purpose: λ >> λ_max must collapse DG to one group.
        // Appendix C derives the bound for the *normalized* objective (§3.4).
        let mut rng = crate::stats::Rng::new(5);
        let vals: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let mags = sorted(&vals);
        let p = Prefix::new(&mags);
        let params = CostParams {
            lambda: lambda_max(&mags) * 50.0,
            normalized: true,
            total: mags.len(),
        };
        let g = dg::solve(&p, 8, &params);
        assert_eq!(g.num_groups(), 1);
    }

    #[test]
    fn below_lambda_min_dg_prefers_fine_partitions() {
        let mut rng = crate::stats::Rng::new(6);
        let vals: Vec<f32> = (0..40).map(|_| rng.normal() as f32).collect();
        let mags = sorted(&vals);
        let p = Prefix::new(&mags);
        let tiny = lambda_min(&mags) * 1e-3;
        let g = dg::solve(&p, 8, &CostParams::unnormalized(tiny));
        assert_eq!(g.num_groups(), 8, "with negligible λ, use all capacity");
    }
}
