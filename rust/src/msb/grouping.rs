//! The [`Grouping`] type: a partition of the sorted magnitude sequence into
//! contiguous intervals, plus cost evaluation and invariants.

use super::objective::{CostParams, Prefix};

/// A partition of `n` sorted elements into `bounds.len()` contiguous
/// groups; `bounds[k]` is the *exclusive* end of group `k` (so
/// `bounds.last() == n` and bounds are strictly increasing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Grouping {
    pub bounds: Vec<usize>,
}

impl Grouping {
    pub fn new(bounds: Vec<usize>) -> Self {
        let g = Grouping { bounds };
        g.validate();
        g
    }

    /// Single group covering everything.
    pub fn whole(n: usize) -> Self {
        Grouping::new(vec![n])
    }

    pub fn validate(&self) {
        assert!(!self.bounds.is_empty(), "empty grouping");
        let mut prev = 0;
        for &b in &self.bounds {
            assert!(b > prev, "non-increasing bound {b} after {prev}");
            prev = b;
        }
    }

    pub fn num_groups(&self) -> usize {
        self.bounds.len()
    }

    pub fn n(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// (start, end) of group `k`.
    pub fn interval(&self, k: usize) -> (usize, usize) {
        let start = if k == 0 { 0 } else { self.bounds[k - 1] };
        (start, self.bounds[k])
    }

    pub fn intervals(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.num_groups()).map(|k| self.interval(k))
    }

    /// Group index of sorted position `pos` (binary search).
    pub fn group_of(&self, pos: usize) -> usize {
        debug_assert!(pos < self.n());
        self.bounds.partition_point(|&b| b <= pos)
    }

    /// Total objective value under `params` — the paper's `cost(G)`.
    pub fn cost(&self, prefix: &Prefix, params: &CostParams) -> f64 {
        self.intervals().map(|(i, j)| prefix.cost(i, j, params)).sum()
    }

    /// Pure reconstruction SSE (λ-independent): Σ |A_i|·Var.
    pub fn sse(&self, prefix: &Prefix) -> f64 {
        self.intervals().map(|(i, j)| prefix.sse(i, j)).sum()
    }

    /// Per-group optimal scales (mean magnitude), in sorted-group order —
    /// ascending by construction.
    pub fn scales(&self, prefix: &Prefix) -> Vec<f64> {
        self.intervals().map(|(i, j)| prefix.mean(i, j)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msb::objective::SortedMags;

    #[test]
    fn intervals_and_group_of() {
        let g = Grouping::new(vec![2, 5, 9]);
        assert_eq!(g.num_groups(), 3);
        assert_eq!(g.n(), 9);
        assert_eq!(g.interval(0), (0, 2));
        assert_eq!(g.interval(2), (5, 9));
        assert_eq!(g.group_of(0), 0);
        assert_eq!(g.group_of(1), 0);
        assert_eq!(g.group_of(2), 1);
        assert_eq!(g.group_of(8), 2);
    }

    #[test]
    #[should_panic]
    fn rejects_non_increasing() {
        Grouping::new(vec![3, 3, 5]);
    }

    #[test]
    fn cost_decomposes() {
        let mags = [0.1f32, 0.2, 1.0, 1.1, 5.0];
        let p = Prefix::new(&mags);
        let params = CostParams::unnormalized(0.5);
        let g = Grouping::new(vec![2, 4, 5]);
        let manual = p.cost(0, 2, &params) + p.cost(2, 4, &params) + p.cost(4, 5, &params);
        assert_eq!(g.cost(&p, &params), manual);
    }

    #[test]
    fn scales_ascending() {
        let sm = SortedMags::from_values(&[-0.1, 0.2, -1.0, 1.1, 5.0]);
        let p = Prefix::new(&sm.mags);
        let g = Grouping::new(vec![2, 4, 5]);
        let s = g.scales(&p);
        assert!(s.windows(2).all(|w| w[0] <= w[1]), "{s:?}");
    }

    #[test]
    fn group_of_consistent_with_intervals() {
        crate::testing::check(
            "group_of vs intervals",
            30,
            |rng| {
                let n = 1 + rng.below(200);
                let mut cuts: Vec<usize> = (1..n).filter(|_| rng.uniform() < 0.2).collect();
                cuts.push(n);
                cuts.dedup();
                Grouping::new(cuts)
            },
            |g| {
                g.intervals().enumerate().all(|(k, (i, j))| {
                    (i..j).all(|pos| g.group_of(pos) == k)
                })
            },
        );
    }
}
