//! The MSB codebook: group scales α_z + per-element sign/level codes.
//! `ŵ = sign(w) · α_{level(w)}` — a symmetric 2·L-level codebook with a
//! binary sign structure (paper §4.1). Level 0 is reserved for exact zeros
//! (kept as bf16 zeros, zero-loss special group).

use super::grouping::Grouping;
use super::objective::{Prefix, SortedMags};

#[derive(Clone, Debug, PartialEq)]
pub struct MsbCode {
    /// Number of original elements.
    pub n: usize,
    /// Ascending positive scales, one per group.
    pub levels: Vec<f32>,
    /// Per element: 0 = exact zero, else `sign · level_index_plus_one`
    /// (i16 so per-tensor settings with hundreds of groups fit).
    pub codes: Vec<i16>,
}

impl MsbCode {
    /// Assemble from the original values, their sorted view and a grouping
    /// of the sorted magnitudes.
    pub fn build(values: &[f32], sm: &SortedMags, grouping: &Grouping) -> Self {
        let prefix = Prefix::new(&sm.mags);
        Self::build_with_prefix(values, sm, grouping, &prefix)
    }

    /// Like [`MsbCode::build`], reusing an existing prefix-sum table
    /// (§Perf: avoids the second O(n) pass and assigns codes by interval
    /// iteration instead of per-element binary search).
    pub fn build_with_prefix(
        values: &[f32],
        sm: &SortedMags,
        grouping: &Grouping,
        prefix: &Prefix,
    ) -> Self {
        assert_eq!(sm.mags.len() + sm.zeros.len(), values.len());
        assert!(grouping.num_groups() <= i16::MAX as usize);
        let levels: Vec<f32> = grouping.scales(prefix).iter().map(|&s| s as f32).collect();
        let mut codes = vec![0i16; values.len()];
        for (k, (s, e)) in grouping.intervals().enumerate() {
            let lvl = k as i16 + 1;
            for &orig in &sm.order[s..e] {
                let orig = orig as usize;
                codes[orig] = if values[orig] < 0.0 { -lvl } else { lvl };
            }
        }
        MsbCode { n: values.len(), levels, codes }
    }

    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    /// Effective bit-width of the sign+level code: 1 sign bit + ⌈log2 L⌉.
    pub fn code_bits(&self) -> u32 {
        1 + (self.num_levels().max(1) as f64).log2().ceil() as u32
    }

    /// Decode all elements back to f32.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n];
        self.dequantize_into(&mut out);
        out
    }

    /// Decode into a caller-provided buffer (hot path for block-wise
    /// whole-matrix reconstruction).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.n);
        for (o, &c) in out.iter_mut().zip(&self.codes) {
            *o = if c == 0 {
                0.0
            } else {
                let level = (c.unsigned_abs() as usize) - 1;
                let mag = self.levels[level];
                if c < 0 {
                    -mag
                } else {
                    mag
                }
            };
        }
    }

    /// Total squared reconstruction error against the original values.
    pub fn sse(&self, values: &[f32]) -> f64 {
        assert_eq!(values.len(), self.n);
        let mut acc = 0.0f64;
        for (&v, &c) in values.iter().zip(&self.codes) {
            let w = if c == 0 {
                0.0f32
            } else {
                let mag = self.levels[(c.unsigned_abs() as usize) - 1];
                if c < 0 {
                    -mag
                } else {
                    mag
                }
            };
            let d = (v - w) as f64;
            acc += d * d;
        }
        acc
    }

    /// Export as int8 codes for the L1 Pallas kernel (requires ≤ 127
    /// levels; block-wise 4-bit uses 8).
    pub fn codes_i8(&self) -> Option<Vec<i8>> {
        if self.num_levels() > 127 {
            return None;
        }
        Some(self.codes.iter().map(|&c| c as i8).collect())
    }

    /// Levels padded/truncated to exactly `l` entries (kernel ABI wants a
    /// fixed 2^{b-1} table; unused entries repeat the top scale).
    pub fn levels_padded(&self, l: usize) -> Vec<f32> {
        let mut v = self.levels.clone();
        let last = v.last().copied().unwrap_or(0.0);
        v.resize(l, last);
        v.truncate(l);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msb::{Algo, Solver};

    #[test]
    fn roundtrip_structure() {
        let vals = [-4.0f32, -1.0, 0.0, 1.2, 3.9, 4.1];
        let code = Solver::new(Algo::Gg).quantize(&vals, 2);
        assert_eq!(code.n, 6);
        assert!(code.num_levels() <= 2);
        let deq = code.dequantize();
        // zero preserved, signs preserved, magnitudes are level values
        assert_eq!(deq[2], 0.0);
        for (v, d) in vals.iter().zip(&deq) {
            if *v != 0.0 {
                assert_eq!(v.signum(), d.signum());
                assert!(code.levels.contains(&d.abs()));
            }
        }
    }

    #[test]
    fn sse_matches_dequant_sse() {
        let mut rng = crate::stats::Rng::new(3);
        let vals: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        let code = Solver::new(Algo::Wgm { window: 4 }).quantize(&vals, 8);
        let deq = code.dequantize();
        let direct = crate::stats::sse(&vals, &deq);
        crate::testing::assert_close(code.sse(&vals), direct, 1e-9, 1e-12);
    }

    #[test]
    fn single_level_is_xnor() {
        // one group == XNOR: scale = mean |w|
        let vals = [1.0f32, -2.0, 3.0, -4.0];
        let code = Solver::new(Algo::Gg).quantize(&vals, 1);
        assert_eq!(code.num_levels(), 1);
        crate::testing::assert_close(code.levels[0] as f64, 2.5, 1e-6, 0.0);
    }

    #[test]
    fn code_bits() {
        let vals: Vec<f32> = (1..=64).map(|i| i as f32).collect();
        for (g, bits) in [(1usize, 1u32), (2, 2), (8, 4), (32, 6)] {
            let code = Solver::new(Algo::Gg).quantize(&vals, g);
            if code.num_levels() == g {
                assert_eq!(code.code_bits(), bits, "g={g}");
            }
        }
    }

    #[test]
    fn i8_export_bounds() {
        let vals: Vec<f32> = (1..=300).map(|i| i as f32).collect();
        let small = Solver::new(Algo::Gg).quantize(&vals, 8);
        assert!(small.codes_i8().is_some());
        let big = Solver::new(Algo::Wgm { window: 1 }).quantize(&vals, 300);
        if big.num_levels() > 127 {
            assert!(big.codes_i8().is_none());
        }
    }

    #[test]
    fn levels_padded() {
        let vals = [1.0f32, 2.0];
        let code = Solver::new(Algo::Gg).quantize(&vals, 2);
        let padded = code.levels_padded(8);
        assert_eq!(padded.len(), 8);
        assert_eq!(padded[7], *code.levels.last().unwrap());
    }

    #[test]
    fn all_zero_input() {
        let vals = [0.0f32; 16];
        let sm = SortedMags::from_values(&vals);
        assert!(sm.is_empty());
        // a degenerate grouping is not buildable from an empty sort — the
        // quantizer layer handles this by emitting a pure-zero code
        assert_eq!(sm.zeros.len(), 16);
    }

    #[test]
    fn monotone_improvement_with_levels() {
        let mut rng = crate::stats::Rng::new(7);
        let vals: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let mut last = f64::INFINITY;
        for g in [1usize, 2, 4, 8, 16, 32] {
            let code = Solver::new(Algo::Gg).quantize(&vals, g);
            let sse = code.sse(&vals);
            assert!(sse <= last + 1e-9, "g={g}: {sse} > {last}");
            last = sse;
        }
    }
}
