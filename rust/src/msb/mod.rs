//! The paper's contribution: the MSB (Multi-Scale Binary) objective and its
//! four dynamic-grouping solvers.
//!
//! Pipeline (§3): take the non-zero *magnitudes* of a weight tensor, sort
//! them ascending (optimal variance-minimizing partitions are contiguous in
//! sorted order), then partition the sorted sequence into at most
//! `max_groups` intervals minimizing
//!
//! ```text
//! cost(G) = Σ_i |A_i|·Var(|A_i|) + λ/|A_i|          (eq. 2, unnormalized)
//! cost(G) = Σ_i |A_i|/|A|·Var(|A_i|) + λ/|A_i|      (§3.4, normalized)
//! ```
//!
//! Each group's optimal scale is its mean magnitude (XNOR closed form per
//! group); a weight decodes as `ŵ = sign(w)·α_{group(w)}` — a symmetric
//! `2·g`-level codebook with binary sign structure. Exact zeros go to a
//! zero-loss special group (§3.2).
//!
//! Solvers:
//! * [`dg`] — Algorithm 1, exact dynamic programming (oracle).
//! * [`gg`] — Algorithm 2, greedy merging from singletons.
//! * [`wgm`] — Algorithm 3, windowed greedy merging.
//! * [`wgm_lo`] — Algorithm 4, equal-range binning + stochastic local search.

pub mod codebook;
pub mod dg;
pub mod gg;
pub mod grouping;
pub mod lambda;
pub mod objective;
pub mod wgm;
pub mod wgm_lo;

pub use codebook::MsbCode;
pub use grouping::Grouping;
pub use objective::{CostParams, Prefix, SortedMags};

/// Which solver to run, with its hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub enum Algo {
    /// Algorithm 1: exact DP oracle. O(g·n²) — small instances only.
    Dg,
    /// Algorithm 2: greedy merging from singleton groups.
    Gg,
    /// Algorithm 3: greedy merging from `window`-sized initial groups.
    Wgm { window: usize },
    /// Algorithm 4: equal-range binning into `bins` initial groups, greedy
    /// merge, then stochastic local boundary optimization.
    WgmLo { bins: usize, range: usize, max_iters: usize, patience: usize },
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Dg => "dg",
            Algo::Gg => "gg",
            Algo::Wgm { .. } => "wgm",
            Algo::WgmLo { .. } => "wgm-lo",
        }
    }
}

/// A configured solver: algorithm + objective parameters.
#[derive(Clone, Debug)]
pub struct Solver {
    pub algo: Algo,
    /// λ regularization weight (paper default: λ̃ = 0.75 through the Λ map,
    /// but Table 5 shows insensitivity; we expose the raw value).
    pub lambda: f64,
    /// Use the §3.4 group-mass-normalized variance term.
    pub normalized: bool,
}

impl Solver {
    pub fn new(algo: Algo) -> Self {
        Solver { algo, lambda: 0.0, normalized: false }
    }

    pub fn with_lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }

    pub fn normalized(mut self) -> Self {
        self.normalized = true;
        self
    }

    /// Partition the (sorted) magnitudes into at most `max_groups` groups.
    pub fn solve_sorted(&self, sm: &SortedMags, max_groups: usize) -> Grouping {
        let prefix = Prefix::new(&sm.mags);
        self.solve_with_prefix(sm, &prefix, max_groups)
    }

    /// [`Solver::solve_sorted`] with a caller-provided prefix table (§Perf).
    ///
    /// λ handling follows Appendix C to the letter: "λ is originally
    /// introduced to determine the optimal number of groups in Algorithm 1,
    /// whereas in other algorithms the number of groups is treated as a
    /// user-defined hyperparameter, rendering it *inapplicable*" — so the
    /// greedy solvers (GG/WGM/WGM-LO) optimize pure within-group variance
    /// and only DG sees the penalty. (Folding λ into the greedy merge
    /// deltas measurably corrupts merge order on small blocks: it rewards
    /// merging small groups regardless of variance.)
    pub fn solve_with_prefix(
        &self,
        sm: &SortedMags,
        prefix: &Prefix,
        max_groups: usize,
    ) -> Grouping {
        let lambda = if matches!(self.algo, Algo::Dg) { self.lambda } else { 0.0 };
        let params = CostParams {
            lambda,
            normalized: self.normalized,
            total: sm.mags.len(),
        };
        match &self.algo {
            Algo::Dg => dg::solve(prefix, max_groups, &params),
            Algo::Gg => gg::solve(prefix, max_groups, &params),
            Algo::Wgm { window } => wgm::solve(prefix, max_groups, *window, &params),
            Algo::WgmLo { bins, range, max_iters, patience } => wgm_lo::solve(
                &sm.mags, prefix, max_groups, *bins, *range, *max_iters, *patience, &params,
            ),
        }
    }

    /// Full quantization of a value slice: sort, group, build the codebook.
    pub fn quantize(&self, values: &[f32], max_groups: usize) -> MsbCode {
        let sm = SortedMags::from_values(values);
        let prefix = Prefix::new(&sm.mags);
        let grouping = self.solve_with_prefix(&sm, &prefix, max_groups);
        MsbCode::build_with_prefix(values, &sm, &grouping, &prefix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names() {
        assert_eq!(Algo::Dg.name(), "dg");
        assert_eq!(Algo::Wgm { window: 4 }.name(), "wgm");
    }

    #[test]
    fn solver_end_to_end_small() {
        let vals = [-3.0f32, -1.0, 0.0, 1.1, 2.9, 3.1];
        for algo in [Algo::Dg, Algo::Gg, Algo::Wgm { window: 1 }] {
            let code = Solver::new(algo).quantize(&vals, 2);
            let deq = code.dequantize();
            assert_eq!(deq.len(), vals.len());
            assert_eq!(deq[2], 0.0, "exact zero preserved");
            // signs preserved
            for (v, d) in vals.iter().zip(&deq) {
                if *v != 0.0 {
                    assert_eq!(v.signum(), d.signum());
                }
            }
        }
    }
}
