//! Algorithm 3 — Windowed Greedy Merging (paper §3.3.3): instead of mn
//! singleton groups, start from mn/k windows of k consecutive sorted
//! elements, then greedy-merge. Coarsening the initial decisions trades a
//! little accuracy for an O(k) reduction in heap traffic — the paper's
//! production solver (w=64 per-tensor, w=1 block-wise).

use super::gg::greedy_merge;
use super::grouping::Grouping;
use super::objective::{CostParams, Prefix};

/// Window partition of `n` sorted elements: ceil(n/k) groups of `k` (last
/// one ragged).
pub fn window_bounds(n: usize, k: usize) -> Grouping {
    assert!(n > 0 && k > 0);
    let mut bounds = Vec::with_capacity(n.div_ceil(k));
    let mut b = k;
    while b < n {
        bounds.push(b);
        b += k;
    }
    bounds.push(n);
    Grouping::new(bounds)
}

pub fn solve(prefix: &Prefix, max_groups: usize, window: usize, params: &CostParams) -> Grouping {
    let n = prefix.len();
    assert!(n > 0, "empty instance");
    let initial = window_bounds(n, window.max(1));
    greedy_merge(prefix, initial, max_groups, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msb::{gg, objective::SortedMags};

    #[test]
    fn window_bounds_cover() {
        let g = window_bounds(10, 3);
        assert_eq!(g.bounds, vec![3, 6, 9, 10]);
        let g1 = window_bounds(9, 3);
        assert_eq!(g1.bounds, vec![3, 6, 9]);
        let g2 = window_bounds(5, 10);
        assert_eq!(g2.bounds, vec![5]);
    }

    #[test]
    fn window_one_equals_gg() {
        let mut rng = crate::stats::Rng::new(7);
        let vals: Vec<f32> = (0..300).map(|_| rng.normal() as f32).collect();
        let sm = SortedMags::from_values(&vals);
        let p = Prefix::new(&sm.mags);
        let params = CostParams::unnormalized(0.0);
        let a = solve(&p, 8, 1, &params);
        let b = gg::solve(&p, 8, &params);
        assert_eq!(a, b);
    }

    #[test]
    fn window_n_degenerates_to_xnor() {
        // window >= n: a single initial group => standard XNOR (Fig 2's
        // convergence artifact, reproduced deliberately)
        let vals: Vec<f32> = (1..=64).map(|i| i as f32).collect();
        let sm = SortedMags::from_values(&vals);
        let p = Prefix::new(&sm.mags);
        let g = solve(&p, 8, 64, &CostParams::unnormalized(0.0));
        assert_eq!(g.num_groups(), 1);
    }

    #[test]
    fn larger_window_never_beats_smaller_on_sse() {
        crate::testing::check(
            "wgm sse monotone-ish in window",
            15,
            |rng| {
                let n = 64 + rng.below(512);
                let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                vals
            },
            |vals| {
                let sm = SortedMags::from_values(vals);
                let p = Prefix::new(&sm.mags);
                let params = CostParams::unnormalized(0.0);
                let fine = solve(&p, 8, 1, &params).sse(&p);
                let coarse = solve(&p, 8, 32, &params).sse(&p);
                // coarse initialization can only restrict the search space
                fine <= coarse + 1e-6 * (1.0 + coarse)
            },
        );
    }

    #[test]
    fn respects_max_groups() {
        let mut rng = crate::stats::Rng::new(11);
        let vals: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let sm = SortedMags::from_values(&vals);
        let p = Prefix::new(&sm.mags);
        for (g_target, w) in [(8usize, 4usize), (32, 16), (256, 2)] {
            let g = solve(&p, g_target, w, &CostParams::unnormalized(0.5));
            assert!(g.num_groups() <= g_target);
            g.validate();
        }
    }
}
