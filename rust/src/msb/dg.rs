//! Algorithm 1 — Dynamic Grouping: exact DP oracle over contiguous
//! partitions of the sorted magnitudes (paper §3.3.1).
//!
//! `dp[k][j]` = min cost of splitting the first `j` sorted elements into
//! exactly `k` groups; recurrence `dp[k][j] = min_i dp[k-1][i] + f([i:j])`
//! with `f` the O(1) prefix-sum interval cost. The answer minimizes over
//! `k ≤ max_groups` (λ's 1/|A_i| penalty is what makes fewer groups win
//! when variance permits). O(max_groups · n²) time, O(max_groups · n)
//! memory — an oracle for small instances (Table 4), not a production path.

use super::grouping::Grouping;
use super::objective::{CostParams, Prefix};

pub fn solve(prefix: &Prefix, max_groups: usize, params: &CostParams) -> Grouping {
    let n = prefix.len();
    assert!(n > 0, "empty instance");
    let g_max = max_groups.min(n).max(1);

    // dp rows: previous and current k; split[k][j] = argmin i
    let mut prev = vec![f64::INFINITY; n + 1];
    let mut curr = vec![f64::INFINITY; n + 1];
    let mut split = vec![vec![0u32; n + 1]; g_max + 1];

    // k = 1: one group [0, j)
    for j in 1..=n {
        prev[j] = prefix.cost(0, j, params);
    }

    let mut best_cost = prev[n];
    let mut best_k = 1usize;

    for k in 2..=g_max {
        curr[0] = f64::INFINITY;
        for j in 1..=n {
            // j elements into k groups needs j >= k
            if j < k {
                curr[j] = f64::INFINITY;
                continue;
            }
            let mut best = f64::INFINITY;
            let mut arg = k - 1;
            // last group is [i, j); i ranges over [k-1, j)
            for i in (k - 1)..j {
                let c = prev[i] + prefix.cost(i, j, params);
                if c < best {
                    best = c;
                    arg = i;
                }
            }
            curr[j] = best;
            split[k][j] = arg as u32;
        }
        if curr[n] < best_cost {
            best_cost = curr[n];
            best_k = k;
        }
        std::mem::swap(&mut prev, &mut curr);
    }

    // backtrack from (best_k, n)
    let mut bounds = vec![0usize; best_k];
    let mut j = n;
    for k in (1..=best_k).rev() {
        bounds[k - 1] = j;
        j = if k >= 2 { split[k][j] as usize } else { 0 };
    }
    Grouping::new(bounds)
}

/// Exact DP with the group count *fixed* to exactly `groups` (when
/// feasible). Used by Table 4 to compare against WGM at identical bits.
pub fn solve_exact_groups(prefix: &Prefix, groups: usize, params: &CostParams) -> Grouping {
    let n = prefix.len();
    let g = groups.min(n).max(1);
    let mut prev = vec![f64::INFINITY; n + 1];
    let mut curr = vec![f64::INFINITY; n + 1];
    let mut split = vec![vec![0u32; n + 1]; g + 1];
    for j in 1..=n {
        prev[j] = prefix.cost(0, j, params);
    }
    for k in 2..=g {
        curr.fill(f64::INFINITY);
        for j in k..=n {
            let mut best = f64::INFINITY;
            let mut arg = k - 1;
            for i in (k - 1)..j {
                let c = prev[i] + prefix.cost(i, j, params);
                if c < best {
                    best = c;
                    arg = i;
                }
            }
            curr[j] = best;
            split[k][j] = arg as u32;
        }
        std::mem::swap(&mut prev, &mut curr);
    }
    let mut bounds = vec![0usize; g];
    let mut j = n;
    for k in (1..=g).rev() {
        bounds[k - 1] = j;
        j = if k >= 2 { split[k][j] as usize } else { 0 };
    }
    Grouping::new(bounds)
}

/// Brute-force optimum by enumerating *all* contiguous partitions with
/// ≤ max_groups groups. Exponential; test-only ground truth.
#[doc(hidden)]
pub fn brute_force(prefix: &Prefix, max_groups: usize, params: &CostParams) -> (f64, Grouping) {
    let n = prefix.len();
    let mut best = (f64::INFINITY, Grouping::whole(n));
    // enumerate cut masks over n-1 positions
    assert!(n <= 16, "brute force limited to tiny instances");
    for mask in 0u32..(1 << (n - 1)) {
        if (mask.count_ones() as usize) + 1 > max_groups {
            continue;
        }
        let mut bounds = Vec::new();
        for pos in 1..n {
            if mask & (1 << (pos - 1)) != 0 {
                bounds.push(pos);
            }
        }
        bounds.push(n);
        let g = Grouping::new(bounds);
        let c = g.cost(prefix, params);
        if c < best.0 {
            best = (c, g);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msb::objective::SortedMags;
    use crate::testing::{assert_close, hostile_magnitudes};

    fn prefix_of(values: &[f32]) -> (SortedMags, Prefix) {
        let sm = SortedMags::from_values(values);
        let p = Prefix::new(&sm.mags);
        (sm, p)
    }

    #[test]
    fn two_clusters_found() {
        let vals = [0.1f32, 0.11, 0.12, 5.0, 5.1, 5.2];
        let (_, p) = prefix_of(&vals);
        let params = CostParams::unnormalized(1e-4);
        let g = solve(&p, 4, &params);
        // λ tiny but group penalty still discourages singletons; the two
        // natural clusters should be split apart
        assert!(g.num_groups() >= 2);
        assert!(g.bounds.contains(&3), "{:?}", g.bounds);
    }

    #[test]
    fn matches_brute_force() {
        crate::testing::check(
            "dg == brute force",
            25,
            |rng| {
                let n = 2 + rng.below(9);
                let vals = hostile_magnitudes(rng, n);
                let lambda = rng.range_f64(0.0, 0.5);
                (vals, lambda)
            },
            |(vals, lambda)| {
                let sm = SortedMags::from_values(vals);
                if sm.mags.is_empty() {
                    return true;
                }
                let p = Prefix::new(&sm.mags);
                let params = CostParams::unnormalized(*lambda);
                let g = solve(&p, 4, &params);
                let (bc, _) = brute_force(&p, 4, &params);
                (g.cost(&p, &params) - bc).abs() <= 1e-9 * (1.0 + bc.abs())
            },
        );
    }

    #[test]
    fn large_lambda_forces_single_group() {
        let vals: Vec<f32> = (1..=50).map(|i| i as f32).collect();
        let (_, p) = prefix_of(&vals);
        let params = CostParams::unnormalized(1e9);
        let g = solve(&p, 8, &params);
        assert_eq!(g.num_groups(), 1);
    }

    #[test]
    fn zero_lambda_uses_all_groups_when_it_helps() {
        let vals = [1.0f32, 2.0, 4.0, 8.0];
        let (_, p) = prefix_of(&vals);
        let params = CostParams::unnormalized(0.0);
        let g = solve(&p, 4, &params);
        assert_eq!(g.num_groups(), 4); // singletons have zero variance
        assert_close(g.cost(&p, &params), 0.0, 0.0, 1e-12);
    }

    #[test]
    fn exact_groups_fixed_count() {
        let vals: Vec<f32> = (1..=20).map(|i| i as f32 * 0.3).collect();
        let (_, p) = prefix_of(&vals);
        let params = CostParams::unnormalized(0.0);
        for g_target in [1usize, 2, 3, 5, 20] {
            let g = solve_exact_groups(&p, g_target, &params);
            assert_eq!(g.num_groups(), g_target);
        }
    }

    #[test]
    fn exact_groups_monotone_sse() {
        // more groups can never increase the optimal SSE
        let mut rng = crate::stats::Rng::new(17);
        let vals: Vec<f32> = (0..60).map(|_| rng.normal().abs() as f32 + 1e-5).collect();
        let (_, p) = prefix_of(&vals);
        let params = CostParams::unnormalized(0.0);
        let mut last = f64::INFINITY;
        for k in 1..=8 {
            let g = solve_exact_groups(&p, k, &params);
            let sse = g.sse(&p);
            assert!(sse <= last + 1e-9, "k={k}: {sse} > {last}");
            last = sse;
        }
    }

    #[test]
    fn single_element() {
        let (_, p) = prefix_of(&[3.0]);
        let g = solve(&p, 4, &CostParams::unnormalized(0.1));
        assert_eq!(g.bounds, vec![1]);
    }
}
