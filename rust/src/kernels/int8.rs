//! Integer-MAC fast path: on-the-fly i8 activation quantization and the
//! i8·i8→i32 block kernels behind [`MacMode::Int8`](super::MacMode).
//!
//! The f32 fused path decodes every weight code to f32 before the
//! multiply. For methods whose decode is a pure affine map of the code —
//! `w = a·c + b` with per-block `(a, b)` derived from the stored scale
//! table (RTN sym/asym, HQQ, XNOR) — the multiply can stay integer:
//! quantize the activation to i8 with per-[`QBLOCK`]-element symmetric
//! scales at call time (calibration-free by construction: the scale is
//! `max|x|/127` of the live input block, never from held-out data),
//! accumulate `Σ c·x̂` (and `Σ x̂` when `b ≠ 0`) in i32 per
//! (weight-block × activation-block) pair, and apply
//! `(a·Σc·x̂ + b·Σx̂)·x_scale` once per pair into the f32 chunk-partial
//! chain the f32 path already uses.
//!
//! Determinism is inherited for free: i32 accumulation is exactly
//! associative, so the scalar loop, the AVX2 widening multiply-add
//! (`_mm256_madd_epi16` on sign-extended lanes — the `maddubs` shape
//! without its u8×i8 saturation hazard), and any row striping produce the
//! same integers; the f32 epilogue then executes one fixed expression per
//! block pair in chunk order. Scalar/AVX2/threads are bit-identical by
//! construction, not by tolerance.
//!
//! Accuracy: the path is approximate where the f32 path is exact — the
//! activation is rounded to 8 bits per block, and when the payload's
//! `bf16` flag is set the f32 path rounds each decoded *product*
//! `bf16(s·c)` while this path folds only the (already bf16-stored)
//! scales. Both effects are bounded by the documented relative-error
//! budget (`perf_gemv` gates the synthetic forward at ≤1e-2 of the f32
//! twin). Methods whose decode is a codebook or per-level gather (NF4,
//! MSB) have no affine form; [`affine_plan`] returns `None` and
//! `MacMode::Auto` keeps them on the f32 path per layer.

use super::Kernel;
use crate::quant::packing::PackedTensor;

/// Activation quantization block: matches the weight-tile [`CHUNK`]
/// (one paper block, t=64) so a weight sub-chunk never spans more than
/// two activation blocks and the splitter stays trivial.
///
/// [`CHUNK`]: super::CHUNK
pub const QBLOCK: usize = super::CHUNK;

/// Per-block affine decode coefficients: block `bi` reconstructs as
/// `w = a[bi]·code + b[bi]`. Built once at [`PackedLinear::new`] from the
/// stored (bf16-rounded) scale table, alongside the f32 reconstruction
/// LUT.
///
/// [`PackedLinear::new`]: super::PackedLinear::new
pub(crate) struct Int8Plan {
    pub a: Vec<f32>,
    pub b: Vec<f32>,
}

/// Derive the per-block `(a, b)` affine coefficients for `pt`, or `None`
/// when the method's decode is not a pure scale×code affine map (NF4's
/// codebook gather, MSB's per-level scale gather) — the eligibility rule
/// `MacMode::Auto` dispatches on. The mapping mirrors each method's
/// `decode_block` exactly:
///
/// * `rtn`:           `w = s·c`            → `a = s,  b = 0`
/// * `rtn-asym`:      `w = s·c + z`        → `a = s,  b = z`
/// * `hqq`:           `w = s·(c − z)`      → `a = s,  b = −s·z`
/// * `xnor` variants: `w = α·c`, c∈{−1,0,1} → `a = α, b = 0`
pub(crate) fn affine_plan(pt: &PackedTensor, scales: &[f32]) -> Option<Int8Plan> {
    let nb = pt.n_blocks();
    let spb = pt.scales_per_block;
    let mut a = Vec::with_capacity(nb);
    let mut b = Vec::with_capacity(nb);
    match pt.method.as_str() {
        "rtn" | "xnor" | "blocked-xnor" if spb >= 1 => {
            for bi in 0..nb {
                a.push(scales[bi * spb]);
                b.push(0.0);
            }
        }
        "rtn-asym" if spb >= 2 => {
            for bi in 0..nb {
                a.push(scales[bi * spb]);
                b.push(scales[bi * spb + 1]);
            }
        }
        "hqq" if spb >= 2 => {
            for bi in 0..nb {
                let s = scales[bi * spb];
                a.push(s);
                b.push(-s * scales[bi * spb + 1]);
            }
        }
        _ => return None,
    }
    Some(Int8Plan { a, b })
}

/// An activation vector (or small-batch matrix) quantized to i8 with
/// per-[`QBLOCK`]-element symmetric scales, computed on the fly at call
/// time. Row `b`'s element `i` reconstructs as
/// `codes[b·cols + i] · scales[b·n_qblocks + i/QBLOCK]`; an all-zero (or
/// non-finite-max) block stores scale 0 and zero codes, so it contributes
/// exactly nothing.
pub struct QuantizedVec {
    codes: Vec<i8>,
    scales: Vec<f32>,
    cols: usize,
    batch: usize,
    n_qblocks: usize,
}

impl QuantizedVec {
    /// Quantize a row-major `[batch, cols]` activation buffer. Symmetric
    /// round-to-nearest per block: `scale = max|x|/127`,
    /// `x̂ = round(x/scale)` clamped to ±127. Deterministic — no state,
    /// no data-dependent ordering.
    pub fn quantize(xs: &[f32], batch: usize, cols: usize) -> QuantizedVec {
        assert_eq!(xs.len(), batch * cols, "activation shape != [batch, cols]");
        let n_qblocks = cols.div_ceil(QBLOCK);
        let mut codes = vec![0i8; batch * cols];
        let mut scales = vec![0.0f32; batch * n_qblocks];
        for bt in 0..batch {
            let x = &xs[bt * cols..(bt + 1) * cols];
            let qrow = &mut codes[bt * cols..(bt + 1) * cols];
            for qi in 0..n_qblocks {
                let start = qi * QBLOCK;
                let end = (start + QBLOCK).min(cols);
                let m = x[start..end].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                if m == 0.0 || !m.is_finite() {
                    continue; // scale 0, codes 0: the block drops out exactly
                }
                let inv = 127.0 / m;
                for (o, &v) in qrow[start..end].iter_mut().zip(&x[start..end]) {
                    // in range by the clamp; rounding may hit ±127.000…1
                    #[allow(clippy::cast_possible_truncation)]
                    {
                        *o = (v * inv).round().clamp(-127.0, 127.0) as i8;
                    }
                }
                scales[bt * n_qblocks + qi] = m / 127.0;
            }
        }
        QuantizedVec { codes, scales, cols, batch, n_qblocks }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Batch row `b`'s i8 codes (`cols` entries).
    pub fn codes(&self, b: usize) -> &[i8] {
        &self.codes[b * self.cols..(b + 1) * self.cols]
    }

    /// Batch row `b`'s scale for activation block `qi`.
    pub fn scale(&self, b: usize, qi: usize) -> f32 {
        self.scales[b * self.n_qblocks + qi]
    }
}

/// `Σ c[i]·x[i]` in i32. Exact for any i8 inputs (|c·x| ≤ 127², tile
/// lengths ≤ [`QBLOCK`] keep the sum far from i32 range), so every
/// accumulation order is identical — the dispatch below needs no lane
/// discipline to stay bit-identical.
pub(crate) fn dot_i8(kernel: Kernel, c: &[i8], x: &[i8]) -> i32 {
    match kernel {
        Kernel::Scalar => dot_i8_scalar(c, x),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the same availability contract as `Kernel::dot` — every
        // entry point asserts `available()` before the hot loop.
        Kernel::Avx2 => unsafe { dot_i8_avx2(c, x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is part of the aarch64 baseline ISA, so
        // `available()` is unconditionally true for this variant.
        Kernel::Neon => unsafe { dot_i8_neon(c, x) },
    }
}

/// `Σ x[i]` in i32 — the `b·Σx̂` epilogue term for zero-point schemes.
pub(crate) fn sum_i8(kernel: Kernel, x: &[i8]) -> i32 {
    match kernel {
        Kernel::Scalar => sum_i8_scalar(x),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as for `dot_i8`.
        Kernel::Avx2 => unsafe { sum_i8_avx2(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as for `dot_i8`.
        Kernel::Neon => unsafe { sum_i8_neon(x) },
    }
}

fn dot_i8_scalar(c: &[i8], x: &[i8]) -> i32 {
    debug_assert_eq!(c.len(), x.len());
    let mut acc = 0i32;
    for (&a, &b) in c.iter().zip(x) {
        acc += a as i32 * b as i32;
    }
    acc
}

fn sum_i8_scalar(x: &[i8]) -> i32 {
    x.iter().map(|&v| v as i32).sum()
}

/// AVX2 widening multiply-add: 16 i8 lanes sign-extend to i16
/// (`_mm256_cvtepi8_epi16`), `_mm256_madd_epi16` multiplies and pair-sums
/// into i32 — exact, unlike `_mm256_maddubs_epi16` whose u8×i8 i16
/// accumulation saturates. The horizontal i32 reduction needs no fixed
/// tree: integer addition is associative, so any shape equals the scalar
/// loop bit-for-bit.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(c: &[i8], x: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(c.len(), x.len());
    let n = c.len();
    let m = n - n % 16;
    let mut acc = _mm256_setzero_si256();
    let mut k = 0;
    while k < m {
        let a = _mm256_cvtepi8_epi16(_mm_loadu_si128(c.as_ptr().add(k) as *const __m128i));
        let b = _mm256_cvtepi8_epi16(_mm_loadu_si128(x.as_ptr().add(k) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a, b));
        k += 16;
    }
    let mut sum = hsum_i32(acc);
    for i in m..n {
        sum += c[i] as i32 * x[i] as i32;
    }
    sum
}

/// AVX2 lane sum via `madd` against a ones vector (same exactness
/// argument as [`dot_i8_avx2`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sum_i8_avx2(x: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let m = n - n % 16;
    let ones = _mm256_set1_epi16(1);
    let mut acc = _mm256_setzero_si256();
    let mut k = 0;
    while k < m {
        let a = _mm256_cvtepi8_epi16(_mm_loadu_si128(x.as_ptr().add(k) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a, ones));
        k += 16;
    }
    let mut sum = hsum_i32(acc);
    for i in m..n {
        sum += x[i] as i32;
    }
    sum
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_i32(acc: std::arch::x86_64::__m256i) -> i32 {
    use std::arch::x86_64::*;
    let q = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256(acc, 1));
    let q = _mm_add_epi32(q, _mm_shuffle_epi32(q, 0b0100_1110));
    let q = _mm_add_epi32(q, _mm_shuffle_epi32(q, 0b1011_0001));
    _mm_cvtsi128_si32(q)
}

/// Portable reference for the `sdot` accumulation shape the NEON kernel
/// uses: four i32 lanes, each absorbing one 4-element product group per
/// 16-element step, reduced as `(l0+l1) + (l2+l3)`, sequential tail.
/// i32 accumulation is exact for i8·i8 products at these tile lengths, so
/// this must equal the plain scalar loop *bit-for-bit* on every input —
/// the contract that lets the aarch64 path skip lane discipline entirely.
/// Compiled and tested on every arch so the shape cannot rot unseen.
pub fn dot_i8_sdot_ref(c: &[i8], x: &[i8]) -> i32 {
    debug_assert_eq!(c.len(), x.len());
    let n = c.len();
    let m = n - n % 16;
    let mut lanes = [0i32; 4];
    let mut k = 0;
    while k < m {
        for (j, l) in lanes.iter_mut().enumerate() {
            let g = k + 4 * j;
            for i in g..g + 4 {
                *l += c[i] as i32 * x[i] as i32;
            }
        }
        k += 16;
    }
    let mut sum = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for i in m..n {
        sum += c[i] as i32 * x[i] as i32;
    }
    sum
}

/// NEON i8 dot in the `sdot` accumulation shape, built from baseline
/// intrinsics (no `dotprod` extension needed): widening multiply to
/// i16×8 (`vmull_s8`), pairwise-add-accumulate into four i32 lanes
/// (`vpadalq_s16`), horizontal `vaddvq_s32` finish, sequential tail for
/// `len % 8`. Bit-identical to [`dot_i8_sdot_ref`] and to the scalar
/// loop because i32 accumulation is exact — see the module docs.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_i8_neon(c: &[i8], x: &[i8]) -> i32 {
    use std::arch::aarch64::*;
    debug_assert_eq!(c.len(), x.len());
    let n = c.len();
    let m = n - n % 8;
    let mut acc = vdupq_n_s32(0);
    let mut k = 0;
    while k < m {
        let a = vld1_s8(c.as_ptr().add(k));
        let b = vld1_s8(x.as_ptr().add(k));
        acc = vpadalq_s16(acc, vmull_s8(a, b));
        k += 8;
    }
    let mut sum = vaddvq_s32(acc);
    for i in m..n {
        sum += c[i] as i32 * x[i] as i32;
    }
    sum
}

/// NEON lane sum: sign-extend (`vmovl_s8`), pairwise-accumulate, add
/// across (same exactness argument as [`dot_i8_neon`]).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn sum_i8_neon(x: &[i8]) -> i32 {
    use std::arch::aarch64::*;
    let n = x.len();
    let m = n - n % 8;
    let mut acc = vdupq_n_s32(0);
    let mut k = 0;
    while k < m {
        acc = vpadalq_s16(acc, vmovl_s8(vld1_s8(x.as_ptr().add(k))));
        k += 8;
    }
    let mut sum = vaddvq_s32(acc);
    for i in m..n {
        sum += x[i] as i32;
    }
    sum
}

#[cfg(test)]
// test data generation casts freely (values constructed in range by hand)
#[allow(clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    #[test]
    fn quantized_vec_reconstructs_within_half_step() {
        let cols = 150; // ragged: 3 blocks, last one 22 wide
        let mut xs = vec![0.0f32; 2 * cols];
        Rng::new(71).fill_normal(&mut xs, 1.0);
        // an exactly-zero block must drop out with scale 0
        for v in &mut xs[QBLOCK..2 * QBLOCK] {
            *v = 0.0;
        }
        let q = QuantizedVec::quantize(&xs, 2, cols);
        assert_eq!(q.batch(), 2);
        assert_eq!(q.cols(), cols);
        assert_eq!(q.scale(0, 1), 0.0);
        for b in 0..2 {
            let x = &xs[b * cols..(b + 1) * cols];
            let codes = q.codes(b);
            for (i, (&v, &c)) in x.iter().zip(codes).enumerate() {
                let s = q.scale(b, i / QBLOCK);
                let back = c as f32 * s;
                let tol = 0.5 * s + 1e-12;
                assert!(
                    (back - v).abs() <= tol,
                    "row {b} elem {i}: {v} -> code {c} (scale {s}) off by {}",
                    (back - v).abs()
                );
                assert!(c >= -127, "code range");
            }
        }
    }

    #[test]
    fn int8_dot_simd_matches_scalar_exactly() {
        let Some(simd) = Kernel::detect_simd() else {
            eprintln!("skipping: no SIMD kernel on this CPU");
            return;
        };
        let mut rng = Rng::new(72);
        for len in [1usize, 7, 15, 16, 17, 31, 32, 48, 63, 64] {
            let c: Vec<i8> = (0..len).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let x: Vec<i8> = (0..len).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            assert_eq!(dot_i8(simd, &c, &x), dot_i8(Kernel::Scalar, &c, &x), "dot len {len}");
            assert_eq!(sum_i8(simd, &x), sum_i8(Kernel::Scalar, &x), "sum len {len}");
        }
        // extremes: ±127 everywhere — the maddubs saturation trap this
        // kernel must not have
        let c = vec![-127i8; 64];
        let x = vec![127i8; 64];
        assert_eq!(dot_i8(simd, &c, &x), -127 * 127 * 64);
        assert_eq!(dot_i8(Kernel::Scalar, &c, &x), -127 * 127 * 64);
    }

    /// The `sdot` accumulation shape must equal the plain scalar loop
    /// bit-for-bit on any input and any (ragged) length — the contract
    /// the aarch64 NEON kernel relies on, checked on every arch.
    #[test]
    fn sdot_shaped_reference_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(73);
        for len in [1usize, 3, 4, 8, 15, 16, 17, 32, 48, 63, 64, 127] {
            let c: Vec<i8> = (0..len).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let x: Vec<i8> = (0..len).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            assert_eq!(dot_i8_sdot_ref(&c, &x), dot_i8_scalar(&c, &x), "len {len}");
        }
        // i16-overflow territory per product group: ±127 everywhere
        let c = vec![-127i8; 64];
        let x = vec![127i8; 64];
        assert_eq!(dot_i8_sdot_ref(&c, &x), -127 * 127 * 64);
    }
}
