//! Fused packed-weight GEMV/GEMM kernels: compute directly on codes.
//!
//! Every serving path used to pay an O(model) unpack-to-f32
//! materialization (`engine::decode_packed`) before the first multiply —
//! throwing away the 4–6× memory win the packed `.msbt` format bought.
//! This module computes `y = W·x` (and small-batch `Y = W·Xᵀ`) straight
//! from a [`PackedTensor`]: per block, the codes are decoded into a
//! register-resident 64-element tile, the block's scales applied, and the
//! multiply-accumulate fused — the f32 weight matrix never exists.
//!
//! Determinism is a hard invariant, matching the engine's contract:
//!
//! * **Threading** — rows are striped over [`ThreadPool`] via
//!   `submit_many`; every output row is computed start-to-finish by one
//!   worker in the same order the serial path uses, so threaded and
//!   serial runs are bit-identical.
//! * **SIMD** — accumulation is structured as fixed per-block partial
//!   sums: each ≤64-element chunk reduces through eight strided lanes and
//!   a fixed lane-combination tree (exactly the AVX2 horizontal-add
//!   shape), then chunk partials add in block order. The runtime-dispatched
//!   AVX2 path (`std::arch` + `is_x86_feature_detected!`) and the portable
//!   scalar fallback — always compiled, always tested — execute the same
//!   tree, so they are bit-identical too. The AVX2 kernel deliberately
//!   uses separate multiply+add rather than `vfmadd`: FP contraction would
//!   change the rounding of every product and break identity with the
//!   scalar path (whose only single-rounding fallback is a slow libm
//!   `fmaf`).
//!
//! Decode semantics are exactly [`engine::decode_packed`]'s: scheme-decoded
//! codes through the method's `decode_block`, exact-zero exception-list
//! positions forced to 0.0, and the bf16 storage round-trip applied — so
//! the fused product matches the decode-then-matvec reference to f32
//! summation-order error (≤ 1e-5 relative; asserted across the method
//! grid by tests and by the `perf_gemv` bench). Since every method decodes
//! pointwise (a code's value depends only on its block's scales),
//! [`PackedLinear::new`] folds method-decode *and* the bf16 finish into a
//! per-block reconstruction table once at construction; the hot loop is a
//! plain table gather, with no rounding pass per tile. Bit-identity with
//! the historical decode-per-tile path is asserted by the kernel grid test.
//!
//! **Integer MAC path** ([`MacMode`], [`int8`]): methods whose decode is a
//! pure affine map of the code (`w = a·c + b` per block — RTN sym/asym,
//! HQQ, XNOR) can additionally run an i8·i8→i32 kernel that quantizes the
//! activation on the fly ([`QuantizedVec`]) and never decodes weights to
//! f32 at all; [`MacMode::Auto`] picks it per layer, falling back to the
//! f32 path for codebook/per-level methods (NF4, MSB). The integer
//! accumulation is exactly associative, so that path's scalar/AVX2/thread
//! bit-identity holds by construction rather than by lane discipline; its
//! f32 epilogue applies `(a·Σc·x̂ + b·Σx̂)·x_scale` once per
//! (weight-block × activation-block) pair in the same chunk-ordered
//! partial-sum chain as the f32 path. See the [`int8`] module docs for
//! the accuracy budget.

// The i8/i32 cast surface in this module is audited: every narrowing cast
// is either provably in range or explicitly allow-listed with its range
// argument. CI's clippy gate (-D warnings) enforces this deny.
#![deny(clippy::cast_possible_truncation)]

pub mod int8;

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::pool::ThreadPool;
use crate::quant::engine::{pool_ordered_map, BlockQuantizer};
use crate::quant::packing::{CodeScheme, PackedCodes, PackedTensor};
use crate::quant::registry;
use crate::tensor::{bf16, Matrix};

/// Elements per register-resident tile: one paper block (t=64). Larger
/// blocks and per-tensor plans are walked in 64-element sub-chunks; the
/// partial-sum structure is anchored at block starts, so the chunking is
/// deterministic for a given payload regardless of threads or SIMD.
const CHUNK: usize = 64;

pub use int8::QuantizedVec;

/// Which multiply-accumulate path a [`PackedLinear`] executes.
///
/// * `F32` — the exact fused path: codes gather through the per-block
///   reconstruction table, the MAC runs in f32. Always available.
/// * `Int8` — the integer MAC path: activations quantize to i8 on the
///   fly, the MAC runs i8·i8→i32 with one f32 epilogue per block pair.
///   Only meaningful for affine-decodable methods;
///   [`PackedLinear::with_mac`] rejects it otherwise.
/// * `Auto` — `Int8` where the layer's method is affine-decodable, `F32`
///   otherwise, resolved per layer at construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MacMode {
    /// Exact f32 fused MAC (the default).
    #[default]
    F32,
    /// Integer MAC; construction fails for non-affine methods.
    Int8,
    /// Per-layer automatic choice with f32 fallback.
    Auto,
}

impl MacMode {
    /// Parse a `--mac` CLI value.
    pub fn parse(s: &str) -> Result<MacMode> {
        match s {
            "f32" => Ok(MacMode::F32),
            "int8" => Ok(MacMode::Int8),
            "auto" => Ok(MacMode::Auto),
            other => anyhow::bail!("bad mac mode '{other}' (expected f32|int8|auto)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MacMode::F32 => "f32",
            MacMode::Int8 => "int8",
            MacMode::Auto => "auto",
        }
    }
}

// ---------------------------------------------------------------------------
// The dot-product micro-kernel: scalar reference + runtime-dispatched AVX2.
// ---------------------------------------------------------------------------

/// Which micro-kernel executes the per-chunk dot products.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable 8-lane scalar fallback — always compiled, always tested;
    /// the reference the SIMD path must reproduce bit-for-bit.
    Scalar,
    /// AVX2 path (requires only `avx2` at runtime — the kernel
    /// deliberately avoids `vfmadd`, so FMA support is not needed; see
    /// the module docs).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// NEON path (aarch64 baseline, so always available there). The f32
    /// dot currently delegates to the scalar lane structure — the win on
    /// this target is the `sdot`-shaped int8 kernel in [`int8`]; see
    /// [`int8::dot_i8_sdot_ref`] for the everywhere-tested reference of
    /// its accumulation shape.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Kernel {
    /// Pick the fastest kernel this CPU supports.
    pub fn detect() -> Kernel {
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") {
            return Kernel::Avx2;
        }
        #[cfg(target_arch = "aarch64")]
        {
            Kernel::Neon
        }
        #[cfg(not(target_arch = "aarch64"))]
        {
            Kernel::Scalar
        }
    }

    /// The detected SIMD kernel, or `None` when only the scalar fallback
    /// is available (lets tests compare both paths without cfg gymnastics).
    pub fn detect_simd() -> Option<Kernel> {
        let k = Kernel::detect();
        if k == Kernel::Scalar {
            None
        } else {
            Some(k)
        }
    }

    /// Whether this kernel can run on the current CPU.
    pub fn available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
            // NEON is part of the aarch64 baseline ISA
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => true,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => "neon",
        }
    }

    /// Dot product of one ≤64-element chunk in the fixed lane structure.
    #[inline]
    fn dot(self, w: &[f32], x: &[f32]) -> f32 {
        match self {
            Kernel::Scalar => dot_chunk_scalar(w, x),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: every entry point accepting a Kernel (detect,
            // with_kernel, dense_gemv) asserts `available()` before this
            // variant can reach the hot loop, so avx2 is present.
            Kernel::Avx2 => unsafe { dot_chunk_avx2(w, x) },
            // f32 stub: bit-identity with the scalar lane tree for free;
            // the integer path below is where NEON actually accelerates
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => dot_chunk_scalar(w, x),
        }
    }
}

/// Portable chunk dot: eight strided lanes (`lanes[j] += w[8k+j]·x[8k+j]`)
/// reduced through the fixed tree `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`
/// — the exact shape of the AVX2 `vextractf128`/`movehl`/`shuffle`
/// horizontal add — then a sequential tail for `len % 8` elements.
fn dot_chunk_scalar(w: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let m = n - n % 8;
    let mut lanes = [0.0f32; 8];
    let mut k = 0;
    while k < m {
        for j in 0..8 {
            lanes[j] += w[k + j] * x[k + j];
        }
        k += 8;
    }
    let q = [lanes[0] + lanes[4], lanes[1] + lanes[5], lanes[2] + lanes[6], lanes[3] + lanes[7]];
    let mut sum = (q[0] + q[2]) + (q[1] + q[3]);
    for i in m..n {
        sum += w[i] * x[i];
    }
    sum
}

/// AVX2 chunk dot with the same lane/reduction structure as
/// [`dot_chunk_scalar`]. Multiply and add stay separate instructions
/// (no `vfmadd`): Rust/LLVM never contracts FP by default, so both paths
/// round every product identically and the results are bit-equal.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_chunk_avx2(w: &[f32], x: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let m = n - n % 8;
    let mut acc = _mm256_setzero_ps();
    let mut k = 0;
    while k < m {
        let a = _mm256_loadu_ps(w.as_ptr().add(k));
        let b = _mm256_loadu_ps(x.as_ptr().add(k));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(a, b));
        k += 8;
    }
    // horizontal add in the fixed tree: q = lo128 + hi128, r = q + movehl(q),
    // sum = r0 + r1  ==  ((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))
    let q = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps(acc, 1));
    let r = _mm_add_ps(q, _mm_movehl_ps(q, q));
    let s = _mm_add_ss(r, _mm_shuffle_ps(r, r, 0b01));
    let mut sum = _mm_cvtss_f32(s);
    for i in m..n {
        sum += w[i] * x[i];
    }
    sum
}

// ---------------------------------------------------------------------------
// PackedLinear: the serving-side handle over a packed layer.
// ---------------------------------------------------------------------------

/// What [`PackedLinear`] shares between the caller and its pool jobs.
struct Shared {
    pt: PackedTensor,
    /// Exact-zero exception indices, sorted ascending.
    zeros: Vec<u32>,
    /// Per-block reconstruction table, `lut_len` entries per block: entry
    /// `bi * lut_len + (c - code_min)` holds the decoded value of code `c`
    /// in block `bi`, with the bf16 storage round-trip already applied when
    /// the payload calls for it. Every method decodes pointwise, so this
    /// table is exact — the hot loop gathers instead of re-deriving values.
    recon: Vec<f32>,
    /// Smallest decodable code value (the table's index origin).
    code_min: i16,
    /// Table entries per block.
    lut_len: usize,
    /// Per-block affine decode coefficients when the method is
    /// int8-eligible (`w = a·c + b`), else `None` — the [`MacMode::Auto`]
    /// eligibility fact, resolved once at construction.
    int8: Option<int8::Int8Plan>,
}

/// Reusable per-invocation tile scratch shared by the f32 and int8 row
/// kernels: the unpacked i8 code tile plus the f32 weight tile the f32
/// path gathers into. Stack-resident and created once per `run_rows*`
/// call (one per pool job), never per block — the `perf_gemv`
/// allocation-count gate pins that the hot loops allocate nothing.
struct TileScratch {
    codes: [i8; CHUNK],
    w: [f32; CHUNK],
}

impl TileScratch {
    fn new() -> TileScratch {
        TileScratch { codes: [0; CHUNK], w: [0.0; CHUNK] }
    }
}

/// A linear layer held *as its packed payload*: codes + scale table +
/// exception list, never the f32 weight matrix. The runtime/server keep
/// one of these per layer instead of decoded f32 buffers; [`gemv`] /
/// [`gemm`] fuse decode and multiply-accumulate per block.
///
/// Cloning is cheap (the payload is shared behind an `Arc`), so handles
/// can be handed to server threads freely.
///
/// [`gemv`]: PackedLinear::gemv
/// [`gemm`]: PackedLinear::gemm
#[derive(Clone)]
pub struct PackedLinear {
    inner: Arc<Shared>,
    kernel: Kernel,
    mac: MacMode,
}

impl PackedLinear {
    /// Wrap a payload, resolving its decode method and validating the
    /// layout (the same invariants `pipeline`'s reconstruction enforces on
    /// files, re-checked so handles built from in-memory payloads cannot
    /// index out of bounds in the hot loop).
    pub fn new(pt: PackedTensor) -> Result<PackedLinear> {
        let decoder = registry::block_decoder(&pt.method)
            .with_context(|| format!("no fused kernel for method '{}'", pt.method))?;
        let n = pt.n_elems();
        let scales = pt.scales_f32();
        ensure!(
            scales.len() == pt.n_blocks() * pt.scales_per_block,
            "scale table len {} != {} blocks x {} scales/block",
            scales.len(),
            pt.n_blocks(),
            pt.scales_per_block
        );
        ensure!(pt.block > 0 || n == 0, "degenerate block size");
        let code_len_ok = match &pt.codes {
            PackedCodes::I8(v) => v.len() == n,
            PackedCodes::U1(p) | PackedCodes::U2(p) | PackedCodes::U4(p) => {
                p.len() == n.div_ceil((8 / pt.codes.width()) as usize)
            }
        };
        ensure!(code_len_ok, "code buffer does not cover {n} elements");
        let mut zeros = pt.zeros.clone();
        zeros.sort_unstable();
        if let Some(&last) = zeros.last() {
            ensure!((last as usize) < n, "zero exception {last} out of range");
        }
        // Reconstruction range: every code value the payload can decode to.
        // Sub-byte storage is enumerated through the scheme (≤ 2^code_bits
        // symbols); i8 storage scans the actual buffer.
        let (code_min, code_max) = match &pt.codes {
            PackedCodes::I8(v) => v
                .iter()
                .fold((0i16, 0i16), |(lo, hi), &c| (lo.min(c as i16), hi.max(c as i16))),
            // in range: sub-byte storage means code_bits ≤ 4, so every
            // enumerated symbol fits u8
            #[allow(clippy::cast_possible_truncation)]
            PackedCodes::U1(_) | PackedCodes::U2(_) | PackedCodes::U4(_) => (0u16
                ..1u16 << pt.code_bits)
                .map(|s| pt.scheme.decode(s as u8, pt.code_bits) as i16)
                .fold((0i16, 0i16), |(lo, hi), c| (lo.min(c), hi.max(c))),
        };
        if n > 0 && pt.scheme == CodeScheme::SignLevel {
            // Scale-indexing schemes read scales[|c| - 1]; bound the
            // magnitude here so a corrupt payload fails construction
            // instead of panicking in the table build.
            let max_mag = (-code_min).max(code_max) as usize;
            ensure!(
                max_mag <= pt.scales_per_block,
                "code magnitude {max_mag} exceeds {} scales/block",
                pt.scales_per_block
            );
        }
        let lut_len = (code_max - code_min) as usize + 1;
        // in range: code_min..=code_max is the decodable code span, which
        // fits i8 by construction (I8 storage scans i8 values; sub-byte
        // symbols decode through the scheme's i8 output)
        #[allow(clippy::cast_possible_truncation)]
        let codes_enum: Vec<i8> = (code_min..=code_max).map(|c| c as i8).collect();
        let spb = pt.scales_per_block;
        let mut recon = vec![0.0f32; pt.n_blocks() * lut_len];
        for (bi, lut) in recon.chunks_exact_mut(lut_len).enumerate() {
            decoder.decode_block(&codes_enum, &scales[bi * spb..(bi + 1) * spb], lut);
        }
        if pt.bf16 {
            for v in &mut recon {
                *v = bf16::round(*v);
            }
        }
        let int8 = int8::affine_plan(&pt, &scales);
        Ok(PackedLinear {
            inner: Arc::new(Shared { pt, zeros, recon, code_min, lut_len, int8 }),
            kernel: Kernel::detect(),
            mac: MacMode::F32,
        })
    }

    /// Select the multiply-accumulate path. `F32` and `Auto` always
    /// succeed (`Auto` resolves per layer against the method's
    /// affine-decode eligibility); an explicit `Int8` request fails for
    /// methods whose decode is not an affine scale×code map — use `Auto`
    /// to fall back per layer instead.
    pub fn with_mac(mut self, mac: MacMode) -> Result<PackedLinear> {
        ensure!(
            mac != MacMode::Int8 || self.inner.int8.is_some(),
            "method '{}' decode is not an affine scale×code map — \
             no int8 MAC path (use mac=auto to fall back per layer)",
            self.inner.pt.method
        );
        self.mac = mac;
        Ok(self)
    }

    /// The requested MAC mode (see [`PackedLinear::int8_active`] for the
    /// per-layer resolution of `Auto`).
    pub fn mac(&self) -> MacMode {
        self.mac
    }

    /// Whether this layer's method decodes as a pure affine scale×code
    /// map, i.e. whether the int8 MAC path exists for it.
    pub fn int8_eligible(&self) -> bool {
        self.inner.int8.is_some()
    }

    /// Whether calls on this handle execute the int8 MAC path (`Int8`
    /// always, `Auto` when eligible, `F32` never).
    pub fn int8_active(&self) -> bool {
        match self.mac {
            MacMode::F32 => false,
            MacMode::Int8 => true,
            MacMode::Auto => self.int8_eligible(),
        }
    }

    /// Force a specific micro-kernel (tests and the SIMD-vs-scalar bench
    /// ablation). Panics if the kernel is unavailable on this CPU.
    pub fn with_kernel(mut self, kernel: Kernel) -> PackedLinear {
        assert!(kernel.available(), "{} kernel not available on this CPU", kernel.name());
        self.kernel = kernel;
        self
    }

    pub fn rows(&self) -> usize {
        self.inner.pt.rows
    }

    pub fn cols(&self) -> usize {
        self.inner.pt.cols
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The wrapped payload (storage accounting, layout inspection).
    pub fn packed(&self) -> &PackedTensor {
        &self.inner.pt
    }

    /// Serialized payload size — the bytes this handle actually holds, vs
    /// the `rows·cols·4` an f32 weight buffer would cost.
    pub fn payload_bytes(&self) -> usize {
        self.inner.pt.payload_bytes()
    }

    /// Fused matrix-vector product `y = W·x` (`x.len() == cols`,
    /// `y.len() == rows`), serial reference order. Routes through the
    /// int8 MAC path when [`PackedLinear::int8_active`].
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        self.gemm(x, 1)
    }

    /// Fused small-batch product: `xs` is row-major `[batch, cols]`, the
    /// result row-major `[batch, rows]`. Each block tile is decoded once
    /// and multiplied against every batch row — the decode cost amortizes
    /// across the batch, which is where fused serving wins hardest.
    /// Routes through the int8 MAC path when
    /// [`PackedLinear::int8_active`].
    pub fn gemm(&self, xs: &[f32], batch: usize) -> Vec<f32> {
        if self.int8_active() {
            return self.gemm_int8(xs, batch);
        }
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(xs.len(), batch * cols, "activation shape != [batch, cols]");
        let mut out = vec![0.0f32; batch * rows];
        let mut scratch = TileScratch::new();
        run_rows(&self.inner, self.kernel, 0, rows, xs, batch, &mut out, &mut scratch);
        out
    }

    /// Integer-MAC matrix-vector product: quantize `x` to i8 per
    /// 64-element block on the fly, run the i8·i8→i32 kernel. Panics
    /// unless the method is [`PackedLinear::int8_eligible`]. Approximate
    /// (see the [`int8`] module docs for the budget); batch-invariant and
    /// bit-identical across kernels/threads by construction.
    pub fn gemv_int8(&self, x: &[f32]) -> Vec<f32> {
        self.gemm_int8(x, 1)
    }

    /// Integer-MAC small-batch product (see [`PackedLinear::gemv_int8`]).
    /// Each batch row quantizes independently, so every output row equals
    /// the corresponding `gemv_int8` bit-for-bit.
    pub fn gemm_int8(&self, xs: &[f32], batch: usize) -> Vec<f32> {
        let cols = self.cols();
        assert_eq!(xs.len(), batch * cols, "activation shape != [batch, cols]");
        let qx = QuantizedVec::quantize(xs, batch, cols);
        self.gemm_int8_quantized(&qx)
    }

    /// Serial int8 product over a pre-quantized activation buffer.
    fn gemm_int8_quantized(&self, qx: &QuantizedVec) -> Vec<f32> {
        assert!(
            self.int8_eligible(),
            "method '{}' has no int8 MAC path (decode is not affine)",
            self.inner.pt.method
        );
        let rows = self.rows();
        assert_eq!(qx.cols(), self.cols(), "quantized activation cols mismatch");
        let mut out = vec![0.0f32; qx.batch() * rows];
        let mut scratch = TileScratch::new();
        run_rows_int8(&self.inner, self.kernel, 0, rows, qx, &mut out, &mut scratch);
        out
    }

    /// [`PackedLinear::gemv`] with rows striped over `pool` — bit-identical
    /// to the serial path for every worker count.
    pub fn gemv_pooled(&self, x: &[f32], pool: &ThreadPool) -> Vec<f32> {
        self.gemm_pooled(x, 1, pool)
    }

    /// [`PackedLinear::gemm`] with rows striped over `pool` via
    /// `submit_many` (one lock acquisition per worker stripe). Every row is
    /// computed whole by one job, so the output is bit-identical to the
    /// serial path regardless of worker count or completion order. Copies
    /// `xs` once to share with the jobs; callers that already own the
    /// batch buffer can avoid that copy with [`PackedLinear::gemm_shared`].
    pub fn gemm_pooled(&self, xs: &[f32], batch: usize, pool: &ThreadPool) -> Vec<f32> {
        self.gemm_shared(Arc::new(xs.to_vec()), batch, pool)
    }

    /// [`PackedLinear::gemm_pooled`] over a caller-owned shared buffer —
    /// no activation copy (the serving loop builds its batch directly
    /// into the `Arc`). Routes through the int8 MAC path when
    /// [`PackedLinear::int8_active`]: the activation quantizes once, the
    /// row stripes share the result, and every row depends only on
    /// (payload, quantized activation) — so pooled int8 equals serial
    /// int8 bit-for-bit, same as the f32 discipline.
    pub fn gemm_shared(&self, xs: Arc<Vec<f32>>, batch: usize, pool: &ThreadPool) -> Vec<f32> {
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(xs.len(), batch * cols, "activation shape != [batch, cols]");
        let threads = pool.threads();
        if threads <= 1 || rows <= 1 {
            return self.gemm(&xs, batch);
        }
        let stripe = rows.div_ceil(threads * 4).max(1);
        let n_stripes = rows.div_ceil(stripe);
        if n_stripes <= 1 {
            return self.gemm(&xs, batch);
        }
        let kernel = self.kernel;
        let stripes = if self.int8_active() {
            assert!(
                self.int8_eligible(),
                "method '{}' has no int8 MAC path (decode is not affine)",
                self.inner.pt.method
            );
            let qx = Arc::new(QuantizedVec::quantize(&xs, batch, cols));
            let jobs: Vec<_> = (0..n_stripes)
                .map(|si| {
                    let sh = Arc::clone(&self.inner);
                    let qx = Arc::clone(&qx);
                    move || {
                        let r0 = si * stripe;
                        let r1 = ((si + 1) * stripe).min(rows);
                        let mut out = vec![0.0f32; batch * (r1 - r0)];
                        let mut scratch = TileScratch::new();
                        run_rows_int8(&sh, kernel, r0, r1, &qx, &mut out, &mut scratch);
                        out
                    }
                })
                .collect();
            pool_ordered_map(pool, jobs)
        } else {
            let jobs: Vec<_> = (0..n_stripes)
                .map(|si| {
                    let sh = Arc::clone(&self.inner);
                    let xs = Arc::clone(&xs);
                    move || {
                        let r0 = si * stripe;
                        let r1 = ((si + 1) * stripe).min(rows);
                        let mut out = vec![0.0f32; batch * (r1 - r0)];
                        let mut scratch = TileScratch::new();
                        run_rows(&sh, kernel, r0, r1, &xs, batch, &mut out, &mut scratch);
                        out
                    }
                })
                .collect();
            pool_ordered_map(pool, jobs)
        };
        let mut y = vec![0.0f32; batch * rows];
        for (si, chunk) in stripes.into_iter().enumerate() {
            let r0 = si * stripe;
            let width = chunk.len() / batch;
            for b in 0..batch {
                y[b * rows + r0..b * rows + r0 + width]
                    .copy_from_slice(&chunk[b * width..(b + 1) * width]);
            }
        }
        y
    }
}

/// The fused row kernel: rows `[r0, r1)` of `y = W·x` for every batch row,
/// written into `out[b·(r1−r0) + (r−r0)]`. Walks each row as segments
/// (row ∩ block instance) sub-chunked at [`CHUNK`] elements: unpack codes
/// into an i8 tile, gather the block's reconstruction table (decode + bf16
/// already folded in at construction) into an f32 tile, zero the
/// exception-listed positions, then one [`Kernel::dot`] per batch row.
/// Partial sums add in chunk order — the fixed structure every execution
/// mode shares. Zeroing after the gather is exact because
/// `bf16::round(0.0) == 0.0`.
fn run_rows(
    sh: &Shared,
    kernel: Kernel,
    r0: usize,
    r1: usize,
    xs: &[f32],
    batch: usize,
    out: &mut [f32],
    scratch: &mut TileScratch,
) {
    let (rows, cols) = (sh.pt.rows, sh.pt.cols);
    let n = rows * cols;
    let block = sh.pt.block.max(1);
    let (lut_len, code_min) = (sh.lut_len, sh.code_min);
    let out_rows = r1 - r0;
    for r in r0..r1 {
        let row_start = r * cols;
        let row_end = row_start + cols;
        let mut g = row_start;
        while g < row_end {
            // flat plans let blocks cross rows; clamp the segment to both
            let bi = g / block;
            let seg_end = row_end.min(((bi + 1) * block).min(n));
            let lut = &sh.recon[bi * lut_len..(bi + 1) * lut_len];
            let mut c = g;
            while c < seg_end {
                let end = (c + CHUNK).min(seg_end);
                let len = end - c;
                sh.pt.codes_range_into(c, &mut scratch.codes[..len]);
                let w = &mut scratch.w[..len];
                for (o, &cd) in w.iter_mut().zip(&scratch.codes[..len]) {
                    *o = lut[(cd as i16 - code_min) as usize];
                }
                if !sh.zeros.is_empty() {
                    let z0 = sh.zeros.partition_point(|&z| (z as usize) < c);
                    let z1 = sh.zeros.partition_point(|&z| (z as usize) < end);
                    for &z in &sh.zeros[z0..z1] {
                        w[z as usize - c] = 0.0;
                    }
                }
                let x_off = c - row_start;
                for b in 0..batch {
                    let xb = &xs[b * cols + x_off..b * cols + x_off + len];
                    out[b * out_rows + (r - r0)] += kernel.dot(w, xb);
                }
                c = end;
            }
            g = seg_end;
        }
    }
}

/// The int8 row kernel: rows `[r0, r1)` of `y ≈ W·x` against a
/// pre-quantized activation. Walks the same (row ∩ block) segments as
/// [`run_rows`], additionally splitting each ≤[`CHUNK`] sub-chunk at
/// activation-block boundaries so exactly one
/// (weight-block × activation-block) pair owns every tile. Per tile:
/// unpack codes (exception-listed positions zeroed *in the code tile* —
/// their `a·c` term vanishes; their `b` term is removed by subtracting
/// their `x̂` from the block sum), accumulate `Σ c·x̂` (and `Σ x̂` when the
/// block's `b ≠ 0`) in exact i32, then apply the one f32 epilogue
/// `(a·Σc·x̂ + b·Σx̂)·x_scale` into the chunk-ordered partial chain.
/// Integer accumulation is associative, so scalar/AVX2/striping are
/// bit-identical with no further discipline.
fn run_rows_int8(
    sh: &Shared,
    kernel: Kernel,
    r0: usize,
    r1: usize,
    qx: &QuantizedVec,
    out: &mut [f32],
    scratch: &mut TileScratch,
) {
    let plan = sh.int8.as_ref().expect("int8 plan missing for int8 run");
    let (rows, cols) = (sh.pt.rows, sh.pt.cols);
    let n = rows * cols;
    let block = sh.pt.block.max(1);
    let batch = qx.batch();
    let out_rows = r1 - r0;
    const QB: usize = int8::QBLOCK;
    for r in r0..r1 {
        let row_start = r * cols;
        let row_end = row_start + cols;
        let mut g = row_start;
        while g < row_end {
            let bi = g / block;
            let seg_end = row_end.min(((bi + 1) * block).min(n));
            let (a, bc) = (plan.a[bi], plan.b[bi]);
            let mut c = g;
            while c < seg_end {
                let x_off = c - row_start;
                let qi = x_off / QB;
                // flat plans can start a tile mid-activation-block; split
                // at the next x-block boundary so (a, b, x_scale) are all
                // constant across the tile
                let end = (c + CHUNK).min(seg_end).min(row_start + (qi + 1) * QB);
                let len = end - c;
                let ct = &mut scratch.codes[..len];
                sh.pt.codes_range_into(c, ct);
                let (z0, z1) = if sh.zeros.is_empty() {
                    (0, 0)
                } else {
                    (
                        sh.zeros.partition_point(|&z| (z as usize) < c),
                        sh.zeros.partition_point(|&z| (z as usize) < end),
                    )
                };
                for &z in &sh.zeros[z0..z1] {
                    ct[z as usize - c] = 0;
                }
                for b in 0..batch {
                    let sx = qx.scale(b, qi);
                    if sx == 0.0 {
                        continue; // all-zero activation block: exact no-op
                    }
                    let xq = &qx.codes(b)[x_off..x_off + len];
                    let dot = int8::dot_i8(kernel, ct, xq);
                    // the b·Σx̂ term only exists for zero-point schemes;
                    // both kernels branch on the same block coefficient,
                    // so the skip cannot split scalar/SIMD behaviour
                    let xsum = if bc != 0.0 {
                        let mut s = int8::sum_i8(kernel, xq);
                        for &z in &sh.zeros[z0..z1] {
                            s -= xq[z as usize - c] as i32;
                        }
                        s
                    } else {
                        0
                    };
                    out[b * out_rows + (r - r0)] += (a * dot as f32 + bc * xsum as f32) * sx;
                }
                c = end;
            }
            g = seg_end;
        }
    }
}

/// Dense matvec over an already-decoded f32 matrix with the *same* chunked
/// lane structure the fused path uses — the fair decode-then-matmul
/// baseline for the `perf_gemv` ablation and `msb gemv-bench`.
pub fn dense_gemv(m: &Matrix, x: &[f32], kernel: Kernel) -> Vec<f32> {
    assert!(kernel.available(), "{} kernel not available on this CPU", kernel.name());
    assert_eq!(x.len(), m.cols, "x len != cols");
    let mut y = vec![0.0f32; m.rows];
    for (r, yr) in y.iter_mut().enumerate() {
        let row = m.row(r);
        let mut c = 0;
        while c < m.cols {
            let end = (c + CHUNK).min(m.cols);
            *yr += kernel.dot(&row[c..end], &x[c..end]);
            c = end;
        }
    }
    y
}

/// f64-accumulated matvec — the near-exact reference the fused output is
/// checked against (1e-5 relative, scaled by the row's |w·x| mass so
/// cancellation-heavy rows don't produce false alarms).
pub fn reference_matvec(m: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), m.cols, "x len != cols");
    (0..m.rows)
        .map(|r| {
            m.row(r).iter().zip(x).map(|(&w, &v)| w as f64 * v as f64).sum::<f64>() as f32
        })
        .collect()
}

/// Assert `got` matches the f64 reference for `m·x` within `rel` of each
/// row's L1 product mass (the natural scale for f32 summation error).
pub fn assert_matvec_close(m: &Matrix, x: &[f32], got: &[f32], rel: f64) {
    assert_eq!(got.len(), m.rows);
    for r in 0..m.rows {
        let row = m.row(r);
        let (mut sum, mut mass) = (0.0f64, 0.0f64);
        for (&w, &v) in row.iter().zip(x) {
            let p = w as f64 * v as f64;
            sum += p;
            mass += p.abs();
        }
        let tol = rel * mass.max(1e-30) + 1e-12;
        let diff = (got[r] as f64 - sum).abs();
        assert!(diff <= tol, "row {r}: got {} vs ref {sum} (diff {diff} > {tol})", got[r]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::engine::{decode_packed, quantize_serial};
    use crate::quant::hqq::HqqQuantizer;
    use crate::quant::msb::MsbQuantizer;
    use crate::quant::nf4::Nf4Quantizer;
    use crate::quant::rtn::RtnQuantizer;
    use crate::quant::xnor::XnorQuantizer;
    use crate::quant::QuantConfig;
    use crate::stats::Rng;

    fn weight_with_zeros(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut w = Matrix::randn(rows, cols, &mut Rng::new(seed));
        for i in (0..w.len()).step_by(41) {
            w.data[i] = 0.0; // exercise the exact-zero exception list
        }
        w
    }

    fn activation(cols: usize, seed: u64) -> Vec<f32> {
        let mut x = vec![0.0f32; cols];
        Rng::new(seed).fill_normal(&mut x, 1.0);
        x
    }

    /// The pre-fold hot loop: per-tile method decode, exception zeroing,
    /// then a bf16 rounding pass, with scalar dots — exactly the flow
    /// `run_rows` used before the reconstruction table existed. The LUT
    /// fold must reproduce it bit-for-bit.
    fn gemv_old_path(pt: &PackedTensor, x: &[f32]) -> Vec<f32> {
        let decoder = registry::block_decoder(&pt.method).unwrap();
        let (rows, cols) = (pt.rows, pt.cols);
        let n = rows * cols;
        let block = pt.block.max(1);
        let spb = pt.scales_per_block;
        let scales = pt.scales_f32();
        let mut zeros = pt.zeros.clone();
        zeros.sort_unstable();
        let mut y = vec![0.0f32; rows];
        let mut ctile = [0i8; CHUNK];
        let mut wtile = [0.0f32; CHUNK];
        for (r, yr) in y.iter_mut().enumerate() {
            let row_start = r * cols;
            let row_end = row_start + cols;
            let mut g = row_start;
            while g < row_end {
                let bi = g / block;
                let seg_end = row_end.min(((bi + 1) * block).min(n));
                let sc = &scales[bi * spb..(bi + 1) * spb];
                let mut c = g;
                while c < seg_end {
                    let end = (c + CHUNK).min(seg_end);
                    let len = end - c;
                    pt.codes_range_into(c, &mut ctile[..len]);
                    let w = &mut wtile[..len];
                    decoder.decode_block(&ctile[..len], sc, w);
                    let z0 = zeros.partition_point(|&z| (z as usize) < c);
                    let z1 = zeros.partition_point(|&z| (z as usize) < end);
                    for &z in &zeros[z0..z1] {
                        w[z as usize - c] = 0.0;
                    }
                    if pt.bf16 {
                        for v in w.iter_mut() {
                            *v = bf16::round(*v);
                        }
                    }
                    let x_off = c - row_start;
                    *yr += Kernel::Scalar.dot(w, &x[x_off..x_off + len]);
                    c = end;
                }
                g = seg_end;
            }
        }
        y
    }

    /// Fused gemv must (a) match the decode-then-matvec f64 reference to
    /// 1e-5 relative, (b) be bit-identical serial vs pooled at every
    /// thread count, (c) be bit-identical scalar vs SIMD, and (d) be
    /// bit-identical to the historical decode-per-tile path the LUT fold
    /// replaced.
    fn check_fused(q: Arc<dyn BlockQuantizer>, w: &Matrix, cfg: &QuantConfig, label: &str) {
        let cfg = cfg.clone().with_packed();
        let qt = quantize_serial(&*q, w, &cfg);
        let pt = qt.packed.unwrap_or_else(|| panic!("{label}: no payload"));
        let decoded = decode_packed(Arc::clone(&q), &pt, None);
        assert_eq!(decoded.data, qt.dequant.data, "{label}: decode sanity");
        let pl = PackedLinear::new(pt).unwrap_or_else(|e| panic!("{label}: {e}"));
        let x = activation(w.cols, 0xA11CE);

        let scalar = pl.clone().with_kernel(Kernel::Scalar);
        let y = scalar.gemv(&x);
        assert_matvec_close(&decoded, &x, &y, 1e-5);
        assert_eq!(y, gemv_old_path(pl.packed(), &x), "{label}: LUT fold != historical path");

        for threads in [1usize, 4] {
            let pool = ThreadPool::new(threads, threads * 4);
            let yp = scalar.gemv_pooled(&x, &pool);
            assert_eq!(y, yp, "{label}: pooled (threads={threads}) != serial");
        }

        if Kernel::detect_simd().is_some() {
            let ys = pl.clone().with_kernel(Kernel::detect()).gemv(&x);
            assert_eq!(y, ys, "{label}: SIMD != scalar");
        }
    }

    /// Satellite grid: every packable method × both granularities, all
    /// four storage widths (U1 xnor, U2 2-bit MSB, U4 4-bit grid, I8
    /// per-tensor 6-bit MSB), zero-exception rows included.
    #[test]
    fn fused_grid_matches_reference() {
        let w = weight_with_zeros(16, 256, 51);
        let bw = QuantConfig::block_wise(4, 64).unwrap();
        let pt_cfg = QuantConfig::per_tensor(4).unwrap().with_window(16).unwrap();
        let grid: Vec<(Arc<dyn BlockQuantizer>, &QuantConfig, &str)> = vec![
            (Arc::new(RtnQuantizer::symmetric()), &bw, "rtn/bw"),
            (Arc::new(RtnQuantizer::asymmetric()), &bw, "rtn-asym/bw"),
            (Arc::new(Nf4Quantizer::nf4()), &bw, "nf4/bw"),
            (Arc::new(HqqQuantizer::default()), &bw, "hqq/bw"),
            (Arc::new(XnorQuantizer::whole()), &bw, "xnor/bw"),
            (Arc::new(XnorQuantizer::blocked()), &bw, "blocked-xnor/bw"),
            (Arc::new(MsbQuantizer::wgm()), &bw, "wgm/bw"),
            (Arc::new(RtnQuantizer::symmetric()), &pt_cfg, "rtn/pt"),
            (Arc::new(HqqQuantizer::default()), &pt_cfg, "hqq/pt"),
            (Arc::new(XnorQuantizer::whole()), &pt_cfg, "xnor/pt"),
            (Arc::new(MsbQuantizer::wgm()), &pt_cfg, "wgm/pt"),
        ];
        for (q, cfg, label) in grid {
            check_fused(q, &w, cfg, label);
        }
        // U2: 2-bit MSB codes; U1: blocked-XNOR sign bits
        let two_bit = QuantConfig::block_wise(2, 64).unwrap().with_window(1).unwrap();
        check_fused(Arc::new(MsbQuantizer::wgm()), &w, &two_bit, "wgm/2-bit(u2)");
        check_fused(Arc::new(XnorQuantizer::blocked()), &w, &two_bit, "blocked-xnor(u1)");
        // I8: per-tensor 6-bit MSB (32 levels overflow a nibble)
        let six_bit = QuantConfig::per_tensor(6).unwrap().with_window(16).unwrap();
        let w_small = weight_with_zeros(8, 96, 52);
        check_fused(Arc::new(MsbQuantizer::wgm()), &w_small, &six_bit, "wgm/6-bit(i8)");
    }

    /// Ragged shapes: `cols % 64 != 0` (t=32 over 96 columns) and a flat
    /// plan whose blocks cross row boundaries (blocked-XNOR on 5×7, t=8).
    #[test]
    fn fused_ragged_and_flat_plans() {
        let w = weight_with_zeros(9, 96, 53);
        let cfg = QuantConfig::block_wise(4, 32).unwrap();
        check_fused(Arc::new(MsbQuantizer::wgm()), &w, &cfg, "wgm/t=32,cols=96");
        check_fused(Arc::new(RtnQuantizer::symmetric()), &w, &cfg, "rtn/t=32,cols=96");
        let tiny = Matrix::randn(5, 7, &mut Rng::new(54));
        let flat = QuantConfig::block_wise(4, 8).unwrap();
        check_fused(Arc::new(XnorQuantizer::blocked()), &tiny, &flat, "blocked-xnor/flat5x7");
    }

    #[test]
    fn gemm_batches_match_individual_gemvs() {
        let w = weight_with_zeros(12, 128, 55);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let q: Arc<dyn BlockQuantizer> = Arc::new(MsbQuantizer::wgm());
        let pt = quantize_serial(&*q, &w, &cfg).packed.unwrap();
        let pl = PackedLinear::new(pt).unwrap();
        let batch = 3;
        let mut xs = vec![0.0f32; batch * w.cols];
        Rng::new(56).fill_normal(&mut xs, 1.0);
        let ys = pl.gemm(&xs, batch);
        for b in 0..batch {
            let yb = pl.gemv(&xs[b * w.cols..(b + 1) * w.cols]);
            assert_eq!(&ys[b * w.rows..(b + 1) * w.rows], &yb[..], "batch row {b}");
        }
        let pool = ThreadPool::new(3, 12);
        assert_eq!(ys, pl.gemm_pooled(&xs, batch, &pool), "pooled gemm != serial");
    }

    #[test]
    fn dense_gemv_matches_fused_at_aligned_blocks() {
        // at t=64 the dense baseline's chunk anchoring coincides with the
        // fused path's, so the two are bit-identical — the ablation in
        // perf_gemv compares equal math, differing only in weight residency
        let w = weight_with_zeros(8, 256, 57);
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let q: Arc<dyn BlockQuantizer> = Arc::new(MsbQuantizer::wgm());
        let pt = quantize_serial(&*q, &w, &cfg).packed.unwrap();
        let decoded = decode_packed(Arc::clone(&q), &pt, None);
        let pl = PackedLinear::new(pt).unwrap().with_kernel(Kernel::Scalar);
        let x = activation(w.cols, 58);
        assert_eq!(pl.gemv(&x), dense_gemv(&decoded, &x, Kernel::Scalar));
    }

    #[test]
    fn scalar_dot_reduction_tree_is_fixed() {
        // a permutation-sensitive probe: if the lane tree changed, the
        // rounded result would drift from this frozen expectation
        let w: Vec<f32> = (0..19).map(|i| 1.0 + i as f32 * 0.125).collect();
        let x: Vec<f32> = (0..19).map(|i| 0.5 - i as f32 * 0.0625).collect();
        let d = dot_chunk_scalar(&w, &x);
        let mut lanes = [0.0f32; 8];
        for k in (0..16).step_by(8) {
            for j in 0..8 {
                lanes[j] += w[k + j] * x[k + j];
            }
        }
        let q =
            [lanes[0] + lanes[4], lanes[1] + lanes[5], lanes[2] + lanes[6], lanes[3] + lanes[7]];
        let mut want = (q[0] + q[2]) + (q[1] + q[3]);
        for i in 16..19 {
            want += w[i] * x[i];
        }
        assert_eq!(d, want);
    }

    #[test]
    fn simd_dot_bit_identical_to_scalar() {
        let Some(simd) = Kernel::detect_simd() else {
            eprintln!("skipping: no SIMD kernel on this CPU");
            return;
        };
        let mut rng = Rng::new(59);
        for len in [1usize, 7, 8, 9, 16, 33, 63, 64] {
            let mut w = vec![0.0f32; len];
            let mut x = vec![0.0f32; len];
            rng.fill_normal(&mut w, 1.0);
            rng.fill_normal(&mut x, 1.0);
            assert_eq!(simd.dot(&w, &x), Kernel::Scalar.dot(&w, &x), "len {len}");
        }
    }

    /// Randomized property: random shapes (cols a multiple of 32, so the
    /// ragged `cols % 64 != 0` case comes up constantly), random zero
    /// sprinkling, two methods — fused gemv always matches the
    /// decode-then-matvec reference.
    #[test]
    fn fused_gemv_property() {
        crate::testing::check(
            "fused gemv matches reference",
            8,
            |rng| {
                let rows = 1 + rng.below(12);
                let cols = 32 * (1 + rng.below(6));
                let mut w = Matrix::randn(rows, cols, rng);
                for v in &mut w.data {
                    if rng.uniform() < 0.02 {
                        *v = 0.0;
                    }
                }
                (w, rng.below(2))
            },
            |(w, pick)| {
                let q: Arc<dyn BlockQuantizer> = if *pick == 0 {
                    Arc::new(MsbQuantizer::wgm())
                } else {
                    Arc::new(RtnQuantizer::symmetric())
                };
                let cfg = QuantConfig::block_wise(4, 32).unwrap().with_packed();
                let qt = quantize_serial(&*q, w, &cfg);
                let decoded = decode_packed(Arc::clone(&q), qt.packed.as_ref().unwrap(), None);
                let pl = PackedLinear::new(qt.packed.unwrap()).unwrap();
                let x = activation(w.cols, 0xCAFE);
                assert_matvec_close(&decoded, &x, &pl.gemv(&x), 1e-5);
                true
            },
        );
    }

    #[test]
    fn rejects_corrupt_payloads() {
        let w = Matrix::randn(4, 64, &mut Rng::new(60));
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let q: Arc<dyn BlockQuantizer> = Arc::new(RtnQuantizer::symmetric());
        let pt = quantize_serial(&*q, &w, &cfg).packed.unwrap();
        let mut bad = pt.clone();
        bad.method = "nope".into();
        assert!(PackedLinear::new(bad).is_err());
        let mut bad = pt.clone();
        bad.zeros.push(1 << 30);
        assert!(PackedLinear::new(bad).is_err());
        let mut bad = pt;
        bad.scales_per_block = 7; // scale table no longer covers the blocks
        assert!(PackedLinear::new(bad).is_err());
        // SignLevel i8 magnitude beyond the scale table fails construction
        // instead of panicking inside the reconstruction-table build.
        let w6 = Matrix::randn(4, 64, &mut Rng::new(61));
        let cfg6 = QuantConfig::per_tensor(6).unwrap().with_window(16).unwrap().with_packed();
        let q6: Arc<dyn BlockQuantizer> = Arc::new(MsbQuantizer::wgm());
        let mut bad = quantize_serial(&*q6, &w6, &cfg6).packed.unwrap();
        if let PackedCodes::I8(v) = &mut bad.codes {
            v[0] = 127;
        } else {
            panic!("6-bit per-tensor payload should store i8 codes");
        }
        assert!(PackedLinear::new(bad).is_err());
    }

    #[test]
    fn mac_mode_parses() {
        assert_eq!(MacMode::parse("f32").unwrap(), MacMode::F32);
        assert_eq!(MacMode::parse("int8").unwrap(), MacMode::Int8);
        assert_eq!(MacMode::parse("auto").unwrap(), MacMode::Auto);
        assert!(MacMode::parse("i4").is_err());
    }

    /// Int8 MAC: run the integer path against the decoded f64 reference
    /// within the activation-quantization budget, and require
    /// bit-identity across scalar/SIMD/pooled — integer accumulation is
    /// associative, so the i8 path gets determinism for free.
    fn check_int8(q: Arc<dyn BlockQuantizer>, w: &Matrix, cfg: &QuantConfig, label: &str) {
        let cfg = cfg.clone().with_packed();
        let qt = quantize_serial(&*q, w, &cfg);
        let pt = qt.packed.unwrap_or_else(|| panic!("{label}: no payload"));
        let decoded = decode_packed(Arc::clone(&q), &pt, None);
        let pl = PackedLinear::new(pt).unwrap_or_else(|e| panic!("{label}: {e}"));
        assert!(pl.int8_eligible(), "{label}: expected an affine decode");
        let pl = pl.with_mac(MacMode::Int8).unwrap_or_else(|e| panic!("{label}: {e}"));
        let x = activation(w.cols, 0xB10C);

        let scalar = pl.clone().with_kernel(Kernel::Scalar);
        let y = scalar.gemv(&x);
        // per-block i8 activation rounding costs ~0.5% relative per dot;
        // 2.5e-2 under the L1-mass scale leaves slack for cancellation
        assert_matvec_close(&decoded, &x, &y, 2.5e-2);

        if Kernel::detect_simd().is_some() {
            let ys = pl.clone().with_kernel(Kernel::detect()).gemv(&x);
            assert_eq!(y, ys, "{label}: int8 SIMD != scalar");
        }
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads, threads * 4);
            assert_eq!(y, scalar.gemv_pooled(&x, &pool), "{label}: int8 pooled t={threads}");
        }
        let batch = 2;
        let mut xs = vec![0.0f32; batch * w.cols];
        Rng::new(0x1B).fill_normal(&mut xs, 1.0);
        let ys = scalar.gemm(&xs, batch);
        for b in 0..batch {
            let yb = scalar.gemv(&xs[b * w.cols..(b + 1) * w.cols]);
            assert_eq!(&ys[b * w.rows..(b + 1) * w.rows], &yb[..], "{label}: int8 batch {b}");
        }
    }

    /// Tentpole grid: every affine-eligible method × both granularities,
    /// plus ragged columns (`96 % 64 != 0`, so weight sub-chunks cross
    /// activation-block edges) and a flat plan whose blocks cross rows.
    #[test]
    fn int8_grid_matches_reference() {
        let w = weight_with_zeros(16, 256, 71);
        let bw = QuantConfig::block_wise(4, 64).unwrap();
        let pt_cfg = QuantConfig::per_tensor(4).unwrap().with_window(16).unwrap();
        let grid: Vec<(Arc<dyn BlockQuantizer>, &QuantConfig, &str)> = vec![
            (Arc::new(RtnQuantizer::symmetric()), &bw, "rtn/bw"),
            (Arc::new(RtnQuantizer::asymmetric()), &bw, "rtn-asym/bw"),
            (Arc::new(HqqQuantizer::default()), &bw, "hqq/bw"),
            (Arc::new(XnorQuantizer::whole()), &bw, "xnor/bw"),
            (Arc::new(XnorQuantizer::blocked()), &bw, "blocked-xnor/bw"),
            (Arc::new(RtnQuantizer::symmetric()), &pt_cfg, "rtn/pt"),
            (Arc::new(HqqQuantizer::default()), &pt_cfg, "hqq/pt"),
            (Arc::new(XnorQuantizer::whole()), &pt_cfg, "xnor/pt"),
        ];
        for (q, cfg, label) in grid {
            check_int8(q, &w, cfg, label);
        }
        let ragged = weight_with_zeros(9, 96, 72);
        let t32 = QuantConfig::block_wise(4, 32).unwrap();
        check_int8(Arc::new(RtnQuantizer::symmetric()), &ragged, &t32, "rtn/t=32,cols=96");
        check_int8(Arc::new(RtnQuantizer::asymmetric()), &ragged, &t32, "rtn-asym/t=32,cols=96");
        let tiny = Matrix::randn(5, 7, &mut Rng::new(73));
        let flat = QuantConfig::block_wise(4, 8).unwrap();
        check_int8(Arc::new(XnorQuantizer::blocked()), &tiny, &flat, "blocked-xnor/flat5x7");
    }

    /// Non-affine decodes (NF4 codebook lookup, MSB sign·level table) must
    /// refuse `MacMode::Int8` and fall back bit-exactly under `Auto`;
    /// affine methods under `Auto` must actually take the integer path.
    #[test]
    fn int8_eligibility_and_auto_fallback() {
        let w = weight_with_zeros(8, 128, 74);
        let bw = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let ineligible: Vec<(Arc<dyn BlockQuantizer>, &str)> = vec![
            (Arc::new(Nf4Quantizer::nf4()), "nf4"),
            (Arc::new(MsbQuantizer::wgm()), "msb-wgm"),
        ];
        for (q, label) in ineligible {
            let pt = quantize_serial(&*q, &w, &bw).packed.unwrap();
            let pl = PackedLinear::new(pt).unwrap();
            assert!(!pl.int8_eligible(), "{label}: codebook decode must not be affine");
            assert!(pl.clone().with_mac(MacMode::Int8).is_err(), "{label}: Int8 must refuse");
            let auto = pl.clone().with_mac(MacMode::Auto).unwrap();
            assert!(!auto.int8_active(), "{label}: Auto must fall back");
            let x = activation(w.cols, 75);
            assert_eq!(auto.gemv(&x), pl.gemv(&x), "{label}: Auto fallback != f32 path");
        }
        let q: Arc<dyn BlockQuantizer> = Arc::new(RtnQuantizer::symmetric());
        let pt = quantize_serial(&*q, &w, &bw).packed.unwrap();
        let auto = PackedLinear::new(pt).unwrap().with_mac(MacMode::Auto).unwrap();
        assert!(auto.int8_active(), "rtn under Auto must engage the integer MAC");
        let x = activation(w.cols, 76);
        assert_eq!(auto.gemv(&x), auto.gemv_int8(&x), "Auto(eligible) must route to int8");
    }

    /// Randomized property: random eligible method / shape / zero
    /// sprinkling — the integer MAC stays inside the activation-quant
    /// budget of the decoded reference and pooled equals serial bitwise.
    #[test]
    fn int8_gemv_property() {
        crate::testing::check(
            "int8 gemv within budget of reference",
            8,
            |rng| {
                let rows = 1 + rng.below(10);
                let cols = 32 * (1 + rng.below(6));
                let mut w = Matrix::randn(rows, cols, rng);
                for v in &mut w.data {
                    if rng.uniform() < 0.02 {
                        *v = 0.0;
                    }
                }
                (w, rng.below(3))
            },
            |(w, pick)| {
                let q: Arc<dyn BlockQuantizer> = match *pick {
                    0 => Arc::new(RtnQuantizer::symmetric()),
                    1 => Arc::new(RtnQuantizer::asymmetric()),
                    _ => Arc::new(HqqQuantizer::default()),
                };
                let cfg = QuantConfig::block_wise(4, 32).unwrap().with_packed();
                let qt = quantize_serial(&*q, w, &cfg);
                let decoded = decode_packed(Arc::clone(&q), qt.packed.as_ref().unwrap(), None);
                let pl = PackedLinear::new(qt.packed.unwrap())
                    .unwrap()
                    .with_mac(MacMode::Int8)
                    .unwrap();
                let x = activation(w.cols, 0xD07);
                let y = pl.gemv(&x);
                assert_matvec_close(&decoded, &x, &y, 2.5e-2);
                let pool = ThreadPool::new(2, 8);
                assert_eq!(y, pl.gemv_pooled(&x, &pool), "int8 pooled != serial");
                true
            },
        );
    }
}
