//! Dependency-free CLI argument parsing (no clap offline): positional
//! subcommand + `--key value` / `--flag` pairs.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = argv.into_iter();
        let command = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut pending: Option<String> = None;
        for a in it {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(key) = pending.take() {
                    flags.insert(key, "true".into()); // bare flag
                }
                pending = Some(stripped.to_string());
            } else if let Some(key) = pending.take() {
                flags.insert(key, a);
            } else {
                anyhow::bail!("unexpected positional argument '{a}'");
            }
        }
        if let Some(key) = pending.take() {
            flags.insert(key, "true".into());
        }
        Ok(Args { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}: not an integer")),
        }
    }

    pub fn u32_or(&self, key: &str, default: u32) -> Result<u32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}: not an integer")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}: not a number")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic() {
        let a = parse("eval --model base --bits 4 --verbose");
        assert_eq!(a.command, "eval");
        assert_eq!(a.get("model"), Some("base"));
        assert_eq!(a.u32_or("bits", 0).unwrap(), 4);
        assert!(a.has("verbose"));
        assert_eq!(a.str_or("method", "wgm"), "wgm");
    }

    #[test]
    fn empty() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "");
    }

    #[test]
    fn bad_positional_rejected() {
        assert!(Args::parse(["x".into(), "stray".into()]).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let a = parse("x --bits four");
        assert!(a.u32_or("bits", 0).is_err());
    }

    #[test]
    fn trailing_bare_flag() {
        let a = parse("x --fast");
        assert!(a.has("fast"));
    }
}
