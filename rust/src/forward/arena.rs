//! Paged KV-cache arena for multi-stream decode.
//!
//! [`KvArena`] replaces the one-[`KvState`](super::KvState)-per-request
//! model for serving: instead of every stream owning a private
//! `[seq, d]` slab per layer (heap-grown up front to the full context
//! window), the arena holds **one slab of fixed-size pages per layer**
//! and hands pages to streams on demand through a free-list allocator.
//! Each stream carries a page table (`position / page_tokens → page id`);
//! retiring a stream returns its pages to the free list immediately, so
//! a mix of short and long requests shares the same bounded memory.
//!
//! The page id is layer-agnostic: page `p` addresses the same slot in
//! every layer's slab, so one table per stream covers the whole stack.
//!
//! Determinism: page *placement* never touches the math. Attention reads
//! positions in ascending order through the table
//! ([`super::ops::attend_paged`]), and the per-position f64 accumulation
//! is identical to the contiguous [`super::ops::attend`] — which page a
//! position happens to live in only changes addresses, never values or
//! operation order. `ForwardModel::step_batch` outputs are therefore
//! bit-identical to per-stream solo `step` runs regardless of allocation
//! history.

use anyhow::{ensure, Result};

/// Handle to one stream's cache inside a [`KvArena`]. Obtained from
/// [`KvArena::alloc_stream`]; invalidated by [`KvArena::free_stream`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamId(usize);

/// Per-stream bookkeeping: the page table and the decode position.
struct StreamEntry {
    /// Page ids in position order: position `t` lives in
    /// `pages[t / page_tokens]` at in-page offset `t % page_tokens`.
    pages: Vec<usize>,
    /// Positions already decoded into the cache.
    len: usize,
}

/// One slab of fixed-size KV pages per layer plus a free-list allocator
/// and per-stream page tables. See the module docs.
pub struct KvArena {
    /// `[layers][total_pages * page_tokens * d]` key / value slabs.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Free page ids (LIFO: a retired stream's pages are reused first).
    free: Vec<usize>,
    /// Slot map of live streams; `None` slots are reusable.
    streams: Vec<Option<StreamEntry>>,
    layers: usize,
    d: usize,
    page_tokens: usize,
    /// Per-stream position cap (the model's context window).
    seq: usize,
    total_pages: usize,
    /// High-water mark of simultaneously allocated pages.
    peak_pages: usize,
}

impl KvArena {
    /// An arena of `total_pages` pages of `page_tokens` positions each,
    /// shared by any number of concurrent streams (each capped at `seq`
    /// positions). Sizing rule of thumb:
    /// `total_pages = max_streams * seq.div_ceil(page_tokens)` guarantees
    /// `max_streams` full-context streams never starve —
    /// [`super::ForwardModel::kv_arena`] applies it.
    pub fn new(
        layers: usize,
        d: usize,
        seq: usize,
        page_tokens: usize,
        total_pages: usize,
    ) -> Result<KvArena> {
        ensure!(layers > 0 && d > 0 && seq > 0, "degenerate arena shape");
        ensure!(page_tokens > 0, "page_tokens must be positive");
        ensure!(total_pages > 0, "total_pages must be positive");
        let slab = total_pages * page_tokens * d;
        Ok(KvArena {
            k: (0..layers).map(|_| vec![0.0; slab]).collect(),
            v: (0..layers).map(|_| vec![0.0; slab]).collect(),
            // LIFO free list: ids pushed in reverse so the first alloc
            // takes page 0 (cosmetic; placement never affects the math)
            free: (0..total_pages).rev().collect(),
            streams: Vec::new(),
            layers,
            d,
            page_tokens,
            seq,
            total_pages,
            peak_pages: 0,
        })
    }

    /// Admit a new stream (empty cache, no pages yet). Stream ids are
    /// cheap slot-map handles; the page allocator in
    /// [`KvArena::reserve`] is the real capacity bound.
    pub fn alloc_stream(&mut self) -> StreamId {
        let mut entry = Some(StreamEntry { pages: Vec::new(), len: 0 });
        let mut id = None;
        for (i, slot) in self.streams.iter_mut().enumerate() {
            if slot.is_none() {
                *slot = entry.take();
                id = Some(StreamId(i));
                break;
            }
        }
        let id = id.unwrap_or_else(|| {
            self.streams.push(entry);
            StreamId(self.streams.len() - 1)
        });
        self.debug_check_balance();
        id
    }

    /// Retire a stream: its pages return to the free list immediately and
    /// are reused by the next allocation. The id becomes invalid.
    pub fn free_stream(&mut self, id: StreamId) {
        if let Some(entry) = self.streams.get_mut(id.0).and_then(|slot| slot.take()) {
            self.free.extend(entry.pages);
        }
        self.debug_check_balance();
    }

    fn entry(&self, id: StreamId) -> Result<&StreamEntry> {
        self.streams
            .get(id.0)
            .and_then(Option::as_ref)
            .ok_or_else(|| anyhow::anyhow!("stream {} is not live", id.0))
    }

    /// Positions already decoded for `id`.
    pub fn len(&self, id: StreamId) -> Result<usize> {
        Ok(self.entry(id)?.len)
    }

    /// Whether `id` has decoded any positions yet.
    pub fn is_empty(&self, id: StreamId) -> Result<bool> {
        Ok(self.entry(id)?.len == 0)
    }

    /// Grow `id`'s page table to cover positions `0..new_len`, taking
    /// pages from the free list. Fails (leaving the stream unchanged) if
    /// the arena is out of pages or `new_len` exceeds the context window.
    pub fn reserve(&mut self, id: StreamId, new_len: usize) -> Result<()> {
        ensure!(new_len <= self.seq, "stream overflow: {new_len} > seq {}", self.seq);
        let have = self.entry(id)?.pages.len();
        let need = new_len.div_ceil(self.page_tokens);
        if need <= have {
            return Ok(());
        }
        ensure!(
            self.free.len() >= need - have,
            "KV arena out of pages: need {} more, {} free of {}",
            need - have,
            self.free.len(),
            self.total_pages
        );
        for _ in have..need {
            let page = self.free.pop().expect("free list checked above");
            self.streams[id.0].as_mut().expect("entry checked above").pages.push(page);
        }
        self.peak_pages = self.peak_pages.max(self.pages_in_use());
        self.debug_check_balance();
        Ok(())
    }

    /// Roll a stream back to `new_len` decoded positions — the reject
    /// path of speculative decode. Whole pages past
    /// `ceil(new_len / page_tokens)` return to the LIFO free list and are
    /// reused by the next reservation; a partially covered tail page
    /// stays (its stale positions are simply overwritten by the next
    /// [`KvArena::append`], and attention never reads positions `>= len`,
    /// so stale data is unreachable). `peak_pages` is a lifetime
    /// high-water mark and deliberately does not move. Fails (leaving the
    /// stream unchanged) if the stream is dead or `new_len` exceeds its
    /// current length — truncate never grows.
    pub fn truncate_stream(&mut self, id: StreamId, new_len: usize) -> Result<()> {
        let len = self.entry(id)?.len;
        ensure!(
            new_len <= len,
            "truncate_stream cannot grow stream {}: {new_len} > len {len}",
            id.0
        );
        let keep = new_len.div_ceil(self.page_tokens);
        let entry = self.streams[id.0].as_mut().expect("entry checked above");
        while entry.pages.len() > keep {
            let page = entry.pages.pop().expect("len checked by loop condition");
            self.free.push(page);
        }
        entry.len = new_len;
        self.debug_check_balance();
        Ok(())
    }

    /// Page-conservation invariant: every page is either held by exactly
    /// one live stream's table or on the free list. The serving layer
    /// asserts this after quarantining a faulted stream; release builds
    /// can call it too (it is O(streams), not O(pages)).
    pub fn balanced(&self) -> bool {
        self.streams.iter().flatten().map(|e| e.pages.len()).sum::<usize>() + self.free.len()
            == self.total_pages
    }

    /// Debug-build check of [`KvArena::balanced`] after every operation
    /// that moves pages or streams — alloc/free/reserve/truncate.
    fn debug_check_balance(&self) {
        debug_assert!(
            self.balanced(),
            "KV arena page balance violated: pages_in_tables + free != total"
        );
    }

    /// Write a chunk of roped keys/values (`[t_new, d]` row-major) for
    /// stream `id` into layer `li` at positions `t0..t0 + t_new`, and (on
    /// the final layer) advance the stream's length. The pages must have
    /// been reserved ([`KvArena::reserve`]) beforehand.
    pub(super) fn append(
        &mut self,
        li: usize,
        id: StreamId,
        t0: usize,
        k: &[f32],
        v: &[f32],
        t_new: usize,
    ) {
        let (d, pt) = (self.d, self.page_tokens);
        let entry = self.streams[id.0].as_ref().expect("append to dead stream");
        debug_assert!(entry.pages.len() * pt >= t0 + t_new, "append past reservation");
        for i in 0..t_new {
            let pos = t0 + i;
            let base = (entry.pages[pos / pt] * pt + pos % pt) * d;
            self.k[li][base..base + d].copy_from_slice(&k[i * d..(i + 1) * d]);
            self.v[li][base..base + d].copy_from_slice(&v[i * d..(i + 1) * d]);
        }
    }

    /// Record that `t_new` positions were appended to `id` (after the
    /// last layer's [`KvArena::append`]).
    pub(super) fn advance(&mut self, id: StreamId, t_new: usize) {
        let entry = self.streams[id.0].as_mut().expect("advance on dead stream");
        entry.len += t_new;
    }

    /// Layer `li`'s key/value slabs (read-side of the attention jobs).
    pub(super) fn layer(&self, li: usize) -> (&[f32], &[f32]) {
        (&self.k[li], &self.v[li])
    }

    /// Stream `id`'s page table (read-side of the attention jobs).
    pub(super) fn pages(&self, id: StreamId) -> &[usize] {
        &self.streams[id.0].as_ref().expect("pages of dead stream").pages
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Pages currently held by live streams.
    pub fn pages_in_use(&self) -> usize {
        self.total_pages - self.free.len()
    }

    /// High-water mark of [`KvArena::pages_in_use`] over the arena's
    /// lifetime — the honest memory cost of the workload served so far.
    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    /// Live streams right now.
    pub fn live_streams(&self) -> usize {
        self.streams.iter().filter(|s| s.is_some()).count()
    }

    /// Bytes of K+V storage one page covers across every layer.
    pub fn page_bytes(&self) -> usize {
        2 * self.layers * self.page_tokens * self.d * std::mem::size_of::<f32>()
    }

    /// Peak bytes actually committed to live streams
    /// (`peak_pages * page_bytes`) — the number the `perf_serve` bench
    /// holds against the sum of naive per-request `[seq, d]` caches.
    pub fn peak_bytes(&self) -> usize {
        self.peak_pages * self.page_bytes()
    }

    /// What one naive per-request cache costs at full context: a
    /// `[seq, d]` K+V slab per layer ([`super::KvState`] with batch 1).
    pub fn naive_stream_bytes(&self) -> usize {
        2 * self.layers * self.seq * self.d * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> KvArena {
        // 2 layers, d=4, seq=10, 4-token pages, 8 pages total
        KvArena::new(2, 4, 10, 4, 8).unwrap()
    }

    #[test]
    fn rejects_degenerate_shapes() {
        assert!(KvArena::new(0, 4, 8, 4, 4).is_err());
        assert!(KvArena::new(1, 4, 8, 0, 4).is_err());
        assert!(KvArena::new(1, 4, 8, 4, 0).is_err());
    }

    #[test]
    fn reserve_allocates_on_page_boundaries() {
        let mut a = arena();
        let s = a.alloc_stream();
        assert_eq!(a.len(s).unwrap(), 0);
        a.reserve(s, 3).unwrap(); // fits one 4-token page
        assert_eq!(a.pages_in_use(), 1);
        a.reserve(s, 4).unwrap(); // still one page
        assert_eq!(a.pages_in_use(), 1);
        a.reserve(s, 5).unwrap(); // crosses into a second page
        assert_eq!(a.pages_in_use(), 2);
        // overflow past the context window is refused
        assert!(a.reserve(s, 11).is_err());
    }

    #[test]
    fn free_list_recycles_pages() {
        let mut a = arena();
        let s1 = a.alloc_stream();
        let s2 = a.alloc_stream();
        a.reserve(s1, 8).unwrap(); // 2 pages
        a.reserve(s2, 8).unwrap(); // 2 pages
        assert_eq!(a.pages_in_use(), 4);
        assert_eq!(a.peak_pages(), 4);
        a.free_stream(s1);
        assert_eq!(a.pages_in_use(), 2, "retirement returns pages immediately");
        // a new stream reuses the freed pages: peak does not grow
        let s3 = a.alloc_stream();
        a.reserve(s3, 8).unwrap();
        assert_eq!(a.pages_in_use(), 4);
        assert_eq!(a.peak_pages(), 4, "recycled pages must not raise the peak");
        // operations on the dead id fail; the live ones still work
        assert!(a.len(s1).is_err());
        assert_eq!(a.len(s2).unwrap(), 0);
    }

    #[test]
    fn out_of_pages_is_an_error_not_a_corruption() {
        let mut a = arena();
        let s1 = a.alloc_stream();
        let s2 = a.alloc_stream();
        let s3 = a.alloc_stream();
        a.reserve(s1, 10).unwrap(); // 3 pages
        a.reserve(s2, 10).unwrap(); // 3 pages
        a.reserve(s3, 8).unwrap(); // 2 pages -> all 8 gone
        assert_eq!(a.pages_in_use(), 8);
        let s4 = a.alloc_stream();
        assert!(a.reserve(s4, 1).is_err(), "arena must refuse, not corrupt");
        // freeing one stream unblocks the waiter
        a.free_stream(s1);
        a.reserve(s4, 1).unwrap();
        assert!(a.pages_in_use() <= 8);
    }

    #[test]
    fn append_round_trips_through_the_page_table() {
        let mut a = arena();
        let s = a.alloc_stream();
        a.reserve(s, 6).unwrap();
        let d = 4;
        // write positions 0..6 in two chunks with distinct values
        let mk = |t0: usize, t_new: usize, tag: f32| -> (Vec<f32>, Vec<f32>) {
            let mut k = vec![0.0f32; t_new * d];
            let mut v = vec![0.0f32; t_new * d];
            for i in 0..t_new {
                for c in 0..d {
                    k[i * d + c] = tag + (t0 + i) as f32 * 10.0 + c as f32;
                    v[i * d + c] = -(tag + (t0 + i) as f32 * 10.0 + c as f32);
                }
            }
            (k, v)
        };
        for li in 0..2 {
            let (k, v) = mk(0, 4, (li * 1000) as f32);
            a.append(li, s, 0, &k, &v, 4);
        }
        a.advance(s, 4);
        for li in 0..2 {
            let (k, v) = mk(4, 2, (li * 1000) as f32);
            a.append(li, s, 4, &k, &v, 2);
        }
        a.advance(s, 2);
        assert_eq!(a.len(s).unwrap(), 6);
        // read back through the table: every position, both layers
        let pt = a.page_tokens();
        for li in 0..2 {
            let (ks, vs) = a.layer(li);
            let pages = a.pages(s);
            for pos in 0..6 {
                let base = (pages[pos / pt] * pt + pos % pt) * d;
                for c in 0..d {
                    let want = (li * 1000) as f32 + pos as f32 * 10.0 + c as f32;
                    assert_eq!(ks[base + c], want, "k layer {li} pos {pos} col {c}");
                    assert_eq!(vs[base + c], -want, "v layer {li} pos {pos} col {c}");
                }
            }
        }
    }

    #[test]
    fn truncate_returns_whole_page_tails_and_recycles_them() {
        let mut a = arena(); // 4-token pages, 8 pages
        let s = a.alloc_stream();
        a.reserve(s, 10).unwrap(); // 3 pages
        a.advance(s, 10);
        let before = a.pages(s).to_vec();
        assert_eq!(before.len(), 3);
        let peak = a.peak_pages();
        // roll back to 5 positions: the page covering 8..10 returns, the
        // page covering 4..8 stays (position 5 is mid-page)
        a.truncate_stream(s, 5).unwrap();
        assert_eq!(a.len(s).unwrap(), 5);
        assert_eq!(a.pages(s), &before[..2]);
        assert_eq!(a.pages_in_use(), 2);
        assert_eq!(a.peak_pages(), peak, "truncate must not move the high-water mark");
        assert_eq!(a.peak_bytes(), peak * a.page_bytes(), "peak_bytes tracks the same mark");
        // the freed tail page is reused first (LIFO): re-reserving hands
        // the identical id back
        a.reserve(s, 10).unwrap();
        assert_eq!(a.pages(s), &before[..], "freed tail page must recycle LIFO");
        assert_eq!(a.peak_pages(), peak, "recycled page must not raise the peak");
        a.advance(s, 5);
        // page-exact truncate keeps exactly ceil(8/4) = 2 pages
        a.truncate_stream(s, 8).unwrap();
        assert_eq!(a.pages(s), &before[..2]);
        // truncate to 0 returns everything
        a.truncate_stream(s, 0).unwrap();
        assert_eq!(a.pages(s).len(), 0);
        assert_eq!(a.pages_in_use(), 0);
        // growing via truncate is refused; dead streams are refused
        assert!(a.truncate_stream(s, 1).is_err());
        a.free_stream(s);
        assert!(a.truncate_stream(s, 0).is_err());
    }

    /// Randomized accept/reject schedules against a *scripted* greedy
    /// model (next token a pure function of the last token and the
    /// position — no network needed to pin the scheduler algebra). Each
    /// wave drafts random lookahead tokens, feeds `1 + k` positions,
    /// accepts the longest prefix matching the script, and rolls the
    /// arena back with `truncate_stream`. Asserts the speculative
    /// committed stream is bit-equal to plain decode, page balance is
    /// restored after every wave, and the peak never grows once the
    /// first wave set it.
    #[test]
    fn fuzz_random_draft_rollback_against_scripted_model() {
        use crate::stats::Rng;
        let vocab = 23i64;
        let script = |last: i32, pos: usize| -> i32 {
            ((last as i64 * 7 + pos as i64 * 3 + 1).rem_euclid(vocab)) as i32
        };
        let seq = 48;
        let max_draft = 3usize;
        let mut a = KvArena::new(2, 4, seq, 3, 64).unwrap();
        let mut rng = Rng::new(0xD12A);
        let mut peak_after_first_wave = 0usize;
        for wave in 0..8 {
            let plen = 3 + rng.below(5);
            let prompt: Vec<i32> = (0..plen).map(|i| ((wave * 5 + i) % 23) as i32).collect();
            let max_new = 8 + rng.below(20);
            // plain greedy reference: one committed token per step
            let mut plain = prompt.clone();
            let goal = (plen + max_new).min(seq);
            while plain.len() < goal {
                plain.push(script(*plain.last().unwrap(), plain.len()));
            }
            // speculative run over the real arena
            let s = a.alloc_stream();
            let mut committed = prompt.clone();
            a.reserve(s, committed.len()).unwrap();
            a.advance(s, committed.len());
            while committed.len() < plain.len() {
                let fed0 = a.len(s).unwrap();
                let next = plain[committed.len()];
                committed.push(next);
                // random drafts, biased toward correct so accepts happen
                let want = rng.below(1 + max_draft);
                let k = want.min(seq - fed0 - 1);
                let drafts: Vec<i32> = (0..k)
                    .map(|i| {
                        let pos = committed.len() + i;
                        if pos < plain.len() && rng.below(2) == 0 {
                            plain[pos]
                        } else {
                            rng.below(23) as i32
                        }
                    })
                    .collect();
                // feed [next, drafts..]: reserve + advance like step_batch
                a.reserve(s, fed0 + 1 + k).unwrap();
                a.advance(s, 1 + k);
                // scripted verification: accept the longest matching prefix
                let mut j = 0;
                while j < k && committed.len() < plain.len() && drafts[j] == plain[committed.len()]
                {
                    committed.push(drafts[j]);
                    j += 1;
                }
                a.truncate_stream(s, fed0 + 1 + j).unwrap();
                assert_eq!(a.len(s).unwrap(), fed0 + 1 + j, "rollback length");
                assert_eq!(
                    a.pages(s).len(),
                    (fed0 + 1 + j).div_ceil(a.page_tokens()),
                    "rollback page count"
                );
            }
            assert_eq!(committed, plain, "wave {wave}: speculative stream diverged from plain");
            a.free_stream(s);
            assert_eq!(a.pages_in_use(), 0, "wave {wave} leaked pages");
            if wave == 0 {
                peak_after_first_wave = a.peak_pages();
                assert!(peak_after_first_wave > 0);
            }
        }
        // every wave recycled through the same free list; one wave's
        // worth of pages (plus draft overshoot) bounds the peak
        let bound = seq.div_ceil(a.page_tokens()) + max_draft.div_ceil(a.page_tokens());
        assert!(
            a.peak_pages() <= bound,
            "peak {} pages exceeds one stream + draft overshoot bound {bound}",
            a.peak_pages()
        );
    }

    #[test]
    fn byte_accounting() {
        let a = arena();
        // one page: 2 layers * K+V * 4 tokens * d=4 * 4 bytes
        assert_eq!(a.page_bytes(), 2 * 2 * 4 * 4 * 4);
        assert_eq!(a.naive_stream_bytes(), 2 * 2 * 10 * 4 * 4);
        assert_eq!(a.peak_bytes(), 0, "nothing reserved yet");
    }
}
