//! Fused CPU transformer forward pass for full token scoring — no XLA.
//!
//! [`ForwardModel`] runs the whole decoder stack (embedding lookup,
//! RMSNorm, RoPE, causal attention with a KV cache, SwiGLU MLP,
//! final-norm + logits) with *every projection* going through
//! [`crate::kernels`]: quantized layers as [`PackedLinear`] handles that
//! multiply straight off the packed codes, and non-quantized layers (an
//! exception list, or the f32-reference twin) through [`dense_gemv`] with
//! the same chunked lane structure. Quantized-vs-full-precision logits can
//! therefore be compared directly — same layer graph, same accumulation
//! order, only the projection weights differ.
//!
//! # Determinism contract
//!
//! The PR 5 bit-identity discipline extends to the whole stack:
//!
//! * projections inherit [`PackedLinear`]'s fixed block-accumulation
//!   order (serial / pooled / scalar / AVX2 all bit-identical, any batch);
//! * every position-local op ([`ops`]) walks its input in one fixed order
//!   with f64 accumulators;
//! * attention parallelism is per `(batch row, head)` with each output
//!   head-slice computed whole by one worker ([`crate::pool::scoped_map`]
//!   keeps input order);
//! * [`ForwardModel::logits`] and incremental [`ForwardModel::step`]
//!   share one forward chunk path, so a KV-cached decode reproduces the
//!   full-sequence recompute bit for bit;
//! * multi-stream [`ForwardModel::step_batch`] coalesces every stream's
//!   activation rows into the same projection `gemm` calls — per-row
//!   independence of the fixed chunk order keeps each stream's rows
//!   bit-identical to its solo batch-1 [`ForwardModel::step`], and the
//!   paged attention ([`ops::attend_paged`] over a [`KvArena`]) shares
//!   the contiguous path's f64 operation sequence exactly.
//!
//! [`PackedLinear`]: crate::kernels::PackedLinear
//! [`dense_gemv`]: crate::kernels::dense_gemv

pub mod arena;
pub mod ops;
pub mod synth;

pub use arena::{KvArena, StreamId};

use anyhow::{ensure, Context, Result};

use crate::io::msbt::TensorMap;
use crate::kernels::{dense_gemv, Kernel, MacMode, PackedLinear};
use crate::pool::{scoped_map, ThreadPool};
use crate::quant::packing::PackedTensor;
use crate::runtime::LogitsFn;
use crate::tensor::Matrix;

/// Architecture of a [`ForwardModel`]: dimensions only, no weights.
#[derive(Clone, Debug)]
pub struct ForwardSpec {
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    /// Maximum sequence length (KV cache capacity; [`LogitsFn`] shape).
    pub seq: usize,
    pub batch: usize,
    /// RoPE frequency base (10 000 unless stated otherwise).
    pub rope_base: f64,
}

impl ForwardSpec {
    pub fn new(
        vocab: usize,
        d: usize,
        layers: usize,
        heads: usize,
        ff: usize,
        seq: usize,
        batch: usize,
    ) -> Result<ForwardSpec> {
        let fs = ForwardSpec { vocab, d, layers, heads, ff, seq, batch, rope_base: 10_000.0 };
        fs.validate()?;
        Ok(fs)
    }

    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }

    fn validate(&self) -> Result<()> {
        for (v, what) in [
            (self.vocab, "vocab"),
            (self.d, "d"),
            (self.layers, "layers"),
            (self.heads, "heads"),
            (self.ff, "ff"),
            (self.seq, "seq"),
            (self.batch, "batch"),
        ] {
            ensure!(v > 0, "{what} must be positive");
        }
        ensure!(self.d % self.heads == 0, "d {} not divisible by heads {}", self.d, self.heads);
        ensure!(self.head_dim() % 2 == 0, "head dim {} must be even for RoPE", self.head_dim());
        ensure!(self.rope_base > 1.0, "rope base must exceed 1");
        Ok(())
    }
}

/// One projection in the layer graph: packed codes or a dense f32 matrix.
/// Both multiply through [`crate::kernels`] with the same chunked lane
/// structure; which one a layer gets is decided per parameter, so payload
/// exception lists (layers the quantizer left at f32) mix freely with
/// packed ones inside a single model.
pub enum Linear {
    Packed(PackedLinear),
    Dense(Matrix),
}

impl Linear {
    pub fn rows(&self) -> usize {
        match self {
            Linear::Packed(p) => p.rows(),
            Linear::Dense(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Linear::Packed(p) => p.cols(),
            Linear::Dense(m) => m.cols,
        }
    }

    /// Serialized payload bytes actually held (dense layers count f32).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Linear::Packed(p) => p.payload_bytes(),
            Linear::Dense(m) => m.len() * 4,
        }
    }

    fn with_kernel(self, kernel: Kernel) -> Linear {
        match self {
            Linear::Packed(p) => Linear::Packed(p.with_kernel(kernel)),
            dense => dense,
        }
    }

    /// `y[b] = W · xs[b]` for `batch` activation rows, `[batch, rows]`
    /// row-major out. Every output element is computed whole by one
    /// worker in the fixed chunk order, so the bits never depend on
    /// `pool`/`threads`.
    fn gemm(
        &self,
        xs: &[f32],
        batch: usize,
        kernel: Kernel,
        pool: Option<&ThreadPool>,
        threads: usize,
    ) -> Vec<f32> {
        match self {
            Linear::Packed(p) => match pool {
                Some(pl) => p.gemm_pooled(xs, batch, pl),
                None => p.gemm(xs, batch),
            },
            Linear::Dense(m) => {
                assert_eq!(xs.len(), batch * m.cols, "activation shape != [batch, cols]");
                let rows: Vec<usize> = (0..batch).collect();
                let outs = scoped_map(rows, threads, |b| {
                    dense_gemv(m, &xs[b * m.cols..(b + 1) * m.cols], kernel)
                });
                let mut y = Vec::with_capacity(batch * m.rows);
                for o in outs {
                    y.extend_from_slice(&o);
                }
                y
            }
        }
    }
}

/// One decoder layer's parameters.
struct Layer {
    attn_norm: Vec<f32>,
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    mlp_norm: Vec<f32>,
    w_gate: Linear,
    w_up: Linear,
    w_down: Linear,
}

/// Per-sequence decode state: the roped key/value cache, one
/// `[batch, seq, d]` slab per layer. Create with
/// [`ForwardModel::kv_state`], feed to [`ForwardModel::step`].
pub struct KvState {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
    batch: usize,
    seq: usize,
    d: usize,
}

impl KvState {
    /// Positions already decoded into the cache.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write the chunk's roped keys/values (`[batch, t_new, d]`) into
    /// layer `li` at positions `t0..t0 + t_new`.
    fn append(&mut self, li: usize, t0: usize, k: &[f32], v: &[f32], t_new: usize) {
        let d = self.d;
        for bi in 0..self.batch {
            for i in 0..t_new {
                let src = (bi * t_new + i) * d;
                let dst = (bi * self.seq + t0 + i) * d;
                self.k[li][dst..dst + d].copy_from_slice(&k[src..src + d]);
                self.v[li][dst..dst + d].copy_from_slice(&v[src..src + d]);
            }
        }
    }
}

/// One stream's contribution to a [`ForwardModel::step_batch`] call: the
/// arena stream to append into and the token chunk to decode (any length
/// ≥ 1 that fits the context window — a prefill chunk and a single
/// decode token are the same thing here).
pub struct StreamSlot<'a> {
    pub id: StreamId,
    pub tokens: &'a [i32],
}

/// The fused CPU forward model. See the module docs for the determinism
/// contract; see [`synth`] for the parameter naming the constructors load.
pub struct ForwardModel {
    spec: ForwardSpec,
    tok_emb: Matrix,
    layers: Vec<Layer>,
    final_norm: Vec<f32>,
    lm_head: Linear,
    kernel: Kernel,
    threads: usize,
    pool: Option<ThreadPool>,
    mac_fallbacks: usize,
}

/// Rename real-checkpoint parameter keys onto the [`synth`] naming
/// contract ([`synth::canonical_param_name`]); contract-named keys pass
/// through untouched. A rename that lands on an already-present key is an
/// error — the map would silently drop a tensor otherwise.
fn canonicalize_names<V>(
    map: std::collections::BTreeMap<String, V>,
) -> Result<std::collections::BTreeMap<String, V>> {
    let mut out = std::collections::BTreeMap::new();
    for (name, v) in map {
        let canon = match synth::canonical_param_name(&name) {
            Some(c) => c,
            None => name,
        };
        ensure!(
            !out.contains_key(&canon),
            "parameter '{canon}' appears twice after checkpoint-name canonicalization"
        );
        out.insert(canon, v);
    }
    Ok(out)
}

/// Parameter source shared by the two constructors: packed payloads win,
/// anything else is looked up as a dense f32 tensor.
struct Params<'a> {
    packed: std::collections::BTreeMap<String, PackedTensor>,
    dense: &'a TensorMap,
    /// Multiply-accumulate mode applied to every packed projection.
    mac: MacMode,
    /// Projections that asked for `Auto` int8 but lack an affine decode
    /// and stayed on the f32 MAC ([`ForwardModel::mac_fallbacks`]).
    fallbacks: usize,
}

impl Params<'_> {
    fn linear(&mut self, name: &str, rows: usize, cols: usize) -> Result<Linear> {
        if let Some(pt) = self.packed.remove(name) {
            ensure!(
                pt.rows == rows && pt.cols == cols,
                "{name}: packed shape [{}, {}] != expected [{rows}, {cols}]",
                pt.rows,
                pt.cols
            );
            let pl = PackedLinear::new(pt)
                .with_context(|| format!("fused handle for '{name}'"))?
                .with_mac(self.mac)
                .with_context(|| format!("mac mode for '{name}'"))?;
            if self.mac == MacMode::Auto && !pl.int8_eligible() {
                self.fallbacks += 1;
            }
            return Ok(Linear::Packed(pl));
        }
        Ok(Linear::Dense(self.matrix(name, rows, cols)?))
    }

    fn matrix(&self, name: &str, rows: usize, cols: usize) -> Result<Matrix> {
        let t = self.dense.get(name).with_context(|| format!("missing tensor '{name}'"))?;
        ensure!(
            t.dims == [rows, cols],
            "{name}: shape {:?} != expected [{rows}, {cols}]",
            t.dims
        );
        Ok(Matrix::from_vec(rows, cols, t.as_f32()?.to_vec()))
    }

    fn vector(&self, name: &str, len: usize) -> Result<Vec<f32>> {
        let t = self.dense.get(name).with_context(|| format!("missing tensor '{name}'"))?;
        ensure!(t.dims == [len], "{name}: shape {:?} != expected [{len}]", t.dims);
        Ok(t.as_f32()?.to_vec())
    }
}

impl ForwardModel {
    /// Boot from an `export_packed` artifact: quantized projections stay
    /// packed ([`PackedLinear`] handles computing straight off the codes),
    /// pass-through tensors (norms, embeddings, exception-listed layers)
    /// load dense. No full f32 weight set is ever materialized. Parameter
    /// names follow the [`synth`] contract; real-checkpoint conventions
    /// (HF `model.layers.N.self_attn.q_proj.weight` style) are renamed
    /// onto it via [`synth::canonical_param_name`] before lookup.
    pub fn from_packed_map(spec: ForwardSpec, map: &TensorMap) -> Result<ForwardModel> {
        Self::from_packed_map_with(spec, map, MacMode::F32)
    }

    /// [`ForwardModel::from_packed_map`] with a multiply-accumulate mode
    /// applied to every packed projection. `MacMode::Int8` fails if the
    /// payload's method has no affine decode; `MacMode::Auto` keeps such
    /// projections on the f32 path, counting each fallback
    /// ([`ForwardModel::mac_fallbacks`]).
    pub fn from_packed_map_with(
        spec: ForwardSpec,
        map: &TensorMap,
        mac: MacMode,
    ) -> Result<ForwardModel> {
        spec.validate()?;
        let (_method, packed, passthrough) = crate::pipeline::packed_tensors(map)?;
        let packed = canonicalize_names(packed)?;
        let passthrough = canonicalize_names(passthrough)?;
        Self::build(spec, Params { packed, dense: &passthrough, mac, fallbacks: 0 })
    }

    /// The f32-reference twin: every projection dense, same layer graph.
    /// Feed it the original weights for the full-precision baseline, or a
    /// [`crate::pipeline::decode_packed_model`] output to isolate the
    /// fused kernels from the quantization error itself.
    pub fn from_dense(spec: ForwardSpec, map: &TensorMap) -> Result<ForwardModel> {
        spec.validate()?;
        Self::build(
            spec,
            Params { packed: Default::default(), dense: map, mac: MacMode::F32, fallbacks: 0 },
        )
    }

    fn build(spec: ForwardSpec, mut params: Params<'_>) -> Result<ForwardModel> {
        let (v, d, ff) = (spec.vocab, spec.d, spec.ff);
        let tok_emb = params.matrix("tok_emb", v, d)?;
        let mut layers = Vec::with_capacity(spec.layers);
        for l in 0..spec.layers {
            let p = |s: &str| format!("layer{l}.{s}");
            layers.push(Layer {
                attn_norm: params.vector(&p("attn_norm"), d)?,
                wq: params.linear(&p("wq"), d, d)?,
                wk: params.linear(&p("wk"), d, d)?,
                wv: params.linear(&p("wv"), d, d)?,
                wo: params.linear(&p("wo"), d, d)?,
                mlp_norm: params.vector(&p("mlp_norm"), d)?,
                w_gate: params.linear(&p("w_gate"), ff, d)?,
                w_up: params.linear(&p("w_up"), ff, d)?,
                w_down: params.linear(&p("w_down"), d, ff)?,
            });
        }
        let final_norm = params.vector("final_norm", d)?;
        let lm_head = params.linear("lm_head", v, d)?;
        ensure!(
            params.packed.is_empty(),
            "packed payload has layers the spec does not name: {:?}",
            params.packed.keys().collect::<Vec<_>>()
        );
        Ok(ForwardModel {
            spec,
            tok_emb,
            layers,
            final_norm,
            lm_head,
            kernel: Kernel::detect(),
            threads: 1,
            pool: None,
            mac_fallbacks: params.fallbacks,
        })
    }

    /// Stripe projections and attention heads over `threads` workers.
    /// Output bits are unchanged (see the module docs).
    pub fn with_threads(mut self, threads: usize) -> ForwardModel {
        self.threads = threads.max(1);
        self.pool = (self.threads > 1).then(|| ThreadPool::new(self.threads, self.threads * 4));
        self
    }

    /// Force a specific dot micro-kernel (tests compare scalar vs SIMD).
    pub fn with_kernel(mut self, kernel: Kernel) -> ForwardModel {
        assert!(kernel.available(), "{} kernel not available on this CPU", kernel.name());
        self.kernel = kernel;
        self.lm_head = std::mem::replace(&mut self.lm_head, Linear::Dense(Matrix::zeros(0, 0)))
            .with_kernel(kernel);
        for l in &mut self.layers {
            for w in [&mut l.wq, &mut l.wk, &mut l.wv, &mut l.wo, &mut l.w_gate, &mut l.w_up,
                &mut l.w_down]
            {
                let owned = std::mem::replace(w, Linear::Dense(Matrix::zeros(0, 0)));
                *w = owned.with_kernel(kernel);
            }
        }
        self
    }

    pub fn spec(&self) -> &ForwardSpec {
        &self.spec
    }

    /// How many packed projections requested `MacMode::Auto` int8 but
    /// have no affine decode and stayed on the f32 MAC. Zero under an
    /// explicit mode, or when every projection engaged the integer path.
    pub fn mac_fallbacks(&self) -> usize {
        self.mac_fallbacks
    }

    /// Projection payload bytes actually resident (packed layers count
    /// their codes + scales, dense layers f32).
    pub fn payload_bytes(&self) -> usize {
        let mut n = self.lm_head.payload_bytes();
        for l in &self.layers {
            for w in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down] {
                n += w.payload_bytes();
            }
        }
        n
    }

    /// What the same projections would cost decoded to f32.
    pub fn f32_bytes(&self) -> usize {
        let per_layer = 4 * self.spec.d * self.spec.d + 3 * self.spec.ff * self.spec.d;
        (per_layer * self.spec.layers + self.spec.vocab * self.spec.d) * 4
    }

    /// A fresh paged KV arena sized so `max_streams` concurrent streams
    /// can each reach the full context window:
    /// `total_pages = max_streams * ceil(seq / page_tokens)`. Feed to
    /// [`ForwardModel::step_batch`].
    pub fn kv_arena(&self, max_streams: usize, page_tokens: usize) -> Result<KvArena> {
        ensure!(max_streams > 0, "max_streams must be positive");
        ensure!(page_tokens > 0, "kv_page_tokens must be positive");
        let per_stream = self.spec.seq.div_ceil(page_tokens);
        KvArena::new(
            self.spec.layers,
            self.spec.d,
            self.spec.seq,
            page_tokens,
            max_streams * per_stream,
        )
    }

    /// A fresh (empty) KV cache sized for this model.
    pub fn kv_state(&self) -> KvState {
        let slab = self.spec.batch * self.spec.seq * self.spec.d;
        KvState {
            k: (0..self.spec.layers).map(|_| vec![0.0; slab]).collect(),
            v: (0..self.spec.layers).map(|_| vec![0.0; slab]).collect(),
            len: 0,
            batch: self.spec.batch,
            seq: self.spec.seq,
            d: self.spec.d,
        }
    }

    /// Full-sequence scoring: `tokens` is `[batch, seq]` row-major,
    /// returns `[batch, seq, vocab]` logits. Equivalent to (and
    /// bit-identical with) one [`ForwardModel::step`] on a fresh cache.
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        ensure!(
            tokens.len() == self.spec.batch * self.spec.seq,
            "tokens len {} != {}x{}",
            tokens.len(),
            self.spec.batch,
            self.spec.seq
        );
        self.step(&mut self.kv_state(), tokens)
    }

    /// Incremental decode: append `tokens` (`[batch, t_new]` row-major,
    /// any `t_new ≥ 1` that fits the cache) and return `[batch, t_new,
    /// vocab]` logits for the new positions. Splitting a sequence into
    /// chunks in any way yields the same bits as one full-sequence call.
    pub fn step(&self, kv: &mut KvState, tokens: &[i32]) -> Result<Vec<f32>> {
        let ForwardSpec { d, heads, batch: b, seq, vocab, rope_base, .. } = self.spec;
        ensure!(
            kv.batch == b && kv.seq == seq && kv.d == d && kv.k.len() == self.layers.len(),
            "KV cache shape does not match this model"
        );
        ensure!(!tokens.is_empty() && tokens.len() % b == 0, "tokens not [batch, t_new]");
        let t_new = tokens.len() / b;
        let t0 = kv.len;
        ensure!(t0 + t_new <= seq, "cache overflow: {t0} + {t_new} > {seq}");
        let n = b * t_new;
        let hd = self.spec.head_dim();
        let (kernel, pool, threads) = (self.kernel, self.pool.as_ref(), self.threads);

        // Embedding lookup, rows laid out [batch, t_new, d].
        let mut x = vec![0.0f32; n * d];
        for (r, &tok) in tokens.iter().enumerate() {
            ensure!(
                tok >= 0 && (tok as usize) < vocab,
                "token {tok} outside vocab 0..{vocab}"
            );
            x[r * d..(r + 1) * d].copy_from_slice(self.tok_emb.row(tok as usize));
        }

        let mut nrm = vec![0.0f32; n * d];
        for (li, layer) in self.layers.iter().enumerate() {
            // attention block
            for (xs, os) in x.chunks_exact(d).zip(nrm.chunks_exact_mut(d)) {
                ops::rmsnorm(xs, &layer.attn_norm, os);
            }
            let mut q = layer.wq.gemm(&nrm, n, kernel, pool, threads);
            let mut k = layer.wk.gemm(&nrm, n, kernel, pool, threads);
            let v = layer.wv.gemm(&nrm, n, kernel, pool, threads);
            for bi in 0..b {
                for i in 0..t_new {
                    let r = (bi * t_new + i) * d;
                    ops::rope_in_place(&mut q[r..r + d], heads, t0 + i, rope_base);
                    ops::rope_in_place(&mut k[r..r + d], heads, t0 + i, rope_base);
                }
            }
            kv.append(li, t0, &k, &v, t_new);

            // one job per (batch row, head); each head-slice computed whole
            let kb_all = &kv.k[li];
            let vb_all = &kv.v[li];
            let jobs: Vec<(usize, usize)> =
                (0..b).flat_map(|bi| (0..heads).map(move |h| (bi, h))).collect();
            let head_outs = scoped_map(jobs, threads, |(bi, h)| {
                let kb = &kb_all[bi * seq * d..(bi + 1) * seq * d];
                let vb = &vb_all[bi * seq * d..(bi + 1) * seq * d];
                let h0 = h * hd;
                let (mut scores, mut acc) = (Vec::new(), Vec::new());
                let mut out = vec![0.0f32; t_new * hd];
                for i in 0..t_new {
                    let r = (bi * t_new + i) * d;
                    ops::attend(
                        &q[r + h0..r + h0 + hd],
                        kb,
                        vb,
                        d,
                        h0,
                        t0 + i,
                        &mut scores,
                        &mut acc,
                        &mut out[i * hd..(i + 1) * hd],
                    );
                }
                out
            });
            let mut att = vec![0.0f32; n * d];
            for (idx, ho) in head_outs.iter().enumerate() {
                let (bi, h) = (idx / heads, idx % heads);
                for i in 0..t_new {
                    let dst = (bi * t_new + i) * d + h * hd;
                    att[dst..dst + hd].copy_from_slice(&ho[i * hd..(i + 1) * hd]);
                }
            }
            let o = layer.wo.gemm(&att, n, kernel, pool, threads);
            for (xv, &ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }

            // SwiGLU MLP block
            for (xs, os) in x.chunks_exact(d).zip(nrm.chunks_exact_mut(d)) {
                ops::rmsnorm(xs, &layer.mlp_norm, os);
            }
            let mut g = layer.w_gate.gemm(&nrm, n, kernel, pool, threads);
            let u = layer.w_up.gemm(&nrm, n, kernel, pool, threads);
            for (gv, &uv) in g.iter_mut().zip(&u) {
                *gv = ops::silu(*gv) * uv;
            }
            let down = layer.w_down.gemm(&g, n, kernel, pool, threads);
            for (xv, &dv) in x.iter_mut().zip(&down) {
                *xv += dv;
            }
        }

        for (xs, os) in x.chunks_exact(d).zip(nrm.chunks_exact_mut(d)) {
            ops::rmsnorm(xs, &self.final_norm, os);
        }
        let logits = self.lm_head.gemm(&nrm, n, kernel, pool, threads);
        kv.len = t0 + t_new;
        Ok(logits)
    }

    /// One coalesced decode step for many independent streams at
    /// possibly different sequence positions. Each slot appends its
    /// `tokens` chunk to its stream's paged cache and gets back that
    /// chunk's `[t_new, vocab]` logits (`out[i]` belongs to `slots[i]`).
    ///
    /// Every projection runs as ONE `gemm` over the slot-concatenated
    /// activation rows, so weight-tile unpacking (and the int8
    /// activation quantization under [`MacMode::Int8`]) amortizes across
    /// all streams; attention runs one `(stream, head)` job per worker
    /// through the page table. Per-row independence of the fixed chunk
    /// order makes each stream's logits bit-identical to a solo
    /// [`ForwardModel::step`] of the same chunks on a batch-1 spec —
    /// `spec.batch` is ignored here, each stream is one sequence.
    pub fn step_batch(
        &self,
        arena: &mut KvArena,
        slots: &[StreamSlot<'_>],
    ) -> Result<Vec<Vec<f32>>> {
        let ForwardSpec { d, heads, seq, vocab, rope_base, .. } = self.spec;
        ensure!(
            arena.layers() == self.layers.len() && arena.d() == d && arena.seq() == seq,
            "KV arena shape does not match this model"
        );
        ensure!(!slots.is_empty(), "step_batch with no streams");
        for (i, s) in slots.iter().enumerate() {
            ensure!(!s.tokens.is_empty(), "stream slot {i} has an empty chunk");
            ensure!(
                !slots[..i].iter().any(|t| t.id == s.id),
                "stream id appears twice in one step_batch call"
            );
        }

        // Starting position + page reservation per slot, and the row
        // layout: slot si owns rows row_off[si]..row_off[si + 1].
        let mut t0s = Vec::with_capacity(slots.len());
        let mut row_off = Vec::with_capacity(slots.len() + 1);
        let mut n = 0usize;
        for s in slots {
            let t0 = arena.len(s.id)?;
            arena.reserve(s.id, t0 + s.tokens.len())?;
            t0s.push(t0);
            row_off.push(n);
            n += s.tokens.len();
        }
        row_off.push(n);
        let hd = self.spec.head_dim();
        let (kernel, pool, threads) = (self.kernel, self.pool.as_ref(), self.threads);

        // Embedding lookup over the slot-concatenated rows.
        let mut x = vec![0.0f32; n * d];
        let mut r = 0usize;
        for s in slots {
            for &tok in s.tokens {
                ensure!(
                    tok >= 0 && (tok as usize) < vocab,
                    "token {tok} outside vocab 0..{vocab}"
                );
                x[r * d..(r + 1) * d].copy_from_slice(self.tok_emb.row(tok as usize));
                r += 1;
            }
        }

        let mut nrm = vec![0.0f32; n * d];
        for (li, layer) in self.layers.iter().enumerate() {
            // attention block
            for (xs, os) in x.chunks_exact(d).zip(nrm.chunks_exact_mut(d)) {
                ops::rmsnorm(xs, &layer.attn_norm, os);
            }
            let mut q = layer.wq.gemm(&nrm, n, kernel, pool, threads);
            let mut k = layer.wk.gemm(&nrm, n, kernel, pool, threads);
            let v = layer.wv.gemm(&nrm, n, kernel, pool, threads);
            for (si, s) in slots.iter().enumerate() {
                for i in 0..s.tokens.len() {
                    let row = (row_off[si] + i) * d;
                    ops::rope_in_place(&mut q[row..row + d], heads, t0s[si] + i, rope_base);
                    ops::rope_in_place(&mut k[row..row + d], heads, t0s[si] + i, rope_base);
                }
            }
            for (si, s) in slots.iter().enumerate() {
                let (r0, r1) = (row_off[si] * d, row_off[si + 1] * d);
                arena.append(li, s.id, t0s[si], &k[r0..r1], &v[r0..r1], s.tokens.len());
            }

            // one job per (stream, head), reading through the page table
            let (kb_all, vb_all) = arena.layer(li);
            let pt = arena.page_tokens();
            let tables: Vec<&[usize]> = slots.iter().map(|s| arena.pages(s.id)).collect();
            let jobs: Vec<(usize, usize)> =
                (0..slots.len()).flat_map(|si| (0..heads).map(move |h| (si, h))).collect();
            let head_outs = scoped_map(jobs, threads, |(si, h)| {
                let h0 = h * hd;
                let t_new = slots[si].tokens.len();
                let (mut scores, mut acc) = (Vec::new(), Vec::new());
                let mut out = vec![0.0f32; t_new * hd];
                for i in 0..t_new {
                    let row = (row_off[si] + i) * d;
                    ops::attend_paged(
                        &q[row + h0..row + h0 + hd],
                        kb_all,
                        vb_all,
                        tables[si],
                        pt,
                        d,
                        h0,
                        t0s[si] + i,
                        &mut scores,
                        &mut acc,
                        &mut out[i * hd..(i + 1) * hd],
                    );
                }
                out
            });
            let mut att = vec![0.0f32; n * d];
            for (idx, ho) in head_outs.iter().enumerate() {
                let (si, h) = (idx / heads, idx % heads);
                for i in 0..slots[si].tokens.len() {
                    let dst = (row_off[si] + i) * d + h * hd;
                    att[dst..dst + hd].copy_from_slice(&ho[i * hd..(i + 1) * hd]);
                }
            }
            let o = layer.wo.gemm(&att, n, kernel, pool, threads);
            for (xv, &ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }

            // SwiGLU MLP block
            for (xs, os) in x.chunks_exact(d).zip(nrm.chunks_exact_mut(d)) {
                ops::rmsnorm(xs, &layer.mlp_norm, os);
            }
            let mut g = layer.w_gate.gemm(&nrm, n, kernel, pool, threads);
            let u = layer.w_up.gemm(&nrm, n, kernel, pool, threads);
            for (gv, &uv) in g.iter_mut().zip(&u) {
                *gv = ops::silu(*gv) * uv;
            }
            let down = layer.w_down.gemm(&g, n, kernel, pool, threads);
            for (xv, &dv) in x.iter_mut().zip(&down) {
                *xv += dv;
            }
        }

        for (xs, os) in x.chunks_exact(d).zip(nrm.chunks_exact_mut(d)) {
            ops::rmsnorm(xs, &self.final_norm, os);
        }
        let logits = self.lm_head.gemm(&nrm, n, kernel, pool, threads);
        let mut out = Vec::with_capacity(slots.len());
        for (si, s) in slots.iter().enumerate() {
            out.push(logits[row_off[si] * vocab..row_off[si + 1] * vocab].to_vec());
            arena.advance(s.id, s.tokens.len());
        }
        Ok(out)
    }

    /// Score the next token after a prefix: run positions `0..p` of each
    /// batch row from scratch and return the last position's logits,
    /// `[batch, vocab]`. This is the full-recompute arm the `perf_forward`
    /// bench races against KV-cached [`ForwardModel::step`]s.
    pub fn score_prefix(&self, tokens: &[i32], p: usize) -> Result<Vec<f32>> {
        let b = self.spec.batch;
        ensure!(tokens.len() % b == 0, "tokens not [batch, len]");
        let len = tokens.len() / b;
        ensure!(p >= 1 && p <= len, "prefix {p} outside 1..={len}");
        let mut pref = Vec::with_capacity(b * p);
        for bi in 0..b {
            pref.extend_from_slice(&tokens[bi * len..bi * len + p]);
        }
        let logits = self.step(&mut self.kv_state(), &pref)?;
        let vocab = self.spec.vocab;
        let mut out = Vec::with_capacity(b * vocab);
        for bi in 0..b {
            let last = (bi * p + p - 1) * vocab;
            out.extend_from_slice(&logits[last..last + vocab]);
        }
        Ok(out)
    }
}

/// Greedy token choice for one logits row: the index of the largest
/// value, **lowest index on ties** and NaNs never winning (NaN compares
/// false under `>`). Every greedy-decode surface — the batched
/// scheduler's commit step, speculative verification, solo references in
/// tests and benches — shares this one definition, so tie-breaking can
/// never make "bit-identical logits" and "identical tokens" diverge.
pub fn argmax_row(row: &[f32]) -> usize {
    let mut best = f32::NEG_INFINITY;
    let mut idx = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > best {
            best = v;
            idx = i;
        }
    }
    idx
}

/// Row-wise [`argmax_row`] over a `[rows, vocab]` logits slab — the
/// multi-position verification surface for speculative decode: one
/// [`ForwardModel::step_batch`] chunk's every position greedy-decoded in
/// a single call.
pub fn argmax_rows(logits: &[f32], vocab: usize) -> Vec<usize> {
    assert!(vocab > 0 && logits.len() % vocab == 0, "logits are not [rows, vocab={vocab}]");
    logits.chunks_exact(vocab).map(argmax_row).collect()
}

impl LogitsFn for ForwardModel {
    fn batch(&self) -> usize {
        self.spec.batch
    }

    fn seq(&self) -> usize {
        self.spec.seq
    }

    fn vocab(&self) -> usize {
        self.spec.vocab
    }

    fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        ForwardModel::logits(self, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{self, quantize, Method, QuantizeOptions};
    use crate::quant::QuantConfig;

    fn tiny() -> ForwardSpec {
        ForwardSpec::new(40, 32, 2, 4, 48, 8, 2).unwrap()
    }

    /// Quantize the synthetic instance and return (packed artifact map,
    /// decoded f32 map, original f32 map).
    fn fixture(fs: &ForwardSpec) -> (TensorMap, TensorMap, TensorMap) {
        let spec = synth::model_spec(fs, "fwd-test");
        let weights = synth::synth_weights(fs, 21);
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        let qm = quantize(&spec, weights.clone(), None, Method::Wgm, &cfg, &opts).unwrap();
        let packed = qm.export_packed().unwrap();
        let decoded = pipeline::decode_packed_model(&packed, 1).unwrap();
        (packed, decoded, weights)
    }

    fn max_rel_diff(a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b)
            .map(|(&x, &y)| {
                let scale = f64::max(x.abs().max(y.abs()) as f64, 1e-3);
                (x as f64 - y as f64).abs() / scale
            })
            .fold(0.0, f64::max)
    }

    #[test]
    fn spec_rejects_degenerate_shapes() {
        assert!(ForwardSpec::new(0, 32, 1, 4, 48, 8, 1).is_err());
        assert!(ForwardSpec::new(40, 30, 1, 4, 48, 8, 1).is_err(), "d % heads != 0");
        assert!(ForwardSpec::new(40, 4, 1, 4, 48, 8, 1).is_err(), "odd head dim");
        assert!(ForwardSpec::new(40, 32, 1, 4, 48, 0, 1).is_err());
    }

    #[test]
    fn quantized_logits_match_dense_twin() {
        let fs = tiny();
        let (packed, decoded, original) = fixture(&fs);
        let fused = ForwardModel::from_packed_map(fs.clone(), &packed).unwrap();
        // fused handles stay packed: payload well under the f32 footprint
        assert!(fused.payload_bytes() * 2 < fused.f32_bytes());
        let twin = ForwardModel::from_dense(fs.clone(), &decoded).unwrap();
        let full = ForwardModel::from_dense(fs.clone(), &original).unwrap();
        let toks = synth::synth_tokens(&fs, fs.seq, 4);
        let yf = fused.logits(&toks).unwrap();
        let yt = twin.logits(&toks).unwrap();
        let y0 = full.logits(&toks).unwrap();
        assert_eq!(yf.len(), fs.batch * fs.seq * fs.vocab);
        assert!(yf.iter().all(|v| v.is_finite()));
        // same layer graph on the decoded weights: only kernel-side
        // rounding differs, well inside 1e-4 relative
        let rel = max_rel_diff(&yf, &yt);
        assert!(rel <= 1e-4, "fused vs decoded twin rel diff {rel}");
        // the full-precision baseline differs by genuine quantization
        // error — nonzero, but small relative to the logit mass
        let rel0 = max_rel_diff(&yf, &y0);
        assert!(rel0 > 1e-4, "quantization should move the logits");
        let mass: f64 = y0.iter().map(|v| v.abs() as f64).sum::<f64>() / y0.len() as f64;
        let err: f64 = yf.iter().zip(&y0).map(|(&a, &b)| (a as f64 - b as f64).abs()).sum::<f64>()
            / y0.len() as f64;
        assert!(err < 0.5 * mass, "4-bit logits drifted: mean err {err} vs mass {mass}");
    }

    #[test]
    fn logits_bit_identical_across_threads_and_kernels() {
        let fs = tiny();
        let (packed, _, _) = fixture(&fs);
        let toks = synth::synth_tokens(&fs, fs.seq, 7);
        let base = ForwardModel::from_packed_map(fs.clone(), &packed)
            .unwrap()
            .with_kernel(Kernel::Scalar);
        let y1 = base.logits(&toks).unwrap();
        for threads in [2, 4] {
            let m = ForwardModel::from_packed_map(fs.clone(), &packed)
                .unwrap()
                .with_kernel(Kernel::Scalar)
                .with_threads(threads);
            assert_eq!(y1, m.logits(&toks).unwrap(), "threads={threads} changed bits");
        }
        if let Some(simd) = Kernel::detect_simd() {
            let m = ForwardModel::from_packed_map(fs.clone(), &packed)
                .unwrap()
                .with_kernel(simd)
                .with_threads(3);
            assert_eq!(y1, m.logits(&toks).unwrap(), "{} changed bits", simd.name());
        }
    }

    #[test]
    fn incremental_decode_matches_full_recompute() {
        let fs = tiny();
        let (packed, _, _) = fixture(&fs);
        let model =
            ForwardModel::from_packed_map(fs.clone(), &packed).unwrap().with_threads(2);
        let toks = synth::synth_tokens(&fs, fs.seq, 11);
        let full = model.logits(&toks).unwrap();
        let (b, t, v) = (fs.batch, fs.seq, fs.vocab);

        // one token at a time through a shared cache
        let mut kv = model.kv_state();
        let mut inc = vec![0.0f32; b * t * v];
        for i in 0..t {
            let col: Vec<i32> = (0..b).map(|bi| toks[bi * t + i]).collect();
            let step = model.step(&mut kv, &col).unwrap();
            assert_eq!(kv.len(), i + 1);
            for bi in 0..b {
                inc[(bi * t + i) * v..(bi * t + i) * v + v]
                    .copy_from_slice(&step[bi * v..(bi + 1) * v]);
            }
        }
        assert_eq!(full, inc, "KV-cached decode != full-sequence recompute");

        // uneven chunking (prefill 3, then 1, then 4) also reproduces it
        let mut kv2 = model.kv_state();
        let mut at = 0;
        for w in [3usize, 1, 4] {
            let chunk: Vec<i32> = (0..b)
                .flat_map(|bi| toks[bi * t + at..bi * t + at + w].to_vec())
                .collect();
            let y = model.step(&mut kv2, &chunk).unwrap();
            for bi in 0..b {
                for i in 0..w {
                    let want = &full[(bi * t + at + i) * v..(bi * t + at + i) * v + v];
                    let got = &y[(bi * w + i) * v..(bi * w + i) * v + v];
                    assert_eq!(want, got, "chunk at {at} width {w} pos {i}");
                }
            }
            at += w;
        }
        assert_eq!(kv2.len(), t);

        // score_prefix agrees with the full pass at every cut point
        for p in 1..=t {
            let sp = model.score_prefix(&toks, p).unwrap();
            for bi in 0..b {
                let want = &full[(bi * t + p - 1) * v..(bi * t + p - 1) * v + v];
                assert_eq!(&sp[bi * v..(bi + 1) * v], want, "score_prefix({p})");
            }
        }
    }

    #[test]
    fn forward_model_feeds_eval_ppl() {
        let fs = tiny();
        let (packed, decoded, _) = fixture(&fs);
        let model = ForwardModel::from_packed_map(fs.clone(), &packed).unwrap();
        let stream: Vec<i32> =
            (0..64).map(|i| ((i * 7 + 3) % fs.vocab as i64) as i32).collect();
        let ppl = crate::eval::perplexity(&model, &stream).unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "ppl {ppl}");
        // the dense twin plugs into the same evaluator
        let twin = ForwardModel::from_dense(fs, &decoded).unwrap();
        let ppl_twin = crate::eval::perplexity(&twin, &stream).unwrap();
        assert!((ppl - ppl_twin).abs() / ppl < 1e-3, "{ppl} vs {ppl_twin}");
    }

    /// Satellite: a payload quantized under the HF checkpoint naming
    /// convention boots through `from_packed_map` unchanged — the alias
    /// table renames every parameter onto the contract — and scores
    /// bit-identically to the contract-named boot of the same weights.
    #[test]
    fn boots_from_checkpoint_named_payload() {
        let fs = tiny();
        let mut spec = synth::model_spec(&fs, "hf-named");
        let weights = synth::synth_weights(&fs, 21);
        // rename spec + weights to the HF convention before quantizing,
        // so the packed artifact carries checkpoint-style keys throughout
        let mut hf_weights = TensorMap::new();
        for p in &mut spec.params {
            let hf = synth::checkpoint_param_name(&p.name)
                .unwrap_or_else(|| panic!("no checkpoint alias for {}", p.name));
            hf_weights.insert(hf.clone(), weights.get(&p.name).unwrap().clone());
            p.name = hf;
        }
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        let qm = quantize(&spec, hf_weights, None, Method::Wgm, &cfg, &opts).unwrap();
        let hf_packed = qm.export_packed().unwrap();
        assert!(
            hf_packed.keys().any(|k| k.starts_with("model.layers.0.self_attn")),
            "fixture should actually carry checkpoint-style keys"
        );
        let model = ForwardModel::from_packed_map(fs.clone(), &hf_packed).unwrap();

        // contract-named boot of the same weights, same quantization
        let (packed, _, _) = fixture(&fs);
        let contract = ForwardModel::from_packed_map(fs.clone(), &packed).unwrap();
        let toks = synth::synth_tokens(&fs, fs.seq, 4);
        assert_eq!(
            model.logits(&toks).unwrap(),
            contract.logits(&toks).unwrap(),
            "checkpoint-named boot != contract-named boot"
        );
    }

    /// MAC-mode plumbing: `Auto` over a wgm payload (non-affine) falls
    /// back per projection and scores bit-identically to the f32 boot;
    /// an explicit `Int8` request on it refuses.
    #[test]
    fn mac_mode_threads_through_projections() {
        use crate::kernels::MacMode;
        let fs = tiny();
        let (packed, _, _) = fixture(&fs);
        assert!(ForwardModel::from_packed_map_with(fs.clone(), &packed, MacMode::Int8).is_err());
        let auto =
            ForwardModel::from_packed_map_with(fs.clone(), &packed, MacMode::Auto).unwrap();
        let f32m = ForwardModel::from_packed_map(fs.clone(), &packed).unwrap();
        let toks = synth::synth_tokens(&fs, fs.seq, 9);
        assert_eq!(auto.logits(&toks).unwrap(), f32m.logits(&toks).unwrap());

        // an rtn payload under Int8 runs end-to-end and lands near the
        // f32 twin (activation-quant noise only)
        let spec = synth::model_spec(&fs, "fwd-int8");
        let weights = synth::synth_weights(&fs, 21);
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        let qm = quantize(&spec, weights, None, Method::Rtn, &cfg, &opts).unwrap();
        let rmap = qm.export_packed().unwrap();
        let int8 =
            ForwardModel::from_packed_map_with(fs.clone(), &rmap, MacMode::Int8).unwrap();
        let twin = ForwardModel::from_packed_map(fs.clone(), &rmap).unwrap();
        let yi = int8.logits(&toks).unwrap();
        let yf = twin.logits(&toks).unwrap();
        assert!(yi.iter().all(|v| v.is_finite()));
        // L2-relative drift of the whole logit slab stays well under the
        // serving budget the perf_gemv bench gates at 1e-2
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (&a, &b) in yi.iter().zip(&yf) {
            num += (a as f64 - b as f64).powi(2);
            den += (b as f64).powi(2);
        }
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel <= 2.5e-2, "int8 forward drifted {rel:.3e} from the f32 MAC");
        // threads don't change the integer path's bits either
        assert_eq!(yi, int8.with_threads(3).logits(&toks).unwrap());
    }

    /// An rtn payload (affine decode, so both MAC paths exist) packed for
    /// a batch-1 spec — the shape solo-vs-batched comparisons want.
    fn rtn_fixture(fs: &ForwardSpec) -> TensorMap {
        let spec = synth::model_spec(fs, "fwd-batch");
        let weights = synth::synth_weights(fs, 21);
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        quantize(&spec, weights, None, Method::Rtn, &cfg, &opts).unwrap().export_packed().unwrap()
    }

    /// Tentpole: a staggered multi-stream schedule through `step_batch`
    /// (streams admitted and retired at different steps, chunked prefill
    /// mixed with single-token decodes, partial last pages) reproduces
    /// every stream's solo `step` bit for bit, at threads {1,4} and both
    /// MAC modes — and retired pages provably recycle.
    #[test]
    fn step_batch_bit_identical_to_solo_streams() {
        use crate::kernels::MacMode;
        let fs = ForwardSpec::new(40, 32, 2, 4, 48, 8, 1).unwrap();
        let packed = rtn_fixture(&fs);
        let v = fs.vocab;
        // stream token sets of uneven lengths (C fills the full window)
        let toks: Vec<Vec<i32>> = [6usize, 5, 8]
            .iter()
            .enumerate()
            .map(|(s, &len)| synth::synth_tokens(&fs, len, 30 + s as u64))
            .collect();
        for mac in [MacMode::F32, MacMode::Int8] {
            for threads in [1usize, 4] {
                let model = ForwardModel::from_packed_map_with(fs.clone(), &packed, mac)
                    .unwrap()
                    .with_threads(threads);
                // solo references: one full-chunk step per stream
                let solo: Vec<Vec<f32>> = toks
                    .iter()
                    .map(|t| model.step(&mut model.kv_state(), t).unwrap())
                    .collect();

                // page_tokens 3 does not divide seq 8: partial pages
                let mut arena = model.kv_arena(3, 3).unwrap();
                let ids: Vec<StreamId> =
                    (0..3).map(|_| arena.alloc_stream()).collect();
                let (a, b, c) = (ids[0], ids[1], ids[2]);
                let mut got: Vec<Vec<f32>> = vec![Vec::new(); 3];
                // (stream index, token range) per coalesced step — streams
                // join late (C), advance unevenly, and finish early (A)
                let schedule: [&[(usize, std::ops::Range<usize>)]; 4] = [
                    &[(0, 0..3), (1, 0..2)],
                    &[(0, 3..4), (2, 0..4)],
                    &[(1, 2..4), (2, 4..6), (0, 4..6)],
                    &[(1, 4..5), (2, 6..8)],
                ];
                for step in schedule {
                    let slots: Vec<StreamSlot> = step
                        .iter()
                        .map(|(s, r)| StreamSlot { id: ids[*s], tokens: &toks[*s][r.clone()] })
                        .collect();
                    let outs = model.step_batch(&mut arena, &slots).unwrap();
                    for ((s, _), o) in step.iter().zip(outs) {
                        got[*s].extend_from_slice(&o);
                    }
                }
                for (s, (g, want)) in got.iter().zip(&solo).enumerate() {
                    assert_eq!(
                        g, want,
                        "stream {s}: batched != solo (mac {mac:?}, threads {threads})"
                    );
                    assert_eq!(g.len(), toks[s].len() * v);
                }

                // retirement recycles pages: a second wave reuses them
                // without raising the peak, and correctness holds on the
                // recycled storage
                let peak = arena.peak_pages();
                assert_eq!(arena.pages_in_use(), 2 + 2 + 3, "2+2+3 pages live");
                for id in [a, b, c] {
                    arena.free_stream(id);
                }
                assert_eq!(arena.pages_in_use(), 0, "retirement frees every page");
                let d_toks = synth::synth_tokens(&fs, 4, 77);
                let d_id = arena.alloc_stream();
                let mut d_got = Vec::new();
                for r in [0..3usize, 3..4] {
                    let slot = StreamSlot { id: d_id, tokens: &d_toks[r] };
                    d_got.extend_from_slice(&model.step_batch(&mut arena, &[slot]).unwrap()[0]);
                }
                assert_eq!(
                    d_got,
                    model.step(&mut model.kv_state(), &d_toks).unwrap(),
                    "recycled pages corrupted a later stream"
                );
                assert_eq!(arena.peak_pages(), peak, "recycling must not grow the peak");
            }
        }
    }

    #[test]
    fn step_batch_rejects_bad_batches() {
        let fs = ForwardSpec::new(40, 32, 2, 4, 48, 8, 1).unwrap();
        let packed = rtn_fixture(&fs);
        let model = ForwardModel::from_packed_map(fs.clone(), &packed).unwrap();
        let mut arena = model.kv_arena(2, 4).unwrap();
        let s = arena.alloc_stream();
        let toks = [1i32, 2, 3];
        assert!(model.step_batch(&mut arena, &[]).is_err(), "empty batch");
        assert!(
            model
                .step_batch(
                    &mut arena,
                    &[
                        StreamSlot { id: s, tokens: &toks },
                        StreamSlot { id: s, tokens: &toks },
                    ],
                )
                .is_err(),
            "duplicate stream id"
        );
        // arena from a different shape is refused
        let other = ForwardSpec::new(40, 32, 1, 4, 48, 8, 1).unwrap();
        let mut wrong = KvArena::new(other.layers, other.d, other.seq, 4, 4).unwrap();
        let ws = wrong.alloc_stream();
        assert!(
            model.step_batch(&mut wrong, &[StreamSlot { id: ws, tokens: &toks }]).is_err(),
            "layer-count mismatch"
        );
        // overflowing the context window is refused, stream intact
        let long = synth::synth_tokens(&fs, 8, 5);
        model.step_batch(&mut arena, &[StreamSlot { id: s, tokens: &long }]).unwrap();
        assert!(
            model.step_batch(&mut arena, &[StreamSlot { id: s, tokens: &toks }]).is_err(),
            "past seq"
        );
        assert_eq!(arena.len(s).unwrap(), 8);
    }

    /// Satellite: `Auto` fallbacks are counted, not printed — a wgm
    /// payload (no affine decode) falls back on every packed projection,
    /// while rtn under `Auto` and any explicit mode report zero.
    #[test]
    fn mac_fallbacks_are_counted() {
        use crate::kernels::MacMode;
        let fs = tiny();
        let (wgm, _, _) = fixture(&fs);
        let auto = ForwardModel::from_packed_map_with(fs.clone(), &wgm, MacMode::Auto).unwrap();
        assert!(auto.mac_fallbacks() > 0, "wgm under Auto must fall back somewhere");
        let f32m = ForwardModel::from_packed_map(fs.clone(), &wgm).unwrap();
        assert_eq!(f32m.mac_fallbacks(), 0, "explicit F32 is not a fallback");
        let fs1 = ForwardSpec::new(40, 32, 2, 4, 48, 8, 1).unwrap();
        let rtn = rtn_fixture(&fs1);
        let rtn_auto =
            ForwardModel::from_packed_map_with(fs1.clone(), &rtn, MacMode::Auto).unwrap();
        assert_eq!(rtn_auto.mac_fallbacks(), 0, "rtn is affine: int8 engages everywhere");
    }

    #[test]
    fn constructors_reject_mismatched_payloads() {
        let fs = tiny();
        let (packed, decoded, _) = fixture(&fs);
        // a spec whose shapes disagree with the payload
        let wrong = ForwardSpec::new(40, 32, 3, 4, 48, 8, 2).unwrap();
        assert!(ForwardModel::from_packed_map(wrong.clone(), &packed).is_err());
        assert!(ForwardModel::from_dense(wrong, &decoded).is_err());
        // a dense map missing a norm vector
        let mut broken = decoded.clone();
        broken.remove("layer1.mlp_norm");
        assert!(ForwardModel::from_dense(fs.clone(), &broken).is_err());
        // token ids outside the vocab are rejected, not indexed
        let model = ForwardModel::from_packed_map(fs.clone(), &packed).unwrap();
        let mut toks = synth::synth_tokens(&fs, fs.seq, 2);
        toks[3] = fs.vocab as i32;
        assert!(model.logits(&toks).is_err());
    }

    #[test]
    fn argmax_ties_break_low_and_nans_never_win() {
        assert_eq!(argmax_row(&[1.0, 3.0, 2.0]), 1);
        // tie: the lowest index wins
        assert_eq!(argmax_row(&[5.0, 2.0, 5.0]), 0);
        // NaN compares false under > in both directions
        assert_eq!(argmax_row(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax_row(&[0.5, f32::NAN, 1.0]), 2);
        // all -inf (or empty): index 0 by convention
        assert_eq!(argmax_row(&[f32::NEG_INFINITY; 3]), 0);
        assert_eq!(argmax_row(&[]), 0);
    }

    #[test]
    fn argmax_rows_matches_per_row_scan() {
        let vocab = 4;
        let logits = [0.1, 0.9, 0.2, 0.3, 7.0, 1.0, 7.0, 2.0, -1.0, -3.0, -2.0, -0.5];
        let rows = argmax_rows(&logits, vocab);
        assert_eq!(rows.len(), 3);
        for (r, &got) in rows.iter().enumerate() {
            assert_eq!(got, argmax_row(&logits[r * vocab..(r + 1) * vocab]), "row {r}");
        }
        assert_eq!(rows, vec![1, 0, 3]);
    }
}
