//! Position-local math for the CPU forward pass: RMSNorm, rotary position
//! embedding, causal attention over a KV cache, and the SwiGLU activation.
//!
//! Everything here is *per position* (or per query row) and walks its
//! inputs in one fixed order with f64 accumulators, so the results are
//! bit-identical no matter how the caller schedules positions across
//! threads or whether the surrounding projections ran full-sequence or
//! incrementally. The projections themselves are NOT here — they go
//! through [`crate::kernels`], which owns the chunked lane structure.

/// RMSNorm epsilon (added to the mean square before the square root).
pub const RMS_EPS: f64 = 1e-5;

/// RMSNorm one position: `out[i] = x[i] / rms(x) * w[i]` with the sum of
/// squares accumulated in f64 (one fixed left-to-right order).
pub fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    debug_assert_eq!(x.len(), out.len());
    let ss: f64 = x.iter().map(|&v| v as f64 * v as f64).sum();
    let inv = 1.0 / (ss / x.len() as f64 + RMS_EPS).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(w) {
        *o = (v as f64 * inv) as f32 * g;
    }
}

/// Rotary position embedding over one position's `[d]` projection row,
/// rotating pairs `(i, i + hd/2)` within each head by
/// `pos / base^(2i/hd)` radians. Trig runs in f64 and each output element
/// rounds to f32 once, so the value depends only on `(x, pos)` — never on
/// chunking or thread count.
pub fn rope_in_place(x: &mut [f32], heads: usize, pos: usize, base: f64) {
    let d = x.len();
    debug_assert_eq!(d % heads, 0);
    let hd = d / heads;
    let half = hd / 2;
    for h in 0..heads {
        let xs = &mut x[h * hd..(h + 1) * hd];
        for i in 0..half {
            let freq = base.powf(-((2 * i) as f64) / hd as f64);
            let (sin, cos) = (pos as f64 * freq).sin_cos();
            let a = xs[i] as f64;
            let b = xs[i + half] as f64;
            xs[i] = (a * cos - b * sin) as f32;
            xs[i + half] = (a * sin + b * cos) as f32;
        }
    }
}

/// Causal attention for one `(batch row, head, query position)` triple.
///
/// `q` is the head's roped `[hd]` query row; `kb`/`vb` are the batch row's
/// cached key/value slabs laid out `[seq, d]` with `h0 = head * hd` the
/// head's column offset. Attends positions `0..=p` in ascending order:
/// f64 dot products scaled by `1/sqrt(hd)`, a max-subtracted softmax, and
/// an f64 weighted value sum — all in position order, so full-sequence and
/// incremental callers produce the same bits from the same cache contents.
///
/// `scores` and `acc` are caller-owned scratch (cleared/resized here) so a
/// per-head job allocates once, not once per position.
#[allow(clippy::too_many_arguments)]
pub fn attend(
    q: &[f32],
    kb: &[f32],
    vb: &[f32],
    d: usize,
    h0: usize,
    p: usize,
    scores: &mut Vec<f32>,
    acc: &mut Vec<f64>,
    out: &mut [f32],
) {
    attend_core(q, kb, vb, |j| j * d, h0, p, scores, acc, out);
}

/// [`attend`] reading the cache through a page table instead of a
/// contiguous `[seq, d]` slab: position `j` lives in slab row
/// `pages[j / page_tokens] * page_tokens + j % page_tokens`.
///
/// The f64 operation sequence is shared with [`attend`] — only the row
/// *address* differs — so paged and contiguous caches holding the same
/// values produce bit-identical outputs.
#[allow(clippy::too_many_arguments)]
pub fn attend_paged(
    q: &[f32],
    kb: &[f32],
    vb: &[f32],
    pages: &[usize],
    page_tokens: usize,
    d: usize,
    h0: usize,
    p: usize,
    scores: &mut Vec<f32>,
    acc: &mut Vec<f64>,
    out: &mut [f32],
) {
    let base = |j: usize| (pages[j / page_tokens] * page_tokens + j % page_tokens) * d;
    attend_core(q, kb, vb, base, h0, p, scores, acc, out);
}

/// The shared attention body: ascending-position f64 dot products scaled
/// by `1/sqrt(hd)`, max-subtracted softmax, f64 weighted value sum.
/// `row_base(j)` maps a logical position to its element offset in the
/// key/value slabs; every arithmetic op is independent of that mapping.
#[allow(clippy::too_many_arguments)]
fn attend_core(
    q: &[f32],
    kb: &[f32],
    vb: &[f32],
    row_base: impl Fn(usize) -> usize,
    h0: usize,
    p: usize,
    scores: &mut Vec<f32>,
    acc: &mut Vec<f64>,
    out: &mut [f32],
) {
    let hd = q.len();
    debug_assert_eq!(out.len(), hd);
    let scale = 1.0 / (hd as f64).sqrt();
    scores.clear();
    let mut max = f32::NEG_INFINITY;
    for j in 0..=p {
        let b = row_base(j) + h0;
        let krow = &kb[b..b + hd];
        let dot: f64 = q.iter().zip(krow).map(|(&a, &b)| a as f64 * b as f64).sum();
        let s = (dot * scale) as f32;
        if s > max {
            max = s;
        }
        scores.push(s);
    }
    let mut denom = 0.0f64;
    for s in scores.iter_mut() {
        let e = ((*s - max) as f64).exp();
        denom += e;
        *s = e as f32;
    }
    acc.clear();
    acc.resize(hd, 0.0);
    for (j, &w) in scores.iter().enumerate() {
        let b = row_base(j) + h0;
        let vrow = &vb[b..b + hd];
        for (a, &v) in acc.iter_mut().zip(vrow) {
            *a += w as f64 * v as f64;
        }
    }
    for (o, &a) in out.iter_mut().zip(acc.iter()) {
        *o = (a / denom) as f32;
    }
}

/// SiLU (swish) activation, computed in f64: `x * sigmoid(x)`.
pub fn silu(x: f32) -> f32 {
    let xf = x as f64;
    (xf / (1.0 + (-xf).exp())) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_scales_to_unit_rms() {
        let x = [2.0f32; 8];
        let w = [1.0f32; 8];
        let mut out = [0.0f32; 8];
        rmsnorm(&x, &w, &mut out);
        // rms(x) = sqrt(4 + eps) ≈ 2, so every output is ≈ 1
        for &o in &out {
            assert!((o - 1.0).abs() < 1e-3, "got {o}");
        }
        // gain vector is applied per element
        let w2 = [0.5f32; 8];
        let mut out2 = [0.0f32; 8];
        rmsnorm(&x, &w2, &mut out2);
        for (o, o2) in out.iter().zip(&out2) {
            assert_eq!(o2, &(o * 0.5));
        }
    }

    #[test]
    fn rope_identity_at_position_zero_and_norm_preserving() {
        let mut rng = crate::stats::Rng::new(9);
        let mut x = vec![0.0f32; 32];
        rng.fill_normal(&mut x, 1.0);
        let orig = x.clone();
        let mut at0 = x.clone();
        rope_in_place(&mut at0, 4, 0, 10_000.0);
        assert_eq!(at0, orig, "pos 0 rotates by zero radians");
        rope_in_place(&mut x, 4, 17, 10_000.0);
        assert_ne!(x, orig);
        // each rotated pair keeps its Euclidean norm
        let hd = 8;
        for h in 0..4 {
            for i in 0..hd / 2 {
                let (a, b) = (orig[h * hd + i], orig[h * hd + i + hd / 2]);
                let (c, d) = (x[h * hd + i], x[h * hd + i + hd / 2]);
                let n0 = (a * a + b * b).sqrt();
                let n1 = (c * c + d * d).sqrt();
                assert!((n0 - n1).abs() < 1e-5, "pair ({h},{i}): {n0} vs {n1}");
            }
        }
    }

    #[test]
    fn attend_single_position_returns_value_row() {
        let d = 8;
        let hd = 4;
        let q = [0.3f32, -1.0, 0.7, 0.2];
        let kb = vec![0.5f32; d];
        let vb: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let (mut scores, mut acc) = (Vec::new(), Vec::new());
        let mut out = [0.0f32; 4];
        // head 1 (h0 = 4): softmax over one score is 1, so out == v[4..8]
        attend(&q, &kb, &vb, d, 4, 0, &mut scores, &mut acc, &mut out);
        assert_eq!(out, [4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn attend_equal_keys_average_values() {
        let d = 4;
        let q = [1.0f32, 2.0, -0.5, 0.25];
        // three cached positions, identical keys -> uniform weights
        let kb = vec![0.1f32; 3 * d];
        let mut vb = vec![0.0f32; 3 * d];
        for j in 0..3 {
            for c in 0..d {
                vb[j * d + c] = (j * 10 + c) as f32;
            }
        }
        let (mut scores, mut acc) = (Vec::new(), Vec::new());
        let mut out = [0.0f32; 4];
        attend(&q, &kb, &vb, d, 0, 2, &mut scores, &mut acc, &mut out);
        for c in 0..d {
            let want = (vb[c] + vb[d + c] + vb[2 * d + c]) / 3.0;
            assert!((out[c] - want).abs() < 1e-5, "col {c}: {} vs {want}", out[c]);
        }
    }

    #[test]
    fn attend_paged_matches_contiguous_bits() {
        let mut rng = crate::stats::Rng::new(41);
        let d = 8;
        let hd = 4;
        let positions = 7; // spans 3 pages of 3 tokens, last page partial
        let page_tokens = 3;
        let mut kb = vec![0.0f32; positions * d];
        let mut vb = vec![0.0f32; positions * d];
        let mut q = vec![0.0f32; hd];
        rng.fill_normal(&mut kb, 1.0);
        rng.fill_normal(&mut vb, 1.0);
        rng.fill_normal(&mut q, 1.0);
        // scatter the contiguous rows into a paged slab with a
        // deliberately non-monotonic page table
        let pages = [2usize, 0, 3];
        let slab_rows = 4 * page_tokens;
        let mut kp = vec![0.0f32; slab_rows * d];
        let mut vp = vec![0.0f32; slab_rows * d];
        for j in 0..positions {
            let b = (pages[j / page_tokens] * page_tokens + j % page_tokens) * d;
            kp[b..b + d].copy_from_slice(&kb[j * d..(j + 1) * d]);
            vp[b..b + d].copy_from_slice(&vb[j * d..(j + 1) * d]);
        }
        let (mut scores, mut acc) = (Vec::new(), Vec::new());
        for h0 in [0, hd] {
            for p in 0..positions {
                let mut a = vec![0.0f32; hd];
                let mut b = vec![0.0f32; hd];
                attend(&q, &kb, &vb, d, h0, p, &mut scores, &mut acc, &mut a);
                attend_paged(
                    &q, &kp, &vp, &pages, page_tokens, d, h0, p, &mut scores, &mut acc, &mut b,
                );
                assert_eq!(a, b, "h0 {h0} p {p}: paged attention must be bit-identical");
            }
        }
    }

    #[test]
    fn silu_shape() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!(silu(-10.0).abs() < 1e-3);
        assert!(silu(1.0) > 0.7 && silu(1.0) < 0.74);
    }
}
