//! Synthetic transformer instances for the forward pass: the parameter
//! naming contract, a [`ModelSpec`] view the quantization pipeline can
//! consume, and seeded weight generation.
//!
//! Parameter naming (the contract [`super::ForwardModel`] loads by):
//!
//! | name                | shape        | quantized |
//! |---------------------|--------------|-----------|
//! | `tok_emb`           | `[vocab, d]` | no        |
//! | `layer{l}.attn_norm`| `[d]`        | no        |
//! | `layer{l}.wq/wk/wv/wo` | `[d, d]`  | yes       |
//! | `layer{l}.mlp_norm` | `[d]`        | no        |
//! | `layer{l}.w_gate`   | `[ff, d]`    | yes       |
//! | `layer{l}.w_up`     | `[ff, d]`    | yes       |
//! | `layer{l}.w_down`   | `[d, ff]`    | yes       |
//! | `final_norm`        | `[d]`        | no        |
//! | `lm_head`           | `[vocab, d]` | yes       |

use crate::io::manifest::{ModelSpec, ParamSpec};
use crate::io::msbt::{Tensor, TensorMap};
use crate::stats::Rng;
use crate::tensor::Matrix;

use super::ForwardSpec;

/// The full parameter list for `fs`, in forward-pass order.
pub fn param_specs(fs: &ForwardSpec) -> Vec<ParamSpec> {
    let (v, d, ff) = (fs.vocab, fs.d, fs.ff);
    let mut out = vec![ParamSpec { name: "tok_emb".into(), shape: vec![v, d], quant: false }];
    for l in 0..fs.layers {
        let p = |s: &str| format!("layer{l}.{s}");
        out.push(ParamSpec { name: p("attn_norm"), shape: vec![d], quant: false });
        for w in ["wq", "wk", "wv", "wo"] {
            out.push(ParamSpec { name: p(w), shape: vec![d, d], quant: true });
        }
        out.push(ParamSpec { name: p("mlp_norm"), shape: vec![d], quant: false });
        out.push(ParamSpec { name: p("w_gate"), shape: vec![ff, d], quant: true });
        out.push(ParamSpec { name: p("w_up"), shape: vec![ff, d], quant: true });
        out.push(ParamSpec { name: p("w_down"), shape: vec![d, ff], quant: true });
    }
    out.push(ParamSpec { name: "final_norm".into(), shape: vec![d], quant: false });
    out.push(ParamSpec { name: "lm_head".into(), shape: vec![v, d], quant: true });
    out
}

/// A [`ModelSpec`] over the synthetic parameter list, ready for
/// [`crate::pipeline::quantize`] (no artifact files are referenced).
pub fn model_spec(fs: &ForwardSpec, name: &str) -> ModelSpec {
    ModelSpec {
        name: name.into(),
        d: fs.d,
        layers: fs.layers,
        heads: fs.heads,
        ff: fs.ff,
        seq: fs.seq,
        params: param_specs(fs),
        weights_file: String::new(),
        calib_file: String::new(),
        fwd_hlo: String::new(),
    }
}

/// Seeded synthetic weights matching [`param_specs`]: heavy-tailed
/// weight-like projections (so quantizers see realistic outliers and
/// exception lists), N(0,1) embeddings, and near-unit norm gains.
pub fn synth_weights(fs: &ForwardSpec, seed: u64) -> TensorMap {
    let mut rng = Rng::new(seed);
    let mut map = TensorMap::new();
    for p in param_specs(fs) {
        let t = match p.shape.as_slice() {
            [n] => {
                let gains: Vec<f32> =
                    (0..*n).map(|_| 1.0 + 0.05 * rng.normal() as f32).collect();
                Tensor::f32(p.shape.clone(), gains)
            }
            [r, c] if p.quant => {
                Tensor::f32(p.shape.clone(), Matrix::weightlike(*r, *c, &mut rng).data)
            }
            [r, c] => Tensor::f32(p.shape.clone(), Matrix::randn(*r, *c, &mut rng).data),
            other => unreachable!("synthetic param {} has rank {}", p.name, other.len()),
        };
        map.insert(p.name, t);
    }
    map
}

/// A seeded token batch in `[batch, len]` row-major order, every id
/// strictly below `fs.vocab`.
pub fn synth_tokens(fs: &ForwardSpec, len: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..fs.batch * len).map(|_| rng.below(fs.vocab) as i32).collect()
}

/// Map a real-checkpoint parameter name (the HF Llama-style convention)
/// onto this module's naming contract, or `None` when the name is not a
/// recognized alias (contract-native names return `None` too — they need
/// no renaming). The table:
///
/// | checkpoint name                                   | contract name      |
/// |---------------------------------------------------|--------------------|
/// | `model.embed_tokens.weight`                       | `tok_emb`          |
/// | `model.layers.{l}.input_layernorm.weight`         | `layer{l}.attn_norm` |
/// | `model.layers.{l}.self_attn.{q,k,v,o}_proj.weight`| `layer{l}.w{q,k,v,o}` |
/// | `model.layers.{l}.post_attention_layernorm.weight`| `layer{l}.mlp_norm` |
/// | `model.layers.{l}.mlp.{gate,up,down}_proj.weight` | `layer{l}.w_{gate,up,down}` |
/// | `model.norm.weight`                               | `final_norm`       |
/// | `lm_head.weight`                                  | `lm_head`          |
pub fn canonical_param_name(name: &str) -> Option<String> {
    match name {
        "model.embed_tokens.weight" => return Some("tok_emb".into()),
        "model.norm.weight" => return Some("final_norm".into()),
        "lm_head.weight" => return Some("lm_head".into()),
        _ => {}
    }
    let rest = name.strip_prefix("model.layers.")?;
    let dot = rest.find('.')?;
    let l: usize = rest[..dot].parse().ok()?;
    let suffix = match &rest[dot + 1..] {
        "input_layernorm.weight" => "attn_norm",
        "self_attn.q_proj.weight" => "wq",
        "self_attn.k_proj.weight" => "wk",
        "self_attn.v_proj.weight" => "wv",
        "self_attn.o_proj.weight" => "wo",
        "post_attention_layernorm.weight" => "mlp_norm",
        "mlp.gate_proj.weight" => "w_gate",
        "mlp.up_proj.weight" => "w_up",
        "mlp.down_proj.weight" => "w_down",
        _ => return None,
    };
    Some(format!("layer{l}.{suffix}"))
}

/// The checkpoint-convention alias of a contract parameter name, when one
/// exists ([`canonical_param_name`]'s inverse; tests rename synthetic
/// payloads through it).
pub fn checkpoint_param_name(name: &str) -> Option<String> {
    match name {
        "tok_emb" => return Some("model.embed_tokens.weight".into()),
        "final_norm" => return Some("model.norm.weight".into()),
        "lm_head" => return Some("lm_head.weight".into()),
        _ => {}
    }
    let rest = name.strip_prefix("layer")?;
    let dot = rest.find('.')?;
    let l: usize = rest[..dot].parse().ok()?;
    let suffix = match &rest[dot + 1..] {
        "attn_norm" => "input_layernorm.weight",
        "wq" => "self_attn.q_proj.weight",
        "wk" => "self_attn.k_proj.weight",
        "wv" => "self_attn.v_proj.weight",
        "wo" => "self_attn.o_proj.weight",
        "mlp_norm" => "post_attention_layernorm.weight",
        "w_gate" => "mlp.gate_proj.weight",
        "w_up" => "mlp.up_proj.weight",
        "w_down" => "mlp.down_proj.weight",
        _ => return None,
    };
    Some(format!("model.layers.{l}.{suffix}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ForwardSpec {
        ForwardSpec::new(40, 32, 2, 4, 48, 8, 2).unwrap()
    }

    #[test]
    fn specs_and_weights_agree() {
        let fs = tiny();
        let specs = param_specs(&fs);
        // 1 embedding + 9 per layer + final_norm + lm_head
        assert_eq!(specs.len(), 1 + 9 * fs.layers + 2);
        let w = synth_weights(&fs, 3);
        for p in &specs {
            let t = w.get(&p.name).unwrap_or_else(|| panic!("missing {}", p.name));
            assert_eq!(t.dims, p.shape, "{}", p.name);
        }
        let ms = model_spec(&fs, "tiny");
        assert_eq!(ms.quantizable().count(), 7 * fs.layers + 1);
    }

    #[test]
    fn weights_are_seed_deterministic() {
        let fs = tiny();
        let a = synth_weights(&fs, 11);
        let b = synth_weights(&fs, 11);
        let c = synth_weights(&fs, 12);
        assert_eq!(
            a.get("layer0.wq").unwrap().as_f32().unwrap(),
            b.get("layer0.wq").unwrap().as_f32().unwrap()
        );
        assert_ne!(
            a.get("layer0.wq").unwrap().as_f32().unwrap(),
            c.get("layer0.wq").unwrap().as_f32().unwrap()
        );
    }

    #[test]
    fn tokens_stay_in_vocab() {
        let fs = tiny();
        let toks = synth_tokens(&fs, fs.seq, 5);
        assert_eq!(toks.len(), fs.batch * fs.seq);
        assert!(toks.iter().all(|&t| t >= 0 && (t as usize) < fs.vocab));
    }

    /// Every contract name round-trips through the checkpoint alias table,
    /// and unrecognized names map to nothing.
    #[test]
    fn checkpoint_aliases_round_trip() {
        let fs = tiny();
        for p in param_specs(&fs) {
            let ckpt = checkpoint_param_name(&p.name)
                .unwrap_or_else(|| panic!("no checkpoint alias for {}", p.name));
            assert_eq!(canonical_param_name(&ckpt).as_deref(), Some(p.name.as_str()));
            // contract-native names need no renaming
            assert_eq!(canonical_param_name(&p.name), None);
        }
        assert_eq!(
            canonical_param_name("model.layers.11.self_attn.k_proj.weight").as_deref(),
            Some("layer11.wk")
        );
        assert_eq!(canonical_param_name("model.layers.x.self_attn.k_proj.weight"), None);
        assert_eq!(canonical_param_name("optimizer.step"), None);
        assert_eq!(checkpoint_param_name("layer0.bogus"), None);
    }
}
