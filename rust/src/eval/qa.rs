//! Zero-shot multiple-choice QA scoring: pick the candidate with the best
//! length-normalized logprob given the prompt — the lm-eval-harness
//! protocol behind the paper's seven QA columns.

use anyhow::{Context, Result};

use super::LogProbs;
use crate::io::msbt::TensorMap;
use crate::runtime::LogitsFn;

#[derive(Clone, Debug)]
pub struct Probe {
    pub prompt: Vec<i32>,
    pub candidates: Vec<Vec<i32>>,
    pub answer: usize,
}

#[derive(Clone, Debug)]
pub struct ProbeSuite {
    pub name: String,
    pub probes: Vec<Probe>,
}

#[derive(Clone, Copy, Debug)]
pub struct QaScore {
    pub correct: usize,
    pub total: usize,
}

impl QaScore {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

/// Look up one flattened probe array (`{suite}.{suffix}`) as i32s.
fn probe_field<'m>(tensors: &'m TensorMap, name: &str, suffix: &str) -> Result<&'m [i32]> {
    tensors
        .get(&format!("{name}.{suffix}"))
        .with_context(|| format!("probes missing {name}.{suffix}"))?
        .as_i32()
}

/// Decode the flattened probe arrays written by python/compile/aot.py.
pub fn load_probe_suites(tensors: &TensorMap, names: &[String]) -> Result<Vec<ProbeSuite>> {
    let mut suites = Vec::new();
    for name in names {
        let p_tok = probe_field(tensors, name, "prompt_tok")?;
        let p_off = probe_field(tensors, name, "prompt_off")?;
        let c_tok = probe_field(tensors, name, "cand_tok")?;
        let c_off = probe_field(tensors, name, "cand_off")?;
        let c_cnt = probe_field(tensors, name, "cand_count")?;
        let answer = probe_field(tensors, name, "answer")?;
        let n = c_cnt.len();
        anyhow::ensure!(p_off.len() == n + 1 && answer.len() == n, "{name}: ragged");
        let mut probes = Vec::with_capacity(n);
        let mut cand_idx = 0usize;
        for i in 0..n {
            let prompt = p_tok[p_off[i] as usize..p_off[i + 1] as usize].to_vec();
            let mut candidates = Vec::with_capacity(c_cnt[i] as usize);
            for _ in 0..c_cnt[i] {
                let s = c_off[cand_idx] as usize;
                let e = c_off[cand_idx + 1] as usize;
                candidates.push(c_tok[s..e].to_vec());
                cand_idx += 1;
            }
            probes.push(Probe { prompt, candidates, answer: answer[i] as usize });
        }
        suites.push(ProbeSuite { name: name.clone(), probes });
    }
    Ok(suites)
}

/// One scoring unit: a (probe, candidate) pair packed as a sequence.
struct Item {
    probe: usize,
    cand: usize,
    /// prompt+candidate tokens, truncated to seq
    tokens: Vec<i32>,
    /// candidate token span [start, end) within `tokens`
    span: (usize, usize),
}

/// Score one suite: batch all (probe, candidate) sequences through the
/// model, pick argmax_c mean-logprob(candidate | prompt).
pub fn score_suite<M: LogitsFn + ?Sized>(model: &M, suite: &ProbeSuite) -> Result<QaScore> {
    let (b, t, v) = (model.batch(), model.seq(), model.vocab());

    let mut items = Vec::new();
    for (pi, probe) in suite.probes.iter().enumerate() {
        for (ci, cand) in probe.candidates.iter().enumerate() {
            let mut tokens = probe.prompt.clone();
            tokens.extend_from_slice(cand);
            if tokens.len() > t {
                // keep the tail (the candidate must stay in-window)
                let cut = tokens.len() - t;
                tokens.drain(..cut);
            }
            let end = tokens.len();
            // candidate occupies the tail; position 0 has no predictor, so
            // clamp the span start to 1 if truncation ate the whole prompt
            let start = end.saturating_sub(cand.len()).max(1).min(end);
            items.push(Item { probe: pi, cand: ci, tokens, span: (start, end) });
        }
    }

    // batched scoring
    let mut scores: Vec<Vec<f64>> =
        suite.probes.iter().map(|p| vec![f64::NEG_INFINITY; p.candidates.len()]).collect();
    for chunk in items.chunks(b) {
        let mut tokens = vec![0i32; b * t];
        for (row, item) in chunk.iter().enumerate() {
            tokens[row * t..row * t + item.tokens.len()].copy_from_slice(&item.tokens);
        }
        let logits = model.logits(&tokens)?;
        let lp = LogProbs::new(&logits, v);
        for (row, item) in chunk.iter().enumerate() {
            let (s, e) = item.span;
            let mut acc = 0.0f64;
            for p in s..e {
                // token at p is predicted by logits at p-1
                acc += lp.logp(row * t + p - 1, item.tokens[p] as usize);
            }
            scores[item.probe][item.cand] = acc / (e - s).max(1) as f64;
        }
    }

    let mut correct = 0usize;
    for (pi, probe) in suite.probes.iter().enumerate() {
        let best = scores[pi]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if best == probe.answer {
            correct += 1;
        }
    }
    Ok(QaScore { correct, total: suite.probes.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::mock::SuccessorModel;

    fn successor_suite(vocab: i32) -> ProbeSuite {
        // prompt [a, a+1, a+2]; correct candidate continues the run
        let mut probes = Vec::new();
        for a in 0..10 {
            let prompt = vec![a, a + 1, a + 2];
            let candidates = vec![
                vec![a + 3, a + 4],        // correct successor run
                vec![a + 7, a + 2],        // wrong
                vec![a, a],                // wrong
            ];
            probes.push(Probe { prompt, candidates, answer: 0 });
        }
        let _ = vocab;
        ProbeSuite { name: "succ".into(), probes }
    }

    #[test]
    fn successor_model_aces_successor_suite() {
        let m = SuccessorModel { batch: 4, seq: 16, vocab: 32, boost: 10.0 };
        let score = score_suite(&m, &successor_suite(32)).unwrap();
        assert_eq!(score.correct, score.total);
        crate::testing::assert_close(score.accuracy(), 1.0, 0.0, 0.0);
    }

    #[test]
    fn uniform_model_ties_resolve_to_last_candidate() {
        // uniform logits => equal-length candidates all tie; Rust's
        // max_by keeps the *last* maximum, so only answers at the last
        // index win. This pins the deterministic tie-break behaviour.
        let m = SuccessorModel { batch: 4, seq: 16, vocab: 32, boost: 0.0 };
        let score = score_suite(&m, &successor_suite(32)).unwrap();
        assert_eq!(score.correct, 0, "answer=0 never wins a tie");
        let mut suite = successor_suite(32);
        for p in &mut suite.probes {
            let last = p.candidates.len() - 1;
            p.answer = last;
        }
        let score = score_suite(&m, &suite).unwrap();
        assert_eq!(score.correct, score.total, "last index wins ties");
    }

    #[test]
    fn length_normalization_matters() {
        // a longer all-successor candidate must not lose to a shorter one
        // just for accumulating more logprob mass
        let m = SuccessorModel { batch: 2, seq: 16, vocab: 32, boost: 10.0 };
        let probe = Probe {
            prompt: vec![1, 2, 3],
            candidates: vec![vec![4, 5, 6, 7, 8], vec![9]],
            answer: 0,
        };
        let suite = ProbeSuite { name: "ln".into(), probes: vec![probe] };
        let score = score_suite(&m, &suite).unwrap();
        assert_eq!(score.correct, 1);
    }

    #[test]
    fn roundtrip_probe_container() {
        use crate::io::msbt::{Tensor, TensorMap};
        let mut t = TensorMap::new();
        t.insert("x.prompt_tok".into(), Tensor::i32(vec![4], vec![1, 2, 3, 4]));
        t.insert("x.prompt_off".into(), Tensor::i32(vec![3], vec![0, 2, 4]));
        t.insert("x.cand_tok".into(), Tensor::i32(vec![4], vec![5, 6, 7, 8]));
        t.insert("x.cand_off".into(), Tensor::i32(vec![5], vec![0, 1, 2, 3, 4]));
        t.insert("x.cand_count".into(), Tensor::i32(vec![2], vec![2, 2]));
        t.insert("x.answer".into(), Tensor::i32(vec![2], vec![1, 0]));
        let suites = load_probe_suites(&t, &["x".to_string()]).unwrap();
        assert_eq!(suites.len(), 1);
        assert_eq!(suites[0].probes.len(), 2);
        assert_eq!(suites[0].probes[0].prompt, vec![1, 2]);
        assert_eq!(suites[0].probes[0].candidates, vec![vec![5], vec![6]]);
        assert_eq!(suites[0].probes[1].answer, 0);
    }

    #[test]
    fn long_prompt_truncation_keeps_candidate() {
        let m = SuccessorModel { batch: 1, seq: 8, vocab: 32, boost: 10.0 };
        let probe = Probe {
            prompt: (0..20).collect(),
            candidates: vec![vec![20, 21], vec![3, 9]],
            answer: 0,
        };
        let suite = ProbeSuite { name: "trunc".into(), probes: vec![probe] };
        let score = score_suite(&m, &suite).unwrap();
        assert_eq!(score.correct, 1);
    }
}
