//! Evaluation: perplexity over held-out token streams and the 7-suite QA
//! probe protocol — the analogs of the paper's WK2/PTB/C4 PPL and
//! seven-task zero-shot QA averages (Table 1).

pub mod ppl;
pub mod qa;

pub use ppl::perplexity;
pub use qa::{load_probe_suites, score_suite, ProbeSuite, QaScore};

/// Numerically-stable log-softmax over the last axis of a [positions,
/// vocab] logits slab, evaluated lazily per requested (position, token).
pub struct LogProbs<'a> {
    logits: &'a [f32],
    vocab: usize,
}

impl<'a> LogProbs<'a> {
    pub fn new(logits: &'a [f32], vocab: usize) -> Self {
        assert_eq!(logits.len() % vocab, 0);
        LogProbs { logits, vocab }
    }

    pub fn positions(&self) -> usize {
        self.logits.len() / self.vocab
    }

    /// log p(token | position) = logit − logsumexp(position row).
    pub fn logp(&self, position: usize, token: usize) -> f64 {
        let row = &self.logits[position * self.vocab..(position + 1) * self.vocab];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
        let lse: f64 = row.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln() + max;
        (row[token] as f64) - lse
    }
}

#[cfg(test)]
pub(crate) mod mock {
    //! A deterministic mock [`crate::runtime::LogitsFn`] for eval-logic
    //! tests: logit(next == (cur + 1) % vocab) is boosted, so the "model"
    //! prefers successor tokens. PPL/QA math can be validated analytically.

    use crate::runtime::LogitsFn;

    pub struct SuccessorModel {
        pub batch: usize,
        pub seq: usize,
        pub vocab: usize,
        pub boost: f32,
    }

    impl LogitsFn for SuccessorModel {
        fn batch(&self) -> usize {
            self.batch
        }

        fn seq(&self) -> usize {
            self.seq
        }

        fn vocab(&self) -> usize {
            self.vocab
        }

        fn logits(&self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
            assert_eq!(tokens.len(), self.batch * self.seq);
            let mut out = vec![0.0f32; self.batch * self.seq * self.vocab];
            for (pos, &t) in tokens.iter().enumerate() {
                let succ = ((t as usize) + 1) % self.vocab;
                out[pos * self.vocab + succ] = self.boost;
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logprobs_uniform() {
        let logits = vec![0.0f32; 10];
        let lp = LogProbs::new(&logits, 10);
        crate::testing::assert_close(lp.logp(0, 3), -(10f64.ln()), 1e-9, 0.0);
    }

    #[test]
    fn logprobs_sum_to_one() {
        let mut rng = crate::stats::Rng::new(1);
        let logits: Vec<f32> = (0..50).map(|_| rng.normal() as f32 * 3.0).collect();
        let lp = LogProbs::new(&logits, 10);
        for pos in 0..5 {
            let total: f64 = (0..10).map(|t| lp.logp(pos, t).exp()).sum();
            crate::testing::assert_close(total, 1.0, 1e-9, 0.0);
        }
    }

    #[test]
    fn logprobs_stable_at_extremes() {
        let logits = vec![1000.0f32, -1000.0, 0.0];
        let lp = LogProbs::new(&logits, 3);
        assert!(lp.logp(0, 0) > -1e-6);
        assert!(lp.logp(0, 1).is_finite());
    }
}
