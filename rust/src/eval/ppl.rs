//! Perplexity: exp(mean next-token NLL) over a token stream, computed by
//! chunking the stream into non-overlapping [seq]-windows and batching them
//! through a [`LogitsFn`] — the standard strided PPL protocol the paper
//! inherits from GPTQ/BiLLM evaluations.

use anyhow::Result;

use super::LogProbs;
use crate::runtime::LogitsFn;

/// Perplexity of `stream` under `model`. Windows shorter than `seq` at the
/// stream tail are dropped (standard protocol); padding rows added to fill
/// the final batch are masked out of the average.
pub fn perplexity<M: LogitsFn + ?Sized>(model: &M, stream: &[i32]) -> Result<f64> {
    let (b, t, v) = (model.batch(), model.seq(), model.vocab());
    let windows: Vec<&[i32]> = stream.chunks_exact(t).collect();
    anyhow::ensure!(!windows.is_empty(), "stream shorter than one window ({t})");

    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    for batch in windows.chunks(b) {
        let mut tokens = vec![0i32; b * t];
        for (row, win) in batch.iter().enumerate() {
            tokens[row * t..(row + 1) * t].copy_from_slice(win);
        }
        let logits = model.logits(&tokens)?;
        anyhow::ensure!(logits.len() == b * t * v, "bad logits size");
        let lp = LogProbs::new(&logits, v);
        for (row, win) in batch.iter().enumerate() {
            // position p predicts token p+1
            for p in 0..t - 1 {
                let target = win[p + 1] as usize;
                total_nll -= lp.logp(row * t + p, target);
                total_tok += 1;
            }
        }
    }
    Ok((total_nll / total_tok as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::mock::SuccessorModel;

    #[test]
    fn uniform_model_gives_vocab_ppl() {
        let m = SuccessorModel { batch: 2, seq: 8, vocab: 16, boost: 0.0 };
        let stream: Vec<i32> = (0..64).map(|i| i % 16).collect();
        let ppl = perplexity(&m, &stream).unwrap();
        crate::testing::assert_close(ppl, 16.0, 1e-9, 0.0);
    }

    #[test]
    fn successor_stream_scores_low() {
        // stream of consecutive tokens == exactly what SuccessorModel likes
        let m = SuccessorModel { batch: 2, seq: 8, vocab: 16, boost: 8.0 };
        let stream: Vec<i32> = (0..64).map(|i| i % 16).collect();
        let good = perplexity(&m, &stream).unwrap();
        // anti-correlated stream: constant token (successor never matches)
        let bad_stream = vec![3i32; 64];
        let bad = perplexity(&m, &bad_stream).unwrap();
        assert!(good < 2.0, "{good}");
        assert!(bad > good * 4.0, "{bad} vs {good}");
    }

    #[test]
    fn tail_dropped_and_padding_masked() {
        let m = SuccessorModel { batch: 4, seq: 8, vocab: 16, boost: 2.0 };
        let stream: Vec<i32> = (0..8 * 5 + 3).map(|i| i % 16).collect(); // 5 windows + ragged tail
        let a = perplexity(&m, &stream).unwrap();
        let b = perplexity(&m, &stream[..8 * 5]).unwrap();
        crate::testing::assert_close(a, b, 1e-12, 0.0);
    }

    #[test]
    fn too_short_stream_errors() {
        let m = SuccessorModel { batch: 1, seq: 8, vocab: 4, boost: 0.0 };
        assert!(perplexity(&m, &[1, 2, 3]).is_err());
    }

    #[test]
    fn degraded_logits_raise_ppl() {
        // the core signal the paper measures: noisier models => higher PPL
        let sharp = SuccessorModel { batch: 2, seq: 8, vocab: 16, boost: 8.0 };
        let blunt = SuccessorModel { batch: 2, seq: 8, vocab: 16, boost: 1.0 };
        let stream: Vec<i32> = (0..128).map(|i| i % 16).collect();
        assert!(
            perplexity(&sharp, &stream).unwrap() < perplexity(&blunt, &stream).unwrap()
        );
    }
}
