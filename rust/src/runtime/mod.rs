//! PJRT runtime: loads the AOT-lowered HLO text produced by
//! `python/compile/aot.py`, compiles it once per model variant on the CPU
//! PJRT client, and executes it from the rust request path.
//!
//! Interchange is HLO *text* — jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! Weights are uploaded once as persistent [`xla::PjRtBuffer`]s and reused
//! across every call (`execute_b`); only the token batch is re-uploaded per
//! request. That keeps the request path free of O(model) host↔device
//! traffic — see EXPERIMENTS.md §Perf for the before/after.

use std::path::Path;

use anyhow::{Context, Result};

use crate::io::manifest::{Manifest, ModelSpec};
use crate::io::msbt::TensorMap;

/// Thin owner of the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file into a reusable executable.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| {
            format!("PJRT compile of {}", path.display())
        })?;
        Ok(Executable { exe })
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i8(&self, data: &[i8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// A compiled model executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute over persistent device buffers; returns the first element of
    /// the output 1-tuple as f32s (the lowering wraps results in a tuple —
    /// `return_tuple=True`).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let out = self.exe.execute_b(args).context("execute_b")?;
        let lit = out[0][0].to_literal_sync()?;
        let inner = lit.to_tuple1()?;
        Ok(inner.to_vec::<f32>()?)
    }
}

/// The L3-facing model handle: one compiled executable + the weight
/// buffers in ABI order. Feeding different (e.g. quantized-dequantized)
/// weights to the *same* executable is exactly the paper's
/// simulated-quantization protocol.
pub struct ModelRunner {
    rt: Runtime,
    exe: Executable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// ABI order of weight names (for targeted updates).
    names: Vec<String>,
    shapes: Vec<Vec<usize>>,
    /// Workers for decoding packed payload maps on weight swap-in;
    /// `None` = one per available core. Set via [`BackendBuilder`].
    decode_threads: Option<usize>,
}

impl ModelRunner {
    /// Compile `spec`'s forward HLO and upload `weights` (ABI order from the
    /// manifest).
    pub fn new(manifest: &Manifest, spec: &ModelSpec, weights: &TensorMap) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo(manifest.path(&spec.fwd_hlo))?;
        let mut weight_bufs = Vec::with_capacity(spec.params.len());
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        for p in &spec.params {
            let t = weights
                .get(&p.name)
                .with_context(|| format!("weights file missing '{}'", p.name))?;
            anyhow::ensure!(t.dims == p.shape, "{}: shape {:?} != manifest {:?}",
                p.name, t.dims, p.shape);
            weight_bufs.push(rt.upload_f32(t.as_f32()?, &p.shape)?);
            names.push(p.name.clone());
            shapes.push(p.shape.clone());
        }
        Ok(ModelRunner {
            rt,
            exe,
            weight_bufs,
            batch: manifest.eval_batch,
            seq: spec.seq,
            vocab: manifest.vocab,
            names,
            shapes,
            decode_threads: None,
        })
    }

    /// Pin the worker count used to decode packed payload maps on
    /// swap-in (default: one per available core).
    pub fn set_decode_threads(&mut self, threads: usize) {
        self.decode_threads = (threads > 0).then_some(threads);
    }

    /// Replace a subset of weights (by name) — used to swap in each
    /// quantized variant without recompiling or re-uploading the rest.
    /// Packed payload maps ([`crate::pipeline::QuantizedModel::export_packed`])
    /// are detected and decoded transparently on the configured decode
    /// pool ([`ModelRunner::set_decode_threads`] /
    /// [`BackendBuilder::threads`]; default one worker per core).
    pub fn update_weights(&mut self, updates: &TensorMap) -> Result<usize> {
        if crate::pipeline::is_packed_map(updates) {
            let threads = self.decode_threads.unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
            // the decoded map is plain f32 (no payload keys): no recursion
            let decoded = crate::pipeline::decode_packed_model(updates, threads)?;
            return self.update_weights(&decoded);
        }
        let mut n = 0;
        for (i, name) in self.names.iter().enumerate() {
            if let Some(t) = updates.get(name) {
                anyhow::ensure!(t.dims == self.shapes[i], "{name}: bad update shape");
                self.weight_bufs[i] = self.rt.upload_f32(t.as_f32()?, &self.shapes[i])?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Forward pass: `tokens` is a row-major [batch, seq] i32 buffer;
    /// returns logits [batch, seq, vocab].
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.batch * self.seq,
            "tokens len {} != {}x{}",
            tokens.len(),
            self.batch,
            self.seq
        );
        let tok_buf = self.rt.upload_i32(tokens, &[self.batch, self.seq])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(&tok_buf);
        args.extend(self.weight_bufs.iter());
        self.exe.run_buffers(&args)
    }
}

/// A model held *entirely in the packed domain*: one
/// [`PackedLinear`](crate::kernels::PackedLinear) handle per quantized
/// layer plus the pass-through tensors — never the decoded f32 weight
/// set. Where the `runner` backend ([`ModelRunner::update_weights`])
/// pays an O(model) unpack-to-f32 before PJRT upload, a `FusedModel`
/// keeps the 4–6× storage win at serve time and answers
/// matvec/batched-matmul requests straight off the codes
/// (`kernels::PackedLinear::gemv`/`gemm`). `server::GemvServer` wraps
/// one of these behind a dynamic-batching request loop; `serve_eval
/// fused` is the end-to-end driver.
pub struct FusedModel {
    method: String,
    linears: std::collections::BTreeMap<String, crate::kernels::PackedLinear>,
    passthrough: TensorMap,
    mac: crate::kernels::MacMode,
    mac_fallbacks: usize,
}

impl FusedModel {
    /// Build fused handles from an `export_packed` artifact (typically a
    /// `.msbt` file written by `msb pack`). No f32 weight buffer is
    /// materialized at any point. Layers run the exact f32 MAC; use
    /// [`FusedModel::from_packed_map_with`] to request the integer path.
    pub fn from_packed_map(map: &TensorMap) -> Result<FusedModel> {
        FusedModel::from_packed_map_with(map, crate::kernels::MacMode::F32)
    }

    /// [`FusedModel::from_packed_map`] with a multiply-accumulate mode
    /// applied to every layer. `MacMode::Int8` fails if any layer's method
    /// has no affine decode; `MacMode::Auto` keeps such layers on the f32
    /// path, counting each fallback ([`FusedModel::mac_fallbacks`]).
    pub fn from_packed_map_with(
        map: &TensorMap,
        mac: crate::kernels::MacMode,
    ) -> Result<FusedModel> {
        let (method, packed, passthrough) = crate::pipeline::packed_tensors(map)?;
        let mut linears = std::collections::BTreeMap::new();
        let mut mac_fallbacks = 0;
        for (name, pt) in packed {
            let pl = crate::kernels::PackedLinear::new(pt)
                .with_context(|| format!("fused handle for layer '{name}'"))?
                .with_mac(mac)
                .with_context(|| format!("mac mode for layer '{name}'"))?;
            if mac == crate::kernels::MacMode::Auto && !pl.int8_eligible() {
                mac_fallbacks += 1;
            }
            linears.insert(name, pl);
        }
        Ok(FusedModel { method, linears, passthrough, mac, mac_fallbacks })
    }

    /// The quantization method the payloads were emitted by.
    pub fn method(&self) -> &str {
        &self.method
    }

    /// The multiply-accumulate mode every layer handle was built with.
    pub fn mac(&self) -> crate::kernels::MacMode {
        self.mac
    }

    /// How many layers requested `MacMode::Auto` int8 but have no affine
    /// decode and stayed on the f32 MAC (zero under an explicit mode).
    pub fn mac_fallbacks(&self) -> usize {
        self.mac_fallbacks
    }

    /// Layer name → fused handle map (iteration order = BTreeMap order).
    pub fn linears(&self) -> &std::collections::BTreeMap<String, crate::kernels::PackedLinear> {
        &self.linears
    }

    pub fn linear(&self, name: &str) -> Option<&crate::kernels::PackedLinear> {
        self.linears.get(name)
    }

    /// Non-quantized tensors carried alongside (norms, embeddings).
    pub fn passthrough(&self) -> &TensorMap {
        &self.passthrough
    }

    /// Total serialized payload bytes actually held by the fused handles.
    pub fn payload_bytes(&self) -> usize {
        self.linears.values().map(|l| l.payload_bytes()).sum()
    }

    /// What the same layers would cost as decoded f32 buffers.
    pub fn f32_bytes(&self) -> usize {
        self.linears.values().map(|l| l.rows() * l.cols() * 4).sum()
    }

    /// Fused `y = W·x` for one layer (serial reference order).
    pub fn gemv(&self, layer: &str, x: &[f32]) -> Result<Vec<f32>> {
        let l = self.linears.get(layer).with_context(|| format!("no packed layer '{layer}'"))?;
        anyhow::ensure!(x.len() == l.cols(), "{layer}: x len {} != cols {}", x.len(), l.cols());
        Ok(l.gemv(x))
    }

    /// Fused batched product for one layer; bit-identical to per-request
    /// [`FusedModel::gemv`] for every batch size and worker count.
    pub fn gemm_pooled(
        &self,
        layer: &str,
        xs: &[f32],
        batch: usize,
        pool: &crate::pool::ThreadPool,
    ) -> Result<Vec<f32>> {
        let l = self.linears.get(layer).with_context(|| format!("no packed layer '{layer}'"))?;
        anyhow::ensure!(
            xs.len() == batch * l.cols(),
            "{layer}: activations {} != {batch}x{}",
            xs.len(),
            l.cols()
        );
        Ok(l.gemm_pooled(xs, batch, pool))
    }
}

/// Anything that maps a [batch, seq] token tensor to [batch, seq, vocab]
/// logits. `ModelRunner` is the real one; tests use closures/mocks.
pub trait LogitsFn {
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;
    fn vocab(&self) -> usize;
    fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>>;
}

impl LogitsFn for ModelRunner {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        ModelRunner::logits(self, tokens)
    }
}

// ---------------------------------------------------------------------------
// Backend: one handle over the three serving constructions.
// ---------------------------------------------------------------------------

/// The three ways this crate serves a model, behind one enum so drivers
/// (`examples/serve_eval.rs`, `msb score`) pick a backend by name instead
/// of growing mutually exclusive flags:
///
/// * [`Backend::Runner`] — the PJRT-compiled HLO executable (XLA forward)
///   over f32 weight buffers; packed payloads decode on swap-in.
/// * [`Backend::Fused`] — per-layer [`crate::kernels::PackedLinear`]
///   handles answering matvec/matmul requests straight off the codes
///   (behind [`crate::server::GemvServer`]); never decodes.
/// * [`Backend::Forward`] — the fused CPU transformer forward
///   ([`crate::forward::ForwardModel`]): full token scoring straight off
///   the codes, no XLA anywhere.
///
/// Build one with [`BackendBuilder`].
pub enum Backend {
    Runner(ModelRunner),
    Fused(FusedModel),
    Forward(crate::forward::ForwardModel),
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Runner(_) => "runner",
            Backend::Fused(_) => "fused",
            Backend::Forward(_) => "forward",
        }
    }

    /// Token-scoring view, when this backend has one (`runner` and
    /// `forward` do; `fused` serves per-layer products instead).
    pub fn logits_fn(&self) -> Option<&dyn LogitsFn> {
        match self {
            Backend::Runner(r) => Some(r),
            Backend::Forward(f) => Some(f),
            Backend::Fused(_) => None,
        }
    }

    pub fn into_runner(self) -> Result<ModelRunner> {
        match self {
            Backend::Runner(r) => Ok(r),
            other => anyhow::bail!("backend '{}' is not a PJRT runner", other.name()),
        }
    }

    pub fn into_fused(self) -> Result<FusedModel> {
        match self {
            Backend::Fused(f) => Ok(f),
            other => anyhow::bail!("backend '{}' is not a fused gemv model", other.name()),
        }
    }

    pub fn into_forward(self) -> Result<crate::forward::ForwardModel> {
        match self {
            Backend::Forward(f) => Ok(f),
            other => anyhow::bail!("backend '{}' is not a CPU forward model", other.name()),
        }
    }
}

/// Carries the knobs every serving construction shares (worker threads,
/// MAC mode, batching limits) and hands back a [`Backend`] — the single
/// entry point that replaced the `ModelRunner` / `FusedModel` /
/// `ForwardModel` constructor trio drivers used to wire by hand.
#[derive(Clone, Debug)]
pub struct BackendBuilder {
    threads: usize,
    mac: crate::kernels::MacMode,
    max_streams: usize,
    kv_page_tokens: usize,
    speculative: bool,
    draft_len: usize,
    max_waiting: usize,
    faults: crate::server::faults::FaultPlan,
}

impl Default for BackendBuilder {
    fn default() -> BackendBuilder {
        BackendBuilder::new()
    }
}

impl BackendBuilder {
    pub fn new() -> BackendBuilder {
        BackendBuilder {
            threads: 0,
            mac: crate::kernels::MacMode::F32,
            max_streams: 4,
            kv_page_tokens: 16,
            speculative: false,
            draft_len: 4,
            max_waiting: 256,
            faults: crate::server::faults::FaultPlan::default(),
        }
    }

    /// Worker threads: payload decode for `runner`, pooled kernels for
    /// `forward`. `0` (the default) means one per available core.
    pub fn threads(mut self, threads: usize) -> BackendBuilder {
        self.threads = threads;
        self
    }

    /// Concurrent decode streams the continuous-batching scheduler admits
    /// (`forward` backend; sizes the [`crate::forward::KvArena`]).
    /// Default 4.
    pub fn max_streams(mut self, max_streams: usize) -> BackendBuilder {
        self.max_streams = max_streams.max(1);
        self
    }

    /// Positions per KV page in the paged arena. Small pages waste less
    /// memory on short requests; large pages mean fewer table hops.
    /// Default 16.
    pub fn kv_page_tokens(mut self, kv_page_tokens: usize) -> BackendBuilder {
        self.kv_page_tokens = kv_page_tokens.max(1);
        self
    }

    /// Self-speculative greedy decode in the continuous batcher
    /// (`forward` backend generation): draft tokens from the per-stream
    /// prompt-lookup index, verify them in the same fused `step_batch`
    /// pass, roll rejected pages back. Output is bit-identical to plain
    /// greedy decode — this only changes how many steps it takes.
    /// Default off.
    pub fn speculative(mut self, speculative: bool) -> BackendBuilder {
        self.speculative = speculative;
        self
    }

    /// Draft-length cap per stream when [`BackendBuilder::speculative`]
    /// is on (the adaptive controller moves below this). Default 4.
    pub fn draft_len(mut self, draft_len: usize) -> BackendBuilder {
        self.draft_len = draft_len.max(1);
        self
    }

    pub fn get_max_streams(&self) -> usize {
        self.max_streams
    }

    pub fn get_kv_page_tokens(&self) -> usize {
        self.kv_page_tokens
    }

    pub fn get_speculative(&self) -> bool {
        self.speculative
    }

    pub fn get_draft_len(&self) -> usize {
        self.draft_len
    }

    /// Bound on the continuous batcher's waiting queue: admission beyond
    /// this replies [`crate::server::ServerError::Overloaded`]
    /// (load-shedding) instead of queueing without limit. Default 256.
    pub fn max_waiting(mut self, max_waiting: usize) -> BackendBuilder {
        self.max_waiting = max_waiting.max(1);
        self
    }

    /// Deterministic fault-injection script for the serving layer
    /// ([`crate::server::faults::FaultPlan`]) — scripted step panics, NaN
    /// logits, drafter panics, and per-step stalls at exact scheduler
    /// rounds. Default empty (no faults, no overhead beyond one branch
    /// per seam).
    pub fn faults(mut self, faults: crate::server::faults::FaultPlan) -> BackendBuilder {
        self.faults = faults;
        self
    }

    pub fn get_max_waiting(&self) -> usize {
        self.max_waiting
    }

    pub fn get_faults(&self) -> &crate::server::faults::FaultPlan {
        &self.faults
    }

    /// The continuous-batching scheduler config these knobs describe —
    /// drivers hand this straight to
    /// [`crate::server::EvalServer::spawn_batched`].
    pub fn batch_config(&self) -> crate::server::BatchConfig {
        crate::server::BatchConfig {
            max_streams: self.max_streams,
            kv_page_tokens: self.kv_page_tokens,
            speculative: self.speculative,
            draft_len: self.draft_len,
            max_waiting: self.max_waiting,
            faults: self.faults.clone(),
            ..crate::server::BatchConfig::default()
        }
    }

    /// Multiply-accumulate mode for the packed backends (`fused`,
    /// `forward`): `f32` (default, exact), `int8` (integer MAC, fails on
    /// non-affine methods), or `auto` (int8 per eligible layer, f32
    /// fallback otherwise). The `runner` backend decodes to f32 buffers
    /// and ignores this.
    pub fn mac(mut self, mac: crate::kernels::MacMode) -> BackendBuilder {
        self.mac = mac;
        self
    }

    fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// PJRT runner over `spec`'s compiled HLO; quantized variants (packed
    /// or plain) swap in later via [`ModelRunner::update_weights`].
    pub fn runner(
        &self,
        manifest: &Manifest,
        spec: &ModelSpec,
        weights: &TensorMap,
    ) -> Result<Backend> {
        let mut r = ModelRunner::new(manifest, spec, weights)?;
        r.set_decode_threads(self.resolved_threads());
        Ok(Backend::Runner(r))
    }

    /// Fused per-layer serving handles from an `export_packed` artifact.
    pub fn fused(&self, map: &TensorMap) -> Result<Backend> {
        Ok(Backend::Fused(FusedModel::from_packed_map_with(map, self.mac)?))
    }

    /// Fused CPU transformer forward from an `export_packed` artifact.
    pub fn forward(
        &self,
        spec: crate::forward::ForwardSpec,
        map: &TensorMap,
    ) -> Result<Backend> {
        let m = crate::forward::ForwardModel::from_packed_map_with(spec, map, self.mac)?
            .with_threads(self.resolved_threads());
        Ok(Backend::Forward(m))
    }

    /// The f32-reference twin of [`BackendBuilder::forward`]: same layer
    /// graph over a dense weight map.
    pub fn forward_dense(
        &self,
        spec: crate::forward::ForwardSpec,
        map: &TensorMap,
    ) -> Result<Backend> {
        let m = crate::forward::ForwardModel::from_dense(spec, map)?
            .with_threads(self.resolved_threads());
        Ok(Backend::Forward(m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/integration.rs;
    // here we only check graceful failure paths.

    #[test]
    fn missing_hlo_file_errors() {
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT in this environment: skip
        };
        assert!(rt.load_hlo("/nonexistent/file.hlo.txt").is_err());
    }

    #[test]
    fn upload_shape_mismatch_errors() {
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(_) => return,
        };
        assert!(rt.upload_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(rt.upload_f32(&[1.0, 2.0], &[2]).is_ok());
    }

    fn packed_fixture() -> (crate::pipeline::QuantizedModel, TensorMap) {
        use crate::io::manifest::{ModelSpec, ParamSpec};
        use crate::io::msbt::Tensor;
        use crate::pipeline::{quantize, Method, QuantizeOptions};
        use crate::quant::QuantConfig;
        let spec = ModelSpec {
            name: "f".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![
                ParamSpec { name: "tok_emb".into(), shape: vec![10, 32], quant: false },
                ParamSpec { name: "layer0.wq".into(), shape: vec![32, 64], quant: true },
                ParamSpec { name: "layer0.wv".into(), shape: vec![48, 128], quant: true },
            ],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        };
        let mut rng = crate::stats::Rng::new(71);
        let mut weights = TensorMap::new();
        let dims = [("tok_emb", 10, 32), ("layer0.wq", 32, 64), ("layer0.wv", 48, 128)];
        for (name, r, c) in dims {
            let mut m = crate::tensor::Matrix::randn(r, c, &mut rng);
            m.data[7] = 0.0; // exception-list coverage
            weights.insert(name.into(), Tensor::f32(vec![r, c], m.data));
        }
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let opts = QuantizeOptions::new().with_threads(2).with_packed();
        let qm = quantize(&spec, weights, None, Method::Wgm, &cfg, &opts).unwrap();
        let map = qm.export_packed().unwrap();
        (qm, map)
    }

    /// The fused serving handle never materializes f32 weights yet its
    /// matvec agrees with the decode-then-matvec reference, and its byte
    /// accounting reflects the packed payload, not the f32 set.
    #[test]
    fn fused_model_matches_decoded_reference() {
        let (qm, map) = packed_fixture();
        let fm = FusedModel::from_packed_map(&map).unwrap();
        assert_eq!(fm.method(), "msb-wgm");
        assert_eq!(fm.linears().len(), 2);
        assert!(fm.passthrough().contains_key("tok_emb"));
        assert!(fm.payload_bytes() * 4 < fm.f32_bytes(), "fused handle must stay packed");

        let decoded = crate::pipeline::decode_packed_model(&map, 1).unwrap();
        let pool = crate::pool::ThreadPool::new(3, 12);
        for (name, l) in fm.linears() {
            let w = decoded.get(name).unwrap().to_matrix().unwrap();
            assert_eq!(w.data, qm.weights.get(name).unwrap().as_f32().unwrap());
            let mut x = vec![0.0f32; l.cols()];
            crate::stats::Rng::new(72).fill_normal(&mut x, 1.0);
            let y = fm.gemv(name, &x).unwrap();
            crate::kernels::assert_matvec_close(&w, &x, &y, 1e-5);
            // batched + pooled path is bit-identical to per-request gemv
            let xs: Vec<f32> = x.iter().chain(x.iter()).copied().collect();
            let ys = fm.gemm_pooled(name, &xs, 2, &pool).unwrap();
            assert_eq!(&ys[..l.rows()], &y[..]);
            assert_eq!(&ys[l.rows()..], &y[..]);
        }
        assert!(fm.gemv("nope", &[]).is_err());
    }

    /// One builder constructs every backend; the token-scoring view is
    /// present exactly where a full forward pass exists.
    #[test]
    fn backend_builder_unifies_serving_constructions() {
        use crate::forward::{synth, ForwardSpec};
        use crate::pipeline::{quantize, Method, QuantizeOptions};
        use crate::quant::QuantConfig;

        let fs = ForwardSpec::new(40, 32, 1, 4, 48, 8, 2).unwrap();
        let spec = synth::model_spec(&fs, "b");
        let weights = synth::synth_weights(&fs, 5);
        let cfg = QuantConfig::block_wise(4, 16).unwrap();
        let opts = QuantizeOptions::new().with_packed();
        let qm = quantize(&spec, weights, None, Method::Wgm, &cfg, &opts).unwrap();
        let map = qm.export_packed().unwrap();

        let b = BackendBuilder::new().threads(2);
        let fused = b.fused(&map).unwrap();
        assert_eq!(fused.name(), "fused");
        assert!(fused.logits_fn().is_none(), "fused serves matvecs, not tokens");
        assert!(fused.into_forward().is_err(), "wrong converter must refuse");

        let fwd = b.forward(fs.clone(), &map).unwrap();
        assert_eq!(fwd.name(), "forward");
        let toks = synth::synth_tokens(&fs, fs.seq, 1);
        let y = fwd.logits_fn().unwrap().logits(&toks).unwrap();
        assert_eq!(y.len(), fs.batch * fs.seq * fs.vocab);

        // the dense twin rides the same builder and scores the same shape
        let decoded = crate::pipeline::decode_packed_model(&map, 1).unwrap();
        let twin = b.forward_dense(fs.clone(), &decoded).unwrap();
        let yt = twin.logits_fn().unwrap().logits(&toks).unwrap();
        assert_eq!(yt.len(), y.len());
        let model = fwd.into_forward().unwrap();
        assert!(model.payload_bytes() * 2 < model.f32_bytes());
    }

    #[test]
    fn builder_speculative_knobs_flow_into_batch_config() {
        let plan = crate::server::faults::FaultPlan::new().panic_at(3, 1);
        let b = BackendBuilder::new()
            .speculative(true)
            .draft_len(0)
            .max_streams(3)
            .kv_page_tokens(8)
            .max_waiting(0)
            .faults(plan.clone());
        assert!(b.get_speculative());
        assert_eq!(b.get_draft_len(), 1, "draft_len clamps to >= 1");
        assert_eq!(b.get_max_waiting(), 1, "max_waiting clamps to >= 1");
        assert_eq!(b.get_faults(), &plan);
        let cfg = b.batch_config();
        assert!(cfg.speculative);
        assert_eq!(cfg.draft_len, 1);
        assert_eq!(cfg.max_streams, 3);
        assert_eq!(cfg.kv_page_tokens, 8);
        assert_eq!(cfg.max_waiting, 1);
        assert_eq!(cfg.faults, plan);
        let d = BackendBuilder::new().batch_config();
        assert!(!d.speculative, "speculative decode is opt-in");
        assert_eq!(d.draft_len, 4);
        assert_eq!(d.max_waiting, 256);
        assert!(d.faults.is_empty(), "fault injection is opt-in");
    }

    /// MAC-mode plumbing: `Auto` on a non-affine payload (msb-wgm) falls
    /// back to the f32 path bit-exactly; an explicit `Int8` request on it
    /// fails construction; `Int8` on an affine payload (rtn) engages the
    /// integer path on every layer.
    #[test]
    fn fused_model_mac_modes() {
        use crate::kernels::MacMode;
        let (_, map) = packed_fixture(); // msb-wgm: no affine decode
        assert!(FusedModel::from_packed_map_with(&map, MacMode::Int8).is_err());
        let auto = FusedModel::from_packed_map_with(&map, MacMode::Auto).unwrap();
        assert_eq!(auto.mac(), MacMode::Auto);
        let f32m = FusedModel::from_packed_map(&map).unwrap();
        for (name, l) in auto.linears() {
            assert!(!l.int8_active(), "{name}: wgm must fall back");
            let mut x = vec![0.0f32; l.cols()];
            crate::stats::Rng::new(73).fill_normal(&mut x, 1.0);
            assert_eq!(
                auto.gemv(name, &x).unwrap(),
                f32m.gemv(name, &x).unwrap(),
                "{name}: Auto fallback != f32"
            );
        }

        // rtn payload: every layer affine, Int8 engages
        use crate::io::manifest::{ModelSpec, ParamSpec};
        use crate::io::msbt::Tensor;
        use crate::pipeline::{quantize, Method, QuantizeOptions};
        use crate::quant::QuantConfig;
        let spec = ModelSpec {
            name: "r".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![ParamSpec { name: "layer0.wq".into(), shape: vec![16, 64], quant: true }],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        };
        let mut weights = crate::io::msbt::TensorMap::new();
        let m = crate::tensor::Matrix::randn(16, 64, &mut crate::stats::Rng::new(74));
        weights.insert("layer0.wq".into(), Tensor::f32(vec![16, 64], m.data));
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let opts = QuantizeOptions::new().with_threads(1).with_packed();
        let qm = quantize(&spec, weights, None, Method::Rtn, &cfg, &opts).unwrap();
        let rmap = qm.export_packed().unwrap();
        let int8 = FusedModel::from_packed_map_with(&rmap, MacMode::Int8).unwrap();
        for (name, l) in int8.linears() {
            assert!(l.int8_active(), "{name}: rtn must take the integer MAC");
        }
    }
}
