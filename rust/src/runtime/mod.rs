//! PJRT runtime: loads the AOT-lowered HLO text produced by
//! `python/compile/aot.py`, compiles it once per model variant on the CPU
//! PJRT client, and executes it from the rust request path.
//!
//! Interchange is HLO *text* — jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! Weights are uploaded once as persistent [`xla::PjRtBuffer`]s and reused
//! across every call (`execute_b`); only the token batch is re-uploaded per
//! request. That keeps the request path free of O(model) host↔device
//! traffic — see EXPERIMENTS.md §Perf for the before/after.

use std::path::Path;

use anyhow::{Context, Result};

use crate::io::manifest::{Manifest, ModelSpec};
use crate::io::msbt::TensorMap;

/// Thin owner of the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file into a reusable executable.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| {
            format!("PJRT compile of {}", path.display())
        })?;
        Ok(Executable { exe })
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i8(&self, data: &[i8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// A compiled model executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute over persistent device buffers; returns the first element of
    /// the output 1-tuple as f32s (the lowering wraps results in a tuple —
    /// `return_tuple=True`).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let out = self.exe.execute_b(args).context("execute_b")?;
        let lit = out[0][0].to_literal_sync()?;
        let inner = lit.to_tuple1()?;
        Ok(inner.to_vec::<f32>()?)
    }
}

/// The L3-facing model handle: one compiled executable + the weight
/// buffers in ABI order. Feeding different (e.g. quantized-dequantized)
/// weights to the *same* executable is exactly the paper's
/// simulated-quantization protocol.
pub struct ModelRunner {
    rt: Runtime,
    exe: Executable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// ABI order of weight names (for targeted updates).
    names: Vec<String>,
    shapes: Vec<Vec<usize>>,
}

impl ModelRunner {
    /// Compile `spec`'s forward HLO and upload `weights` (ABI order from the
    /// manifest).
    pub fn new(manifest: &Manifest, spec: &ModelSpec, weights: &TensorMap) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo(manifest.path(&spec.fwd_hlo))?;
        let mut weight_bufs = Vec::with_capacity(spec.params.len());
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        for p in &spec.params {
            let t = weights
                .get(&p.name)
                .with_context(|| format!("weights file missing '{}'", p.name))?;
            anyhow::ensure!(t.dims == p.shape, "{}: shape {:?} != manifest {:?}",
                p.name, t.dims, p.shape);
            weight_bufs.push(rt.upload_f32(t.as_f32()?, &p.shape)?);
            names.push(p.name.clone());
            shapes.push(p.shape.clone());
        }
        Ok(ModelRunner {
            rt,
            exe,
            weight_bufs,
            batch: manifest.eval_batch,
            seq: spec.seq,
            vocab: manifest.vocab,
            names,
            shapes,
        })
    }

    /// Replace a subset of weights (by name) — used to swap in each
    /// quantized variant without recompiling or re-uploading the rest.
    /// Packed payload maps ([`crate::pipeline::QuantizedModel::export_packed`])
    /// are detected and decoded transparently on one worker per available
    /// core; use [`ModelRunner::update_weights_packed`] to pick the decode
    /// pool size explicitly.
    pub fn update_weights(&mut self, updates: &TensorMap) -> Result<usize> {
        if crate::pipeline::is_packed_map(updates) {
            let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            return self.update_weights_packed(updates, threads);
        }
        let mut n = 0;
        for (i, name) in self.names.iter().enumerate() {
            if let Some(t) = updates.get(name) {
                anyhow::ensure!(t.dims == self.shapes[i], "{name}: bad update shape");
                self.weight_bufs[i] = self.rt.upload_f32(t.as_f32()?, &self.shapes[i])?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Decode a packed payload map (u4/i8 codes + scale tables, `.msbt`
    /// v2) on `threads` workers and swap the reconstructed weights in —
    /// the serving path for booting straight from a packed artifact.
    pub fn update_weights_packed(&mut self, packed: &TensorMap, threads: usize) -> Result<usize> {
        // the decoded map is plain f32 (no payload keys): no recursion
        let decoded = crate::pipeline::decode_packed_model(packed, threads)?;
        self.update_weights(&decoded)
    }

    /// Forward pass: `tokens` is a row-major [batch, seq] i32 buffer;
    /// returns logits [batch, seq, vocab].
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.batch * self.seq,
            "tokens len {} != {}x{}",
            tokens.len(),
            self.batch,
            self.seq
        );
        let tok_buf = self.rt.upload_i32(tokens, &[self.batch, self.seq])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(&tok_buf);
        args.extend(self.weight_bufs.iter());
        self.exe.run_buffers(&args)
    }
}

/// A model held *entirely in the packed domain*: one
/// [`PackedLinear`](crate::kernels::PackedLinear) handle per quantized
/// layer plus the pass-through tensors — never the decoded f32 weight
/// set. Where [`ModelRunner::update_weights_packed`] pays an O(model)
/// unpack-to-f32 before PJRT upload, a `FusedModel` keeps the 4–6×
/// storage win at serve time and answers matvec/batched-matmul requests
/// straight off the codes (`kernels::PackedLinear::gemv`/`gemm`).
/// `server::GemvServer` wraps one of these behind a dynamic-batching
/// request loop; `serve_eval --fused` is the end-to-end driver.
pub struct FusedModel {
    method: String,
    linears: std::collections::BTreeMap<String, crate::kernels::PackedLinear>,
    passthrough: TensorMap,
}

impl FusedModel {
    /// Build fused handles from an `export_packed` artifact (typically a
    /// `.msbt` file written by `msb pack`). No f32 weight buffer is
    /// materialized at any point.
    pub fn from_packed_map(map: &TensorMap) -> Result<FusedModel> {
        let (method, packed, passthrough) = crate::pipeline::packed_tensors(map)?;
        let mut linears = std::collections::BTreeMap::new();
        for (name, pt) in packed {
            let pl = crate::kernels::PackedLinear::new(pt)
                .with_context(|| format!("fused handle for layer '{name}'"))?;
            linears.insert(name, pl);
        }
        Ok(FusedModel { method, linears, passthrough })
    }

    /// The quantization method the payloads were emitted by.
    pub fn method(&self) -> &str {
        &self.method
    }

    /// Layer name → fused handle map (iteration order = BTreeMap order).
    pub fn linears(&self) -> &std::collections::BTreeMap<String, crate::kernels::PackedLinear> {
        &self.linears
    }

    pub fn linear(&self, name: &str) -> Option<&crate::kernels::PackedLinear> {
        self.linears.get(name)
    }

    /// Non-quantized tensors carried alongside (norms, embeddings).
    pub fn passthrough(&self) -> &TensorMap {
        &self.passthrough
    }

    /// Total serialized payload bytes actually held by the fused handles.
    pub fn payload_bytes(&self) -> usize {
        self.linears.values().map(|l| l.payload_bytes()).sum()
    }

    /// What the same layers would cost as decoded f32 buffers.
    pub fn f32_bytes(&self) -> usize {
        self.linears.values().map(|l| l.rows() * l.cols() * 4).sum()
    }

    /// Fused `y = W·x` for one layer (serial reference order).
    pub fn gemv(&self, layer: &str, x: &[f32]) -> Result<Vec<f32>> {
        let l = self.linears.get(layer).with_context(|| format!("no packed layer '{layer}'"))?;
        anyhow::ensure!(x.len() == l.cols(), "{layer}: x len {} != cols {}", x.len(), l.cols());
        Ok(l.gemv(x))
    }

    /// Fused batched product for one layer; bit-identical to per-request
    /// [`FusedModel::gemv`] for every batch size and worker count.
    pub fn gemm_pooled(
        &self,
        layer: &str,
        xs: &[f32],
        batch: usize,
        pool: &crate::pool::ThreadPool,
    ) -> Result<Vec<f32>> {
        let l = self.linears.get(layer).with_context(|| format!("no packed layer '{layer}'"))?;
        anyhow::ensure!(
            xs.len() == batch * l.cols(),
            "{layer}: activations {} != {batch}x{}",
            xs.len(),
            l.cols()
        );
        Ok(l.gemm_pooled(xs, batch, pool))
    }
}

/// Anything that maps a [batch, seq] token tensor to [batch, seq, vocab]
/// logits. `ModelRunner` is the real one; tests use closures/mocks.
pub trait LogitsFn {
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;
    fn vocab(&self) -> usize;
    fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>>;
}

impl LogitsFn for ModelRunner {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        ModelRunner::logits(self, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/integration.rs;
    // here we only check graceful failure paths.

    #[test]
    fn missing_hlo_file_errors() {
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT in this environment: skip
        };
        assert!(rt.load_hlo("/nonexistent/file.hlo.txt").is_err());
    }

    #[test]
    fn upload_shape_mismatch_errors() {
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(_) => return,
        };
        assert!(rt.upload_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(rt.upload_f32(&[1.0, 2.0], &[2]).is_ok());
    }

    fn packed_fixture() -> (crate::pipeline::QuantizedModel, TensorMap) {
        use crate::io::manifest::{ModelSpec, ParamSpec};
        use crate::io::msbt::Tensor;
        use crate::pipeline::{quantize_model, Method};
        use crate::quant::QuantConfig;
        let spec = ModelSpec {
            name: "f".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![
                ParamSpec { name: "tok_emb".into(), shape: vec![10, 32], quant: false },
                ParamSpec { name: "layer0.wq".into(), shape: vec![32, 64], quant: true },
                ParamSpec { name: "layer0.wv".into(), shape: vec![48, 128], quant: true },
            ],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        };
        let mut rng = crate::stats::Rng::new(71);
        let mut weights = TensorMap::new();
        let dims = [("tok_emb", 10, 32), ("layer0.wq", 32, 64), ("layer0.wv", 48, 128)];
        for (name, r, c) in dims {
            let mut m = crate::tensor::Matrix::randn(r, c, &mut rng);
            m.data[7] = 0.0; // exception-list coverage
            weights.insert(name.into(), Tensor::f32(vec![r, c], m.data));
        }
        let cfg = QuantConfig::block_wise(4, 64).with_packed();
        let qm = quantize_model(&spec, weights, None, Method::Wgm, &cfg, 2).unwrap();
        let map = qm.export_packed().unwrap();
        (qm, map)
    }

    /// The fused serving handle never materializes f32 weights yet its
    /// matvec agrees with the decode-then-matvec reference, and its byte
    /// accounting reflects the packed payload, not the f32 set.
    #[test]
    fn fused_model_matches_decoded_reference() {
        let (qm, map) = packed_fixture();
        let fm = FusedModel::from_packed_map(&map).unwrap();
        assert_eq!(fm.method(), "msb-wgm");
        assert_eq!(fm.linears().len(), 2);
        assert!(fm.passthrough().contains_key("tok_emb"));
        assert!(fm.payload_bytes() * 4 < fm.f32_bytes(), "fused handle must stay packed");

        let decoded = crate::pipeline::decode_packed_model(&map, 1).unwrap();
        let pool = crate::pool::ThreadPool::new(3, 12);
        for (name, l) in fm.linears() {
            let w = decoded.get(name).unwrap().to_matrix().unwrap();
            assert_eq!(w.data, qm.weights.get(name).unwrap().as_f32().unwrap());
            let mut x = vec![0.0f32; l.cols()];
            crate::stats::Rng::new(72).fill_normal(&mut x, 1.0);
            let y = fm.gemv(name, &x).unwrap();
            crate::kernels::assert_matvec_close(&w, &x, &y, 1e-5);
            // batched + pooled path is bit-identical to per-request gemv
            let xs: Vec<f32> = x.iter().chain(x.iter()).copied().collect();
            let ys = fm.gemm_pooled(name, &xs, 2, &pool).unwrap();
            assert_eq!(&ys[..l.rows()], &y[..]);
            assert_eq!(&ys[l.rows()..], &y[..]);
        }
        assert!(fm.gemv("nope", &[]).is_err());
    }
}
