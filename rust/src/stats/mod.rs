//! Statistics substrate: a seeded RNG (no `rand` crate offline), standard
//! distributions, and summary statistics used by benches / property tests.

/// xorshift64* — fast, seedable, good-enough equidistribution for synthetic
/// workloads and property tests. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller variate
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zeros fixed point
        let state = if seed == 0 { 0x9E3779B97F4A7C15 } else { seed };
        Rng { state, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out {
            *v = (self.normal() as f32) * sigma;
        }
    }

    /// Heavy-tailed "LLM-weight-like" samples: Gaussian bulk + sparse
    /// outliers, mimicking the kurtotic layers quantizers struggle with.
    pub fn fill_weightlike(&mut self, out: &mut [f32], sigma: f32, outlier_rate: f64) {
        for v in out.iter_mut() {
            let base = self.normal() as f32 * sigma;
            *v = if self.uniform() < outlier_rate {
                base * 8.0
            } else {
                base
            };
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Summary statistics of a slice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub var: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f32]) -> Summary {
    if xs.is_empty() {
        return Summary { n: 0, mean: 0.0, var: 0.0, min: 0.0, max: 0.0 };
    }
    let n = xs.len() as f64;
    let mut s1 = 0.0f64;
    let mut s2 = 0.0f64;
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    for &x in xs {
        let x = x as f64;
        s1 += x;
        s2 += x * x;
        min = min.min(x);
        max = max.max(x);
    }
    let mean = s1 / n;
    Summary { n: xs.len(), mean, var: (s2 / n - mean * mean).max(0.0), min, max }
}

/// Mean squared error between two equal-length slices (f64 accumulation).
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

/// Sum of squared errors (the paper's Frobenius MSE in Tables 2/4/6 is the
/// *total* squared reconstruction error of the matrix).
pub fn sse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_zero_seed_ok() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..200_000).map(|_| r.normal() as f32).collect();
        let s = summarize(&xs);
        assert!(s.mean.abs() < 0.02, "mean {}", s.mean);
        assert!((s.var - 1.0).abs() < 0.03, "var {}", s.var);
    }

    #[test]
    fn weightlike_has_outliers() {
        let mut r = Rng::new(4);
        let mut xs = vec![0.0f32; 100_000];
        r.fill_weightlike(&mut xs, 0.02, 0.001);
        let s = summarize(&xs);
        // kurtosis proxy: max far beyond 4 sigma of the bulk
        assert!(s.max > 0.02 * 6.0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn summary_and_mse() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.0, 5.0];
        assert!((mse(&a, &b) - 4.0 / 3.0).abs() < 1e-9);
        assert_eq!(sse(&a, &b), 4.0);
        let s = summarize(&a);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }
}
