//! The model-global work scheduler: one queue of `(layer, tile)` and
//! whole-layer jobs spanning *every* eligible layer at once.
//!
//! The previous pipeline streamed layers sequentially through the shared
//! [`ThreadPool`] — each layer ended in an ordered-reassembly barrier, so
//! workers idled at every layer's tail tile, and per-layer jobs (GPTQ,
//! per-tensor configs) could not mix with tiled layers at all. Here the
//! whole model is enqueued up front ([`ThreadPool::submit_many`] batches
//! the tiles), heterogeneous jobs share the pool — a whole-matrix GPTQ
//! solve runs *next to* another layer's MSB tiles — and the only barrier
//! is end-of-model. Per-layer completion is tracked by the collector,
//! which reassembles each layer's tiles in input order the moment its last
//! tile lands (overlapping assembly with ongoing worker compute).
//!
//! Determinism: every tile is computed by the same
//! [`engine::run_tile`](crate::quant::engine::run_tile) kernel on the same
//! bytes as the serial driver, and reassembly is input-ordered, so results
//! are bit-identical to `threads = 1` for any worker count and any
//! completion order (asserted across the method × granularity grid).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::io::msbt::TensorMap;
use crate::pool::ThreadPool;
use crate::quant::dq::{double_quantize, DqConfig};
use crate::quant::engine::{self, BlockQuantizer, TileLayout, TileMeta};
use crate::quant::packing::PackedTensor;
use crate::quant::registry::{self, Method};
use crate::quant::{Granularity, QuantConfig, QuantizedTensor};
use crate::tensor::Matrix;

use super::LayerStat;

/// One layer's work order: the (already extracted) weight matrix and the
/// method quantizing it. Heterogeneous method sets are allowed — the
/// scheduler mixes tiled and whole-layer jobs freely.
pub struct LayerJob {
    pub name: String,
    pub w: Matrix,
    pub method: Method,
}

/// What the pipeline collects per layer: name, metrics, dequantized data,
/// optional packed payload.
pub(crate) type LayerResult = (String, LayerStat, Vec<f32>, Option<PackedTensor>);

/// Whether `method` under `cfg` runs as a single whole-matrix job instead
/// of block tiles: GPTQ couples the whole matrix (column-sequential error
/// propagation), per-tensor configs and whole-tensor XNOR are one block
/// instance per layer, so tiling cannot help them.
fn runs_whole(method: Method, cfg: &QuantConfig) -> bool {
    method.needs_calibration()
        || matches!(cfg.granularity, Granularity::PerTensor)
        || method == Method::Xnor
        || registry::block_quantizer(method).is_none()
}

/// Pull the layer Hessian out of the calibration tensors (GPTQ only).
fn layer_hessian<'a>(
    calib: Option<&'a TensorMap>,
    layer: &str,
    in_dim: usize,
) -> Result<(&'a [f32], usize)> {
    let calib = calib.context("gptq requires calibration tensors")?;
    let h = calib
        .get(layer)
        .with_context(|| format!("calib missing Hessian for {layer}"))?;
    anyhow::ensure!(h.dims == vec![in_dim, in_dim], "{layer}: bad Hessian dims");
    Ok((h.as_f32()?, in_dim))
}

/// The WGM-DQ coarsened-scale rebuild (which invalidates the base packed
/// payload) — the one per-layer finishing step shared by every path.
fn finish_qt(method: Method, qt: QuantizedTensor, cfg: &QuantConfig) -> QuantizedTensor {
    if method == Method::WgmDq {
        double_quantize(&qt, cfg, &DqConfig::default())
    } else {
        qt
    }
}

/// Build the per-layer record from a finished tensor.
fn layer_result(name: String, original: &[f32], qt: QuantizedTensor, seconds: f64) -> LayerResult {
    let stat = LayerStat {
        name: name.clone(),
        rows: qt.dequant.rows,
        cols: qt.dequant.cols,
        // same arithmetic as `QuantizedTensor::mse` (dequant vs original)
        sse: crate::stats::sse(&qt.dequant.data, original),
        effective_bits: qt.effective_bits,
        seconds,
    };
    (name, stat, qt.dequant.data, qt.packed)
}

/// Quantize one layer as a single job (serial path and whole-layer pool
/// jobs). `hessian` is pre-extracted so the job can own its inputs.
fn solve_whole(
    method: Method,
    name: String,
    w: &Matrix,
    cfg: &QuantConfig,
    hessian: Option<(&[f32], usize)>,
) -> Result<LayerResult> {
    let t0 = Instant::now();
    let q = registry::build_quantizer(method, hessian)?;
    let qt = finish_qt(method, q.quantize(w, cfg), cfg);
    Ok(layer_result(name, &w.data, qt, t0.elapsed().as_secs_f64()))
}

/// A whole-matrix job awaiting submission.
struct WholeJob {
    layer: usize,
    name: String,
    w: Matrix,
    method: Method,
    hessian: Option<(Vec<f32>, usize)>,
}

/// A tiled layer: submission inputs + the collector's reassembly state.
struct TiledState {
    name: String,
    method: Method,
    q: Arc<dyn BlockQuantizer>,
    data: Arc<Vec<f32>>,
    layout: TileLayout,
    tiles: Vec<Option<(Vec<f32>, TileMeta)>>,
    remaining: usize,
    /// Summed worker-side tile compute time (the layer's CPU cost; layers
    /// overlap under the global queue, so per-layer wall time is not
    /// well-defined).
    seconds: f64,
}

/// Messages landing on the collector channel.
enum Done {
    Whole { layer: usize, result: std::thread::Result<Result<LayerResult>> },
    Tile {
        layer: usize,
        tile: usize,
        result: std::thread::Result<(Vec<f32>, TileMeta)>,
        seconds: f64,
    },
}

/// Execute `jobs` under `cfg` with `threads` workers. Returns per-layer
/// results in input order plus the pool's `(submitted, completed)` stats
/// (`None` on the serial path).
pub(crate) fn run(
    jobs: Vec<LayerJob>,
    calib: Option<&TensorMap>,
    cfg: &QuantConfig,
    threads: usize,
) -> Result<(Vec<LayerResult>, Option<(usize, usize)>)> {
    let threads = threads.max(1);
    if threads == 1 || jobs.is_empty() {
        // serial reference path: every scheduler must match it bit-for-bit
        let mut out = Vec::with_capacity(jobs.len());
        for LayerJob { name, w, method } in jobs {
            let hessian;
            let h_ref = if method.needs_calibration() {
                hessian = layer_hessian(calib, &name, w.cols)?;
                Some(hessian)
            } else {
                None
            };
            out.push(solve_whole(method, name, &w, cfg, h_ref)?);
        }
        return Ok((out, None));
    }

    // classify + extract up front so job closures own everything
    let n_layers = jobs.len();
    let mut wholes: Vec<WholeJob> = Vec::new();
    let mut tiled: Vec<Option<TiledState>> = Vec::with_capacity(n_layers);
    let mut total_jobs = 0usize;
    for (layer, LayerJob { name, w, method }) in jobs.into_iter().enumerate() {
        if runs_whole(method, cfg) {
            // Calibrated jobs own a copy of their Hessian ('static pool
            // jobs cannot borrow `calib`). Copies are extracted up front
            // and each freed as its job retires, so the transient peak is
            // one extra copy of the calibrated layers' Hessians on top of
            // the resident calib map.
            let hessian = if method.needs_calibration() {
                let (h, d) = layer_hessian(calib, &name, w.cols)?;
                Some((h.to_vec(), d))
            } else {
                None
            };
            total_jobs += 1;
            wholes.push(WholeJob { layer, name, w, method, hessian });
            tiled.push(None);
        } else {
            let q = registry::block_quantizer(method).expect("tiled method");
            let layout = engine::tile_layout(&*q, w.rows, w.cols, cfg, threads);
            total_jobs += layout.n_tiles;
            tiled.push(Some(TiledState {
                name,
                method,
                q,
                data: Arc::new(w.data),
                tiles: (0..layout.n_tiles).map(|_| None).collect(),
                remaining: layout.n_tiles,
                layout,
                seconds: 0.0,
            }));
        }
    }

    // the scheduler enqueues the whole model without blocking: capacity is
    // sized to the job count (job closures are a few pointers each)
    let mut pool = ThreadPool::new(threads, total_jobs.max(threads * 4));
    let (tx, rx) = mpsc::channel::<Done>();
    let shared_cfg = Arc::new(cfg.clone());

    // whole-matrix jobs first (the longest poles start earliest), then
    // every tiled layer's tiles in one batched enqueue per layer
    for WholeJob { layer, name, w, method, hessian } in wholes {
        let tx = tx.clone();
        let cfg = Arc::clone(&shared_cfg);
        pool.submit(move || {
            let result = catch_unwind(AssertUnwindSafe(|| {
                let h_ref = hessian.as_ref().map(|(h, d)| (h.as_slice(), *d));
                solve_whole(method, name.clone(), &w, &cfg, h_ref)
            }));
            let _ = tx.send(Done::Whole { layer, result });
        });
    }
    for (layer, slot) in tiled.iter().enumerate() {
        let Some(st) = slot else { continue };
        let q = Arc::clone(&st.q);
        let data = Arc::clone(&st.data);
        let layout = st.layout;
        let cfg = Arc::clone(&shared_cfg);
        let tx = tx.clone();
        pool.submit_many((0..layout.n_tiles).map(move |ti| {
            let q = Arc::clone(&q);
            let data = Arc::clone(&data);
            let cfg = Arc::clone(&cfg);
            let tx = tx.clone();
            move || {
                let t0 = Instant::now();
                let result = catch_unwind(AssertUnwindSafe(|| {
                    engine::run_tile(&*q, &data, &cfg, &layout, ti)
                }));
                let seconds = t0.elapsed().as_secs_f64();
                let _ = tx.send(Done::Tile { layer, tile: ti, result, seconds });
            }
        }));
    }
    drop(tx);

    // collect: assemble each layer the moment its last tile lands
    let mut results: Vec<Option<LayerResult>> = (0..n_layers).map(|_| None).collect();
    let mut first_err: Option<anyhow::Error> = None;
    for _ in 0..total_jobs {
        let Ok(msg) = rx.recv() else {
            break; // workers gone (only reachable after a worker died)
        };
        match msg {
            Done::Whole { layer, result } => match result {
                Err(payload) => resume_unwind(payload),
                Ok(Ok(r)) => results[layer] = Some(r),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            },
            Done::Tile { layer, tile, result, seconds } => match result {
                Err(payload) => resume_unwind(payload),
                Ok(out) => {
                    let st = tiled[layer].as_mut().expect("tile for non-tiled layer");
                    st.tiles[tile] = Some(out);
                    st.seconds += seconds;
                    st.remaining -= 1;
                    if st.remaining == 0 {
                        let st = tiled[layer].take().expect("layer state");
                        results[layer] = Some(assemble_layer(st, cfg));
                    }
                }
            },
        }
    }

    pool.shutdown();
    let stats = pool.stats();
    if let Some(e) = first_err {
        return Err(e);
    }
    let results = results
        .into_iter()
        .map(|r| r.context("scheduler dropped a layer result"))
        .collect::<Result<Vec<_>>>()?;
    Ok((results, Some(stats)))
}

/// Ordered per-layer reassembly: identical epilogue to the engine's
/// drivers, then the shared per-layer finishing.
fn assemble_layer(st: TiledState, cfg: &QuantConfig) -> LayerResult {
    let TiledState { name, method, q, data, layout, tiles, seconds, .. } = st;
    let qt = engine::assemble_tiles(
        &*q,
        cfg,
        &layout.plan,
        tiles.into_iter().map(|t| t.expect("missing tile")),
    );
    let qt = finish_qt(method, qt, cfg);
    layer_result(name, &data, qt, seconds)
}
