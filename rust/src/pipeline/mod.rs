//! The quantization pipeline coordinator: walks a model manifest and
//! assembles a fully-quantized weight set plus per-layer metrics. This is
//! the L3 "offline PTQ" path (the paper's CPU-based quantization step); the
//! online path is `runtime`/`server`.
//!
//! Parallelism: the model-global [`scheduler`] enqueues *every* layer's
//! work at once on one shared [`ThreadPool`] — block-partitioned layers as
//! `(layer, tile)` jobs, whole-matrix layers (GPTQ's column-sequential
//! error propagation, per-tensor configs) as single jobs beside them — so
//! the only barrier is end-of-model and workers never idle at a layer's
//! tail tile. Method dispatch lives in [`crate::quant::registry`].

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::io::manifest::ModelSpec;
use crate::io::msbt::{Tensor, TensorData, TensorMap};
use crate::pool::ThreadPool;
use crate::quant::engine;
use crate::quant::packing::{CodeScheme, PackedCodes, PackedScales, PackedTensor};
use crate::quant::{registry, QuantConfig};

pub mod scheduler;

pub use crate::quant::registry::Method;
pub use scheduler::LayerJob;

/// `<layer>.layout` record version for packed payload maps.
const PACKED_LAYOUT_VERSION: i32 = 2;
/// Global key carrying the packed method name (as i8 name bytes).
const PACKED_METHOD_KEY: &str = "__packed__.method";
/// Per-layer payload key suffixes, in record order.
const PACKED_SUFFIXES: [&str; 4] = [".codes", ".scales", ".zeros", ".layout"];

/// Per-layer quantization record.
#[derive(Clone, Debug)]
pub struct LayerStat {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub sse: f64,
    pub effective_bits: f64,
    pub seconds: f64,
}

/// A fully-quantized model: dequantized weights keyed by ABI name (ready
/// for [`crate::runtime::ModelRunner::update_weights`]) plus metrics and,
/// when the config requested emission, the deployable packed payloads.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub method: Method,
    pub weights: TensorMap,
    pub layers: Vec<LayerStat>,
    pub wall_seconds: f64,
    /// `(submitted, completed)` jobs on the model-global pool — block
    /// tiles and whole-matrix layer jobs combined; `None` when the run
    /// took the serial reference path (threads=1, or nothing to quantize).
    pub pool_stats: Option<(usize, usize)>,
    /// Per-layer packed payloads (codes + scale tables); populated when
    /// [`QuantConfig::emit_packed`] was set and the method supports
    /// packing, empty otherwise.
    pub packed: BTreeMap<String, PackedTensor>,
}

impl QuantizedModel {
    pub fn total_sse(&self) -> f64 {
        self.layers.iter().map(|l| l.sse).sum()
    }

    pub fn mean_effective_bits(&self) -> f64 {
        let (num, den) = self.layers.iter().fold((0.0, 0usize), |(a, b), l| {
            (a + l.effective_bits * (l.rows * l.cols) as f64, b + l.rows * l.cols)
        });
        num / den.max(1) as f64
    }

    /// Measured bits/weight over the packed payloads (actual bytes).
    pub fn packed_effective_bits(&self) -> f64 {
        let (bytes, elems) = self
            .packed
            .values()
            .fold((0usize, 0usize), |(b, n), p| (b + p.payload_bytes(), n + p.n_elems()));
        bytes as f64 * 8.0 / elems.max(1) as f64
    }

    /// Serialize the packed payloads into a `.msbt`-v3-ready [`TensorMap`]:
    /// per layer `<name>.codes` (U1/U2/U4/I8 at the true code width) +
    /// `<name>.scales` (bf16/f32) +
    /// `<name>.layout` (+ `<name>.zeros` when exact-zero exceptions
    /// exist), one global `__packed__.method` record, and the pass-through
    /// (non-quantized) tensors copied as-is so a runner can boot from the
    /// artifact alone. The dequantized f32 weight set is *not* cloned.
    pub fn export_packed(&self) -> Result<TensorMap> {
        ensure!(
            !self.packed.is_empty(),
            "no packed payloads: quantize with a cfg.with_packed() config \
             and a packing-capable method"
        );
        let mut out = TensorMap::new();
        let mut method = None;
        for (name, pt) in &self.packed {
            for suffix in PACKED_SUFFIXES {
                let key = format!("{name}{suffix}");
                ensure!(!self.weights.contains_key(&key), "payload key collides with '{key}'");
            }
            if let Some(m) = &method {
                ensure!(m == &pt.method, "mixed packed methods: {m} vs {}", pt.method);
            } else {
                method = Some(pt.method.clone());
            }
            let dims = vec![pt.rows, pt.cols];
            let codes = match &pt.codes {
                PackedCodes::U1(p) => Tensor::u1(dims, p.clone()),
                PackedCodes::U2(p) => Tensor::u2(dims, p.clone()),
                PackedCodes::U4(p) => Tensor::u4(dims, p.clone()),
                PackedCodes::I8(v) => Tensor::i8(dims, v.clone()),
            };
            out.insert(format!("{name}.codes"), codes);
            let spb = pt.scales_per_block.max(1);
            let scales = match &pt.scales {
                PackedScales::Bf16(v) => Tensor::bf16(vec![v.len() / spb, spb], v.clone()),
                PackedScales::F32(v) => Tensor::f32(vec![v.len() / spb, spb], v.clone()),
            };
            out.insert(format!("{name}.scales"), scales);
            if !pt.zeros.is_empty() {
                let z: Vec<i32> = pt.zeros.iter().map(|&i| i as i32).collect();
                out.insert(format!("{name}.zeros"), Tensor::i32(vec![z.len()], z));
            }
            ensure!(pt.block <= i32::MAX as usize, "{name}: block exceeds i32");
            let layout = vec![
                PACKED_LAYOUT_VERSION,
                pt.code_bits as i32,
                pt.scheme.id(),
                pt.block as i32,
                pt.scales_per_block as i32,
                pt.per_tensor as i32,
                pt.bf16 as i32,
                pt.zeros.len() as i32,
            ];
            out.insert(format!("{name}.layout"), Tensor::i32(vec![layout.len()], layout));
        }
        let method = method.expect("non-empty packed map");
        out.insert(
            PACKED_METHOD_KEY.to_string(),
            Tensor::i8(vec![method.len()], method.bytes().map(|b| b as i8).collect()),
        );
        for (name, t) in &self.weights {
            if !self.packed.contains_key(name) {
                out.insert(name.clone(), t.clone());
            }
        }
        Ok(out)
    }
}

/// Whether a tensor map looks like an `export_packed` artifact.
pub fn is_packed_map(map: &TensorMap) -> bool {
    map.contains_key(PACKED_METHOD_KEY)
}

/// The method name and layer list of an `export_packed` artifact, plus
/// every key the payload records occupy (for pass-through filtering).
fn packed_map_index(map: &TensorMap) -> Result<(String, Vec<String>, Vec<String>)> {
    let method_t = map
        .get(PACKED_METHOD_KEY)
        .context("not a packed artifact: __packed__.method missing")?;
    let method_bytes: Vec<u8> = method_t.as_i8()?.iter().map(|&b| b as u8).collect();
    let method = String::from_utf8(method_bytes).context("packed method name not utf-8")?;
    let layers: Vec<String> = map
        .keys()
        .filter_map(|k| k.strip_suffix(".layout").map(String::from))
        .collect();
    ensure!(!layers.is_empty(), "packed artifact has no .layout records");
    let mut payload_keys: Vec<String> = vec![PACKED_METHOD_KEY.to_string()];
    for name in &layers {
        for suffix in PACKED_SUFFIXES {
            payload_keys.push(format!("{name}{suffix}"));
        }
    }
    Ok((method, layers, payload_keys))
}

/// Parse an `export_packed` artifact back into its parts: the emitting
/// method name, each layer's validated [`PackedTensor`], and the
/// pass-through (non-payload) tensors. This is the front half of the
/// fused serving boot path ([`crate::runtime::FusedModel`]), which must
/// hold every layer's payload at once anyway; the f32 decode path below
/// reconstructs lazily instead so its peak memory stays one layer deep.
pub fn packed_tensors(
    map: &TensorMap,
) -> Result<(String, BTreeMap<String, PackedTensor>, TensorMap)> {
    let (method, layers, payload_keys) = packed_map_index(map)?;
    let decoder = registry::block_decoder(&method)?;
    let mut packed = BTreeMap::new();
    for name in &layers {
        packed.insert(name.clone(), reconstruct_packed(map, name, &method, &*decoder)?);
    }
    let mut passthrough = TensorMap::new();
    for (k, t) in map {
        if !payload_keys.iter().any(|p| p == k) {
            passthrough.insert(k.clone(), t.clone());
        }
    }
    Ok((method, packed, passthrough))
}

/// Reconstruct the full f32 weight set from a packed payload map (the
/// output of [`QuantizedModel::export_packed`], typically read back from a
/// `.msbt` v2 file). Each layer's [`PackedTensor`] is reconstructed
/// lazily (peak payload residency = one layer) and decoded through the
/// emitting method's `decode_block` via the same `BlockPlan` geometry,
/// fanned out over a shared [`ThreadPool`] when `threads > 1`, threading
/// one [`engine::DecodeScratch`] through the layer loop so the code/scale
/// buffers allocate once at the high-water mark; pass-through tensors are
/// copied as-is. The result is bit-identical to the simulated-dequant
/// weights the payload was exported from.
pub fn decode_packed_model(map: &TensorMap, threads: usize) -> Result<TensorMap> {
    let (method, layers, payload_keys) = packed_map_index(map)?;
    let decoder = registry::block_decoder(&method)?;
    let mut pool = (threads > 1).then(|| ThreadPool::new(threads, threads * 4));
    let mut scratch = engine::DecodeScratch::default();
    let mut out = TensorMap::new();
    for name in &layers {
        let pt = reconstruct_packed(map, name, &method, &*decoder)?;
        let m =
            engine::decode_packed_with_scratch(decoder.clone(), &pt, pool.as_ref(), &mut scratch);
        out.insert(name.clone(), Tensor::f32(vec![pt.rows, pt.cols], m.data));
    }
    if let Some(p) = pool.as_mut() {
        p.shutdown();
    }
    for (k, t) in map {
        if !payload_keys.iter().any(|p| p == k) && !out.contains_key(k) {
            out.insert(k.clone(), t.clone());
        }
    }
    Ok(out)
}

/// Rebuild one layer's [`PackedTensor`] from its payload records,
/// validating the layout invariants so corrupt files error instead of
/// panicking in the decode hot loop.
fn reconstruct_packed(
    map: &TensorMap,
    name: &str,
    method: &str,
    decoder: &dyn crate::quant::engine::BlockQuantizer,
) -> Result<PackedTensor> {
    let layout_t = map.get(&format!("{name}.layout")).context("missing layout")?;
    let l = layout_t.as_i32()?;
    ensure!(l.len() >= 8, "{name}: truncated layout record");
    ensure!(l[0] == PACKED_LAYOUT_VERSION, "{name}: unsupported layout version {}", l[0]);
    let code_bits = l[1] as u32;
    let scheme = CodeScheme::from_id(l[2])
        .with_context(|| format!("{name}: unknown code scheme {}", l[2]))?;
    let block = l[3] as usize;
    let scales_per_block = l[4] as usize;
    let (per_tensor, bf16) = (l[5] != 0, l[6] != 0);
    ensure!(block > 0 && scales_per_block > 0, "{name}: degenerate layout");
    ensure!((1..=8).contains(&code_bits), "{name}: bad code bits {code_bits}");
    // The layout must be exactly what the method would emit at this code
    // width — otherwise decode_block would misread (or over-index) the
    // scale table. pack_spec only consults cfg.bits, so any granularity
    // works to reconstruct the expectation.
    let expect = decoder
        .pack_spec(&QuantConfig::per_tensor(code_bits)?)
        .with_context(|| format!("{name}: '{method}' cannot decode {code_bits}-bit codes"))?;
    ensure!(
        expect.scheme == scheme && expect.scales_per_block == scales_per_block,
        "{name}: layout ({scheme:?}, {scales_per_block} scales/block) does not match \
         method '{method}' ({:?}, {} scales/block)",
        expect.scheme,
        expect.scales_per_block
    );

    let codes_t = map.get(&format!("{name}.codes")).context("missing codes")?;
    ensure!(codes_t.dims.len() == 2, "{name}: codes must be 2-d");
    let (rows, cols) = (codes_t.dims[0], codes_t.dims[1]);
    let n = rows * cols;
    let codes = match &codes_t.data {
        TensorData::U1 { packed, .. } => {
            ensure!(code_bits == 1, "{name}: u1 codes with {code_bits}-bit layout");
            PackedCodes::U1(packed.clone())
        }
        TensorData::U2 { packed, .. } => {
            ensure!(code_bits <= 2, "{name}: u2 codes with {code_bits}-bit layout");
            PackedCodes::U2(packed.clone())
        }
        // u4 also carries legacy sub-nibble payloads (v2 artifacts stored
        // 1-bit codes at nibble granularity)
        TensorData::U4 { packed, .. } => {
            ensure!(code_bits <= 4, "{name}: u4 codes with {code_bits}-bit layout");
            PackedCodes::U4(packed.clone())
        }
        TensorData::I8(v) => {
            if scheme == CodeScheme::SignLevel {
                let max = v.iter().map(|c| c.unsigned_abs() as usize).max().unwrap_or(0);
                ensure!(max <= scales_per_block, "{name}: code level {max} out of range");
            }
            PackedCodes::I8(v.clone())
        }
        _ => anyhow::bail!("{name}: codes must be u1, u2, u4 or i8"),
    };
    if !matches!(codes, PackedCodes::I8(_)) && scheme == CodeScheme::SignLevel {
        // packed symbols can address up to 2^{w-1} levels — the scale
        // table must cover them or decode would index out of bounds
        ensure!(
            scales_per_block >= 1usize << (code_bits - 1),
            "{name}: scale table too small for {code_bits}-bit sign-level codes"
        );
    }

    let scales_t = map.get(&format!("{name}.scales")).context("missing scales")?;
    let n_blocks = n.div_ceil(block);
    let scales = match &scales_t.data {
        TensorData::Bf16(v) => PackedScales::Bf16(v.clone()),
        TensorData::F32(v) => PackedScales::F32(v.clone()),
        _ => anyhow::bail!("{name}: scales must be bf16 or f32"),
    };
    let scale_len = scales_t.data.len();
    ensure!(
        scale_len == n_blocks * scales_per_block,
        "{name}: scale table len {scale_len} != {n_blocks}x{scales_per_block}"
    );

    let zeros = match map.get(&format!("{name}.zeros")) {
        Some(t) => {
            let z = t.as_i32()?;
            let mut out = Vec::with_capacity(z.len());
            for &i in z {
                ensure!(i >= 0 && (i as usize) < n, "{name}: zero index {i} out of range");
                out.push(i as u32);
            }
            out
        }
        None => Vec::new(),
    };
    ensure!(zeros.len() == l[7] as usize, "{name}: zero count mismatch");

    Ok(PackedTensor {
        method: method.to_string(),
        rows,
        cols,
        code_bits,
        scheme,
        block,
        scales_per_block,
        per_tensor,
        bf16,
        codes,
        scales,
        zeros,
    })
}

/// Everything [`quantize`] takes beyond "which method, which config":
/// scheduler threads, packed-payload emission, and an optional per-layer
/// method assignment. One struct instead of the historical
/// `quantize_model` / `quantize_model_mixed` pair of positional tails.
#[derive(Clone, Debug, Default)]
pub struct QuantizeOptions {
    /// Worker threads for the model-global scheduler (`0` behaves as `1`:
    /// the serial reference path).
    pub threads: usize,
    /// Emit deployable packed payloads alongside the simulated dequant
    /// (ORed with `cfg.emit_packed`; never changes the dequant output).
    pub packed: bool,
    /// Heterogeneous per-layer assignment: layers named here use their
    /// assigned method, everything else the default passed to [`quantize`].
    pub overrides: BTreeMap<String, Method>,
}

impl QuantizeOptions {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_packed(mut self) -> Self {
        self.packed = true;
        self
    }

    pub fn with_override(mut self, name: impl Into<String>, method: Method) -> Self {
        self.overrides.insert(name.into(), method);
        self
    }

    pub fn with_overrides(mut self, overrides: BTreeMap<String, Method>) -> Self {
        self.overrides.extend(overrides);
        self
    }
}

/// Quantize every quantizable matrix of `spec` with `method` under `cfg`
/// via the model-global [`scheduler`]: all layers' block tiles and
/// whole-matrix jobs share one pool sized by `opts.threads`, and the only
/// barrier is end-of-model. Non-quantizable parameters (norms, embeddings)
/// pass through untouched — the paper's weight-only protocol.
///
/// Layers named in `opts.overrides` use their assigned method instead of
/// `method`; tiled layers (block-wise calibration-free methods) and
/// whole-matrix layers (GPTQ, per-tensor configs, `Method::Fp`
/// pass-through) mix freely on the one global pool, bit-identical to the
/// serial path for every assignment (asserted by tests). The returned
/// [`QuantizedModel::method`] records `method`.
///
/// `weights` is taken by value: quantized tensors are *moved* into their
/// layer solves and replaced in place, and pass-through tensors are never
/// copied.
pub fn quantize(
    spec: &ModelSpec,
    weights: TensorMap,
    calib: Option<&TensorMap>,
    method: Method,
    cfg: &QuantConfig,
    opts: &QuantizeOptions,
) -> Result<QuantizedModel> {
    let mut cfg = cfg.clone();
    cfg.emit_packed |= opts.packed;
    quantize_impl(spec, weights, calib, method, &opts.overrides, &cfg, opts.threads)
}

fn quantize_impl(
    spec: &ModelSpec,
    mut weights: TensorMap,
    calib: Option<&TensorMap>,
    default: Method,
    overrides: &BTreeMap<String, Method>,
    cfg: &QuantConfig,
    threads: usize,
) -> Result<QuantizedModel> {
    let t0 = Instant::now();
    let threads = threads.max(1);

    // every override must name a quantizable param — a typo'd layer name
    // silently falling through to the default method would ship an
    // artifact with the wrong per-layer precision and no diagnostic
    for key in overrides.keys() {
        ensure!(
            spec.quantizable().any(|p| &p.name == key),
            "override '{key}' does not name a quantizable parameter of '{}'",
            spec.name
        );
    }

    // collect the work list, moving each quantizable tensor out of the map;
    // FP-assigned layers are the identity and stay in place untouched
    let mut jobs: Vec<LayerJob> = Vec::new();
    let mut packing: Option<Method> = None;
    for p in spec.quantizable() {
        let method = overrides.get(&p.name).copied().unwrap_or(default);
        if method == Method::Fp {
            continue;
        }
        // fail BEFORE the (expensive) solve: export_packed can only emit a
        // single-method artifact, and WGM-DQ / GPTQ never carry payloads
        if cfg.emit_packed && !matches!(method, Method::Gptq | Method::WgmDq) {
            match packing {
                None => packing = Some(method),
                Some(prev) if prev != method => anyhow::bail!(
                    "emit_packed with mixed packable methods ({} vs {}): \
                     payloads cannot share one artifact",
                    prev.name(),
                    method.name()
                ),
                _ => {}
            }
        }
        let t = weights
            .remove(&p.name)
            .with_context(|| format!("weights missing {}", p.name))?;
        jobs.push(LayerJob { name: p.name.clone(), w: t.into_matrix()?, method });
    }

    let (results, pool_stats) = scheduler::run(jobs, calib, cfg, threads)?;

    let mut packed = BTreeMap::new();
    let mut layers = Vec::new();
    for (name, stat, data, packed_t) in results {
        weights.insert(name.clone(), Tensor::f32(vec![stat.rows, stat.cols], data));
        if let Some(p) = packed_t {
            packed.insert(name, p);
        }
        layers.push(stat);
    }
    layers.sort_by(|a, b| a.name.cmp(&b.name));

    Ok(QuantizedModel {
        method: default,
        weights,
        layers,
        wall_seconds: t0.elapsed().as_secs_f64(),
        pool_stats,
        packed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::manifest::{ModelSpec, ParamSpec};
    use crate::stats::Rng;
    use crate::tensor::Matrix;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![
                ParamSpec { name: "tok_emb".into(), shape: vec![10, 32], quant: false },
                ParamSpec { name: "layer0.wq".into(), shape: vec![32, 64], quant: true },
                ParamSpec { name: "layer0.wv".into(), shape: vec![32, 64], quant: true },
            ],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        }
    }

    fn tiny_weights(seed: u64) -> TensorMap {
        let mut rng = Rng::new(seed);
        let mut m = TensorMap::new();
        for (name, r, c) in [("tok_emb", 10, 32), ("layer0.wq", 32, 64), ("layer0.wv", 32, 64)] {
            let w = Matrix::randn(r, c, &mut rng);
            m.insert(name.into(), Tensor::f32(vec![r, c], w.data));
        }
        m
    }

    /// [`quantize`] with the historical positional-threads shape the tests
    /// below were written against.
    fn quantize_t(
        spec: &ModelSpec,
        weights: TensorMap,
        calib: Option<&TensorMap>,
        method: Method,
        cfg: &QuantConfig,
        threads: usize,
    ) -> Result<QuantizedModel> {
        quantize(spec, weights, calib, method, cfg, &QuantizeOptions::new().with_threads(threads))
    }

    /// [`quantize_t`] with a per-layer override map.
    fn quantize_mixed_t(
        spec: &ModelSpec,
        weights: TensorMap,
        calib: Option<&TensorMap>,
        default: Method,
        overrides: &BTreeMap<String, Method>,
        cfg: &QuantConfig,
        threads: usize,
    ) -> Result<QuantizedModel> {
        let opts =
            QuantizeOptions::new().with_threads(threads).with_overrides(overrides.clone());
        quantize(spec, weights, calib, default, cfg, &opts)
    }

    #[test]
    fn fp_is_identity() {
        let qm = quantize_t(
            &tiny_spec(),
            tiny_weights(1),
            None,
            Method::Fp,
            &QuantConfig::block_wise(4, 64).unwrap(),
            2,
        )
        .unwrap();
        assert_eq!(qm.weights, tiny_weights(1));
        assert!(qm.pool_stats.is_none());
        assert!(qm.packed.is_empty());
    }

    #[test]
    fn quantizes_only_quantizable() {
        let w = tiny_weights(2);
        let qm = quantize_t(
            &tiny_spec(),
            w.clone(),
            None,
            Method::Wgm,
            &QuantConfig::block_wise(4, 64).unwrap(),
            2,
        )
        .unwrap();
        assert_eq!(qm.weights.get("tok_emb"), w.get("tok_emb"), "embeddings untouched");
        assert_ne!(qm.weights.get("layer0.wq"), w.get("layer0.wq"));
        assert_eq!(qm.layers.len(), 2);
        assert!(qm.total_sse() > 0.0);
        assert!(qm.packed.is_empty(), "emission is opt-in");
    }

    #[test]
    fn method_grid_matches_paper_slashes() {
        let bw = Method::table1_grid(false);
        assert!(bw.contains(&Method::Gptq) && bw.contains(&Method::Bnb));
        assert!(!bw.contains(&Method::WgmLo));
        let pt = Method::table1_grid(true);
        assert!(pt.contains(&Method::WgmLo));
        assert!(!pt.contains(&Method::Gptq) && !pt.contains(&Method::Bnb));
    }

    #[test]
    fn gptq_without_calib_errors() {
        let r = quantize_t(
            &tiny_spec(),
            tiny_weights(3),
            None,
            Method::Gptq,
            &QuantConfig::block_wise(4, 64).unwrap(),
            1,
        );
        assert!(r.is_err());
    }

    #[test]
    fn gptq_with_calib_works() {
        let mut calib = TensorMap::new();
        for name in ["layer0.wq", "layer0.wv"] {
            // identity Hessians
            let mut h = vec![0.0f32; 64 * 64];
            for i in 0..64 {
                h[i * 64 + i] = 1.0;
            }
            calib.insert(name.into(), Tensor::f32(vec![64, 64], h));
        }
        let qm = quantize_t(
            &tiny_spec(),
            tiny_weights(4),
            Some(&calib),
            Method::Gptq,
            &QuantConfig::block_wise(4, 64).unwrap(),
            2,
        )
        .unwrap();
        assert_eq!(qm.layers.len(), 2);
        // GPTQ layers run as whole-matrix jobs on the global pool now:
        // one job per layer, all drained
        assert_eq!(qm.pool_stats, Some((2, 2)));
    }

    #[test]
    fn wgm_dq_has_lower_bits_higher_err() {
        let w = tiny_weights(5);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let a = quantize_t(&tiny_spec(), w.clone(), None, Method::Wgm, &cfg, 1).unwrap();
        let b = quantize_t(&tiny_spec(), w, None, Method::WgmDq, &cfg, 1).unwrap();
        assert!(b.mean_effective_bits() < a.mean_effective_bits());
        assert!(b.total_sse() >= a.total_sse() * 0.999);
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let w = tiny_weights(6);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let a = quantize_t(&tiny_spec(), w.clone(), None, Method::Wgm, &cfg, 1).unwrap();
        let b = quantize_t(&tiny_spec(), w, None, Method::Wgm, &cfg, 4).unwrap();
        assert_eq!(a.weights, b.weights);
    }

    /// Engine determinism across the whole method grid: the tiled pool path
    /// must be bit-identical to `threads=1` for every ported method under
    /// both granularities (the paper's Table-1 settings).
    #[test]
    fn method_grid_thread_determinism() {
        let w = tiny_weights(7);
        let spec = tiny_spec();
        let bw = QuantConfig::block_wise(4, 64).unwrap();
        let pt = QuantConfig::per_tensor(4).unwrap().with_window(16).unwrap();
        let grid: Vec<(Method, &QuantConfig)> = vec![
            (Method::Rtn, &bw),
            (Method::Bnb, &bw),
            (Method::Hqq, &bw),
            (Method::Wgm, &bw),
            (Method::Gg, &bw),
            (Method::WgmDq, &bw),
            (Method::Xnor, &bw),
            (Method::BlockedXnor, &bw),
            (Method::Rtn, &pt),
            (Method::Hqq, &pt),
            (Method::Wgm, &pt),
            (Method::WgmLo, &pt),
            (Method::Xnor, &pt),
            (Method::BlockedXnor, &pt),
        ];
        for (method, cfg) in grid {
            let a = quantize_t(&spec, w.clone(), None, method, cfg, 1).unwrap();
            let b = quantize_t(&spec, w.clone(), None, method, cfg, 4).unwrap();
            assert_eq!(
                a.weights,
                b.weights,
                "{} {:?} diverged across thread counts",
                method.name(),
                cfg.granularity
            );
        }
    }

    /// The point of the engine: a single-layer workload exercises more than
    /// one worker because the *blocks* fan out, not just the layers.
    #[test]
    fn single_layer_uses_block_parallelism() {
        let mut spec = tiny_spec();
        spec.params.retain(|p| !p.quant || p.name == "layer0.wq");
        let w = tiny_weights(8);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let qm = quantize_t(&spec, w, None, Method::Wgm, &cfg, 4).unwrap();
        assert_eq!(qm.layers.len(), 1);
        let (submitted, completed) = qm.pool_stats.expect("pool path must engage");
        assert!(submitted > 1, "expected block-tile fan-out, got {submitted} job(s)");
        assert_eq!(submitted, completed, "all tile jobs must drain");
    }

    /// Tentpole anchor: a heterogeneous method set — a calibrated
    /// whole-matrix GPTQ layer next to a tiled MSB layer — in ONE model on
    /// ONE global pool must be bit-identical to the serial path, and each
    /// layer must match its homogeneous-model counterpart exactly.
    #[test]
    fn global_scheduler_mixed_methods_bit_identity() {
        let spec = tiny_spec();
        let w = tiny_weights(20);
        let mut calib = TensorMap::new();
        let mut h = vec![0.0f32; 64 * 64];
        for i in 0..64 {
            h[i * 64 + i] = 1.0;
        }
        calib.insert("layer0.wq".into(), Tensor::f32(vec![64, 64], h));
        let mut overrides = BTreeMap::new();
        overrides.insert("layer0.wq".to_string(), Method::Gptq);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();

        let serial =
            quantize_mixed_t(&spec, w.clone(), Some(&calib), Method::Wgm, &overrides, &cfg, 1)
                .unwrap();
        assert!(serial.pool_stats.is_none(), "threads=1 is the serial reference");
        for threads in [2usize, 4] {
            let global = quantize_mixed_t(
                &spec,
                w.clone(),
                Some(&calib),
                Method::Wgm,
                &overrides,
                &cfg,
                threads,
            )
            .unwrap();
            assert_eq!(serial.weights, global.weights, "threads={threads}");
            let (submitted, completed) = global.pool_stats.expect("global pool engaged");
            assert_eq!(submitted, completed, "threads={threads}: all jobs drained");
        }

        // each layer == its homogeneous-model counterpart
        let gptq_only = quantize_t(&spec, w.clone(), Some(&calib), Method::Gptq, &cfg, 1);
        // (gptq needs a Hessian for BOTH layers in a homogeneous run)
        assert!(gptq_only.is_err());
        let wgm_only = quantize_t(&spec, w.clone(), None, Method::Wgm, &cfg, 1).unwrap();
        assert_eq!(serial.weights.get("layer0.wv"), wgm_only.weights.get("layer0.wv"));
        assert_ne!(serial.weights.get("layer0.wq"), wgm_only.weights.get("layer0.wq"));
    }

    /// Whole-tensor XNOR (a per-layer job) mixed with tiled MSB blocks:
    /// the exact `(submitted, completed)` accounting is 1 whole job + the
    /// deterministic tile count of the tiled layer.
    #[test]
    fn global_scheduler_pool_accounting() {
        let spec = tiny_spec();
        let w = tiny_weights(21);
        let mut overrides = BTreeMap::new();
        overrides.insert("layer0.wq".to_string(), Method::Xnor);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let qm = quantize_mixed_t(&spec, w.clone(), None, Method::Wgm, &overrides, &cfg, 4)
            .unwrap();
        // layer0.wv: 32x64 = 2048 elems / 64 = 32 blocks; tile_size(32, 4)
        // = 2 blocks/tile => 16 tiles; plus 1 whole-matrix xnor job
        assert_eq!(qm.pool_stats, Some((17, 17)));
        let serial = quantize_mixed_t(&spec, w, None, Method::Wgm, &overrides, &cfg, 1)
            .unwrap();
        assert_eq!(serial.weights, qm.weights);
    }

    /// An FP override passes that layer through untouched while the rest
    /// of the model still quantizes.
    #[test]
    fn mixed_fp_override_passes_through() {
        let spec = tiny_spec();
        let w = tiny_weights(22);
        let mut overrides = BTreeMap::new();
        overrides.insert("layer0.wv".to_string(), Method::Fp);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let qm = quantize_mixed_t(&spec, w.clone(), None, Method::Wgm, &overrides, &cfg, 2)
            .unwrap();
        assert_eq!(qm.weights.get("layer0.wv"), w.get("layer0.wv"));
        assert_ne!(qm.weights.get("layer0.wq"), w.get("layer0.wq"));
        assert_eq!(qm.layers.len(), 1);
    }

    /// Misassignments fail fast: a typo'd override key errors instead of
    /// silently quantizing with the default method, and a packed-emission
    /// run with two different packable methods is rejected BEFORE the
    /// solve instead of after it (export_packed can only emit one method).
    #[test]
    fn mixed_guards_reject_bad_assignments() {
        let spec = tiny_spec();
        let w = tiny_weights(24);
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let mut typo = BTreeMap::new();
        typo.insert("layer0.Wq".to_string(), Method::Rtn); // wrong case
        let err = quantize_mixed_t(&spec, w.clone(), None, Method::Wgm, &typo, &cfg, 1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("layer0.Wq"), "{err:#}");

        let mut mixed = BTreeMap::new();
        mixed.insert("layer0.wq".to_string(), Method::BlockedXnor);
        let packed_cfg = cfg.clone().with_packed();
        let err =
            quantize_mixed_t(&spec, w.clone(), None, Method::Wgm, &mixed, &packed_cfg, 1)
                .unwrap_err();
        assert!(format!("{err:#}").contains("mixed packable methods"), "{err:#}");
        // without emission the same assignment is fine
        assert!(quantize_mixed_t(&spec, w, None, Method::Wgm, &mixed, &cfg, 1).is_ok());
    }

    /// Packed export → decode round-trips bit-identically through the
    /// TensorMap payload layout, pass-through tensors included, and the
    /// payload itself is thread-count deterministic.
    #[test]
    fn packed_export_decode_roundtrip() {
        let spec = tiny_spec();
        let mut w = tiny_weights(9);
        // sprinkle exact zeros to exercise the exception records
        if let TensorData::F32(v) = &mut w.get_mut("layer0.wq").unwrap().data {
            v[3] = 0.0;
            v[100] = 0.0;
        }
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        for method in [Method::Wgm, Method::Rtn, Method::Bnb, Method::Hqq] {
            let qm = quantize_t(&spec, w.clone(), None, method, &cfg, 2).unwrap();
            assert_eq!(qm.packed.len(), 2, "{method:?}");
            let map = qm.export_packed().unwrap();
            assert!(is_packed_map(&map));
            assert!(map.contains_key("layer0.wq.codes"));
            assert!(map.contains_key("layer0.wq.layout"));
            assert_eq!(map.get("tok_emb"), w.get("tok_emb"), "pass-through survives");
            for threads in [1usize, 4] {
                let decoded = decode_packed_model(&map, threads).unwrap();
                assert_eq!(decoded, qm.weights, "{method:?} threads={threads}");
            }
            let qm4 = quantize_t(&spec, w.clone(), None, method, &cfg, 4).unwrap();
            assert_eq!(qm.packed, qm4.packed, "{method:?} payload thread determinism");
        }
    }

    /// Sub-nibble payloads survive the full export → TensorMap → decode
    /// path: blocked-XNOR emits u1 codes, 2-bit MSB u2 codes, and both
    /// decode back bit-identically to the simulated dequant.
    #[test]
    fn packed_sub_nibble_export_roundtrip() {
        let spec = tiny_spec();
        let w = tiny_weights(23);
        for (method, bits) in [(Method::BlockedXnor, 1u32), (Method::Wgm, 2)] {
            let cfg = QuantConfig::block_wise(bits, 64).unwrap().with_packed();
            let qm = quantize_t(&spec, w.clone(), None, method, &cfg, 2).unwrap();
            let map = qm.export_packed().unwrap();
            let codes = map.get("layer0.wq.codes").unwrap();
            match bits {
                1 => assert!(codes.as_u1().is_ok(), "{method:?}"),
                _ => assert!(codes.as_u2().is_ok(), "{method:?}"),
            }
            for threads in [1usize, 3] {
                let decoded = decode_packed_model(&map, threads).unwrap();
                assert_eq!(decoded, qm.weights, "{method:?} threads={threads}");
            }
        }
    }

    #[test]
    fn packed_accounting_at_paper_point() {
        // MSB 4-bit t=64 over the tiny model: 6.00 bits/weight measured
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let qm = quantize_t(&tiny_spec(), tiny_weights(10), None, Method::Wgm, &cfg, 1)
            .unwrap();
        crate::testing::assert_close(qm.packed_effective_bits(), 6.0, 1e-12, 0.0);
    }

    #[test]
    fn export_without_emission_errors() {
        let cfg = QuantConfig::block_wise(4, 64).unwrap();
        let qm = quantize_t(&tiny_spec(), tiny_weights(11), None, Method::Wgm, &cfg, 1)
            .unwrap();
        assert!(qm.export_packed().is_err());
    }

    #[test]
    fn wgm_dq_drops_packed_payload() {
        // the double-quantized scale table invalidates the base payload
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let qm = quantize_t(&tiny_spec(), tiny_weights(12), None, Method::WgmDq, &cfg, 1)
            .unwrap();
        assert!(qm.packed.is_empty());
    }

    #[test]
    fn decode_rejects_corrupt_layout() {
        let cfg = QuantConfig::block_wise(4, 64).unwrap().with_packed();
        let qm = quantize_t(&tiny_spec(), tiny_weights(13), None, Method::Wgm, &cfg, 1)
            .unwrap();
        let map = qm.export_packed().unwrap();
        // not a packed map at all
        assert!(decode_packed_model(&TensorMap::new(), 1).is_err());
        // out-of-range zero index
        let mut bad = map.clone();
        bad.insert("layer0.wq.zeros".into(), Tensor::i32(vec![1], vec![1 << 30]));
        assert!(decode_packed_model(&bad, 1).is_err());
        // truncated layout record
        let mut bad = map.clone();
        bad.insert("layer0.wq.layout".into(), Tensor::i32(vec![2], vec![2, 4]));
        assert!(decode_packed_model(&bad, 1).is_err());
        // unknown method
        let mut bad = map;
        bad.insert(
            "__packed__.method".into(),
            Tensor::i8(vec![4], b"nope".iter().map(|&b| b as i8).collect()),
        );
        assert!(decode_packed_model(&bad, 1).is_err());
    }

    // Method::parse round-tripping is covered in quant::registry::tests,
    // where the dispatch table now lives.
}
