//! The quantization pipeline coordinator: walks a model manifest, fans the
//! per-layer solver work out over the worker substrate, and assembles a
//! fully-quantized weight set plus per-layer metrics. This is the L3
//! "offline PTQ" path (the paper's CPU-based quantization step); the online
//! path is `runtime`/`server`.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::io::manifest::ModelSpec;
use crate::io::msbt::{Tensor, TensorMap};
use crate::quant::dq::{double_quantize, DqConfig};
use crate::quant::{
    gptq::GptqQuantizer, hqq::HqqQuantizer, msb::MsbQuantizer, nf4::Nf4Quantizer,
    rtn::RtnQuantizer, xnor::XnorQuantizer, QuantConfig, Quantizer,
};
use crate::tensor::Matrix;

/// Every method that can appear in a Table-1-style grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full precision (identity) — the FP rows.
    Fp,
    Rtn,
    /// BnB-style NF4 (4-bit block-wise only).
    Bnb,
    Hqq,
    /// Calibration-based; consumes the build-time Gram matrices.
    Gptq,
    /// MSB / Algorithm 3 (the paper's production solver).
    Wgm,
    /// MSB / Algorithm 4 (per-tensor refinement).
    WgmLo,
    /// MSB / Algorithm 2.
    Gg,
    /// MSB / WGM + double quantization of scales (Appendix G).
    WgmDq,
    Xnor,
    BlockedXnor,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp => "fp",
            Method::Rtn => "rtn",
            Method::Bnb => "bnb",
            Method::Hqq => "hqq",
            Method::Gptq => "gptq",
            Method::Wgm => "wgm",
            Method::WgmLo => "wgm-lo",
            Method::Gg => "gg",
            Method::WgmDq => "wgm-dq",
            Method::Xnor => "xnor",
            Method::BlockedXnor => "blocked-xnor",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "fp" => Method::Fp,
            "rtn" => Method::Rtn,
            "bnb" | "nf4" => Method::Bnb,
            "hqq" => Method::Hqq,
            "gptq" => Method::Gptq,
            "wgm" | "msb" => Method::Wgm,
            "wgm-lo" | "wgmlo" => Method::WgmLo,
            "gg" => Method::Gg,
            "wgm-dq" => Method::WgmDq,
            "xnor" => Method::Xnor,
            "blocked-xnor" => Method::BlockedXnor,
            other => anyhow::bail!("unknown method '{other}'"),
        })
    }

    /// The paper's Table 1 grid for a granularity. "/" cells (BnB and GPTQ
    /// per-tensor, WGM-LO block-wise) are omitted exactly as in the paper.
    pub fn table1_grid(per_tensor: bool) -> Vec<Method> {
        if per_tensor {
            vec![Method::Rtn, Method::Hqq, Method::Wgm, Method::WgmLo]
        } else {
            vec![Method::Gptq, Method::Rtn, Method::Bnb, Method::Hqq, Method::Wgm]
        }
    }

    pub fn needs_calibration(&self) -> bool {
        matches!(self, Method::Gptq)
    }
}

/// Per-layer quantization record.
#[derive(Clone, Debug)]
pub struct LayerStat {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub sse: f64,
    pub effective_bits: f64,
    pub seconds: f64,
}

/// A fully-quantized model: dequantized weights keyed by ABI name (ready
/// for [`crate::runtime::ModelRunner::update_weights`]) plus metrics.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub method: Method,
    pub weights: TensorMap,
    pub layers: Vec<LayerStat>,
    pub wall_seconds: f64,
}

impl QuantizedModel {
    pub fn total_sse(&self) -> f64 {
        self.layers.iter().map(|l| l.sse).sum()
    }

    pub fn mean_effective_bits(&self) -> f64 {
        let (num, den) = self.layers.iter().fold((0.0, 0usize), |(a, b), l| {
            (a + l.effective_bits * (l.rows * l.cols) as f64, b + l.rows * l.cols)
        });
        num / den.max(1) as f64
    }
}

/// Build the quantizer for (method, layer). GPTQ binds the layer Hessian.
fn build_quantizer(
    method: Method,
    layer: &str,
    in_dim: usize,
    calib: Option<&TensorMap>,
) -> Result<Box<dyn Quantizer>> {
    Ok(match method {
        Method::Fp => unreachable!("fp short-circuits before here"),
        Method::Rtn => Box::new(RtnQuantizer::symmetric()),
        Method::Bnb => Box::new(Nf4Quantizer::nf4()),
        Method::Hqq => Box::new(HqqQuantizer::default()),
        Method::Gptq => {
            let calib = calib.context("gptq requires calibration tensors")?;
            let h = calib
                .get(layer)
                .with_context(|| format!("calib missing Hessian for {layer}"))?;
            anyhow::ensure!(h.dims == vec![in_dim, in_dim], "{layer}: bad Hessian dims");
            Box::new(GptqQuantizer::new().with_hessian(h.as_f32()?, in_dim))
        }
        Method::Wgm | Method::WgmDq => Box::new(MsbQuantizer::wgm()),
        Method::WgmLo => Box::new(MsbQuantizer::wgm_lo()),
        Method::Gg => Box::new(MsbQuantizer::gg()),
        Method::Xnor => Box::new(XnorQuantizer::whole()),
        Method::BlockedXnor => Box::new(XnorQuantizer::blocked()),
    })
}

/// Quantize every quantizable matrix of `spec` with `method` under `cfg`,
/// fanning layers out over `threads` workers. Non-quantizable parameters
/// (norms, embeddings) pass through untouched — the paper's weight-only
/// protocol.
pub fn quantize_model(
    spec: &ModelSpec,
    weights: &TensorMap,
    calib: Option<&TensorMap>,
    method: Method,
    cfg: &QuantConfig,
    threads: usize,
) -> Result<QuantizedModel> {
    let t0 = Instant::now();
    if method == Method::Fp {
        return Ok(QuantizedModel {
            method,
            weights: weights.clone(),
            layers: Vec::new(),
            wall_seconds: t0.elapsed().as_secs_f64(),
        });
    }

    // collect the work list
    let mut jobs: Vec<(String, Matrix)> = Vec::new();
    for p in spec.quantizable() {
        let t = weights
            .get(&p.name)
            .with_context(|| format!("weights missing {}", p.name))?;
        jobs.push((p.name.clone(), t.to_matrix()?));
    }

    // fan out: one solver instance per layer (GPTQ binds its Hessian inside)
    let results: Vec<Result<(String, LayerStat, Vec<f32>)>> =
        crate::pool::scoped_map(jobs, threads, |(name, w)| {
            let lt0 = Instant::now();
            let q = build_quantizer(method, &name, w.cols, calib)?;
            let mut qt = q.quantize(&w, cfg);
            if method == Method::WgmDq {
                qt = double_quantize(&qt, cfg, &DqConfig::default());
            }
            let stat = LayerStat {
                name: name.clone(),
                rows: w.rows,
                cols: w.cols,
                sse: qt.mse(&w),
                effective_bits: qt.effective_bits,
                seconds: lt0.elapsed().as_secs_f64(),
            };
            Ok((name, stat, qt.dequant.data))
        });

    let mut out = weights.clone();
    let mut layers = Vec::new();
    for r in results {
        let (name, stat, data) = r?;
        let dims = out.get(&name).unwrap().dims.clone();
        out.insert(name, Tensor::f32(dims, data));
        layers.push(stat);
    }
    layers.sort_by(|a, b| a.name.cmp(&b.name));

    Ok(QuantizedModel { method, weights: out, layers, wall_seconds: t0.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::manifest::{ModelSpec, ParamSpec};
    use crate::stats::Rng;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![
                ParamSpec { name: "tok_emb".into(), shape: vec![10, 32], quant: false },
                ParamSpec { name: "layer0.wq".into(), shape: vec![32, 64], quant: true },
                ParamSpec { name: "layer0.wv".into(), shape: vec![32, 64], quant: true },
            ],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        }
    }

    fn tiny_weights(seed: u64) -> TensorMap {
        let mut rng = Rng::new(seed);
        let mut m = TensorMap::new();
        for (name, r, c) in [("tok_emb", 10, 32), ("layer0.wq", 32, 64), ("layer0.wv", 32, 64)] {
            let w = Matrix::randn(r, c, &mut rng);
            m.insert(name.into(), Tensor::f32(vec![r, c], w.data));
        }
        m
    }

    #[test]
    fn fp_is_identity() {
        let qm = quantize_model(
            &tiny_spec(),
            &tiny_weights(1),
            None,
            Method::Fp,
            &QuantConfig::block_wise(4, 64),
            2,
        )
        .unwrap();
        assert_eq!(qm.weights, tiny_weights(1));
    }

    #[test]
    fn quantizes_only_quantizable() {
        let w = tiny_weights(2);
        let qm = quantize_model(
            &tiny_spec(),
            &w,
            None,
            Method::Wgm,
            &QuantConfig::block_wise(4, 64),
            2,
        )
        .unwrap();
        assert_eq!(qm.weights.get("tok_emb"), w.get("tok_emb"), "embeddings untouched");
        assert_ne!(qm.weights.get("layer0.wq"), w.get("layer0.wq"));
        assert_eq!(qm.layers.len(), 2);
        assert!(qm.total_sse() > 0.0);
    }

    #[test]
    fn method_grid_matches_paper_slashes() {
        let bw = Method::table1_grid(false);
        assert!(bw.contains(&Method::Gptq) && bw.contains(&Method::Bnb));
        assert!(!bw.contains(&Method::WgmLo));
        let pt = Method::table1_grid(true);
        assert!(pt.contains(&Method::WgmLo));
        assert!(!pt.contains(&Method::Gptq) && !pt.contains(&Method::Bnb));
    }

    #[test]
    fn gptq_without_calib_errors() {
        let r = quantize_model(
            &tiny_spec(),
            &tiny_weights(3),
            None,
            Method::Gptq,
            &QuantConfig::block_wise(4, 64),
            1,
        );
        assert!(r.is_err());
    }

    #[test]
    fn gptq_with_calib_works() {
        let mut calib = TensorMap::new();
        for name in ["layer0.wq", "layer0.wv"] {
            // identity Hessians
            let mut h = vec![0.0f32; 64 * 64];
            for i in 0..64 {
                h[i * 64 + i] = 1.0;
            }
            calib.insert(name.into(), Tensor::f32(vec![64, 64], h));
        }
        let qm = quantize_model(
            &tiny_spec(),
            &tiny_weights(4),
            Some(&calib),
            Method::Gptq,
            &QuantConfig::block_wise(4, 64),
            2,
        )
        .unwrap();
        assert_eq!(qm.layers.len(), 2);
    }

    #[test]
    fn wgm_dq_has_lower_bits_higher_err() {
        let w = tiny_weights(5);
        let cfg = QuantConfig::block_wise(4, 64);
        let a = quantize_model(&tiny_spec(), &w, None, Method::Wgm, &cfg, 1).unwrap();
        let b = quantize_model(&tiny_spec(), &w, None, Method::WgmDq, &cfg, 1).unwrap();
        assert!(b.mean_effective_bits() < a.mean_effective_bits());
        assert!(b.total_sse() >= a.total_sse() * 0.999);
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let w = tiny_weights(6);
        let cfg = QuantConfig::block_wise(4, 64);
        let a = quantize_model(&tiny_spec(), &w, None, Method::Wgm, &cfg, 1).unwrap();
        let b = quantize_model(&tiny_spec(), &w, None, Method::Wgm, &cfg, 4).unwrap();
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::Fp, Method::Rtn, Method::Bnb, Method::Hqq, Method::Gptq,
            Method::Wgm, Method::WgmLo, Method::Gg, Method::WgmDq, Method::Xnor,
            Method::BlockedXnor,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }
}
