//! The quantization pipeline coordinator: walks a model manifest and
//! assembles a fully-quantized weight set plus per-layer metrics. This is
//! the L3 "offline PTQ" path (the paper's CPU-based quantization step); the
//! online path is `runtime`/`server`.
//!
//! Parallelism: block-partitioned methods fan the *blocks within each
//! layer* out over a shared [`ThreadPool`] (`quant::engine`), so a single
//! large FFN matrix no longer serializes a solve — the dominant wall-time
//! term for Table-3-style runs. Whole-matrix methods (GPTQ's
//! column-sequential error propagation) keep the per-layer fan-out instead.
//! Method dispatch lives in [`crate::quant::registry`].

use std::time::Instant;

use anyhow::{Context, Result};

use crate::io::manifest::ModelSpec;
use crate::io::msbt::{Tensor, TensorMap};
use crate::pool::ThreadPool;
use crate::quant::dq::{double_quantize, DqConfig};
use crate::quant::{registry, Granularity, QuantConfig, Quantizer};
use crate::tensor::Matrix;

pub use crate::quant::registry::Method;

/// Per-layer quantization record.
#[derive(Clone, Debug)]
pub struct LayerStat {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub sse: f64,
    pub effective_bits: f64,
    pub seconds: f64,
}

/// A fully-quantized model: dequantized weights keyed by ABI name (ready
/// for [`crate::runtime::ModelRunner::update_weights`]) plus metrics.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub method: Method,
    pub weights: TensorMap,
    pub layers: Vec<LayerStat>,
    pub wall_seconds: f64,
    /// `(submitted, completed)` block-tile jobs on the intra-layer pool;
    /// `None` when the run used the per-layer path (FP, GPTQ, per-tensor
    /// configs, whole-tensor XNOR, threads=1).
    pub pool_stats: Option<(usize, usize)>,
}

impl QuantizedModel {
    pub fn total_sse(&self) -> f64 {
        self.layers.iter().map(|l| l.sse).sum()
    }

    pub fn mean_effective_bits(&self) -> f64 {
        let (num, den) = self.layers.iter().fold((0.0, 0usize), |(a, b), l| {
            (a + l.effective_bits * (l.rows * l.cols) as f64, b + l.rows * l.cols)
        });
        num / den.max(1) as f64
    }
}

/// Pull the layer Hessian out of the calibration tensors (GPTQ only).
fn layer_hessian<'a>(
    calib: Option<&'a TensorMap>,
    layer: &str,
    in_dim: usize,
) -> Result<(&'a [f32], usize)> {
    let calib = calib.context("gptq requires calibration tensors")?;
    let h = calib
        .get(layer)
        .with_context(|| format!("calib missing Hessian for {layer}"))?;
    anyhow::ensure!(h.dims == vec![in_dim, in_dim], "{layer}: bad Hessian dims");
    Ok((h.as_f32()?, in_dim))
}

type LayerResult = (String, LayerStat, Vec<f32>);

/// Quantize one layer (already-built quantizer borrowed or fresh) and
/// record its stats. `pool` enables block-level parallelism.
fn quantize_layer(
    method: Method,
    name: String,
    w: &Matrix,
    cfg: &QuantConfig,
    calib: Option<&TensorMap>,
    pool: Option<&ThreadPool>,
) -> Result<LayerResult> {
    let lt0 = Instant::now();
    let hessian;
    let h_ref = if method.needs_calibration() {
        hessian = layer_hessian(calib, &name, w.cols)?;
        Some(hessian)
    } else {
        None
    };
    let q = registry::build_quantizer(method, h_ref)?;
    let mut qt = match pool {
        Some(pool) => q.quantize_with_pool(w, cfg, pool),
        None => q.quantize(w, cfg),
    };
    if method == Method::WgmDq {
        qt = double_quantize(&qt, cfg, &DqConfig::default());
    }
    let stat = LayerStat {
        name: name.clone(),
        rows: w.rows,
        cols: w.cols,
        sse: qt.mse(w),
        effective_bits: qt.effective_bits,
        seconds: lt0.elapsed().as_secs_f64(),
    };
    Ok((name, stat, qt.dequant.data))
}

/// Quantize every quantizable matrix of `spec` with `method` under `cfg`
/// using `threads` workers. Block-wise configs parallelize *within* each
/// layer (tiles of blocks on a shared pool); GPTQ and per-tensor configs
/// fan out across layers instead. Non-quantizable parameters (norms,
/// embeddings) pass through untouched — the paper's weight-only protocol.
pub fn quantize_model(
    spec: &ModelSpec,
    weights: &TensorMap,
    calib: Option<&TensorMap>,
    method: Method,
    cfg: &QuantConfig,
    threads: usize,
) -> Result<QuantizedModel> {
    let t0 = Instant::now();
    let threads = threads.max(1);
    if method == Method::Fp {
        return Ok(QuantizedModel {
            method,
            weights: weights.clone(),
            layers: Vec::new(),
            wall_seconds: t0.elapsed().as_secs_f64(),
            pool_stats: None,
        });
    }

    // collect the work list
    let mut jobs: Vec<(String, Matrix)> = Vec::new();
    for p in spec.quantizable() {
        let t = weights
            .get(&p.name)
            .with_context(|| format!("weights missing {}", p.name))?;
        jobs.push((p.name.clone(), t.to_matrix()?));
    }

    // Per-layer fan-out when block tiling cannot help: GPTQ is whole-matrix
    // (column-sequential), per-tensor configs and whole-tensor XNOR are a
    // single block instance per layer, and one worker gains nothing from
    // tiling.
    let per_layer = method.needs_calibration()
        || threads == 1
        || matches!(cfg.granularity, Granularity::PerTensor)
        || method == Method::Xnor;

    let mut pool_stats = None;
    let results: Vec<LayerResult> = if per_layer {
        let raw: Vec<Result<LayerResult>> = crate::pool::scoped_map(jobs, threads, |(name, w)| {
            quantize_layer(method, name, &w, cfg, calib, None)
        });
        raw.into_iter().collect::<Result<Vec<_>>>()?
    } else {
        // intra-layer block parallelism on a shared pool: layers stream
        // through sequentially, each saturating every worker
        let mut pool = ThreadPool::new(threads, threads * 4);
        let mut out = Vec::with_capacity(jobs.len());
        for (name, w) in jobs {
            out.push(quantize_layer(method, name, &w, cfg, calib, Some(&pool))?);
        }
        pool.shutdown();
        pool_stats = Some(pool.stats());
        out
    };

    let mut out = weights.clone();
    let mut layers = Vec::new();
    for (name, stat, data) in results {
        let dims = out.get(&name).unwrap().dims.clone();
        out.insert(name, Tensor::f32(dims, data));
        layers.push(stat);
    }
    layers.sort_by(|a, b| a.name.cmp(&b.name));

    Ok(QuantizedModel {
        method,
        weights: out,
        layers,
        wall_seconds: t0.elapsed().as_secs_f64(),
        pool_stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::manifest::{ModelSpec, ParamSpec};
    use crate::stats::Rng;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            d: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            seq: 16,
            params: vec![
                ParamSpec { name: "tok_emb".into(), shape: vec![10, 32], quant: false },
                ParamSpec { name: "layer0.wq".into(), shape: vec![32, 64], quant: true },
                ParamSpec { name: "layer0.wv".into(), shape: vec![32, 64], quant: true },
            ],
            weights_file: String::new(),
            calib_file: String::new(),
            fwd_hlo: String::new(),
        }
    }

    fn tiny_weights(seed: u64) -> TensorMap {
        let mut rng = Rng::new(seed);
        let mut m = TensorMap::new();
        for (name, r, c) in [("tok_emb", 10, 32), ("layer0.wq", 32, 64), ("layer0.wv", 32, 64)] {
            let w = Matrix::randn(r, c, &mut rng);
            m.insert(name.into(), Tensor::f32(vec![r, c], w.data));
        }
        m
    }

    #[test]
    fn fp_is_identity() {
        let qm = quantize_model(
            &tiny_spec(),
            &tiny_weights(1),
            None,
            Method::Fp,
            &QuantConfig::block_wise(4, 64),
            2,
        )
        .unwrap();
        assert_eq!(qm.weights, tiny_weights(1));
        assert!(qm.pool_stats.is_none());
    }

    #[test]
    fn quantizes_only_quantizable() {
        let w = tiny_weights(2);
        let qm = quantize_model(
            &tiny_spec(),
            &w,
            None,
            Method::Wgm,
            &QuantConfig::block_wise(4, 64),
            2,
        )
        .unwrap();
        assert_eq!(qm.weights.get("tok_emb"), w.get("tok_emb"), "embeddings untouched");
        assert_ne!(qm.weights.get("layer0.wq"), w.get("layer0.wq"));
        assert_eq!(qm.layers.len(), 2);
        assert!(qm.total_sse() > 0.0);
    }

    #[test]
    fn method_grid_matches_paper_slashes() {
        let bw = Method::table1_grid(false);
        assert!(bw.contains(&Method::Gptq) && bw.contains(&Method::Bnb));
        assert!(!bw.contains(&Method::WgmLo));
        let pt = Method::table1_grid(true);
        assert!(pt.contains(&Method::WgmLo));
        assert!(!pt.contains(&Method::Gptq) && !pt.contains(&Method::Bnb));
    }

    #[test]
    fn gptq_without_calib_errors() {
        let r = quantize_model(
            &tiny_spec(),
            &tiny_weights(3),
            None,
            Method::Gptq,
            &QuantConfig::block_wise(4, 64),
            1,
        );
        assert!(r.is_err());
    }

    #[test]
    fn gptq_with_calib_works() {
        let mut calib = TensorMap::new();
        for name in ["layer0.wq", "layer0.wv"] {
            // identity Hessians
            let mut h = vec![0.0f32; 64 * 64];
            for i in 0..64 {
                h[i * 64 + i] = 1.0;
            }
            calib.insert(name.into(), Tensor::f32(vec![64, 64], h));
        }
        let qm = quantize_model(
            &tiny_spec(),
            &tiny_weights(4),
            Some(&calib),
            Method::Gptq,
            &QuantConfig::block_wise(4, 64),
            2,
        )
        .unwrap();
        assert_eq!(qm.layers.len(), 2);
        assert!(qm.pool_stats.is_none(), "gptq keeps the per-layer path");
    }

    #[test]
    fn wgm_dq_has_lower_bits_higher_err() {
        let w = tiny_weights(5);
        let cfg = QuantConfig::block_wise(4, 64);
        let a = quantize_model(&tiny_spec(), &w, None, Method::Wgm, &cfg, 1).unwrap();
        let b = quantize_model(&tiny_spec(), &w, None, Method::WgmDq, &cfg, 1).unwrap();
        assert!(b.mean_effective_bits() < a.mean_effective_bits());
        assert!(b.total_sse() >= a.total_sse() * 0.999);
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let w = tiny_weights(6);
        let cfg = QuantConfig::block_wise(4, 64);
        let a = quantize_model(&tiny_spec(), &w, None, Method::Wgm, &cfg, 1).unwrap();
        let b = quantize_model(&tiny_spec(), &w, None, Method::Wgm, &cfg, 4).unwrap();
        assert_eq!(a.weights, b.weights);
    }

    /// Engine determinism across the whole method grid: the tiled pool path
    /// must be bit-identical to `threads=1` for every ported method under
    /// both granularities (the paper's Table-1 settings).
    #[test]
    fn method_grid_thread_determinism() {
        let w = tiny_weights(7);
        let spec = tiny_spec();
        let bw = QuantConfig::block_wise(4, 64);
        let pt = QuantConfig::per_tensor(4).with_window(16);
        let grid: Vec<(Method, &QuantConfig)> = vec![
            (Method::Rtn, &bw),
            (Method::Bnb, &bw),
            (Method::Hqq, &bw),
            (Method::Wgm, &bw),
            (Method::Gg, &bw),
            (Method::WgmDq, &bw),
            (Method::Xnor, &bw),
            (Method::BlockedXnor, &bw),
            (Method::Rtn, &pt),
            (Method::Hqq, &pt),
            (Method::Wgm, &pt),
            (Method::WgmLo, &pt),
            (Method::Xnor, &pt),
            (Method::BlockedXnor, &pt),
        ];
        for (method, cfg) in grid {
            let a = quantize_model(&spec, &w, None, method, cfg, 1).unwrap();
            let b = quantize_model(&spec, &w, None, method, cfg, 4).unwrap();
            assert_eq!(
                a.weights,
                b.weights,
                "{} {:?} diverged across thread counts",
                method.name(),
                cfg.granularity
            );
        }
    }

    /// The point of the engine: a single-layer workload exercises more than
    /// one worker because the *blocks* fan out, not just the layers.
    #[test]
    fn single_layer_uses_block_parallelism() {
        let mut spec = tiny_spec();
        spec.params.retain(|p| !p.quant || p.name == "layer0.wq");
        let w = tiny_weights(8);
        let cfg = QuantConfig::block_wise(4, 64);
        let qm = quantize_model(&spec, &w, None, Method::Wgm, &cfg, 4).unwrap();
        assert_eq!(qm.layers.len(), 1);
        let (submitted, completed) = qm.pool_stats.expect("pool path must engage");
        assert!(submitted > 1, "expected block-tile fan-out, got {submitted} job(s)");
        assert_eq!(submitted, completed, "all tile jobs must drain");
    }

    // Method::parse round-tripping is covered in quant::registry::tests,
    // where the dispatch table now lives.
}
