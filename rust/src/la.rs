//! Small dense linear algebra substrate (no external LA crates offline):
//! Cholesky factorization / inversion over row-major `Vec<f64>` square
//! matrices. Sized for GPTQ's Hessian work (in-dim ≤ 1024 here).

use anyhow::{bail, Result};

/// Row-major square matrix of f64.
#[derive(Clone, Debug)]
pub struct SquareMat {
    pub n: usize,
    pub a: Vec<f64>,
}

impl SquareMat {
    pub fn zeros(n: usize) -> Self {
        SquareMat { n, a: vec![0.0; n * n] }
    }

    pub fn from_vec(n: usize, a: Vec<f64>) -> Self {
        assert_eq!(a.len(), n * n);
        SquareMat { n, a }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.a[r * self.n + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.a[r * self.n + c] = v;
    }

    /// Add `eps` to the diagonal (Hessian damping).
    pub fn add_diag(&mut self, eps: f64) {
        for i in 0..self.n {
            self.a[i * self.n + i] += eps;
        }
    }

    pub fn mean_diag(&self) -> f64 {
        (0..self.n).map(|i| self.at(i, i)).sum::<f64>() / self.n as f64
    }

    /// Lower Cholesky: A = L·Lᵀ. Errors on non-PD input.
    pub fn cholesky(&self) -> Result<SquareMat> {
        let n = self.n;
        let mut l = SquareMat::zeros(n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self.at(i, j);
                for k in 0..j {
                    sum -= l.at(i, k) * l.at(j, k);
                }
                if i == j {
                    if sum <= 0.0 {
                        bail!("matrix not positive definite at pivot {i} (sum {sum})");
                    }
                    l.set(i, j, sum.sqrt());
                } else {
                    l.set(i, j, sum / l.at(j, j));
                }
            }
        }
        Ok(l)
    }

    /// Inverse via Cholesky (A must be PD): A⁻¹ = L⁻ᵀ·L⁻¹.
    pub fn inverse_pd(&self) -> Result<SquareMat> {
        let n = self.n;
        let l = self.cholesky()?;
        // forward-solve L·X = I column by column => X = L⁻¹
        let mut linv = SquareMat::zeros(n);
        for col in 0..n {
            for i in col..n {
                let mut sum = if i == col { 1.0 } else { 0.0 };
                for k in col..i {
                    sum -= l.at(i, k) * linv.at(k, col);
                }
                linv.set(i, col, sum / l.at(i, i));
            }
        }
        // A⁻¹ = Linvᵀ · Linv
        let mut inv = SquareMat::zeros(n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = 0.0;
                for k in i.max(j)..n {
                    sum += linv.at(k, i) * linv.at(k, j);
                }
                inv.set(i, j, sum);
                inv.set(j, i, sum);
            }
        }
        Ok(inv)
    }

    pub fn matmul(&self, other: &SquareMat) -> SquareMat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = SquareMat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.at(i, k);
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.a[i * n + j] += aik * other.at(k, j);
                }
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &SquareMat) -> f64 {
        self.a
            .iter()
            .zip(&other.a)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    fn random_pd(n: usize, seed: u64) -> SquareMat {
        // A = BᵀB + n·I is PD
        let mut rng = Rng::new(seed);
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = SquareMat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[k * n + i] * b[k * n + j];
                }
                a.set(i, j, s);
            }
        }
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_pd(24, 1);
        let l = a.cholesky().unwrap();
        let mut ll = SquareMat::zeros(a.n);
        for i in 0..a.n {
            for j in 0..a.n {
                let mut s = 0.0;
                for k in 0..a.n {
                    s += l.at(i, k) * l.at(j, k);
                }
                ll.set(i, j, s);
            }
        }
        assert!(ll.max_abs_diff(&a) < 1e-9, "{}", ll.max_abs_diff(&a));
    }

    #[test]
    fn inverse_pd_identity() {
        let a = random_pd(16, 2);
        let inv = a.inverse_pd().unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&SquareMat::identity(16)) < 1e-8);
    }

    #[test]
    fn rejects_non_pd() {
        let mut a = SquareMat::identity(4);
        a.set(0, 0, -1.0);
        assert!(a.cholesky().is_err());
    }

    #[test]
    fn damping_enables_cholesky() {
        let mut a = SquareMat::zeros(8);
        for i in 0..8 {
            for j in 0..8 {
                a.set(i, j, 1.0); // rank-1
            }
        }
        assert!(a.cholesky().is_err());
        a.add_diag(0.01);
        assert!(a.cholesky().is_ok());
    }

    #[test]
    fn identity_inverse_is_identity() {
        let i = SquareMat::identity(10);
        let inv = i.inverse_pd().unwrap();
        assert!(inv.max_abs_diff(&SquareMat::identity(10)) < 1e-12);
    }
}
