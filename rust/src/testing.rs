//! Seeded property-testing helper (the offline crate set has no proptest).
//! `check` runs a predicate over generated cases and, on failure, reports
//! the seed so the case can be replayed deterministically.

use crate::stats::Rng;

/// Run `f` over `cases` generated inputs. `gen` maps a fresh seeded RNG to
/// an input; failures panic with the replay seed.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut f: impl FnMut(&T) -> bool,
) {
    let base = match std::env::var("MSB_PROP_SEED") {
        Ok(s) => s.parse().unwrap_or(0xC0FFEE),
        Err(_) => 0xC0FFEE,
    };
    for i in 0..cases {
        let seed = base ^ ((i as u64) << 32) ^ 0x9E37;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !f(&input) {
            panic!(
                "property '{name}' failed on case {i} (seed {seed:#x}).\ninput: {input:?}"
            );
        }
    }
}

/// Assert two f64s agree to a relative-or-absolute tolerance.
#[track_caller]
pub fn assert_close(a: f64, b: f64, rel: f64, abs: f64) {
    let diff = (a - b).abs();
    let tol = abs + rel * a.abs().max(b.abs());
    assert!(diff <= tol, "{a} vs {b} (diff {diff} > tol {tol})");
}

/// Random magnitude vector with duplicates/zeros sprinkled in — the hostile
/// input shape for grouping solvers.
pub fn hostile_magnitudes(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        let roll = rng.uniform();
        if roll < 0.05 {
            v.push(0.0);
        } else if roll < 0.15 && !v.is_empty() {
            let idx = rng.below(v.len());
            v.push(v[idx]); // exact duplicate
        } else {
            v.push((rng.normal() as f32).abs() + 1e-6);
        }
    }
    for x in v.iter_mut() {
        if rng.uniform() < 0.5 {
            *x = -*x; // signs must not affect grouping of |w|
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("tautology", 10, |r| r.below(100), |_| true);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 5, |r| r.below(100), |&x| x > 1_000);
    }

    #[test]
    fn hostile_has_zeros_and_dups() {
        let mut rng = Rng::new(1);
        let v = hostile_magnitudes(&mut rng, 1000);
        assert!(v.iter().any(|&x| x == 0.0));
        assert_eq!(v.len(), 1000);
    }

    #[test]
    fn assert_close_tolerances() {
        assert_close(1.0, 1.0 + 1e-9, 1e-6, 0.0);
    }
}
